#!/usr/bin/env bash
# CI gate: tier-1 verify + formatting + a smoke-mode bench sweep that
# validates BENCH_aggregation.json end to end.
#
#   scripts/ci.sh              # everything
#   scripts/ci.sh --no-bench   # skip the bench smoke (e.g. constrained CI)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== smoke bench (budget 0.05s/case) =="
  cargo run --release --bin bench_aggregation -- --smoke --budget 0.05 --out BENCH_aggregation.json
  echo "== validate BENCH_aggregation.json =="
  cargo run --release --bin bench_aggregation -- --check BENCH_aggregation.json
fi

echo "ci.sh: all green"
