#!/usr/bin/env bash
# CI gate: tier-1 verify + formatting + a smoke-mode bench sweep that
# validates BENCH_aggregation.json end to end.
#
#   scripts/ci.sh              # everything
#   scripts/ci.sh --no-bench   # skip the bench smoke (e.g. constrained CI)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== smoke bench (budget 0.05s/case, --overlap both) =="
  cargo run --release --bin bench_aggregation -- --smoke --budget 0.05 --overlap both --out BENCH_aggregation.json
  echo "== validate BENCH_aggregation.json =="
  cargo run --release --bin bench_aggregation -- --check BENCH_aggregation.json

  echo "== perf history =="
  mkdir -p bench_history
  sha="$(git rev-parse --short HEAD 2>/dev/null || echo nogit)"
  cp BENCH_aggregation.json "bench_history/${sha}.json"
  echo "archived bench_history/${sha}.json"
  if [[ -f bench_history/baseline.json ]]; then
    # Fail if the aggregate-phase median regresses >1.3x vs the committed
    # baseline (both sides are smoke-grid runs).
    cargo run --release --bin bench_aggregation -- \
      --compare bench_history/baseline.json BENCH_aggregation.json --max-regress 1.3
  else
    cp BENCH_aggregation.json bench_history/baseline.json
    echo "seeded bench_history/baseline.json (commit it to arm the perf gate)"
  fi
fi

echo "ci.sh: all green"
