#!/usr/bin/env bash
# CI gate: tier-1 verify + formatting + clippy + a smoke-mode bench sweep
# that validates BENCH_aggregation.json end to end.
#
#   scripts/ci.sh              # everything
#   scripts/ci.sh --no-bench   # skip the bench smoke (e.g. constrained CI)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy --all-targets -- -D warnings =="
# Noisy lints are allow-listed once, in [workspace.lints.clippy]
# (root Cargo.toml) — never per-site.
cargo clippy --all-targets -- -D warnings

echo "== threaded stress (comm + pipeline interleavings) =="
# Loop the thread-heavy suites under varied harness parallelism so
# interleaving-dependent bugs (arrival-order ingest, rank-death
# propagation) surface before merge rather than as rare CI flakes.
# STRESS_ITERS scales the loop (default 3 passes per --test-threads
# setting); rationale in EXPERIMENTS.md §Threaded-execution.
STRESS_ITERS="${STRESS_ITERS:-3}"
for tt in 1 2 4; do
  for i in $(seq "$STRESS_ITERS"); do
    echo "-- stress pass ${i}/${STRESS_ITERS} (--test-threads ${tt}) --"
    cargo test -q --test parallel_equivalence threaded -- --test-threads "$tt"
    # Hierarchical two-level parity (grouped ingest, node-level bucket
    # completion order varies with scheduling).
    cargo test -q --test parallel_equivalence hier -- --test-threads "$tt"
    # Blocked/pool-sharded kernels vs the scalar oracle: bitwise equality
    # must hold under every harness parallelism, since pool shard
    # scheduling is the one thing these kernels are allowed to vary.
    cargo test -q --test interp_kernel_equiv -- --test-threads "$tt"
    # Compressed-collective equivalence: `--compress none` must stay
    # bitwise-identical to the uncompressed path and the encode/decode
    # round-trip must be deterministic under every harness parallelism
    # (the error-feedback residual is per-(rank, bucket) state touched
    # from pool threads).
    cargo test -q --test parallel_equivalence compress -- --test-threads "$tt"
    # Chaos leg: elastic fault drills (rank death + respawn, straggler
    # cutoff, krum NaN filtering, checkpoint/resume bitwise parity).
    # Every drill derives its faults from the seed it echoes on stderr
    # ("fault seed: N"), so a failing pass is replayable verbatim.
    echo "-- chaos leg: fault_tolerance (--test-threads ${tt}) --"
    cargo test -q --test fault_tolerance -- --test-threads "$tt"
    cargo test -q --lib compress:: -- --test-threads "$tt"
    cargo test -q --lib comm:: -- --test-threads "$tt"
    cargo test -q --lib coordinator:: -- --test-threads "$tt"
  done
done

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== traced smoke run + trace-check =="
  # A short traced hier run, then the in-tree verifier replays the
  # executor's comm accounting from the exported spans and demands it
  # match every step mark and the metrics exposition bit-for-bit
  # (EXPERIMENTS.md §Observability). trace.json/metrics.txt ride the
  # failure-artifact upload for postmortems.
  cargo run --release --bin adacons -- train --workers 8 --steps 8 \
    --topology hier:2x4 --optimizer sgd --schedule const:0.005 \
    --trace-level bucket --trace-out trace.json --metrics-out metrics.txt
  cargo run --release --bin adacons -- trace-check trace.json --metrics metrics.txt

  echo "== smoke bench (budget 0.05s/case, --overlap both) =="
  cargo run --release --bin bench_aggregation -- --smoke --budget 0.05 --overlap both --out BENCH_aggregation.json
  echo "== validate BENCH_aggregation.json =="
  cargo run --release --bin bench_aggregation -- --check BENCH_aggregation.json

  echo "== perf history =="
  mkdir -p bench_history
  sha="$(git rev-parse --short HEAD 2>/dev/null || echo nogit)"
  cp BENCH_aggregation.json "bench_history/${sha}.json"
  echo "archived bench_history/${sha}.json"
  if [[ -f bench_history/baseline.json ]]; then
    # Fail if the aggregate-phase median regresses >1.3x, or any step
    # case's median (adacons_step / interp_step per {mode, artifact} /
    # hier_step / matmul kernel rows) regresses >1.5x, vs the committed
    # baseline (both sides are smoke-grid runs; the step gate is looser —
    # rationale in EXPERIMENTS.md §Perf). Groups absent from an older
    # baseline (dlrm_lite, matmul kernels, hier_step, compress_step,
    # local_step) skip WITH AN EXPLICIT NOTICE; a group the baseline
    # covers but the
    # current run lacks hard-fails (lost coverage). --history lets the
    # accumulated archive tighten the step gate below 1.5x once >=3
    # runs exist on this runner class.
    cargo run --release --bin bench_aggregation -- \
      --compare bench_history/baseline.json BENCH_aggregation.json \
      --max-regress 1.3 --max-regress-step 1.5 \
      --history bench_history
  else
    cp BENCH_aggregation.json bench_history/baseline.json
    # Medians are host-specific: only commit a baseline produced on the
    # same runner class that will evaluate the gate (on ephemeral CI
    # runners, leave it uncommitted — the gate stays informational there
    # and arms on dev machines with a local bench_history/).
    echo "seeded bench_history/baseline.json (commit it to arm the perf gate;"
    echo "  only commit a baseline from the hardware class CI runs on)"
  fi
fi

echo "ci.sh: all green"
