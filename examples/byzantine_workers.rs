//! Faulty-worker study — the §1 motivation ("distributed systems are
//! vulnerable to computing errors from the workers"): how each aggregation
//! scheme behaves when a rank misbehaves.
//!
//! Run: `cargo run --release --example byzantine_workers`

use std::sync::Arc;

use adacons::config::TrainConfig;
use adacons::coordinator::Trainer;
use adacons::data::GradInjector;
use adacons::optim::Schedule;
use adacons::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    adacons::util::logging::init();
    let rt = Arc::new(Runtime::open_default()?);

    let attacks: &[(&str, GradInjector)] = &[
        ("healthy", GradInjector::None),
        ("sign-flip", GradInjector::SignFlip),
        ("scale x25", GradInjector::Scale(25.0)),
        ("zeros", GradInjector::Zero),
        (
            "heavy-tail",
            GradInjector::HeavyTail {
                dof: 2.0,
                scale: 0.05,
            },
        ),
    ];
    let aggregators = ["mean", "adacons", "median", "trimmed-mean", "grawa"];

    println!(
        "final train loss, linreg, N=8, one faulty rank (lower is better):\n{:<12}{}",
        "attack",
        aggregators
            .iter()
            .map(|a| format!("{a:>14}"))
            .collect::<String>()
    );
    for (attack_name, inj) in attacks {
        let mut row = format!("{attack_name:<12}");
        for agg in aggregators {
            let cfg = TrainConfig {
                artifact: "linreg_b16".into(),
                workers: 8,
                aggregator: agg.to_string(),
                optimizer: "sgd".into(),
                schedule: Schedule::Const { lr: 0.003 },
                steps: 80,
                injectors: vec![(0, inj.clone())],
                seed: 21,
                ..TrainConfig::default()
            };
            let loss = Trainer::new(rt.clone(), cfg)?.run()?.final_train_loss(10);
            if loss.is_finite() && loss < 1e3 {
                row.push_str(&format!("{loss:>14.5}"));
            } else {
                row.push_str(&format!("{:>14}", "diverged"));
            }
        }
        println!("{row}");
    }
    println!("\nexpect: mean diverges under sign-flip/scale; median and trimmed-mean");
    println!("survive everything; AdaCons damps outliers via consensus weights but");
    println!("is not a Byzantine defense — the paper motivates, not claims, that.");
    Ok(())
}
