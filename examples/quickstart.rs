//! Quickstart: train the paper's stochastic linear-regression task (Eq. 14)
//! with plain averaging vs AdaCons, each given the optimal analytical step
//! size (the Fig. 2 protocol), and print both loss curves.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::sync::Arc;

use adacons::config::TrainConfig;
use adacons::coordinator::Trainer;
use adacons::optim::Schedule;
use adacons::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    adacons::util::logging::init();
    let rt = Arc::new(Runtime::open_default()?);
    println!("PJRT platform: {}", rt.platform());

    let mut curves = Vec::new();
    for aggregator in ["mean", "adacons"] {
        let cfg = TrainConfig {
            artifact: "linreg_b16".into(),
            workers: 8,
            aggregator: aggregator.into(),
            optimizer: "linreg-exact".into(),
            schedule: Schedule::Const { lr: 0.0 },
            steps: 150,
            seed: 0,
            ..TrainConfig::default()
        };
        let res = Trainer::new(rt.clone(), cfg)?.run()?;
        println!(
            "{aggregator:>8}: initial loss {:.5}, final loss {:.6} ({} steps, {:.2} ms/step wall)",
            res.train_loss[0],
            res.final_train_loss(10),
            res.train_loss.len(),
            res.wall_iter_s * 1e3
        );
        curves.push((aggregator, res.train_loss));
    }

    println!("\nstep, mean_loss, adacons_loss");
    for i in (0..curves[0].1.len()).step_by(10) {
        println!("{i:4}, {:.6}, {:.6}", curves[0].1[i], curves[1].1[i]);
    }
    Ok(())
}
