//! End-to-end driver: pretrain a transformer LM with AdaCons data-parallel
//! aggregation on the synthetic token corpus and log the loss curve —
//! the repo's full-stack proof that all three layers compose
//! (Pallas fused_linear kernel -> JAX fwd/bwd -> AOT HLO -> PJRT -> Rust
//! coordinator with consensus aggregation).
//!
//! Run: `cargo run --release --example train_transformer -- \
//!         [--size sm|md] [--workers 4] [--steps 300] [--aggregator adacons]`
//!
//! `--size md` trains the ~3.7M-parameter model (slower);
//! the default `sm` (~0.39M) fits the single-CPU budget. The paper-scale
//! `lg` (~100M) config exists in python/compile/models/transformer.py for
//! larger hosts (add it to the AOT manifest and pass --size lg).

use std::sync::Arc;

use adacons::config::TrainConfig;
use adacons::coordinator::Trainer;
use adacons::metrics::CsvWriter;
use adacons::optim::Schedule;
use adacons::runtime::Runtime;
use adacons::util::argparse::Args;

fn main() -> anyhow::Result<()> {
    adacons::util::logging::init();
    let args = Args::parse(std::env::args().skip(1), &[]);
    let size = args.str_or("size", "sm");
    let steps = args.usize_or("steps", 300)?;
    let workers = args.usize_or("workers", 4)?;
    let aggregator = args.str_or("aggregator", "adacons");
    let artifact = match size.as_str() {
        "sm" => "tfm_sm_b8",
        "md" => "tfm_md_b4",
        other => anyhow::bail!("--size {other}: build lg artifacts first (see header)"),
    };

    let rt = Arc::new(Runtime::open_default()?);
    let spec = rt.manifest.get(artifact)?.clone();
    println!(
        "training {} ({} params, vocab {}, seq {}) on {} workers, {} steps, aggregator={}",
        artifact,
        spec.param_dim,
        spec.meta.get("vocab").as_usize().unwrap_or(0),
        spec.meta.get("seq").as_usize().unwrap_or(0),
        workers,
        steps,
        aggregator
    );

    let cfg = TrainConfig {
        artifact: artifact.into(),
        workers,
        aggregator: aggregator.clone(),
        optimizer: "adamw".into(),
        schedule: Schedule::WarmupCosine {
            lr: 3e-3,
            warmup: steps / 10,
            total: steps,
            final_frac: 0.1,
        },
        steps,
        eval_every: (steps / 10).max(1),
        eval_batches: 2,
        seed: args.u64_or("seed", 0)?,
        log_every: (steps / 20).max(1),
        ..TrainConfig::default()
    };
    let t = adacons::util::timer::Timer::start();
    let res = Trainer::new(rt, cfg)?.run()?;

    println!("\nstep, train_loss");
    for i in (0..res.train_loss.len()).step_by((steps / 25).max(1)) {
        println!("{i:5}, {:.4}", res.train_loss[i]);
    }
    let vocab_ln = (spec.meta.get("vocab").as_usize().unwrap_or(512) as f64).ln();
    println!(
        "\nloss: {:.3} (init, ~ln(vocab)={:.2}) -> {:.3} final | held-out {:.3}",
        res.train_loss[0],
        vocab_ln,
        res.final_train_loss(10),
        res.evals.last().map(|e| e.outcome.loss).unwrap_or(f64::NAN)
    );
    println!(
        "wall {:.1}s total, {:.0} ms/step; phases:\n{}",
        t.elapsed_s(),
        res.wall_iter_s * 1e3,
        res.phases.report()
    );
    let out = args.str_or("csv", "results/train_transformer_loss.csv");
    let mut w = CsvWriter::create(&out, &["step", "train_loss"])?;
    for (i, l) in res.train_loss.iter().enumerate() {
        w.row(&[i.to_string(), format!("{l}")])?;
    }
    w.flush()?;
    println!("loss curve -> {out}");
    anyhow::ensure!(
        res.final_train_loss(10) < res.train_loss[0] * 0.7,
        "end-to-end training failed to reduce loss"
    );
    Ok(())
}
