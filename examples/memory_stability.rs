// Leak regression probe: the runtime execute path must hold RSS flat.
// (History: the xla crate's execute::<Literal> path leaks its converted
// input buffers; runtime/executable.rs uses execute_b instead.)
use adacons::data::Array;
use adacons::runtime::Runtime;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap();
    let pages: f64 = s.split_whitespace().nth(1).unwrap().parse().unwrap();
    pages * 4096.0 / 1e6
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    let exe = rt.load("linreg_b64")?;
    let params = exe.spec.load_init(0)?;
    let batch = vec![Array::F32(vec![0.5; 64 * 1000], vec![64, 1000])];
    let mut first = 0.0;
    for i in 0..3001 {
        exe.run_train(&params, &batch)?;
        if i == 0 {
            first = rss_mb();
        }
        if i % 1000 == 0 {
            println!("iter {i}: rss {:.1} MB", rss_mb());
        }
    }
    let growth = rss_mb() - first;
    anyhow::ensure!(growth < 50.0, "leak: rss grew {growth:.1} MB over 3000 execs");
    println!("OK: rss growth {growth:.1} MB over 3000 execs");
    Ok(())
}
