//! Worker-scaling study on the paper's linear-regression task (Fig. 2
//! regime): how the Sum/AdaCons gap evolves with the number of workers,
//! plus the simulated communication overhead at two fabric speeds.
//!
//! Run: `cargo run --release --example linreg_scaling [-- --steps 150]`

use std::sync::Arc;

use adacons::collective::{CostModel, Topology};
use adacons::config::TrainConfig;
use adacons::coordinator::Trainer;
use adacons::optim::Schedule;
use adacons::runtime::Runtime;
use adacons::util::argparse::Args;

fn main() -> anyhow::Result<()> {
    adacons::util::logging::init();
    let args = Args::parse(std::env::args().skip(1), &[]);
    let steps = args.usize_or("steps", 150)?;
    let rt = Arc::new(Runtime::open_default()?);

    println!("{:>4} {:>12} {:>12} {:>8}", "N", "Sum loss", "AdaCons", "ratio");
    for n in [2, 4, 8, 16, 32] {
        let run = |agg: &str| -> anyhow::Result<f64> {
            let cfg = TrainConfig {
                artifact: "linreg_b16".into(),
                workers: n,
                aggregator: agg.into(),
                optimizer: "linreg-exact".into(),
                schedule: Schedule::Const { lr: 0.0 },
                steps,
                seed: 11,
                ..TrainConfig::default()
            };
            Ok(Trainer::new(rt.clone(), cfg)?.run()?.final_train_loss(10))
        };
        let sum = run("mean")?;
        let ada = run("adacons")?;
        println!("{n:>4} {sum:>12.6} {ada:>12.6} {:>8.3}", sum / ada);
    }

    println!("\nsimulated AdaCons comm overhead vs Sum (25.6M-param model, 32 ranks):");
    for gbps in [100.0, 800.0] {
        let m = CostModel::from_topology(&Topology::ring_gbps(32, gbps));
        let d = 25_600_000;
        println!(
            "  {gbps:>5} Gb/s: Sum {:.2} ms, AdaCons {:.2} ms ({:+.1} ms)",
            m.sum_iteration_s(d) * 1e3,
            m.adacons_iteration_s(d) * 1e3,
            (m.adacons_iteration_s(d) - m.sum_iteration_s(d)) * 1e3
        );
    }
    Ok(())
}
