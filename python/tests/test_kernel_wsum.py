"""Hypothesis sweep of the weighted-sum Pallas kernel vs the jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import weighted_sum
from compile.kernels.ref import weighted_sum_ref


@given(
    n=st.integers(1, 16),
    d=st.integers(1, 900),
    tile=st.sampled_from([16, 128, 500, 2048]),
    seed=st.integers(0, 2**31 - 1),
)
def test_weighted_sum_matches_ref(n, d, tile, seed):
    rng = np.random.default_rng(seed)
    p = rng.standard_normal((n, d)).astype(np.float32)
    gamma = rng.standard_normal(n).astype(np.float32)
    out = weighted_sum(jnp.asarray(gamma), jnp.asarray(p), tile_d=tile)
    exp = weighted_sum_ref(jnp.asarray(gamma), jnp.asarray(p))
    assert out.shape == (d,)
    assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-4, atol=1e-4)


def test_uniform_weights_recover_mean():
    rng = np.random.default_rng(0)
    p = rng.standard_normal((8, 333)).astype(np.float32)
    gamma = np.full(8, 1.0 / 8, np.float32)
    out = weighted_sum(jnp.asarray(gamma), jnp.asarray(p), tile_d=100)
    assert_allclose(np.asarray(out), p.mean(axis=0), rtol=1e-4, atol=1e-5)


def test_zero_weights_zero_output():
    p = np.ones((4, 64), np.float32)
    out = weighted_sum(jnp.zeros(4), jnp.asarray(p), tile_d=16)
    assert_allclose(np.asarray(out), np.zeros(64), atol=0)
