"""Hypothesis sweep of the consensus Pallas kernel vs the jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import consensus_stats, gram_matrix
from compile.kernels import ref


def _rand_p(n, d, seed, dtype):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((n, d)) * rng.uniform(0.1, 3.0)).astype(dtype)


@given(
    n=st.integers(1, 16),
    d=st.integers(1, 700),
    tile=st.sampled_from([32, 100, 256, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_consensus_stats_matches_ref(n, d, tile, seed):
    p = _rand_p(n, d, seed, np.float32)
    dots, sqn = consensus_stats(jnp.asarray(p), tile_d=tile)
    rd, rs = ref.consensus_stats_ref(jnp.asarray(p))
    assert_allclose(np.asarray(dots), np.asarray(rd), rtol=2e-4, atol=1e-4)
    assert_allclose(np.asarray(sqn), np.asarray(rs), rtol=2e-4, atol=1e-4)


@given(
    n=st.integers(1, 12),
    d=st.integers(1, 500),
    tile=st.sampled_from([64, 128, 333]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matches_ref(n, d, tile, seed):
    p = _rand_p(n, d, seed, np.float32)
    g = gram_matrix(jnp.asarray(p), tile_d=tile)
    rg = ref.gram_matrix_ref(jnp.asarray(p))
    assert_allclose(np.asarray(g), np.asarray(rg), rtol=2e-4, atol=1e-4)


def test_consensus_bf16_input_promotes():
    p = _rand_p(4, 256, 0, np.float32).astype(jnp.bfloat16)
    dots, sqn = consensus_stats(jnp.asarray(p), tile_d=64)
    rd, rs = ref.consensus_stats_ref(jnp.asarray(p))
    assert dots.dtype == jnp.float32 and sqn.dtype == jnp.float32
    assert_allclose(np.asarray(dots), np.asarray(rd), rtol=1e-2, atol=1e-2)


def test_gram_is_psd():
    p = _rand_p(8, 300, 3, np.float32)
    g = np.asarray(gram_matrix(jnp.asarray(p), tile_d=128), dtype=np.float64)
    eig = np.linalg.eigvalsh((g + g.T) / 2)
    assert eig.min() >= -1e-3  # PSD up to accumulation noise


def test_tile_larger_than_d_clamps():
    p = _rand_p(3, 17, 5, np.float32)
    dots, sqn = consensus_stats(jnp.asarray(p), tile_d=4096)
    rd, rs = ref.consensus_stats_ref(jnp.asarray(p))
    assert_allclose(np.asarray(dots), np.asarray(rd), rtol=1e-4, atol=1e-5)
    assert_allclose(np.asarray(sqn), np.asarray(rs), rtol=1e-4, atol=1e-5)


def test_identical_rows_consensus_equals_norm():
    g = np.full((1, 64), 0.3, np.float32)
    p = np.repeat(g, 6, axis=0)
    dots, sqn = consensus_stats(jnp.asarray(p), tile_d=16)
    # <g, mean> = ||g||^2 when all rows identical.
    assert_allclose(np.asarray(dots), np.asarray(sqn), rtol=1e-5)
