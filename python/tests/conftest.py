import os
import sys

# Make `compile` importable when pytest runs from python/ or the repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from hypothesis import settings

# Single-CPU CI budget: keep hypothesis sweeps tight but meaningful.
settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
