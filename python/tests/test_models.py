"""L2 model checks: flat-param gradient correctness, shapes, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.models import linreg, mlp, detection, dlrm, transformer
from compile.aot import golden_batch


def _check_bundle(bundle):
    flat = jnp.asarray(bundle.init_params(0))
    assert flat.shape == (bundle.param_dim,)
    batch = [jnp.asarray(golden_batch(s, bundle.meta)) for s in bundle.train_inputs]
    loss, grads = bundle.train_fn(flat, *batch)
    assert np.asarray(loss).shape == ()
    assert grads.shape == (bundle.param_dim,)
    assert np.isfinite(np.asarray(loss))
    assert np.isfinite(np.asarray(grads)).all()
    return flat, batch, loss, grads


def _fd_check(bundle, flat, batch, grads, n_coords=5, eps=1e-3, rtol=0.15):
    """Finite-difference spot check of the flat gradient."""

    def loss_at(f):
        l, _ = bundle.train_fn(f, *batch)
        return float(l)

    rng = np.random.default_rng(0)
    idx = rng.choice(bundle.param_dim, size=min(n_coords, bundle.param_dim), replace=False)
    f = np.asarray(flat, dtype=np.float64)
    for i in idx:
        fp = f.copy()
        fp[i] += eps
        fm = f.copy()
        fm[i] -= eps
        fd = (loss_at(jnp.asarray(fp, jnp.float32)) - loss_at(jnp.asarray(fm, jnp.float32))) / (2 * eps)
        g = float(grads[i])
        if abs(fd) < 1e-4 and abs(g) < 1e-4:
            continue
        assert abs(fd - g) <= rtol * max(abs(fd), abs(g)) + 1e-4, (i, fd, g)


def test_linreg_grad_is_analytic():
    b = linreg.build(16, dim=64)
    flat, batch, loss, grads = _check_bundle(b)
    x = np.asarray(batch[0], dtype=np.float64)
    w = np.asarray(flat, dtype=np.float64)
    expected = (x * (x @ w)[:, None]).mean(axis=0)
    assert_allclose(np.asarray(grads), expected, rtol=1e-4, atol=1e-6)


def test_mlp_bundle_and_fd():
    b = mlp.build(8, eval_batch=8)
    flat, batch, loss, grads = _check_bundle(b)
    _fd_check(b, flat, batch, grads)
    # eval outputs
    outs = b.eval_fn(flat, *batch)
    assert np.asarray(outs[1]).shape == (8,)
    assert set(np.unique(np.asarray(outs[1]))) <= {0.0, 1.0}


def test_detection_bundle_and_fd():
    b = detection.build(8, eval_batch=8)
    flat, batch, loss, grads = _check_bundle(b)
    _fd_check(b, flat, batch, grads)
    outs = b.eval_fn(flat, *batch)
    probs = np.asarray(outs[1])
    assert probs.shape == (8, detection.CLASSES)
    assert_allclose(probs.sum(axis=-1), np.ones(8), rtol=1e-5)


def test_dlrm_bundle_and_fd():
    b = dlrm.build(16, eval_batch=16)
    flat, batch, loss, grads = _check_bundle(b)
    _fd_check(b, flat, batch, grads)
    outs = b.eval_fn(flat, *batch)
    scores = np.asarray(outs[1])
    assert ((scores >= 0) & (scores <= 1)).all()


def test_transformer_sm_bundle():
    b = transformer.build("sm", 2)
    flat, batch, loss, grads = _check_bundle(b)
    # At random init the LM loss should be near ln(vocab).
    assert abs(float(loss) - np.log(transformer.SIZES["sm"].vocab)) < 1.0


def test_init_seeds_differ_but_shapes_match():
    b = mlp.build(4)
    f0, f1 = b.init_params(0), b.init_params(1)
    assert f0.shape == f1.shape
    assert not np.array_equal(f0, f1)
    assert_allclose(b.init_params(0), f0)  # deterministic


def test_grad_descent_reduces_linreg_loss():
    b = linreg.build(32, dim=32)
    flat = jnp.asarray(b.init_params(0))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(0, 1, (32, 32)).astype(np.float32))
    l0, g = b.train_fn(flat, x)
    l1, _ = b.train_fn(flat - 0.05 * g, x)  # lr < 2/L for E[xx^T], x~U[0,1]^32
    assert float(l1) < float(l0)
