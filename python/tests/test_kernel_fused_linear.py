"""Hypothesis sweep of the fused_linear Pallas kernel vs the jnp oracle,
plus VJP checks (the kernel carries a custom_vjp)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import fused_linear
from compile.kernels.ref import fused_linear_ref

ACTS = ["none", "relu", "gelu", "tanh"]


@given(
    b=st.integers(1, 16),
    i=st.integers(1, 64),
    o=st.integers(1, 200),
    act=st.sampled_from(ACTS),
    tile=st.sampled_from([8, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(b, i, o, act, tile, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, i)).astype(np.float32)
    w = rng.standard_normal((i, o)).astype(np.float32) * 0.3
    bias = rng.standard_normal(o).astype(np.float32)
    out = fused_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), act, tile)
    exp = fused_linear_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), act)
    assert_allclose(np.asarray(out), np.asarray(exp), rtol=3e-4, atol=3e-4)


@given(act=st.sampled_from(ACTS), seed=st.integers(0, 1000))
def test_fused_linear_vjp_matches_ref_grad(act, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4, 8)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((8, 12)).astype(np.float32) * 0.5)
    b = jnp.asarray(rng.standard_normal(12).astype(np.float32))

    def f_kernel(x, w, b):
        return jnp.sum(fused_linear(x, w, b, act, 8) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(fused_linear_ref(x, w, b, act) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        assert_allclose(np.asarray(a), np.asarray(e), rtol=1e-3, atol=1e-3)


def test_unknown_activation_raises():
    x = jnp.ones((2, 2))
    try:
        fused_linear(x, jnp.ones((2, 2)), jnp.ones(2), "swish")
    except ValueError:
        return
    raise AssertionError("expected ValueError")
