"""AOT pipeline checks: manifest schema, HLO text parseability markers,
golden reproducibility, adacons reference pipeline sanity."""

import json
import os

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.aot import build_artifact, golden_batch
from compile.models import linreg
from compile.kernels.ref import adacons_weights_ref

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_build_artifact_roundtrip(tmp_path):
    b = linreg.build(16, dim=32)
    recs = build_artifact(b, str(tmp_path))
    assert set(recs) == {"linreg_b16", "linreg_b16__eval"}
    rec = recs["linreg_b16"]
    hlo = (tmp_path / rec["hlo"]).read_text()
    assert hlo.startswith("HloModule")
    assert "ROOT" in hlo
    blob = (tmp_path / rec["init"]["0"]).read_bytes()
    assert len(blob) == 32 * 4
    flat = np.frombuffer(blob, dtype="<f4")
    assert_allclose(flat, b.init_params(0))
    # Golden is reproducible.
    batch = [jnp.asarray(golden_batch(s, b.meta)) for s in b.train_inputs]
    loss, grads = b.train_fn(jnp.asarray(b.init_params(0)), *batch)
    assert abs(float(loss) - rec["golden"]["loss"]) < 1e-5


def test_repo_manifest_schema_if_built():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        return  # artifacts not built in this checkout
    with open(path) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    arts = manifest["artifacts"]
    assert "linreg_b16" in arts and "tfm_sm_b8" in arts
    for name, rec in arts.items():
        assert os.path.exists(os.path.join(ART_DIR, rec["hlo"])), name
        for blob in rec.get("init", {}).values():
            assert os.path.exists(os.path.join(ART_DIR, blob)), name
        for spec in rec["inputs"] + rec["outputs"]:
            assert spec["dtype"] in ("f32", "i32")
        if rec["kind"] == "train" and rec["param_dim"]:
            g = rec["golden"]
            assert g is not None and np.isfinite(g["loss"])


def test_adacons_ref_weights_sum_one_in_subspace():
    rng = np.random.default_rng(0)
    p = rng.standard_normal((8, 200))
    gamma = np.asarray(adacons_weights_ref(jnp.asarray(p)))
    # Subspace coefficients alpha_i = gamma_i * ||g_i|| sum to one (Eq. 13).
    norms = np.linalg.norm(p, axis=1)
    # jnp truncates the f64 request to f32 without jax_enable_x64.
    assert abs((gamma * norms).sum() - 1.0) < 1e-4


def test_adacons_ref_collapses_to_mean_for_identical_grads():
    g = np.random.default_rng(1).standard_normal(100)
    p = np.tile(g, (4, 1))
    gamma_raw = np.asarray(adacons_weights_ref(jnp.asarray(p), lam=1.0))
    # Raw Eq. 8 with lam=1: gamma_i = 1/N -> exact mean.
    assert_allclose(gamma_raw, np.full(4, 0.25), rtol=1e-6)  # f32 pipeline
