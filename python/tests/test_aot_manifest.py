"""AOT pipeline checks: manifest schema, HLO text parseability markers,
golden reproducibility, adacons reference pipeline sanity."""

import json
import os

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.aot import build_artifact, golden_batch
from compile.models import linreg
from compile.kernels.ref import adacons_weights_ref

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_build_artifact_roundtrip(tmp_path):
    b = linreg.build(16, dim=32)
    recs = build_artifact(b, str(tmp_path))
    assert set(recs) == {"linreg_b16", "linreg_b16__eval"}
    rec = recs["linreg_b16"]
    hlo = (tmp_path / rec["hlo"]).read_text()
    assert hlo.startswith("HloModule")
    assert "ROOT" in hlo
    blob = (tmp_path / rec["init"]["0"]).read_bytes()
    assert len(blob) == 32 * 4
    flat = np.frombuffer(blob, dtype="<f4")
    assert_allclose(flat, b.init_params(0))
    # Golden is reproducible.
    batch = [jnp.asarray(golden_batch(s, b.meta)) for s in b.train_inputs]
    loss, grads = b.train_fn(jnp.asarray(b.init_params(0)), *batch)
    assert abs(float(loss) - rec["golden"]["loss"]) < 1e-5
    # The interpreter program record rides along in the manifest.
    prog = rec["program"]
    assert prog["loss"] == {"kind": "mean_square"}
    assert prog["layers"][0]["w_off"] == 0 and prog["layers"][0]["in"] == 32


def test_mlp_program_offsets_match_ravel_layout():
    """The emitted w_off/b_off must match where ravel_pytree actually puts
    each block — the contract the Rust interpreter relies on to share init
    blobs with the PJRT path."""
    import jax
    from jax.flatten_util import ravel_pytree

    from compile.models import mlp

    b = mlp.build(32, eval_batch=64)
    prog = b.program
    params = mlp._init_pytree(jax.random.PRNGKey(0))
    flat, _ = ravel_pytree(params)
    last = prog["layers"][-1]
    assert last["w_off"] + last["in"] * last["out"] == flat.shape[0] == b.param_dim
    for li, name in enumerate(["l1", "l2", "l3"]):
        rec = prog["layers"][li]
        for leaf, off_key, count in [
            ("b", "b_off", rec["out"]),
            ("w", "w_off", rec["in"] * rec["out"]),
        ]:
            marked = jax.tree_util.tree_map(jnp.zeros_like, params)
            marked[name][leaf] = jnp.ones_like(marked[name][leaf])
            mflat, _ = ravel_pytree(marked)
            idx = np.nonzero(np.asarray(mflat))[0]
            assert idx.shape[0] == count, (name, leaf)
            assert int(idx[0]) == rec[off_key], (name, leaf)
            # Block is contiguous.
            assert int(idx[-1]) == rec[off_key] + count - 1, (name, leaf)


def test_repo_manifest_schema_if_built():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        return  # artifacts not built in this checkout
    with open(path) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    arts = manifest["artifacts"]
    assert "linreg_b16" in arts and "tfm_sm_b8" in arts
    for name, rec in arts.items():
        assert os.path.exists(os.path.join(ART_DIR, rec["hlo"])), name
        for blob in rec.get("init", {}).values():
            assert os.path.exists(os.path.join(ART_DIR, blob)), name
        for spec in rec["inputs"] + rec["outputs"]:
            assert spec["dtype"] in ("f32", "i32")
        if rec["kind"] == "train" and rec["param_dim"]:
            g = rec["golden"]
            assert g is not None and np.isfinite(g["loss"])


def test_adacons_ref_weights_sum_one_in_subspace():
    rng = np.random.default_rng(0)
    p = rng.standard_normal((8, 200))
    gamma = np.asarray(adacons_weights_ref(jnp.asarray(p)))
    # Subspace coefficients alpha_i = gamma_i * ||g_i|| sum to one (Eq. 13).
    norms = np.linalg.norm(p, axis=1)
    # jnp truncates the f64 request to f32 without jax_enable_x64.
    assert abs((gamma * norms).sum() - 1.0) < 1e-4


def test_adacons_ref_collapses_to_mean_for_identical_grads():
    g = np.random.default_rng(1).standard_normal(100)
    p = np.tile(g, (4, 1))
    gamma_raw = np.asarray(adacons_weights_ref(jnp.asarray(p), lam=1.0))
    # Raw Eq. 8 with lam=1: gamma_i = 1/N -> exact mean.
    assert_allclose(gamma_raw, np.full(4, 0.25), rtol=1e-6)  # f32 pipeline
