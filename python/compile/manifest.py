"""The AOT build manifest: every artifact the Rust coordinator can load.

Each entry lowers to ``artifacts/<name>.hlo.txt`` (+ ``<name>__eval.hlo.txt``
when the bundle has an eval function) and ``<name>.init.s<seed>.bin`` blobs.
Keep this list in sync with DESIGN.md §7.
"""

import jax
import jax.numpy as jnp

from .models import ArraySpec, ModelBundle
from .models import linreg, mlp, detection, dlrm, transformer
from .kernels import consensus_stats, weighted_sum

INIT_SEEDS = (0, 1, 2)

# Kernel-artifact geometry for the runtime benches (N workers, D params).
KERNEL_N = 8
KERNEL_D = 1 << 20
KERNEL_TILE = 1 << 16


def model_bundles():
    """All model bundles to build, in build order (cheap first)."""
    return [
        linreg.build(16),
        linreg.build(64),
        linreg.build(128),
        mlp.build(32, eval_batch=256),
        detection.build(32, eval_batch=256),
        dlrm.build(64, eval_batch=512),
        transformer.build("sm", 8),
        transformer.build("md", 4),
    ]


def kernel_bundles():
    """Standalone L1 kernel graphs (consensus + weighted-sum) exposed to the
    Rust runtime for the kernel-path parity tests and benches."""

    def consensus_fn(p):
        dots, sqn = consensus_stats(p, tile_d=KERNEL_TILE)
        return dots, sqn

    def wsum_fn(gamma, p):
        return (weighted_sum(gamma, p, tile_d=KERNEL_TILE),)

    p_spec = ArraySpec("p", "f32", (KERNEL_N, KERNEL_D))
    g_spec = ArraySpec("gamma", "f32", (KERNEL_N,))
    return [
        ModelBundle(
            name=f"kernel_consensus_n{KERNEL_N}",
            param_dim=0,
            init_params=None,
            train_fn=consensus_fn,
            train_inputs=[p_spec],
            train_outputs=[
                ArraySpec("dots", "f32", (KERNEL_N,)),
                ArraySpec("sqn", "f32", (KERNEL_N,)),
            ],
            meta={"model": "kernel", "kind": "kernel", "n": KERNEL_N, "d": KERNEL_D},
        ),
        ModelBundle(
            name=f"kernel_wsum_n{KERNEL_N}",
            param_dim=0,
            init_params=None,
            train_fn=wsum_fn,
            train_inputs=[g_spec, p_spec],
            train_outputs=[ArraySpec("out", "f32", (KERNEL_D,))],
            meta={"model": "kernel", "kind": "kernel", "n": KERNEL_N, "d": KERNEL_D},
        ),
    ]
