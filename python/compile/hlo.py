"""Lowering helpers: jitted JAX function -> HLO *text*.

HLO text (not a serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate links) rejects with
``proto.id() <= INT_MAX``.  The text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.
"""

import jax
from jax._src.lib import xla_client as xc


def lower_to_hlo_text(fn, *arg_specs) -> str:
    """Lower ``fn`` at the given ShapeDtypeStructs and return HLO text.

    The function is lowered with ``return_tuple=True`` so the Rust side
    always unwraps a single tuple literal regardless of arity.
    """
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
