"""AOT pipeline: lower every manifest entry to HLO text + init blobs +
``artifacts/manifest.json``.

Run once via ``make artifacts`` (``cd python && python -m compile.aot
--out-dir ../artifacts``).  Python never runs at training time.

Per train artifact we also record a *golden*: loss / grad checksums on a
deterministic constant batch that the Rust integration tests regenerate
bit-identically (f32 arrays = 0.5, int arrays = index % cardinality).
"""

import argparse
import json
import os
import time

import numpy as np
import jax.numpy as jnp

from .hlo import lower_to_hlo_text
from .manifest import INIT_SEEDS, model_bundles, kernel_bundles


def golden_batch(spec, meta):
    """Deterministic batch the Rust side can regenerate exactly."""
    shape = tuple(spec.shape)
    if spec.dtype == "f32":
        return np.full(shape, 0.5, dtype=np.float32)
    # int arrays: index % cardinality along the flattened array.
    card = {
        "y": meta.get("classes", 2),
        "cat": meta.get("vocab", 2),
        "tokens": meta.get("vocab", 2),
    }.get(spec.name, 2)
    flat = np.arange(int(np.prod(shape)), dtype=np.int64) % card
    return flat.reshape(shape).astype(np.int32)


def build_artifact(bundle, out_dir, skip_golden=False):
    records = {}
    t0 = time.time()
    param_spec = (
        [jnp.zeros((bundle.param_dim,), jnp.float32)] if bundle.param_dim else []
    )

    def lower(fn, inputs):
        specs = [s.sds() for s in inputs]
        if bundle.param_dim:
            import jax

            specs = [jax.ShapeDtypeStruct((bundle.param_dim,), jnp.float32)] + specs
        return lower_to_hlo_text(fn, *specs)

    # --- train graph ---
    hlo = lower(bundle.train_fn, bundle.train_inputs)
    hlo_path = f"{bundle.name}.hlo.txt"
    with open(os.path.join(out_dir, hlo_path), "w") as f:
        f.write(hlo)

    init_paths = {}
    golden = None
    if bundle.init_params is not None:
        for seed in INIT_SEEDS:
            flat = bundle.init_params(seed)
            assert flat.shape == (bundle.param_dim,) and flat.dtype == np.float32
            p = f"{bundle.name}.init.s{seed}.bin"
            with open(os.path.join(out_dir, p), "wb") as f:
                f.write(flat.astype("<f4").tobytes())
            init_paths[str(seed)] = p
        if not skip_golden:
            batch = [golden_batch(s, bundle.meta) for s in bundle.train_inputs]
            flat0 = bundle.init_params(INIT_SEEDS[0])
            loss, grads = bundle.train_fn(jnp.asarray(flat0), *[jnp.asarray(b) for b in batch])
            grads = np.asarray(grads, dtype=np.float64)
            golden = {
                "seed": INIT_SEEDS[0],
                "loss": float(loss),
                "grad_sum": float(grads.sum()),
                "grad_l2": float(np.sqrt((grads * grads).sum())),
            }

    records[bundle.name] = {
        "hlo": hlo_path,
        "kind": bundle.meta.get("kind", "train"),
        "model": bundle.meta.get("model", bundle.name),
        "param_dim": bundle.param_dim,
        "inputs": [s.to_json() for s in bundle.train_inputs],
        "outputs": [s.to_json() for s in bundle.train_outputs],
        "init": init_paths,
        "golden": golden,
        "meta": bundle.meta,
        # Interpreter program (native Rust backend); None for models the
        # interpreter does not cover.
        "program": bundle.program,
    }

    # --- eval graph ---
    if bundle.eval_fn is not None:
        ehlo = lower(bundle.eval_fn, bundle.eval_inputs)
        epath = f"{bundle.name}__eval.hlo.txt"
        with open(os.path.join(out_dir, epath), "w") as f:
            f.write(ehlo)
        records[f"{bundle.name}__eval"] = {
            "hlo": epath,
            "kind": "eval",
            "model": bundle.meta.get("model", bundle.name),
            "param_dim": bundle.param_dim,
            "inputs": [s.to_json() for s in bundle.eval_inputs],
            "outputs": [s.to_json() for s in bundle.eval_outputs],
            "init": init_paths,
            "golden": None,
            "meta": bundle.meta,
            "program": bundle.program,
        }
    print(f"  [{time.time() - t0:6.1f}s] {bundle.name} (d={bundle.param_dim})")
    return records


def main():
    ap = argparse.ArgumentParser(description="AdaCons AOT artifact builder")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on bundle names")
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    bundles = model_bundles() + kernel_bundles()
    if args.only:
        bundles = [b for b in bundles if args.only in b.name]

    artifacts = {}
    for bundle in bundles:
        artifacts.update(build_artifact(bundle, args.out_dir, args.skip_golden))

    manifest = {"version": 1, "artifacts": artifacts}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(artifacts)} artifacts to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
