"""Build-time compile path: L2 JAX models + L1 Pallas kernels -> AOT HLO.

Nothing in this package is imported at training time; ``make artifacts``
runs ``python -m compile.aot`` once and the Rust binary consumes the
resulting ``artifacts/`` directory.
"""
