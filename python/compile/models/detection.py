"""Synthetic detection head — the RetinaNet substitute (Fig. 4, Fig. 7).

A shared trunk over 128-d region features with two heads: focal-weighted
classification over C = 8 object classes and Huber box regression (4 coords),
mirroring RetinaNet's cls+box loss structure.  Rust computes an mAP-proxy
from the eval outputs (per-example class probabilities + box L1 error) by
sweeping score thresholds.
"""

import jax
import jax.numpy as jnp

from . import ArraySpec, ModelBundle, flat_init, make_flat_value_and_grad
from ..kernels import fused_linear

IN_DIM = 128
HIDDEN = 256
CLASSES = 8
FOCAL_GAMMA = 2.0
HUBER_DELTA = 1.0


def _init_pytree(key):
    ks = jax.random.split(key, 4)

    def dense(k, i, o):
        scale = jnp.sqrt(2.0 / i)
        return {
            "w": jax.random.normal(k, (i, o), jnp.float32) * scale,
            "b": jnp.zeros((o,), jnp.float32),
        }

    return {
        "t1": dense(ks[0], IN_DIM, HIDDEN),
        "t2": dense(ks[1], HIDDEN, HIDDEN),
        "cls": dense(ks[2], HIDDEN, CLASSES),
        "box": dense(ks[3], HIDDEN, 4),
    }


def _heads(params, x):
    h = fused_linear(x, params["t1"]["w"], params["t1"]["b"], activation="relu")
    h = fused_linear(h, params["t2"]["w"], params["t2"]["b"], activation="relu")
    logits = h @ params["cls"]["w"] + params["cls"]["b"]
    boxes = h @ params["box"]["w"] + params["box"]["b"]
    return logits, boxes


def _focal_ce(logits, y):
    logp = jax.nn.log_softmax(logits, axis=-1)
    p = jnp.exp(logp)
    pt = jnp.take_along_axis(p, y[:, None], axis=-1)[:, 0]
    logpt = jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    return -jnp.mean(((1.0 - pt) ** FOCAL_GAMMA) * logpt)


def _huber(pred, target):
    err = pred - target
    a = jnp.abs(err)
    quad = jnp.minimum(a, HUBER_DELTA)
    return jnp.mean(0.5 * quad * quad + HUBER_DELTA * (a - quad))


def _loss(params, x, y, box):
    logits, boxes = _heads(params, x)
    return _focal_ce(logits, y) + _huber(boxes, box)


def build(local_batch: int, eval_batch: int = None) -> ModelBundle:
    flat0, unravel = flat_init(_init_pytree, 0)
    d = flat0.shape[0]
    train_fn = make_flat_value_and_grad(_loss, unravel)
    eb = eval_batch or local_batch

    def eval_fn(flat, x, y, box):
        params = unravel(flat)
        logits, boxes = _heads(params, x)
        probs = jax.nn.softmax(logits, axis=-1)
        box_l1 = jnp.mean(jnp.abs(boxes - box), axis=-1)
        loss = _focal_ce(logits, y) + _huber(boxes, box)
        return loss, probs, box_l1

    def init_params(seed):
        flat, _ = flat_init(_init_pytree, seed)
        return flat

    return ModelBundle(
        name=f"det_b{local_batch}",
        param_dim=d,
        init_params=init_params,
        train_fn=train_fn,
        train_inputs=[
            ArraySpec("x", "f32", (local_batch, IN_DIM)),
            ArraySpec("y", "i32", (local_batch,)),
            ArraySpec("box", "f32", (local_batch, 4)),
        ],
        train_outputs=[
            ArraySpec("loss", "f32", ()),
            ArraySpec("grads", "f32", (d,)),
        ],
        eval_fn=eval_fn,
        eval_inputs=[
            ArraySpec("x", "f32", (eb, IN_DIM)),
            ArraySpec("y", "i32", (eb,)),
            ArraySpec("box", "f32", (eb, 4)),
        ],
        eval_outputs=[
            ArraySpec("loss", "f32", ()),
            ArraySpec("probs", "f32", (eb, CLASSES)),
            ArraySpec("box_l1", "f32", (eb,)),
        ],
        meta={
            "model": "det",
            "local_batch": local_batch,
            "eval_batch": eb,
            "in_dim": IN_DIM,
            "classes": CLASSES,
        },
    )
