"""Causal transformer LM — the BERT-Large substitute (Fig. 6 / Fig. 11,
Table 2) and the end-to-end training example.

Pre-LN decoder-only transformer with next-token cross-entropy (the paper
uses MLM phase-1 pretraining; causal LM is the same loss family over the
same synthetic token statistics — see DESIGN.md §Hardware-Adaptation).
MLP blocks run through the fused_linear Pallas kernel.

Two stock sizes:
  sm — d=96,  L=3, h=4, ff=384, seq 64,  vocab 512   (~0.45M params)
  md — d=256, L=4, h=8, ff=1024, seq 128, vocab 2048 (~4.3M params)
plus a documented ``lg`` (~100M) config for larger testbeds.
"""

import dataclasses

import jax
import jax.numpy as jnp

from . import ArraySpec, ModelBundle, flat_init, make_flat_value_and_grad
from ..kernels import fused_linear


@dataclasses.dataclass(frozen=True)
class TfmConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int


SIZES = {
    "sm": TfmConfig(vocab=512, d_model=96, n_layers=3, n_heads=4, d_ff=384, seq=64),
    "md": TfmConfig(vocab=2048, d_model=256, n_layers=4, n_heads=8, d_ff=1024, seq=128),
    # lg is not built by default (single-CPU testbed); kept for completeness.
    "lg": TfmConfig(vocab=32768, d_model=768, n_layers=12, n_heads=12, d_ff=3072, seq=512),
}


def _init_pytree_fn(cfg: TfmConfig):
    def init(key):
        ks = jax.random.split(key, 2 + cfg.n_layers)
        scale = 0.02

        def mat(k, shape):
            return jax.random.normal(k, shape, jnp.float32) * scale

        layers = []
        for l in range(cfg.n_layers):
            lk = jax.random.split(ks[2 + l], 6)
            layers.append(
                {
                    "ln1": {"g": jnp.ones((cfg.d_model,)), "b": jnp.zeros((cfg.d_model,))},
                    "wqkv": mat(lk[0], (cfg.d_model, 3 * cfg.d_model)),
                    "wo": mat(lk[1], (cfg.d_model, cfg.d_model)),
                    "ln2": {"g": jnp.ones((cfg.d_model,)), "b": jnp.zeros((cfg.d_model,))},
                    "w1": mat(lk[2], (cfg.d_model, cfg.d_ff)),
                    "b1": jnp.zeros((cfg.d_ff,)),
                    "w2": mat(lk[3], (cfg.d_ff, cfg.d_model)),
                    "b2": jnp.zeros((cfg.d_model,)),
                }
            )
        return {
            "tok_emb": mat(ks[0], (cfg.vocab, cfg.d_model)),
            "pos_emb": mat(ks[1], (cfg.seq, cfg.d_model)),
            "layers": layers,
            "ln_f": {"g": jnp.ones((cfg.d_model,)), "b": jnp.zeros((cfg.d_model,))},
        }

    return init


def _layer_norm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def _block(cfg, layer, x):
    b, s, d = x.shape
    h = _layer_norm(x, layer["ln1"]["g"], layer["ln1"]["b"])
    qkv = h @ layer["wqkv"]  # (B,S,3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    hd = d // cfg.n_heads

    def heads(t):
        return t.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(hd).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + out @ layer["wo"]
    h = _layer_norm(x, layer["ln2"]["g"], layer["ln2"]["b"])
    # Fused MLP through the L1 Pallas kernel (flatten tokens to rows).
    h2 = fused_linear(h.reshape(b * s, d), layer["w1"], layer["b1"], activation="gelu")
    h2 = fused_linear(h2, layer["w2"], layer["b2"], activation="none")
    return x + h2.reshape(b, s, d)


def _loss_fn(cfg: TfmConfig):
    def loss(params, tokens):
        # tokens: (B, seq+1) int32; inputs = [:, :-1], targets = [:, 1:].
        inp = tokens[:, :-1]
        tgt = tokens[:, 1:]
        x = jnp.take(params["tok_emb"], inp, axis=0) + params["pos_emb"][None, :, :]
        for layer in params["layers"]:
            x = _block(cfg, layer, x)
        x = _layer_norm(x, params["ln_f"]["g"], params["ln_f"]["b"])
        logits = x @ params["tok_emb"].T  # tied LM head
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    return loss


def build(size: str, local_batch: int) -> ModelBundle:
    cfg = SIZES[size]
    loss = _loss_fn(cfg)
    flat0, unravel = flat_init(_init_pytree_fn(cfg), 0)
    d = flat0.shape[0]
    train_fn = make_flat_value_and_grad(loss, unravel)

    def eval_fn(flat, tokens):
        return (loss(unravel(flat), tokens),)

    def init_params(seed):
        flat, _ = flat_init(_init_pytree_fn(cfg), seed)
        return flat

    toks = ArraySpec("tokens", "i32", (local_batch, cfg.seq + 1))
    return ModelBundle(
        name=f"tfm_{size}_b{local_batch}",
        param_dim=d,
        init_params=init_params,
        train_fn=train_fn,
        train_inputs=[toks],
        train_outputs=[
            ArraySpec("loss", "f32", ()),
            ArraySpec("grads", "f32", (d,)),
        ],
        eval_fn=eval_fn,
        eval_inputs=[toks],
        eval_outputs=[ArraySpec("loss", "f32", ())],
        meta={
            "model": f"tfm_{size}",
            "local_batch": local_batch,
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
        },
    )
