"""Embedding + cross-network CTR model — the DLRM-DCNv2 substitute
(Fig. 5 / Fig. 10, Table 2).

F categorical fields with Zipf-distributed ids feed embedding tables; the
concatenated (embeddings, dense) vector x0 passes through DCN-v2 cross
layers ``x_{l+1} = x0 * (W_l x_l + b_l) + x_l`` and a fused_linear MLP tower
to a single logit; BCE loss.  Rust computes AUC from eval scores.
"""

import jax
import jax.numpy as jnp

from . import ArraySpec, ModelBundle, flat_init, make_flat_value_and_grad
from ..kernels import fused_linear

FIELDS = 8
VOCAB = 1000
EMB_DIM = 16
DENSE_DIM = 16
CROSS_LAYERS = 2
X0_DIM = FIELDS * EMB_DIM + DENSE_DIM  # 144
TOWER = (128, 64)


def _init_pytree(key):
    ks = jax.random.split(key, 3 + CROSS_LAYERS + len(TOWER) + 1)

    def dense(k, i, o):
        scale = jnp.sqrt(2.0 / i)
        return {
            "w": jax.random.normal(k, (i, o), jnp.float32) * scale,
            "b": jnp.zeros((o,), jnp.float32),
        }

    params = {
        "emb": jax.random.normal(ks[0], (FIELDS, VOCAB, EMB_DIM), jnp.float32)
        * (1.0 / jnp.sqrt(EMB_DIM)),
        "cross": [dense(ks[1 + l], X0_DIM, X0_DIM) for l in range(CROSS_LAYERS)],
    }
    dims = (X0_DIM,) + TOWER
    params["tower"] = [
        dense(ks[1 + CROSS_LAYERS + i], dims[i], dims[i + 1])
        for i in range(len(TOWER))
    ]
    params["head"] = dense(ks[-1], TOWER[-1], 1)
    return params


def _logit(params, cat, dense_x):
    # cat: (B, FIELDS) int32; gather per-field embeddings.
    embs = []
    for f in range(FIELDS):
        embs.append(jnp.take(params["emb"][f], cat[:, f], axis=0))
    x0 = jnp.concatenate(embs + [dense_x], axis=-1)  # (B, X0_DIM)
    x = x0
    for layer in params["cross"]:
        x = x0 * (x @ layer["w"] + layer["b"]) + x  # DCN-v2 cross
    for layer in params["tower"]:
        x = fused_linear(x, layer["w"], layer["b"], activation="relu", tile_o=64)
    return (x @ params["head"]["w"] + params["head"]["b"])[:, 0]


def _loss(params, cat, dense_x, y):
    logit = _logit(params, cat, dense_x)
    # numerically stable BCE-with-logits
    return jnp.mean(jnp.maximum(logit, 0.0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def build(local_batch: int, eval_batch: int = None) -> ModelBundle:
    flat0, unravel = flat_init(_init_pytree, 0)
    d = flat0.shape[0]
    train_fn = make_flat_value_and_grad(_loss, unravel)
    eb = eval_batch or local_batch

    def eval_fn(flat, cat, dense_x, y):
        params = unravel(flat)
        logit = _logit(params, cat, dense_x)
        loss = jnp.mean(
            jnp.maximum(logit, 0.0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
        )
        return loss, jax.nn.sigmoid(logit)

    def init_params(seed):
        flat, _ = flat_init(_init_pytree, seed)
        return flat

    def inputs(b):
        return [
            ArraySpec("cat", "i32", (b, FIELDS)),
            ArraySpec("dense", "f32", (b, DENSE_DIM)),
            ArraySpec("y", "f32", (b,)),
        ]

    return ModelBundle(
        name=f"dlrm_b{local_batch}",
        param_dim=d,
        init_params=init_params,
        train_fn=train_fn,
        train_inputs=inputs(local_batch),
        train_outputs=[
            ArraySpec("loss", "f32", ()),
            ArraySpec("grads", "f32", (d,)),
        ],
        eval_fn=eval_fn,
        eval_inputs=inputs(eb),
        eval_outputs=[
            ArraySpec("loss", "f32", ()),
            ArraySpec("score", "f32", (eb,)),
        ],
        meta={
            "model": "dlrm",
            "local_batch": local_batch,
            "eval_batch": eb,
            "fields": FIELDS,
            "vocab": VOCAB,
            "dense_dim": DENSE_DIM,
        },
    )
