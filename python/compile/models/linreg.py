"""Stochastic linear regression (paper Eq. 14, Fig. 2 / Fig. 9).

``min_w E_{zeta ~ U[0,1]^d} [ (w^T zeta)^2 / 2 ]`` with d = 1000.
The Rust side generates the U[0,1] batches; this module only lowers the
loss/gradient graph.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import ArraySpec, ModelBundle, dense_program

DIM = 1000


def build(local_batch: int, dim: int = DIM) -> ModelBundle:
    def loss_fn(w, x):
        # x: (B, dim) ~ U[0,1]; loss = mean_b 0.5 * (w . x_b)^2
        y = x @ w
        return 0.5 * jnp.mean(y * y)

    def train_fn(flat, x):
        loss, g = jax.value_and_grad(loss_fn)(flat, x)
        return loss, g

    def eval_fn(flat, x):
        return (loss_fn(flat, x),)

    def init_params(seed):
        rng = np.random.default_rng(seed)
        # Paper starts from a generic non-zero iterate; N(0, 1/sqrt(d)).
        return (rng.standard_normal(dim) / np.sqrt(dim)).astype(np.float32)

    xs = ArraySpec("x", "f32", (local_batch, dim))
    return ModelBundle(
        name=f"linreg_b{local_batch}",
        param_dim=dim,
        init_params=init_params,
        train_fn=train_fn,
        train_inputs=[xs],
        train_outputs=[
            ArraySpec("loss", "f32", ()),
            ArraySpec("grads", "f32", (dim,)),
        ],
        eval_fn=eval_fn,
        eval_inputs=[xs],
        eval_outputs=[ArraySpec("loss", "f32", ())],
        meta={"model": "linreg", "local_batch": local_batch, "dim": dim},
        # Native-interpreter program: one bias-free dense layer into the
        # half-mean-square loss; params are the raw weight vector, so the
        # flat layout is trivially ravel-compatible.
        program=dense_program(
            [(dim, 1)],
            acts=["none"],
            loss={"kind": "mean_square"},
            init_stds=[1.0 / np.sqrt(dim)],
            bias=False,
        ),
    )
