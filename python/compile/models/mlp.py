"""Gaussian-mixture image classifier — the ImageNet/ResNet-50 substitute
(Fig. 3, Table 2).  A 3-layer MLP over 256-d synthetic "image" features with
C = 16 classes; hidden layers run through the fused_linear Pallas kernel so
the lowered HLO carries the L1 kernel on its hot path.
"""

import jax
import jax.numpy as jnp

from . import ArraySpec, ModelBundle, dense_program, flat_init, make_flat_value_and_grad
from ..kernels import fused_linear

IN_DIM = 256
HIDDEN = 512
CLASSES = 16


def _init_pytree(key):
    k1, k2, k3 = jax.random.split(key, 3)

    def dense(k, i, o):
        scale = jnp.sqrt(2.0 / i)
        return {
            "w": jax.random.normal(k, (i, o), jnp.float32) * scale,
            "b": jnp.zeros((o,), jnp.float32),
        }

    return {
        "l1": dense(k1, IN_DIM, HIDDEN),
        "l2": dense(k2, HIDDEN, HIDDEN),
        "l3": dense(k3, HIDDEN, CLASSES),
    }


def _logits(params, x):
    h = fused_linear(x, params["l1"]["w"], params["l1"]["b"], activation="relu")
    h = fused_linear(h, params["l2"]["w"], params["l2"]["b"], activation="relu")
    return fused_linear(h, params["l3"]["w"], params["l3"]["b"], activation="none")


def _loss(params, x, y):
    logits = _logits(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def build(local_batch: int, eval_batch: int = None) -> ModelBundle:
    flat0, unravel = flat_init(_init_pytree, 0)
    d = flat0.shape[0]
    train_fn = make_flat_value_and_grad(_loss, unravel)

    def eval_fn(flat, x, y):
        params = unravel(flat)
        logits = _logits(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        return jnp.mean(nll), correct

    eb = eval_batch or local_batch

    def init_params(seed):
        flat, _ = flat_init(_init_pytree, seed)
        return flat

    return ModelBundle(
        name=f"mlp_cls_b{local_batch}",
        param_dim=d,
        init_params=init_params,
        train_fn=train_fn,
        train_inputs=[
            ArraySpec("x", "f32", (local_batch, IN_DIM)),
            ArraySpec("y", "i32", (local_batch,)),
        ],
        train_outputs=[
            ArraySpec("loss", "f32", ()),
            ArraySpec("grads", "f32", (d,)),
        ],
        eval_fn=eval_fn,
        eval_inputs=[
            ArraySpec("x", "f32", (eb, IN_DIM)),
            ArraySpec("y", "i32", (eb,)),
        ],
        eval_outputs=[
            ArraySpec("loss", "f32", ()),
            ArraySpec("correct", "f32", (eb,)),
        ],
        meta={
            "model": "mlp_cls",
            "local_batch": local_batch,
            "eval_batch": eb,
            "in_dim": IN_DIM,
            "classes": CLASSES,
        },
        # Native-interpreter program mirroring _logits/_loss: offsets
        # follow ravel_pytree's b-before-w per-layer order (validated by
        # test_aot_manifest.py against the actual unravel structure).
        program=dense_program(
            [(IN_DIM, HIDDEN), (HIDDEN, HIDDEN), (HIDDEN, CLASSES)],
            acts=["relu", "relu", "none"],
            loss={"kind": "softmax_xent", "classes": CLASSES},
            init_stds=[(2.0 / IN_DIM) ** 0.5, (2.0 / HIDDEN) ** 0.5, (2.0 / HIDDEN) ** 0.5],
        ),
    )
