"""L2 model zoo.

Every model exposes a :class:`ModelBundle` whose train/eval functions take a
single **flat** ``f32[d]`` parameter vector first (flatten/unflatten lives in
JAX, so the Rust coordinator only ever sees flat vectors) followed by the
batch arrays.  ``train_fn`` returns ``(loss, grads_flat)``; ``eval_fn``
returns model-specific metric arrays (documented per model and recorded in
the artifact manifest).
"""

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree


@dataclasses.dataclass
class ArraySpec:
    """Shape/dtype of one runtime input or output, as seen by Rust."""

    name: str
    dtype: str  # "f32" | "i32"
    shape: Tuple[int, ...]

    def sds(self):
        dt = {"f32": jnp.float32, "i32": jnp.int32}[self.dtype]
        return jax.ShapeDtypeStruct(tuple(self.shape), dt)

    def to_json(self):
        return {"name": self.name, "dtype": self.dtype, "shape": list(self.shape)}


@dataclasses.dataclass
class ModelBundle:
    """Everything the AOT pipeline needs for one (model, local-batch) config."""

    name: str
    param_dim: int
    init_params: Callable[[int], np.ndarray]  # seed -> f32[d]
    train_fn: Callable  # (flat, *batch) -> (loss, grads)
    train_inputs: List[ArraySpec]  # batch arrays (excluding params)
    train_outputs: List[ArraySpec]
    eval_fn: Callable = None  # (flat, *batch) -> metric arrays
    eval_inputs: List[ArraySpec] = None
    eval_outputs: List[ArraySpec] = None
    meta: dict = dataclasses.field(default_factory=dict)
    # Optional interpreter program description (see
    # rust/src/runtime/interp/program.rs): a dense-layer chain + loss with
    # explicit flat-vector offsets. Lets the Rust native backend execute
    # this artifact without XLA. Built with `dense_program(...)`.
    program: dict = None


def dense_program(layer_dims, acts, loss, init_stds=None, bias=True):
    """Build a ``program`` record for a feed-forward dense chain.

    Offsets follow jax's ``ravel_pytree`` order for the standard
    ``{l1: {b, w}, l2: {b, w}, ...}`` pytree: dict keys sort
    alphabetically, so each layer stores its bias before its weight.
    ``layer_dims`` is [(in, out), ...]; ``loss`` is the loss record, e.g.
    ``{"kind": "softmax_xent", "classes": 16}``.
    """
    layers = []
    off = 0
    for i, (in_dim, out_dim) in enumerate(layer_dims):
        rec = {"in": in_dim, "out": out_dim, "act": acts[i]}
        if bias:
            rec["b_off"] = off
            off += out_dim
        rec["w_off"] = off
        off += in_dim * out_dim
        if init_stds is not None:
            rec["init_std"] = float(init_stds[i])
        layers.append(rec)
    return {"layers": layers, "loss": loss}


def flat_init(init_pytree_fn, seed):
    """Initialize a pytree and return (flat f32[d] numpy, unravel)."""
    params = init_pytree_fn(jax.random.PRNGKey(seed))
    flat, unravel = ravel_pytree(params)
    return np.asarray(flat, dtype=np.float32), unravel


def make_flat_value_and_grad(loss_fn, unravel):
    """Wrap a pytree loss into a flat-parameter (loss, flat_grad) function."""

    def flat_loss(flat, *batch):
        return loss_fn(unravel(flat), *batch)

    def train_fn(flat, *batch):
        loss, grads = jax.value_and_grad(flat_loss)(flat, *batch)
        return loss, grads

    return train_fn
