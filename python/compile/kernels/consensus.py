"""Consensus-coefficient Pallas kernel — the AdaCons aggregation hot-spot.

Given the gradient matrix ``P`` of shape ``(N, D)`` (one row per worker,
``N << D``), AdaCons (Eq. 7 of the paper) needs, per worker ``i``:

* ``dots[i] = <g_i, g_bar>`` with ``g_bar = mean_j g_j``
* ``sqn[i]  = ||g_i||^2``

Both are single-pass reductions over the huge ``D`` axis, so the kernel tiles
``D`` into VMEM-sized blocks of ``TILE_D`` columns and accumulates the
``N``-vector partials across the grid.  On a real TPU each ``(N, TILE_D)``
block is one HBM->VMEM DMA and the ``P_tile @ mean_tile`` contraction maps to
the MXU; here we lower with ``interpret=True`` for the CPU PJRT client.

``gram_matrix`` additionally exposes the full ``P P^T`` Gram accumulation used
by the preconditioner perspective (paper Eq. 9) and by the ablation benches.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default column tile. N is tiny (<= 64), so VMEM usage is dominated by the
# (N, TILE_D) input tile: 64 * 8192 * 4B = 2 MiB, comfortably inside the
# ~16 MiB VMEM budget with double-buffering headroom.
DEFAULT_TILE_D = 8192


def _consensus_kernel(p_ref, dots_ref, sqn_ref):
    """Accumulate per-worker <g_i, g_bar> and ||g_i||^2 over one D tile."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        sqn_ref[...] = jnp.zeros_like(sqn_ref)

    p = p_ref[...]  # (N, TILE_D) block in VMEM
    mean_tile = jnp.mean(p, axis=0)  # (TILE_D,)
    # (N, TILE_D) @ (TILE_D,) -> (N,): MXU-friendly contraction in f32.
    dots_ref[...] += jnp.dot(p, mean_tile, preferred_element_type=jnp.float32)
    sqn_ref[...] += jnp.sum(p * p, axis=1).astype(jnp.float32)


def _gram_kernel(p_ref, gram_ref):
    """Accumulate the N x N Gram matrix P P^T over one D tile."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)

    p = p_ref[...]
    gram_ref[...] += jnp.dot(p, p.T, preferred_element_type=jnp.float32)


def _pad_cols(p, tile_d):
    """Zero-pad the D axis up to a multiple of tile_d (zeros are reduction
    identities for both the dot and the squared-norm accumulators)."""
    n, d = p.shape
    rem = d % tile_d
    if rem == 0:
        return p, d
    pad = tile_d - rem
    return jnp.pad(p, ((0, 0), (0, pad))), d + pad


@functools.partial(jax.jit, static_argnames=("tile_d",))
def consensus_stats(p, tile_d=DEFAULT_TILE_D):
    """Per-worker consensus statistics for AdaCons Eq. 7.

    Args:
      p: ``f32[N, D]`` worker-gradient matrix.
      tile_d: column tile size (static).

    Returns:
      ``(dots, sqn)``: ``dots[i] = <g_i, mean_j g_j>`` and
      ``sqn[i] = ||g_i||^2``, both ``f32[N]``.
    """
    p = p.astype(jnp.float32)
    n, _ = p.shape
    tile_d = min(tile_d, p.shape[1]) if p.shape[1] > 0 else 1
    p_padded, d_padded = _pad_cols(p, tile_d)
    grid = (d_padded // tile_d,)
    dots, sqn = pl.pallas_call(
        _consensus_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, tile_d), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(p_padded)
    return dots, sqn


@functools.partial(jax.jit, static_argnames=("tile_d",))
def gram_matrix(p, tile_d=DEFAULT_TILE_D):
    """Full Gram matrix ``P P^T`` (``f32[N, N]``), tiled over D."""
    p = p.astype(jnp.float32)
    n, _ = p.shape
    tile_d = min(tile_d, p.shape[1]) if p.shape[1] > 0 else 1
    p_padded, d_padded = _pad_cols(p, tile_d)
    grid = (d_padded // tile_d,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(p_padded)
