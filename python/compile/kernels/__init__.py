"""L1 Pallas kernels (build-time only; lowered into the model HLO).

All kernels are authored for TPU-style tiling (VMEM-sized blocks feeding an
MXU-friendly contraction) but lowered with ``interpret=True`` so the PJRT CPU
client can execute the resulting HLO. See DESIGN.md §Hardware-Adaptation.
"""

from .consensus import consensus_stats, gram_matrix
from .weighted_sum import weighted_sum
from .fused_linear import fused_linear

__all__ = ["consensus_stats", "gram_matrix", "weighted_sum", "fused_linear"]
