"""Pure-jnp oracles for every Pallas kernel — the build-time correctness bar.

pytest (python/tests/) sweeps shapes and dtypes with hypothesis and asserts
``assert_allclose(kernel(...), ref(...))`` for each pair below.
"""

import jax
import jax.numpy as jnp


def consensus_stats_ref(p):
    """Reference for kernels.consensus.consensus_stats."""
    p = p.astype(jnp.float32)
    g_bar = jnp.mean(p, axis=0)
    dots = p @ g_bar
    sqn = jnp.sum(p * p, axis=1)
    return dots, sqn


def gram_matrix_ref(p):
    """Reference for kernels.consensus.gram_matrix."""
    p = p.astype(jnp.float32)
    return p @ p.T


def weighted_sum_ref(gamma, p):
    """Reference for kernels.weighted_sum.weighted_sum."""
    return gamma.astype(jnp.float32) @ p.astype(jnp.float32)


def fused_linear_ref(x, w, b, activation="none"):
    """Reference for kernels.fused_linear.fused_linear."""
    y = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    if activation == "none":
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(y)
    if activation == "tanh":
        return jnp.tanh(y)
    raise ValueError(activation)


def adacons_weights_ref(p, lam=None):
    """End-to-end oracle for the AdaCons coefficient pipeline (Eq. 7/12/13).

    Returns the per-worker weights ``gamma`` such that the aggregated update
    is ``sum_i gamma_i g_i``.  With ``lam=None`` the sum-one normalization of
    Eq. 13 is applied; otherwise the raw Eq. 8 weights (scaled by ``lam``)
    are returned.  Used by the Rust integration goldens as well.
    """
    p = p.astype(jnp.float64)
    n = p.shape[0]
    g_bar = jnp.mean(p, axis=0)
    dots = p @ g_bar  # <g_i, g_bar>
    sqn = jnp.sum(p * p, axis=1)
    if lam is not None:
        # Raw Eq. 8: w_{t+1} = w_t - lam*eta/N * sum_i dots_i/sqn_i * g_i.
        return (lam / n) * dots / sqn
    # Eq. 13: lambda normalizes the subspace coefficients alpha_i =
    # dots_i/||g_i|| to sum one; the re-projection then divides by ||g_i||
    # once more, giving gamma_i = lambda * dots_i / ||g_i||^2 (Eq. 12).
    lam_star = 1.0 / jnp.sum(dots / jnp.sqrt(sqn))
    return lam_star * dots / sqn
