"""Fused linear + bias + activation Pallas kernel (model-side hot-spot).

``fused_linear(x, w, b, activation)`` computes ``act(x @ w + b)`` with the
output feature axis tiled so each grid step holds ``x`` (B, I), one weight
slab (I, TILE_O) and one output slab (B, TILE_O) in VMEM, contracting on the
MXU in f32.  Used by the transformer MLP block and the DLRM tower (L2),
which makes every model HLO carry a real Pallas region.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTIVATIONS = {
    "none": lambda y: y,
    "relu": lambda y: jnp.maximum(y, 0.0),
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
}

DEFAULT_TILE_O = 256


def _make_kernel(activation):
    act = _ACTIVATIONS[activation]

    def _kernel(x_ref, w_ref, b_ref, o_ref):
        x = x_ref[...]  # (B, I)
        w = w_ref[...]  # (I, TILE_O)
        b = b_ref[...]  # (TILE_O,)
        y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
        o_ref[...] = act(y)

    return _kernel


def _fused_linear_impl(x, w, b, activation, tile_o):
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    b = b.astype(jnp.float32)
    bdim, idim = x.shape
    _, odim = w.shape
    tile_o = min(tile_o, odim) if odim > 0 else 1
    rem = odim % tile_o
    pad = 0 if rem == 0 else tile_o - rem
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, pad),))
    o_padded = odim + pad
    grid = (o_padded // tile_o,)
    out = pl.pallas_call(
        _make_kernel(activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bdim, idim), lambda i: (0, 0)),
            pl.BlockSpec((idim, tile_o), lambda i: (0, i)),
            pl.BlockSpec((tile_o,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bdim, tile_o), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((bdim, o_padded), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out[:, :odim]


# pallas_call does not define a VJP; give the kernel one explicitly so L2
# models can differentiate through it: Pallas forward, rematerialized
# XLA-matmul backward (z = x@w+b is recomputed rather than saved, trading
# one matmul for O(B*O) residual memory — the standard remat choice).
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def fused_linear(x, w, b, activation="none", tile_o=DEFAULT_TILE_O):
    """``act(x @ w + b)`` with output-feature tiling.

    Args:
      x: ``f32[B, I]`` activations.
      w: ``f32[I, O]`` weights.
      b: ``f32[O]`` bias.
      activation: one of ``none|relu|gelu|tanh`` (static).
      tile_o: output-feature tile (static); O is zero-padded to a multiple.
    """
    return _fused_linear_impl(x, w, b, activation, tile_o)


def _fused_linear_fwd(x, w, b, activation, tile_o):
    y = _fused_linear_impl(x, w, b, activation, tile_o)
    return y, (x, w, b)


def _fused_linear_bwd(activation, tile_o, res, dy):
    x, w, b = res
    act = _ACTIVATIONS[activation]
    z = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    _, act_vjp = jax.vjp(act, z)
    (dz,) = act_vjp(dy.astype(jnp.float32))
    dx = dz @ w.T
    dw = x.T @ dz
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
