"""Weighted gradient re-projection Pallas kernel (AdaCons Eq. 12).

Computes ``out = sum_i gamma_i * g_i = gamma @ P`` for ``P`` of shape
``(N, D)``.  Tiled over the D axis; each grid step DMAs one ``(N, TILE_D)``
block plus the tiny ``gamma`` vector into VMEM and emits a ``TILE_D`` output
slab, so the kernel is purely bandwidth-bound (arithmetic intensity 2N flops
per 4N bytes read) — see DESIGN.md §9 for the roofline estimate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .consensus import DEFAULT_TILE_D, _pad_cols


def _wsum_kernel(gamma_ref, p_ref, out_ref):
    gamma = gamma_ref[...]  # (N,)
    p = p_ref[...]  # (N, TILE_D)
    out_ref[...] = jnp.dot(gamma, p, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_d",))
def weighted_sum(gamma, p, tile_d=DEFAULT_TILE_D):
    """``f32[D]`` weighted combination ``sum_i gamma[i] * p[i, :]``."""
    p = p.astype(jnp.float32)
    gamma = gamma.astype(jnp.float32)
    n, d = p.shape
    tile_d = min(tile_d, d) if d > 0 else 1
    p_padded, d_padded = _pad_cols(p, tile_d)
    grid = (d_padded // tile_d,)
    out = pl.pallas_call(
        _wsum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, tile_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_padded,), jnp.float32),
        interpret=True,
    )(gamma, p_padded)
    return out[:d]
