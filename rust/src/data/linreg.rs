//! Stochastic linear-regression stream (paper Eq. 14): ζ ~ U[0,1]^d.

use super::{Array, Batch, DataGen};
use crate::util::prng::Rng;

pub struct LinRegGen {
    rng: Rng,
    dim: usize,
}

impl LinRegGen {
    pub fn new(rng: Rng, dim: usize) -> Self {
        LinRegGen { rng, dim }
    }
}

impl DataGen for LinRegGen {
    fn next_batch(&mut self, b: usize) -> Batch {
        let mut x = vec![0.0f32; b * self.dim];
        self.rng.fill_uniform_f32(&mut x);
        vec![Array::F32(x, vec![b, self.dim])]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values_are_uniform_01() {
        let mut g = LinRegGen::new(Rng::new(0), 32);
        let batch = g.next_batch(64);
        let x = batch[0].as_f32().unwrap();
        assert_eq!(x.len(), 64 * 32);
        assert!(x.iter().all(|&v| (0.0..1.0).contains(&v)));
        let mean: f32 = x.iter().sum::<f32>() / x.len() as f32;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }
}
