//! Synthetic click-through-rate stream — the Criteo/DLRM stand-in.
//!
//! Categorical ids are Zipf-distributed per field (long-tail ids, the
//! regime embedding tables face); the label comes from a **planted
//! logistic teacher** over per-field id weights + dense features, so AUC
//! has a real ceiling the model can climb toward.

use super::{Array, Batch, DataGen};
use crate::util::prng::Rng;

pub struct CtrGen {
    rng: Rng,
    field_w: Vec<f32>, // (fields, vocab) teacher weights
    dense_w: Vec<f32>, // (dense,)
    fields: usize,
    vocab: usize,
    dense: usize,
    zipf_s: f64,
    bias: f32,
}

impl CtrGen {
    pub fn new(task_seed: u64, rng: Rng, fields: usize, vocab: usize, dense: usize) -> Self {
        let mut task_rng = Rng::new(task_seed ^ 0xC7_12AB);
        let mut field_w = vec![0.0f32; fields * vocab];
        task_rng.fill_normal_f32(&mut field_w, 0.8);
        let mut dense_w = vec![0.0f32; dense];
        task_rng.fill_normal_f32(&mut dense_w, 0.5);
        CtrGen {
            rng,
            field_w,
            dense_w,
            fields,
            vocab,
            dense,
            zipf_s: 1.1,
            bias: -0.3,
        }
    }
}

impl DataGen for CtrGen {
    fn next_batch(&mut self, b: usize) -> Batch {
        let mut cat = vec![0i32; b * self.fields];
        let mut dense_x = vec![0.0f32; b * self.dense];
        let mut y = vec![0.0f32; b];
        for i in 0..b {
            let mut logit = self.bias;
            for f in 0..self.fields {
                let id = self.rng.zipf(self.vocab as u64, self.zipf_s) as usize;
                cat[i * self.fields + f] = id as i32;
                logit += self.field_w[f * self.vocab + id];
            }
            for j in 0..self.dense {
                let v = self.rng.normal_f32(1.0);
                dense_x[i * self.dense + j] = v;
                logit += v * self.dense_w[j];
            }
            let p = 1.0 / (1.0 + (-logit as f64).exp());
            y[i] = if self.rng.uniform() < p { 1.0 } else { 0.0 };
        }
        vec![
            Array::I32(cat, vec![b, self.fields]),
            Array::F32(dense_x, vec![b, self.dense]),
            Array::F32(y, vec![b]),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_range() {
        let mut g = CtrGen::new(1, Rng::new(1).fork(0), 4, 100, 8);
        let batch = g.next_batch(32);
        assert_eq!(batch[0].shape(), &[32, 4]);
        assert_eq!(batch[1].shape(), &[32, 8]);
        assert_eq!(batch[2].shape(), &[32]);
        let y = batch[2].as_f32().unwrap();
        assert!(y.iter().all(|&v| v == 0.0 || v == 1.0));
        let cat = batch[0].as_i32().unwrap();
        assert!(cat.iter().all(|&c| (0..100).contains(&c)));
    }

    #[test]
    fn teacher_makes_labels_predictable() {
        // The teacher's own logit must rank positives above negatives
        // (AUC >> 0.5) — i.e. the planted signal exists.
        let mut g = CtrGen::new(2, Rng::new(2).fork(0), 4, 100, 8);
        let batch = g.next_batch(600);
        let cat = batch[0].as_i32().unwrap();
        let dense = batch[1].as_f32().unwrap();
        let y = batch[2].as_f32().unwrap();
        let mut scored: Vec<(f32, f32)> = (0..600)
            .map(|i| {
                let mut logit = g.bias;
                for f in 0..4 {
                    logit += g.field_w[f * 100 + cat[i * 4 + f] as usize];
                }
                for j in 0..8 {
                    logit += dense[i * 8 + j] * g.dense_w[j];
                }
                (logit, y[i])
            })
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        // rank-sum AUC
        let pos: f64 = scored.iter().filter(|s| s.1 > 0.5).count() as f64;
        let neg = 600.0 - pos;
        let mut rank_sum = 0.0f64;
        for (r, s) in scored.iter().enumerate() {
            if s.1 > 0.5 {
                rank_sum += (r + 1) as f64;
            }
        }
        let auc = (rank_sum - pos * (pos + 1.0) / 2.0) / (pos * neg);
        assert!(auc > 0.75, "teacher auc={auc}");
    }

    #[test]
    fn ids_are_long_tailed() {
        let mut g = CtrGen::new(3, Rng::new(3).fork(0), 1, 1000, 1);
        let batch = g.next_batch(2000);
        let cat = batch[0].as_i32().unwrap();
        let head = cat.iter().filter(|&&c| c < 20).count();
        assert!(head > 400, "zipf head mass: {head}");
    }
}
