//! Synthetic token stream — the BERT-pretraining stand-in.
//!
//! Tokens follow a deterministic order-1 structure: with probability
//! `p_pattern` the next token is a fixed affine function of the current one
//! (a learnable bigram rule), otherwise it is a Zipf draw (long-tail
//! unigram noise). A transformer can push the loss well below the unigram
//! entropy by learning the rule — giving the Fig. 6 loss curves a real
//! waterfall + convergence region.

use super::{Array, Batch, DataGen};
use crate::util::prng::Rng;

pub struct TextGen {
    rng: Rng,
    vocab: usize,
    seq: usize,
    mul: u64,
    add: u64,
    p_pattern: f64,
}

impl TextGen {
    pub fn new(task_seed: u64, rng: Rng, vocab: usize, seq: usize) -> Self {
        let mut task_rng = Rng::new(task_seed ^ 0x7E_57ED);
        // Odd multiplier -> bijective map modulo any power-of-two-free vocab;
        // bijectivity is irrelevant, determinism is what matters.
        let mul = task_rng.below(vocab as u64 - 2) * 2 + 1;
        let add = task_rng.below(vocab as u64);
        TextGen {
            rng,
            vocab,
            seq,
            mul,
            add,
            p_pattern: 0.7,
        }
    }

    fn next_token(&mut self, cur: u64) -> u64 {
        if self.rng.uniform() < self.p_pattern {
            (cur.wrapping_mul(self.mul).wrapping_add(self.add)) % self.vocab as u64
        } else {
            self.rng.zipf(self.vocab as u64, 1.05)
        }
    }
}

impl DataGen for TextGen {
    fn next_batch(&mut self, b: usize) -> Batch {
        // Model input is (b, seq+1): inputs = [:, :-1], targets = [:, 1:].
        let w = self.seq + 1;
        let mut toks = vec![0i32; b * w];
        for i in 0..b {
            let mut cur = self.rng.zipf(self.vocab as u64, 1.05);
            toks[i * w] = cur as i32;
            for j in 1..w {
                cur = self.next_token(cur);
                toks[i * w + j] = cur as i32;
            }
        }
        vec![Array::I32(toks, vec![b, w])]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_and_right_shape() {
        let mut g = TextGen::new(1, Rng::new(1).fork(0), 64, 16);
        let batch = g.next_batch(4);
        assert_eq!(batch[0].shape(), &[4, 17]);
        let t = batch[0].as_i32().unwrap();
        assert!(t.iter().all(|&x| (0..64).contains(&x)));
    }

    #[test]
    fn bigram_rule_dominates_transitions() {
        let mut g = TextGen::new(2, Rng::new(2).fork(0), 128, 64);
        let batch = g.next_batch(16);
        let t = batch[0].as_i32().unwrap();
        let w = 65;
        let mut rule_hits = 0;
        let mut total = 0;
        for i in 0..16 {
            for j in 0..64 {
                let cur = t[i * w + j] as u64;
                let nxt = t[i * w + j + 1] as u64;
                let ruled = (cur.wrapping_mul(g.mul).wrapping_add(g.add)) % 128;
                if nxt == ruled {
                    rule_hits += 1;
                }
                total += 1;
            }
        }
        let frac = rule_hits as f64 / total as f64;
        assert!(frac > 0.6, "rule fraction {frac}");
    }

    #[test]
    fn different_tasks_different_rules() {
        let a = TextGen::new(10, Rng::new(10).fork(0), 100, 8);
        let b = TextGen::new(11, Rng::new(11).fork(0), 100, 8);
        assert!(a.mul != b.mul || a.add != b.add);
    }
}
