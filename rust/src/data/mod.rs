//! Synthetic workload generators — the data substrate.
//!
//! The paper trains on MLPerf datasets we cannot ship; each generator here
//! reproduces the *statistical structure the aggregation method actually
//! interacts with*: i.i.d. per-worker shards with controllable inter-worker
//! gradient diversity (sampling noise via local batch size, optional
//! label/feature skew via `heterogeneity`).  See DESIGN.md
//! §Hardware-Adaptation for the substitution argument.

pub mod array;
pub mod classification;
pub mod ctr;
pub mod detection;
pub mod inject;
pub mod linreg;
pub mod text;

pub use array::{Array, Batch};
pub use inject::{GradInjector, StepFault};

use crate::util::prng::Rng;

/// A per-worker batch stream. Implementations are deterministic functions
/// of (task seed, worker rank, draw index).
pub trait DataGen: Send {
    /// Generate the next local batch of `b` examples.
    fn next_batch(&mut self, b: usize) -> Batch;
}

/// Build the generator matching a model family name from the artifact
/// manifest (`linreg`, `mlp_cls`, `det`, `dlrm`, `tfm_sm`, `tfm_md`).
pub fn for_model(
    model: &str,
    task_seed: u64,
    rank: u64,
    heterogeneity: f64,
    meta: &crate::util::json::Json,
) -> Option<Box<dyn DataGen>> {
    let rng = Rng::new(task_seed).fork(rank);
    match model {
        "linreg" => {
            let dim = meta.get("dim").as_usize().unwrap_or(1000);
            Some(Box::new(linreg::LinRegGen::new(rng, dim)))
        }
        "mlp_cls" => {
            let in_dim = meta.get("in_dim").as_usize().unwrap_or(256);
            let classes = meta.get("classes").as_usize().unwrap_or(16);
            Some(Box::new(classification::MixtureGen::new(
                task_seed,
                rng,
                in_dim,
                classes,
                heterogeneity,
            )))
        }
        "det" => {
            let in_dim = meta.get("in_dim").as_usize().unwrap_or(128);
            let classes = meta.get("classes").as_usize().unwrap_or(8);
            Some(Box::new(detection::DetectionGen::new(
                task_seed, rng, in_dim, classes,
            )))
        }
        "dlrm" => {
            let fields = meta.get("fields").as_usize().unwrap_or(8);
            let vocab = meta.get("vocab").as_usize().unwrap_or(1000);
            let dense = meta.get("dense_dim").as_usize().unwrap_or(16);
            Some(Box::new(ctr::CtrGen::new(task_seed, rng, fields, vocab, dense)))
        }
        m if m.starts_with("tfm") => {
            let vocab = meta.get("vocab").as_usize().unwrap_or(512);
            let seq = meta.get("seq").as_usize().unwrap_or(64);
            Some(Box::new(text::TextGen::new(task_seed, rng, vocab, seq)))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn factory_covers_all_models() {
        let meta = Json::parse(r#"{"dim":10,"in_dim":8,"classes":4,"fields":2,"vocab":50,"dense_dim":4,"seq":8}"#).unwrap();
        for m in ["linreg", "mlp_cls", "det", "dlrm", "tfm_sm", "tfm_md"] {
            let mut g = for_model(m, 1, 0, 0.0, &meta).unwrap_or_else(|| panic!("{m}"));
            let batch = g.next_batch(4);
            assert!(!batch.is_empty(), "{m}");
        }
        assert!(for_model("nope", 1, 0, 0.0, &meta).is_none());
    }

    #[test]
    fn ranks_get_distinct_streams() {
        let meta = Json::parse(r#"{"dim":16}"#).unwrap();
        let mut g0 = for_model("linreg", 7, 0, 0.0, &meta).unwrap();
        let mut g1 = for_model("linreg", 7, 1, 0.0, &meta).unwrap();
        let b0 = g0.next_batch(2);
        let b1 = g1.next_batch(2);
        match (&b0[0], &b1[0]) {
            (Array::F32(x0, _), Array::F32(x1, _)) => assert_ne!(x0, x1),
            _ => panic!("expected f32 arrays"),
        }
    }

    #[test]
    fn same_rank_same_seed_reproduces() {
        let meta = Json::parse(r#"{"dim":16}"#).unwrap();
        let mut a = for_model("linreg", 7, 3, 0.0, &meta).unwrap();
        let mut b = for_model("linreg", 7, 3, 0.0, &meta).unwrap();
        match (&a.next_batch(2)[0], &b.next_batch(2)[0]) {
            (Array::F32(x0, _), Array::F32(x1, _)) => assert_eq!(x0, x1),
            _ => panic!(),
        }
    }
}
