//! Synthetic detection stream — the RetinaNet stand-in: region features
//! from a class mixture plus box targets that are a fixed affine function
//! of a latent position vector (so the box head has a learnable signal).

use super::{Array, Batch, DataGen};
use crate::util::prng::Rng;

pub struct DetectionGen {
    rng: Rng,
    prototypes: Vec<f32>, // (classes, dim)
    box_proj: Vec<f32>,   // (dim, 4) fixed projection from features to boxes
    dim: usize,
    classes: usize,
    noise: f32,
}

impl DetectionGen {
    pub fn new(task_seed: u64, rng: Rng, dim: usize, classes: usize) -> Self {
        let mut task_rng = Rng::new(task_seed ^ 0xDE7E_C7ED);
        let mut prototypes = vec![0.0f32; classes * dim];
        task_rng.fill_normal_f32(&mut prototypes, 1.0);
        let mut box_proj = vec![0.0f32; dim * 4];
        task_rng.fill_normal_f32(&mut box_proj, (1.0 / (dim as f32)).sqrt());
        DetectionGen {
            rng,
            prototypes,
            box_proj,
            dim,
            classes,
            noise: 1.0, // prototypes scaled by 1/6: ~90% ceiling for dim=128
        }
    }
}

impl DataGen for DetectionGen {
    fn next_batch(&mut self, b: usize) -> Batch {
        let mut x = vec![0.0f32; b * self.dim];
        let mut y = vec![0i32; b];
        let mut boxes = vec![0.0f32; b * 4];
        for i in 0..b {
            let label = self.rng.below(self.classes as u64) as usize;
            y[i] = label as i32;
            let proto = &self.prototypes[label * self.dim..(label + 1) * self.dim];
            for j in 0..self.dim {
                // prototypes scaled down to keep features ~unit-variance
                x[i * self.dim + j] = proto[j] / 6.0 + self.rng.normal_f32(self.noise);
            }
            // Ground-truth box = projection of the clean feature + jitter.
            for k in 0..4 {
                let mut v = 0.0f32;
                for j in 0..self.dim {
                    v += x[i * self.dim + j] * self.box_proj[j * 4 + k];
                }
                boxes[i * 4 + k] = v + self.rng.normal_f32(0.05);
            }
        }
        vec![
            Array::F32(x, vec![b, self.dim]),
            Array::I32(y, vec![b]),
            Array::F32(boxes, vec![b, 4]),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_three_arrays_with_matching_batch() {
        let mut g = DetectionGen::new(3, Rng::new(3).fork(0), 16, 4);
        let batch = g.next_batch(8);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].shape(), &[8, 16]);
        assert_eq!(batch[1].shape(), &[8]);
        assert_eq!(batch[2].shape(), &[8, 4]);
    }

    #[test]
    fn boxes_are_learnable_function_of_features() {
        // The box target correlates with the projected features: the
        // correlation of target vs projection must be near-perfect.
        let mut g = DetectionGen::new(4, Rng::new(4).fork(0), 32, 4);
        let batch = g.next_batch(64);
        let x = batch[0].as_f32().unwrap();
        let boxes = batch[2].as_f32().unwrap();
        let mut num = 0.0f64;
        let mut den_a = 0.0f64;
        let mut den_b = 0.0f64;
        for i in 0..64 {
            for k in 0..4 {
                let mut proj = 0.0f32;
                for j in 0..32 {
                    proj += x[i * 32 + j] * g.box_proj[j * 4 + k];
                }
                let t = boxes[i * 4 + k];
                num += (proj * t) as f64;
                den_a += (proj * proj) as f64;
                den_b += (t * t) as f64;
            }
        }
        let corr = num / (den_a.sqrt() * den_b.sqrt());
        assert!(corr > 0.95, "corr={corr}");
    }
}
