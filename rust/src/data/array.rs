//! Host arrays exchanged with the PJRT runtime.

/// A typed host array (data, shape).
#[derive(Debug, Clone, PartialEq)]
pub enum Array {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

/// One batch = the model's input arrays, in artifact-manifest order
/// (excluding the leading flat-params input).
pub type Batch = Vec<Array>;

impl Array {
    pub fn shape(&self) -> &[usize] {
        match self {
            Array::F32(_, s) | Array::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Array::F32(d, _) => d.len(),
            Array::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn dtype_str(&self) -> &'static str {
        match self {
            Array::F32(..) => "f32",
            Array::I32(..) => "i32",
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Array::F32(d, _) => Some(d),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Array::I32(d, _) => Some(d),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let a = Array::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(a.shape(), &[2, 2]);
        assert_eq!(a.len(), 4);
        assert_eq!(a.numel(), 4);
        assert_eq!(a.dtype_str(), "f32");
        assert!(a.as_f32().is_some());
        assert!(a.as_i32().is_none());
        let b = Array::I32(vec![1, 2], vec![2]);
        assert_eq!(b.dtype_str(), "i32");
        assert_eq!(b.as_i32().unwrap(), &[1, 2]);
    }
}
