//! Gradient-stream failure injection.
//!
//! The paper motivates adaptive aggregation by "computing errors from the
//! workers or out-of-distribution data samples inducing bad local
//! gradients" (§1) and shows clipping-vs-perturbation behaviour in Fig. 8.
//! An injector wraps one rank's gradient before aggregation.

use crate::util::prng::Rng;

/// What a faulty/noisy worker does to its gradient each step.
#[derive(Debug, Clone, PartialEq)]
pub enum GradInjector {
    /// Healthy worker.
    None,
    /// Byzantine: flips the gradient sign (adversarial ascent).
    SignFlip,
    /// Byzantine: rescales by a large factor.
    Scale(f32),
    /// Sends zeros (crashed accelerator returning stale buffers).
    Zero,
    /// Adds Gaussian noise of the given std (flaky link / ECC errors).
    GaussNoise(f32),
    /// Adds heavy-tailed Student-t noise (dof, scale) — the Fig. 8
    /// perturbed-gradient regime where clipping matters.
    HeavyTail { dof: f64, scale: f32 },
    /// Fires `inner` only with probability `p` per step.
    Intermittent { p: f64, inner: Box<GradInjector> },
    /// Chaos: the rank's compute fails (thread death) exactly at this
    /// step index — deterministic, for replayable fault drills.
    PanicAt(u64),
    /// Chaos: the rank's compute fails with probability `p` per step.
    PanicProb(f64),
    /// Chaos: with probability `p` the rank's reported compute time is
    /// inflated by `factor` (an injected straggler).
    DelayProb { p: f64, factor: f64 },
    /// Chaos: with probability `p` the rank ships an all-NaN gradient
    /// (corrupted buffers) — the krum filter's target.
    NanProb(f64),
}

/// A process-level fault decision for one step, drawn *before* the
/// gradient is computed ([`GradInjector::step_fault`]). Value-independent:
/// probability-based variants draw exactly one uniform per step whether or
/// not they fire, so a replayed RNG stream stays aligned.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepFault {
    /// No process-level fault this step.
    None,
    /// The rank's compute fails this step.
    Panic,
    /// The rank's compute time is multiplied by this factor.
    Delay(f64),
}

impl GradInjector {
    /// Parse `none`, `sign-flip`, `scale:100`, `zero`, `noise:0.5`,
    /// `heavy-tail:2:0.5`, `intermittent:0.1:sign-flip`, and the chaos
    /// forms `panic-at:3`, `panic:0.05`, `delay:0.3:4`, `nan:0.1`.
    pub fn parse(s: &str) -> Option<GradInjector> {
        let parts: Vec<&str> = s.splitn(3, ':').collect();
        match parts.as_slice() {
            ["none"] => Some(GradInjector::None),
            ["sign-flip"] => Some(GradInjector::SignFlip),
            ["zero"] => Some(GradInjector::Zero),
            ["scale", f] => Some(GradInjector::Scale(f.parse().ok()?)),
            ["noise", s] => Some(GradInjector::GaussNoise(s.parse().ok()?)),
            ["heavy-tail", dof, sc] => Some(GradInjector::HeavyTail {
                dof: dof.parse().ok()?,
                scale: sc.parse().ok()?,
            }),
            ["intermittent", p, rest] => Some(GradInjector::Intermittent {
                p: p.parse().ok()?,
                inner: Box::new(GradInjector::parse(rest)?),
            }),
            ["panic-at", s] => Some(GradInjector::PanicAt(s.parse().ok()?)),
            ["panic", p] => Some(GradInjector::PanicProb(p.parse().ok()?)),
            ["delay", p, f] => Some(GradInjector::DelayProb {
                p: p.parse().ok()?,
                factor: f.parse().ok()?,
            }),
            ["nan", p] => Some(GradInjector::NanProb(p.parse().ok()?)),
            _ => None,
        }
    }

    /// Decide this step's process-level fault. Probability-based chaos
    /// variants (`panic:p`, `delay:p:f`) draw exactly one uniform per call
    /// whether or not they fire; every other variant draws nothing. This
    /// keeps the rank's injection RNG stream value-independent, so
    /// checkpoint fast-forward can replay the exact draw count.
    pub fn step_fault(&self, step: u64, rng: &mut Rng) -> StepFault {
        match self {
            GradInjector::PanicAt(s) => {
                if step == *s {
                    StepFault::Panic
                } else {
                    StepFault::None
                }
            }
            GradInjector::PanicProb(p) => {
                if rng.uniform() < *p {
                    StepFault::Panic
                } else {
                    StepFault::None
                }
            }
            GradInjector::DelayProb { p, factor } => {
                if rng.uniform() < *p {
                    StepFault::Delay(*factor)
                } else {
                    StepFault::None
                }
            }
            _ => StepFault::None,
        }
    }

    pub fn apply(&self, grad: &mut [f32], rng: &mut Rng) {
        match self {
            GradInjector::None => {}
            GradInjector::SignFlip => {
                for g in grad.iter_mut() {
                    *g = -*g;
                }
            }
            GradInjector::Scale(f) => {
                for g in grad.iter_mut() {
                    *g *= f;
                }
            }
            GradInjector::Zero => {
                for g in grad.iter_mut() {
                    *g = 0.0;
                }
            }
            GradInjector::GaussNoise(std) => {
                for g in grad.iter_mut() {
                    *g += rng.normal_f32(*std);
                }
            }
            GradInjector::HeavyTail { dof, scale } => {
                for g in grad.iter_mut() {
                    *g += (rng.student_t(*dof) as f32) * scale;
                }
            }
            GradInjector::Intermittent { p, inner } => {
                if rng.uniform() < *p {
                    inner.apply(grad, rng);
                }
            }
            // One uniform per step whether or not it fires (replayable).
            GradInjector::NanProb(p) => {
                if rng.uniform() < *p {
                    for g in grad.iter_mut() {
                        *g = f32::NAN;
                    }
                }
            }
            // Process-level faults: the gradient itself is untouched;
            // `step_fault` owns their RNG draws.
            GradInjector::PanicAt(_)
            | GradInjector::PanicProb(_)
            | GradInjector::DelayProb { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_forms() {
        assert_eq!(GradInjector::parse("none").unwrap(), GradInjector::None);
        assert_eq!(
            GradInjector::parse("sign-flip").unwrap(),
            GradInjector::SignFlip
        );
        assert_eq!(
            GradInjector::parse("scale:8").unwrap(),
            GradInjector::Scale(8.0)
        );
        assert!(matches!(
            GradInjector::parse("heavy-tail:2:0.5").unwrap(),
            GradInjector::HeavyTail { .. }
        ));
        assert!(matches!(
            GradInjector::parse("intermittent:0.5:zero").unwrap(),
            GradInjector::Intermittent { .. }
        ));
        assert!(GradInjector::parse("bogus").is_none());
        assert!(GradInjector::parse("scale:x").is_none());
        assert_eq!(
            GradInjector::parse("panic-at:3").unwrap(),
            GradInjector::PanicAt(3)
        );
        assert_eq!(
            GradInjector::parse("panic:0.05").unwrap(),
            GradInjector::PanicProb(0.05)
        );
        assert_eq!(
            GradInjector::parse("delay:0.3:4").unwrap(),
            GradInjector::DelayProb { p: 0.3, factor: 4.0 }
        );
        assert_eq!(
            GradInjector::parse("nan:0.1").unwrap(),
            GradInjector::NanProb(0.1)
        );
        assert!(GradInjector::parse("panic-at:x").is_none());
        assert!(GradInjector::parse("delay:0.3").is_none());
    }

    #[test]
    fn step_faults_fire_as_specified() {
        let mut rng = Rng::new(7);
        let at = GradInjector::PanicAt(3);
        assert_eq!(at.step_fault(2, &mut rng), StepFault::None);
        assert_eq!(at.step_fault(3, &mut rng), StepFault::Panic);
        assert_eq!(at.step_fault(4, &mut rng), StepFault::None);

        let delay = GradInjector::DelayProb { p: 1.0, factor: 4.0 };
        assert_eq!(delay.step_fault(0, &mut rng), StepFault::Delay(4.0));
        let never = GradInjector::DelayProb { p: 0.0, factor: 4.0 };
        assert_eq!(never.step_fault(0, &mut rng), StepFault::None);

        let mut fired = 0;
        let panic = GradInjector::PanicProb(0.5);
        for s in 0..200 {
            if panic.step_fault(s, &mut rng) == StepFault::Panic {
                fired += 1;
            }
        }
        assert!(fired > 50 && fired < 150, "{fired}");
        // Gradient-only injectors never raise process faults.
        assert_eq!(
            GradInjector::SignFlip.step_fault(0, &mut rng),
            StepFault::None
        );
    }

    #[test]
    fn step_fault_draw_count_is_value_independent() {
        // Two streams with the same seed stay aligned regardless of the
        // step index passed in — one draw per call for prob variants.
        let inj = GradInjector::PanicProb(0.5);
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        for s in 0..50 {
            let _ = inj.step_fault(s, &mut a);
            let _ = inj.step_fault(1000 + s, &mut b);
        }
        assert_eq!(a.uniform(), b.uniform());
        // Deterministic variants draw nothing.
        let mut c = Rng::new(11);
        for s in 0..50 {
            let _ = GradInjector::PanicAt(7).step_fault(s, &mut c);
        }
        let mut d = Rng::new(11);
        assert_eq!(c.uniform(), d.uniform());
    }

    #[test]
    fn nan_injector_poisons_gradient() {
        let inj = GradInjector::NanProb(1.0);
        let mut rng = Rng::new(3);
        let mut g = vec![1.0f32, -2.0];
        inj.apply(&mut g, &mut rng);
        assert!(g.iter().all(|x| x.is_nan()));
        let never = GradInjector::NanProb(0.0);
        let mut g = vec![1.0f32, -2.0];
        never.apply(&mut g, &mut rng);
        assert_eq!(g, vec![1.0, -2.0]);
    }

    #[test]
    fn effects() {
        let mut rng = Rng::new(0);
        let base = vec![1.0f32, -2.0, 3.0];

        let mut g = base.clone();
        GradInjector::SignFlip.apply(&mut g, &mut rng);
        assert_eq!(g, vec![-1.0, 2.0, -3.0]);

        let mut g = base.clone();
        GradInjector::Scale(10.0).apply(&mut g, &mut rng);
        assert_eq!(g, vec![10.0, -20.0, 30.0]);

        let mut g = base.clone();
        GradInjector::Zero.apply(&mut g, &mut rng);
        assert_eq!(g, vec![0.0; 3]);

        let mut g = base.clone();
        GradInjector::GaussNoise(0.1).apply(&mut g, &mut rng);
        assert_ne!(g, base);
    }

    #[test]
    fn intermittent_fires_sometimes() {
        let inj = GradInjector::Intermittent {
            p: 0.5,
            inner: Box::new(GradInjector::Zero),
        };
        let mut rng = Rng::new(1);
        let mut fired = 0;
        for _ in 0..200 {
            let mut g = vec![1.0f32];
            inj.apply(&mut g, &mut rng);
            if g[0] == 0.0 {
                fired += 1;
            }
        }
        assert!(fired > 50 && fired < 150, "{fired}");
    }
}
