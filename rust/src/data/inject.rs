//! Gradient-stream failure injection.
//!
//! The paper motivates adaptive aggregation by "computing errors from the
//! workers or out-of-distribution data samples inducing bad local
//! gradients" (§1) and shows clipping-vs-perturbation behaviour in Fig. 8.
//! An injector wraps one rank's gradient before aggregation.

use crate::util::prng::Rng;

/// What a faulty/noisy worker does to its gradient each step.
#[derive(Debug, Clone, PartialEq)]
pub enum GradInjector {
    /// Healthy worker.
    None,
    /// Byzantine: flips the gradient sign (adversarial ascent).
    SignFlip,
    /// Byzantine: rescales by a large factor.
    Scale(f32),
    /// Sends zeros (crashed accelerator returning stale buffers).
    Zero,
    /// Adds Gaussian noise of the given std (flaky link / ECC errors).
    GaussNoise(f32),
    /// Adds heavy-tailed Student-t noise (dof, scale) — the Fig. 8
    /// perturbed-gradient regime where clipping matters.
    HeavyTail { dof: f64, scale: f32 },
    /// Fires `inner` only with probability `p` per step.
    Intermittent { p: f64, inner: Box<GradInjector> },
}

impl GradInjector {
    /// Parse `none`, `sign-flip`, `scale:100`, `zero`, `noise:0.5`,
    /// `heavy-tail:2:0.5`, `intermittent:0.1:sign-flip`.
    pub fn parse(s: &str) -> Option<GradInjector> {
        let parts: Vec<&str> = s.splitn(3, ':').collect();
        match parts.as_slice() {
            ["none"] => Some(GradInjector::None),
            ["sign-flip"] => Some(GradInjector::SignFlip),
            ["zero"] => Some(GradInjector::Zero),
            ["scale", f] => Some(GradInjector::Scale(f.parse().ok()?)),
            ["noise", s] => Some(GradInjector::GaussNoise(s.parse().ok()?)),
            ["heavy-tail", dof, sc] => Some(GradInjector::HeavyTail {
                dof: dof.parse().ok()?,
                scale: sc.parse().ok()?,
            }),
            ["intermittent", p, rest] => Some(GradInjector::Intermittent {
                p: p.parse().ok()?,
                inner: Box::new(GradInjector::parse(rest)?),
            }),
            _ => None,
        }
    }

    pub fn apply(&self, grad: &mut [f32], rng: &mut Rng) {
        match self {
            GradInjector::None => {}
            GradInjector::SignFlip => {
                for g in grad.iter_mut() {
                    *g = -*g;
                }
            }
            GradInjector::Scale(f) => {
                for g in grad.iter_mut() {
                    *g *= f;
                }
            }
            GradInjector::Zero => {
                for g in grad.iter_mut() {
                    *g = 0.0;
                }
            }
            GradInjector::GaussNoise(std) => {
                for g in grad.iter_mut() {
                    *g += rng.normal_f32(*std);
                }
            }
            GradInjector::HeavyTail { dof, scale } => {
                for g in grad.iter_mut() {
                    *g += (rng.student_t(*dof) as f32) * scale;
                }
            }
            GradInjector::Intermittent { p, inner } => {
                if rng.uniform() < *p {
                    inner.apply(grad, rng);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_forms() {
        assert_eq!(GradInjector::parse("none").unwrap(), GradInjector::None);
        assert_eq!(
            GradInjector::parse("sign-flip").unwrap(),
            GradInjector::SignFlip
        );
        assert_eq!(
            GradInjector::parse("scale:8").unwrap(),
            GradInjector::Scale(8.0)
        );
        assert!(matches!(
            GradInjector::parse("heavy-tail:2:0.5").unwrap(),
            GradInjector::HeavyTail { .. }
        ));
        assert!(matches!(
            GradInjector::parse("intermittent:0.5:zero").unwrap(),
            GradInjector::Intermittent { .. }
        ));
        assert!(GradInjector::parse("bogus").is_none());
        assert!(GradInjector::parse("scale:x").is_none());
    }

    #[test]
    fn effects() {
        let mut rng = Rng::new(0);
        let base = vec![1.0f32, -2.0, 3.0];

        let mut g = base.clone();
        GradInjector::SignFlip.apply(&mut g, &mut rng);
        assert_eq!(g, vec![-1.0, 2.0, -3.0]);

        let mut g = base.clone();
        GradInjector::Scale(10.0).apply(&mut g, &mut rng);
        assert_eq!(g, vec![10.0, -20.0, 30.0]);

        let mut g = base.clone();
        GradInjector::Zero.apply(&mut g, &mut rng);
        assert_eq!(g, vec![0.0; 3]);

        let mut g = base.clone();
        GradInjector::GaussNoise(0.1).apply(&mut g, &mut rng);
        assert_ne!(g, base);
    }

    #[test]
    fn intermittent_fires_sometimes() {
        let inj = GradInjector::Intermittent {
            p: 0.5,
            inner: Box::new(GradInjector::Zero),
        };
        let mut rng = Rng::new(1);
        let mut fired = 0;
        for _ in 0..200 {
            let mut g = vec![1.0f32];
            inj.apply(&mut g, &mut rng);
            if g[0] == 0.0 {
                fired += 1;
            }
        }
        assert!(fired > 50 && fired < 150, "{fired}");
    }
}
