//! Gaussian-mixture classification stream — the synthetic ImageNet stand-in.
//!
//! Class prototypes are drawn once from the **task seed** (shared by all
//! workers so every rank solves the same problem); each example is
//! `prototype[y] + N(0, σ²)`.  `heterogeneity` in (0,1] skews each worker's
//! label distribution toward a rank-specific subset — the knob that widens
//! inter-worker gradient diversity (richer subspace, paper §5.4).

use super::{Array, Batch, DataGen};
use crate::util::prng::Rng;

pub struct MixtureGen {
    rng: Rng,
    prototypes: Vec<f32>, // (classes, dim)
    dim: usize,
    classes: usize,
    heterogeneity: f64,
    rank_bias_class: usize,
    noise: f32,
}

impl MixtureGen {
    pub fn new(task_seed: u64, mut rng: Rng, dim: usize, classes: usize, heterogeneity: f64) -> Self {
        // Prototypes from the shared task stream, NOT the per-rank stream.
        let mut task_rng = Rng::new(task_seed ^ 0xC1A5_5EED);
        let mut prototypes = vec![0.0f32; classes * dim];
        task_rng.fill_normal_f32(&mut prototypes, 1.0);
        let rank_bias_class = rng.below(classes as u64) as usize;
        MixtureGen {
            rng,
            prototypes,
            dim,
            classes,
            heterogeneity,
            rank_bias_class,
            // Separation D/sigma is what sets the Bayes ceiling; with unit
            // noise and prototypes shrunk by 1/8, dim=256 gives ~90% —
            // hard enough that aggregation quality shows in the curves.
            noise: 1.0,
        }
    }
}

impl DataGen for MixtureGen {
    fn next_batch(&mut self, b: usize) -> Batch {
        let mut x = vec![0.0f32; b * self.dim];
        let mut y = vec![0i32; b];
        for i in 0..b {
            let label = if self.rng.uniform() < self.heterogeneity {
                self.rank_bias_class
            } else {
                self.rng.below(self.classes as u64) as usize
            };
            y[i] = label as i32;
            let proto = &self.prototypes[label * self.dim..(label + 1) * self.dim];
            for j in 0..self.dim {
                // prototypes scaled down to keep features ~unit-variance
                x[i * self.dim + j] = proto[j] / 8.0 + self.rng.normal_f32(self.noise);
            }
        }
        vec![
            Array::F32(x, vec![b, self.dim]),
            Array::I32(y, vec![b]),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_in_range_and_prototypes_shared() {
        let a = MixtureGen::new(5, Rng::new(5).fork(0), 16, 4, 0.0);
        let b = MixtureGen::new(5, Rng::new(5).fork(1), 16, 4, 0.0);
        assert_eq!(a.prototypes, b.prototypes); // same task
        let mut g = a;
        let batch = g.next_batch(32);
        let y = batch[1].as_i32().unwrap();
        assert!(y.iter().all(|&l| (0..4).contains(&l)));
    }

    #[test]
    fn heterogeneity_skews_labels() {
        let mut g = MixtureGen::new(5, Rng::new(5).fork(2), 8, 8, 0.9);
        let batch = g.next_batch(200);
        let y = batch[1].as_i32().unwrap();
        let mut counts = [0usize; 8];
        for &l in y {
            counts[l as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        assert!(max > 150, "expected heavy skew, counts={counts:?}");
    }

    #[test]
    fn examples_cluster_around_prototypes() {
        let mut g = MixtureGen::new(9, Rng::new(9).fork(0), 32, 2, 0.0);
        let batch = g.next_batch(64);
        let x = batch[0].as_f32().unwrap();
        let y = batch[1].as_i32().unwrap();
        // Distance to own prototype < distance to the other prototype, on average.
        let (mut own, mut other) = (0.0f64, 0.0f64);
        for i in 0..64 {
            let xi = &x[i * 32..(i + 1) * 32];
            for c in 0..2 {
                let p = &g.prototypes[c * 32..(c + 1) * 32];
                let d: f64 = xi
                    .iter()
                    .zip(p)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum();
                if c == y[i] as usize {
                    own += d;
                } else {
                    other += d;
                }
            }
        }
        assert!(own < other, "own={own} other={other}");
    }
}
