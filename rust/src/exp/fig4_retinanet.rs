//! Fig. 4 — object detection (RetinaNet substitute): Sum vs AdaCons
//! mAP-proxy curves for N ∈ {16, 32} workers.
//!
//! Paper shape: AdaCons converges faster with a +0.7%/+0.2% final gap at
//! 16/32 workers.

use crate::util::error::Result;
use std::sync::Arc;

use super::common;
use crate::config::TrainConfig;
use crate::optim::Schedule;
use crate::runtime::Runtime;
use crate::util::argparse::Args;

pub fn run(rt: Arc<Runtime>, args: &Args) -> Result<()> {
    let out = common::out_dir(args);
    let steps = common::scale_steps(args, 120);
    let workers = args.usize_list_or("workers", &[16, 32])?;
    let seed = args.u64_or("seed", 2)?;

    let mut results = Vec::new();
    for &n in &workers {
        for agg in ["mean", "adacons"] {
            let cfg = TrainConfig {
                artifact: "det_b32".into(),
                workers: n,
                aggregator: agg.into(),
                // Scale-invariant optimizer (see fig3) — the paper's MLPerf
                // baselines use LARS/LAMB/Adam.
                optimizer: "adam".into(),
                schedule: Schedule::WarmupCosine {
                    lr: 0.004,
                    warmup: steps / 10,
                    total: steps,
                    final_frac: 0.05,
                },
                steps,
                eval_every: (steps / 12).max(1),
                eval_batches: 4,
                seed,
                ..TrainConfig::default()
            };
            let res = common::run(rt.clone(), cfg, &format!("N={n} {agg}"))?;
            results.push((format!("N{n}_{agg}"), res));
        }
    }
    let refs: Vec<(String, &crate::coordinator::TrainResult)> =
        results.iter().map(|(n, r)| (n.clone(), r)).collect();
    common::write_loss_curves(out.join("fig4_train_loss.csv"), &refs)?;
    common::write_eval_curves(out.join("fig4_map.csv"), &refs)?;

    println!("final mAP-proxy:");
    for &n in &workers {
        let metric = |agg: &str| {
            results
                .iter()
                .find(|(name, _)| name == &format!("N{n}_{agg}"))
                .and_then(|(_, r)| r.final_metric())
                .unwrap_or(f64::NAN)
        };
        let (m, a) = (metric("mean"), metric("adacons"));
        println!(
            "  N={n:<3} Sum {:.4}  AdaCons {:.4}  (Δ {:+.2}%)",
            m,
            a,
            (a - m) * 100.0
        );
    }
    Ok(())
}
