//! Shared experiment plumbing.

use crate::util::error::Result;
use std::path::PathBuf;
use std::sync::Arc;

use crate::config::TrainConfig;
use crate::coordinator::{TrainResult, Trainer};
use crate::metrics::CsvWriter;
use crate::runtime::Runtime;
use crate::util::argparse::Args;

/// Output directory for results (`--out-dir`, default `results/`).
pub fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("out-dir", "results"))
}

/// Step-count scaling for bigger hosts (`--steps-scale`, default 1.0).
pub fn scale_steps(args: &Args, steps: usize) -> usize {
    let s = args.f64_or("steps-scale", 1.0).unwrap_or(1.0);
    ((steps as f64 * s).round() as usize).max(2)
}

/// Run one config, logging a one-line summary.
pub fn run(rt: Arc<Runtime>, cfg: TrainConfig, tag: &str) -> Result<TrainResult> {
    let t = crate::util::timer::Timer::start();
    let res = Trainer::new(rt, cfg)?.run()?;
    println!(
        "  {tag}: final train loss {:.5}{} [{:.1}s wall, sim {:.3} ms/iter]",
        res.final_train_loss(10),
        res.final_metric()
            .map(|m| format!(", {} {:.4}", res.metric_name, m))
            .unwrap_or_default(),
        t.elapsed_s(),
        res.sim_iter_s * 1e3,
    );
    Ok(res)
}

/// Write per-step training-loss curves: columns (series, step, loss).
pub fn write_loss_curves(
    path: PathBuf,
    curves: &[(String, &TrainResult)],
) -> Result<()> {
    let mut w = CsvWriter::create(&path, &["series", "step", "train_loss"])?;
    for (name, res) in curves {
        for (step, loss) in res.train_loss.iter().enumerate() {
            w.row(&[name.clone(), step.to_string(), format!("{loss}")])?;
        }
    }
    w.flush()?;
    println!("  wrote {path:?}");
    Ok(())
}

/// Write eval-metric curves: columns (series, step, loss, metric).
pub fn write_eval_curves(path: PathBuf, curves: &[(String, &TrainResult)]) -> Result<()> {
    let mut w = CsvWriter::create(&path, &["series", "step", "eval_loss", "metric"])?;
    for (name, res) in curves {
        for p in &res.evals {
            w.row(&[
                name.clone(),
                p.step.to_string(),
                format!("{}", p.outcome.loss),
                format!("{}", p.outcome.metric),
            ])?;
        }
    }
    w.flush()?;
    println!("  wrote {path:?}");
    Ok(())
}
