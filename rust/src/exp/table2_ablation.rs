//! Table 2 — component ablation: Sum / AdaCons (raw Eq. 8) / +Momentum
//! (Eq. 11) / +Normalization (Eq. 13) / both, on the classification
//! (accuracy ↑), recommendation (AUC ↑) and LM (loss ↓) substitutes.
//!
//! Paper shape: Sum < AdaCons < Momentum < Normalization ≤ Moment.&Norm.

use crate::util::error::Result;
use std::sync::Arc;

use super::common;
use crate::config::TrainConfig;
use crate::metrics::CsvWriter;
use crate::optim::Schedule;
use crate::runtime::Runtime;
use crate::util::argparse::Args;

const VARIANTS: &[(&str, &str)] = &[
    ("Sum", "mean"),
    ("AdaCons", "adacons-raw"),
    ("Momentum", "adacons-momentum"),
    ("Normalization", "adacons-norm"),
    ("Moment.&Norm.", "adacons"),
];

pub fn run(rt: Arc<Runtime>, args: &Args) -> Result<()> {
    let out = common::out_dir(args);
    let steps = common::scale_steps(args, 100);
    let seed = args.u64_or("seed", 6)?;
    let mut w = CsvWriter::create(
        out.join("table2_ablation.csv"),
        &["task", "variant", "value", "metric"],
    )?;

    let tasks: Vec<(&str, TrainConfig)> = vec![
        (
            "Imagenet(acc)",
            TrainConfig {
                artifact: "mlp_cls_b32".into(),
                workers: 8,
                optimizer: "adam".into(),
                schedule: Schedule::WarmupCosine {
                    lr: 0.004,
                    warmup: steps / 10,
                    total: steps,
                    final_frac: 0.05,
                },
                steps,
                eval_every: steps - 1,
                eval_batches: 6,
                heterogeneity: 0.3,
                seed,
                ..TrainConfig::default()
            },
        ),
        (
            "DLRM(auc)",
            TrainConfig {
                artifact: "dlrm_b64".into(),
                workers: 8,
                optimizer: "adam".into(),
                schedule: Schedule::WarmupCosine {
                    lr: 0.002,
                    warmup: steps / 10,
                    total: steps,
                    final_frac: 0.1,
                },
                steps,
                eval_every: steps - 1,
                eval_batches: 6,
                seed,
                ..TrainConfig::default()
            },
        ),
        (
            "BERT(loss)",
            TrainConfig {
                artifact: "tfm_sm_b8".into(),
                workers: 4,
                optimizer: "adamw".into(),
                schedule: Schedule::WarmupCosine {
                    lr: 3e-3,
                    warmup: steps / 10,
                    total: steps,
                    final_frac: 0.1,
                },
                steps,
                seed,
                ..TrainConfig::default()
            },
        ),
    ];

    println!(
        "{:<14} {}",
        "Task",
        VARIANTS
            .iter()
            .map(|(label, _)| format!("{label:>14}"))
            .collect::<String>()
    );
    for (task, base_cfg) in tasks {
        let mut row = format!("{task:<14}");
        for (label, agg) in VARIANTS {
            let mut cfg = base_cfg.clone();
            cfg.aggregator = agg.to_string();
            let res = common::run(rt.clone(), cfg, &format!("{task} {label}"))?;
            // Metric: eval metric when available, else final train loss.
            let (value, metric) = match res.final_metric() {
                Some(m) if res.metric_name != "loss" => (m, res.metric_name),
                _ => (res.final_train_loss(10), "loss"),
            };
            row.push_str(&format!("{value:>14.4}"));
            w.row(&[
                task.into(),
                label.to_string(),
                format!("{value}"),
                metric.into(),
            ])?;
        }
        println!("{row}");
    }
    w.flush()?;
    Ok(())
}
