//! Experiment harness: one module per paper figure/table (DESIGN.md §4).
//!
//! Every harness writes `results/<id>*.csv` with the series the paper
//! plots and prints a paper-shaped summary to stdout. Budgets are sized
//! for the single-CPU testbed; `--steps-scale` multiplies all step counts
//! for longer runs on bigger hosts.

pub mod ablation_bucket;
pub mod common;
pub mod fig2_linreg;
pub mod fig3_imagenet;
pub mod fig4_retinanet;
pub mod fig5_dlrm;
pub mod fig6_bert;
pub mod fig7_coeffs;
pub mod fig8_clipping;
pub mod table1_timing;
pub mod table2_ablation;

use crate::util::error::{bail, Result};
use std::sync::Arc;

use crate::runtime::Runtime;
use crate::util::argparse::Args;

pub const FIGURES: &[&str] = &["fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8"];
pub const TABLES: &[&str] = &["table1", "table2", "buckets"];

pub fn run_figure(rt: Arc<Runtime>, id: &str, args: &Args) -> Result<()> {
    match id {
        "fig2" => fig2_linreg::run(rt, args),
        "fig3" => fig3_imagenet::run(rt, args),
        "fig4" => fig4_retinanet::run(rt, args),
        "fig5" => fig5_dlrm::run(rt, args),
        "fig6" => fig6_bert::run(rt, args),
        "fig7" => fig7_coeffs::run(rt, args),
        "fig8" => fig8_clipping::run(rt, args),
        "all" => {
            for f in FIGURES {
                println!("\n=== {f} ===");
                run_figure(rt.clone(), f, args)?;
            }
            Ok(())
        }
        other => bail!("unknown figure {other:?} (known: {FIGURES:?})"),
    }
}

pub fn run_table(rt: Arc<Runtime>, id: &str, args: &Args) -> Result<()> {
    match id {
        "table1" => table1_timing::run(rt, args),
        "table2" => table2_ablation::run(rt, args),
        "buckets" => ablation_bucket::run(rt, args),
        "all" => {
            for t in TABLES {
                println!("\n=== {t} ===");
                run_table(rt.clone(), t, args)?;
            }
            Ok(())
        }
        other => bail!("unknown table {other:?} (known: {TABLES:?})"),
    }
}
