//! Fig. 5 / Fig. 10 — recommendation (DLRM-DCNv2 substitute): Sum vs
//! AdaCons AUC across batch scaling (the paper scales the 64K baseline up
//! to 8x via more workers).
//!
//! Paper shape: AdaCons keeps hitting the AUC target as the effective
//! batch scales; Sum degrades.

use crate::util::error::Result;
use std::sync::Arc;

use super::common;
use crate::config::TrainConfig;
use crate::optim::Schedule;
use crate::runtime::Runtime;
use crate::util::argparse::Args;

pub fn run(rt: Arc<Runtime>, args: &Args) -> Result<()> {
    let out = common::out_dir(args);
    let steps = common::scale_steps(args, 100);
    // Batch scaling 1x/2x/4x/8x via worker count (local batch fixed at 64).
    let workers = args.usize_list_or("workers", &[2, 4, 8, 16])?;
    let seed = args.u64_or("seed", 3)?;

    let mut results = Vec::new();
    for &n in &workers {
        for agg in ["mean", "adacons"] {
            let cfg = TrainConfig {
                artifact: "dlrm_b64".into(),
                workers: n,
                aggregator: agg.into(),
                optimizer: "adam".into(),
                schedule: Schedule::WarmupCosine {
                    lr: 0.002,
                    warmup: steps / 10,
                    total: steps,
                    final_frac: 0.1,
                },
                steps,
                eval_every: (steps / 10).max(1),
                eval_batches: 6,
                seed,
                ..TrainConfig::default()
            };
            let res = common::run(rt.clone(), cfg, &format!("N={n} {agg}"))?;
            results.push((format!("scale{n}x_{agg}"), res));
        }
    }
    let refs: Vec<(String, &crate::coordinator::TrainResult)> =
        results.iter().map(|(n, r)| (n.clone(), r)).collect();
    common::write_eval_curves(out.join("fig5_auc.csv"), &refs)?;
    common::write_loss_curves(out.join("fig5_train_loss.csv"), &refs)?;

    println!("final AUC by batch scale (local batch 64):");
    for &n in &workers {
        let metric = |agg: &str| {
            results
                .iter()
                .find(|(name, _)| name == &format!("scale{n}x_{agg}"))
                .and_then(|(_, r)| r.final_metric())
                .unwrap_or(f64::NAN)
        };
        let (m, a) = (metric("mean"), metric("adacons"));
        println!(
            "  eff_batch={:<5} Sum {:.4}  AdaCons {:.4}  (Δ {:+.4})",
            n * 64,
            m,
            a,
            a - m
        );
    }
    Ok(())
}
