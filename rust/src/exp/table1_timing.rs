//! Table 1 — per-iteration timing, Sum vs AdaCons.
//!
//! Two complementary reproductions:
//! 1. **Measured** — wall-clock per-iteration on this host for each model
//!    artifact (the aggregation overhead on the real hot path).
//! 2. **Simulated** — the α-β cost model at the paper's fabric (100 Gb/s,
//!    32 ranks, MLPerf-scale gradient sizes, with the paper's measured
//!    compute times), which is what reproduces the 1.04–1.05× slowdown,
//!    plus the §5.1 remark that 800 Gb/s makes the overhead negligible.

use crate::util::error::Result;
use std::sync::Arc;

use super::common;
use crate::collective::{CostModel, Topology};
use crate::config::TrainConfig;
use crate::metrics::CsvWriter;
use crate::optim::Schedule;
use crate::runtime::Runtime;
use crate::util::argparse::Args;

/// (task, paper-scale gradient dim, paper Sum-iteration seconds).
const PAPER_TASKS: &[(&str, usize, f64)] = &[
    ("Imagenet/ResNet-50", 25_600_000, 1.08),
    ("RetinaNet", 34_000_000, 2.41),
    ("DLRM/DCNv2", 100_000_000, 1.01),
    ("BERT-Large", 340_000_000, 7.97),
];

pub fn run(rt: Arc<Runtime>, args: &Args) -> Result<()> {
    let out = common::out_dir(args);
    let steps = common::scale_steps(args, 12);
    let mut w = CsvWriter::create(
        out.join("table1_timing.csv"),
        &["kind", "task", "sum_s", "adacons_s", "slowdown"],
    )?;

    // --- measured on this host ---
    println!("measured per-iteration wall time on this host ({steps} steps):");
    let mut engine: Option<crate::parallel::ParPlan> = None;
    for artifact in ["mlp_cls_b32", "det_b32", "dlrm_b64", "tfm_sm_b8"] {
        let mut iter_s = Vec::new();
        for agg in ["mean", "adacons"] {
            let cfg = TrainConfig {
                artifact: artifact.into(),
                workers: 8,
                aggregator: agg.into(),
                optimizer: "sgd".into(),
                schedule: Schedule::Const { lr: 0.01 },
                steps,
                seed: 0,
                ..TrainConfig::default()
            };
            let res = common::run(rt.clone(), cfg, &format!("{artifact} {agg}"))?;
            iter_s.push(res.wall_iter_s);
            if res.agg_par.is_some() {
                engine = res.agg_par;
            }
        }
        let slowdown = iter_s[1] / iter_s[0];
        println!(
            "  {artifact:<14} Sum {:.1}ms  AdaCons {:.1}ms  slowdown {slowdown:.3}x",
            iter_s[0] * 1e3,
            iter_s[1] * 1e3
        );
        w.row(&[
            "measured".into(),
            artifact.into(),
            format!("{}", iter_s[0]),
            format!("{}", iter_s[1]),
            format!("{slowdown}"),
        ])?;
    }

    if let Some(p) = engine {
        println!(
            "  aggregation engine: {} threads x {} shards ({} elems/shard)",
            p.threads, p.shards, p.shard_elems
        );
    }

    // --- simulated at the paper's scale ---
    println!("\nsimulated at paper scale (32 ranks; compute from paper's Sum column):");
    for (gbps, label) in [(100.0, "100 Gb/s"), (800.0, "800 Gb/s")] {
        println!("  fabric {label}:");
        let model = CostModel::from_topology(&Topology::ring_gbps(32, gbps));
        for &(task, d, paper_sum_s) in PAPER_TASKS {
            // compute time = paper iteration minus modeled baseline comm
            let comm_sum = model.sum_iteration_s(d);
            let compute = (paper_sum_s - comm_sum).max(0.0);
            let sum_s = compute + comm_sum;
            let ada_s = compute + model.adacons_iteration_s(d);
            let slowdown = ada_s / sum_s;
            println!(
                "    {task:<20} Sum {sum_s:.2}s  AdaCons {ada_s:.2}s  slowdown {slowdown:.3}x"
            );
            w.row(&[
                format!("simulated_{gbps}gbps"),
                task.into(),
                format!("{sum_s}"),
                format!("{ada_s}"),
                format!("{slowdown}"),
            ])?;
        }
    }
    // --- simulated with DDP-style comm/compute overlap (the deployment
    //     shape; see collective::overlap) ---
    println!("\nsimulated with bucketed overlap (32 buckets):");
    for (gbps, label) in [(100.0, "100 Gb/s"), (800.0, "800 Gb/s")] {
        println!("  fabric {label}:");
        let model = CostModel::from_topology(&Topology::ring_gbps(32, gbps));
        for &(task, d, paper_sum_s) in PAPER_TASKS {
            let comm_sum = model.sum_iteration_s(d);
            let compute = (paper_sum_s - comm_sum).max(0.0);
            let sum_s =
                crate::collective::sum_iteration_overlapped_s(&model, compute, d, 32);
            let ada_s =
                crate::collective::adacons_iteration_overlapped_s(&model, compute, d, 32);
            let slowdown = ada_s / sum_s;
            println!(
                "    {task:<20} Sum {sum_s:.2}s  AdaCons {ada_s:.2}s  slowdown {slowdown:.3}x"
            );
            w.row(&[
                format!("overlap_{gbps}gbps"),
                task.into(),
                format!("{sum_s}"),
                format!("{ada_s}"),
                format!("{slowdown}"),
            ])?;
        }
    }
    w.flush()?;
    println!("\npaper reports 1.04-1.05x at 100 Gb/s and 'negligible' at 800 Gb/s.");
    Ok(())
}
