//! Fig. 3 — image classification (ImageNet/ResNet-50 substitute): Sum vs
//! AdaCons accuracy curves for N ∈ {8, 16, 32} workers.
//!
//! Paper shape: AdaCons converges faster and ends ~1% higher at every N.

use crate::util::error::Result;
use std::sync::Arc;

use super::common;
use crate::config::TrainConfig;
use crate::optim::Schedule;
use crate::runtime::Runtime;
use crate::util::argparse::Args;

pub fn run(rt: Arc<Runtime>, args: &Args) -> Result<()> {
    let out = common::out_dir(args);
    let steps = common::scale_steps(args, 120);
    let workers = args.usize_list_or("workers", &[8, 16, 32])?;
    let seed = args.u64_or("seed", 1)?;

    let mut results = Vec::new();
    for &n in &workers {
        for agg in ["mean", "adacons"] {
            let cfg = TrainConfig {
                artifact: "mlp_cls_b32".into(),
                workers: n,
                aggregator: agg.into(),
                // Scale-invariant optimizer, like the MLPerf baselines the
                // paper rides on (LARS/LAMB/Adam): AdaCons' normalized
                // update has a different magnitude than the mean, and only
                // scale-invariant optimizers make the comparison fair at a
                // shared learning rate.
                optimizer: "adam".into(),
                schedule: Schedule::WarmupCosine {
                    lr: 0.004,
                    warmup: steps / 10,
                    total: steps,
                    final_frac: 0.05,
                },
                steps,
                eval_every: (steps / 12).max(1),
                eval_batches: 4,
                heterogeneity: 0.3, // mild non-i.i.d. shards
                seed,
                ..TrainConfig::default()
            };
            let res = common::run(rt.clone(), cfg, &format!("N={n} {agg}"))?;
            results.push((format!("N{n}_{agg}"), res));
        }
    }
    let refs: Vec<(String, &crate::coordinator::TrainResult)> =
        results.iter().map(|(n, r)| (n.clone(), r)).collect();
    common::write_loss_curves(out.join("fig3_train_loss.csv"), &refs)?;
    common::write_eval_curves(out.join("fig3_accuracy.csv"), &refs)?;

    println!("final accuracy:");
    for &n in &workers {
        let acc = |agg: &str| {
            results
                .iter()
                .find(|(name, _)| name == &format!("N{n}_{agg}"))
                .and_then(|(_, r)| r.final_metric())
                .unwrap_or(f64::NAN)
        };
        let (m, a) = (acc("mean"), acc("adacons"));
        println!(
            "  N={n:<3} Sum {:.4}  AdaCons {:.4}  (Δ {:+.2}%)",
            m,
            a,
            (a - m) * 100.0
        );
    }
    Ok(())
}
