//! Bucket-granularity ablation — the paper's §4 remark: "The aggregation
//! is computed model-wise, while layer-wise aggregation presents similar
//! performance on the tested benchmark."
//!
//! Runs AdaCons model-wise (one bucket) and at several DDP-style bucket
//! capacities (layer-wise stand-in) on the classification task and
//! reports final accuracy side by side, plus per-bucket coefficient
//! dispersion.

use crate::util::error::Result;
use std::sync::Arc;

use super::common;
use crate::config::TrainConfig;
use crate::metrics::CsvWriter;
use crate::optim::Schedule;
use crate::runtime::Runtime;
use crate::util::argparse::Args;

pub fn run(rt: Arc<Runtime>, args: &Args) -> Result<()> {
    let out = common::out_dir(args);
    let steps = common::scale_steps(args, 100);
    let seed = args.u64_or("seed", 7)?;
    let d = rt.manifest.get("mlp_cls_b32")?.param_dim;
    // None = model-wise; capacities chosen to split the MLP into ~2/4/8
    // layer-scale segments.
    let caps: Vec<Option<usize>> = vec![None, Some(d / 2), Some(d / 4), Some(d / 8)];

    let mut w = CsvWriter::create(
        out.join("ablation_bucket.csv"),
        &["buckets", "bucket_cap", "accuracy", "final_loss"],
    )?;
    println!("AdaCons bucket-granularity ablation (mlp_cls, N=8, {steps} steps):");
    for cap in caps {
        let cfg = TrainConfig {
            artifact: "mlp_cls_b32".into(),
            workers: 8,
            aggregator: "adacons".into(),
            optimizer: "adam".into(),
            schedule: Schedule::WarmupCosine {
                lr: 0.004,
                warmup: steps / 10,
                total: steps,
                final_frac: 0.05,
            },
            steps,
            eval_every: steps - 1,
            eval_batches: 6,
            heterogeneity: 0.3,
            bucket_cap: cap,
            seed,
            ..TrainConfig::default()
        };
        let n_buckets = cap.map(|c| d.div_ceil(c)).unwrap_or(1);
        let label = cap
            .map(|c| format!("{n_buckets} buckets (cap {c})"))
            .unwrap_or_else(|| "model-wise".into());
        let res = common::run(rt.clone(), cfg, &label)?;
        let acc = res.final_metric().unwrap_or(f64::NAN);
        w.row(&[
            n_buckets.to_string(),
            cap.map(|c| c.to_string()).unwrap_or_else(|| "inf".into()),
            format!("{acc}"),
            format!("{}", res.final_train_loss(10)),
        ])?;
    }
    w.flush()?;
    println!("  (paper: layer-wise ~= model-wise; expect accuracies within noise)");
    Ok(())
}
