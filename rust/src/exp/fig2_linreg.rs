//! Fig. 2 / Fig. 9 — stochastic linear regression (Eq. 14): Sum vs AdaCons
//! loss curves across worker counts and effective batch sizes, every
//! method given the optimal analytical step size (the paper's protocol).
//!
//! Paper shape to reproduce: AdaCons ≥ Sum everywhere, with the gap
//! growing with N and with batch size (richer subspace).

use crate::util::error::Result;
use std::sync::Arc;

use super::common;
use crate::config::TrainConfig;
use crate::metrics::CsvWriter;
use crate::optim::Schedule;
use crate::runtime::Runtime;
use crate::util::argparse::Args;

pub fn run(rt: Arc<Runtime>, args: &Args) -> Result<()> {
    let out = common::out_dir(args);
    let steps = common::scale_steps(args, 150);
    let workers = args.usize_list_or("workers", &[4, 8, 16, 32])?;
    let local_batches = args.usize_list_or("local-batches", &[16, 64, 128])?;
    // Final losses at the 1e-3 scale are seed-noisy; average several
    // replicates per cell like the paper's figure does.
    let n_seeds = args.usize_or("seeds", 3)? as u64;
    let seed0 = args.u64_or("seed", 0)?;

    let mut curves = CsvWriter::create(
        out.join("fig2_curves.csv"),
        &["workers", "local_batch", "eff_batch", "aggregator", "step", "loss"],
    )?;
    let mut summary = CsvWriter::create(
        out.join("fig2_summary.csv"),
        &["workers", "local_batch", "eff_batch", "aggregator", "final_loss"],
    )?;

    println!(
        "workers x local_batch sweep, {steps} steps x {n_seeds} seeds (optimal analytic step size):"
    );
    for &n in &workers {
        for &b in &local_batches {
            let mut finals = Vec::new();
            for agg in ["mean", "adacons"] {
                let mut seed_finals = Vec::new();
                let mut curve_acc: Vec<f64> = vec![0.0; steps];
                for s in 0..n_seeds {
                    let cfg = TrainConfig {
                        artifact: format!("linreg_b{b}"),
                        workers: n,
                        aggregator: agg.into(),
                        optimizer: "linreg-exact".into(),
                        schedule: Schedule::Const { lr: 0.0 },
                        steps,
                        seed: seed0 + s,
                        ..TrainConfig::default()
                    };
                    let res =
                        common::run(rt.clone(), cfg, &format!("N={n} b={b} {agg} seed{s}"))?;
                    for (step, loss) in res.train_loss.iter().enumerate() {
                        curve_acc[step] += loss / n_seeds as f64;
                    }
                    seed_finals.push(res.final_train_loss(10));
                }
                for (step, loss) in curve_acc.iter().enumerate() {
                    curves.row(&[
                        n.to_string(),
                        b.to_string(),
                        (n * b).to_string(),
                        agg.to_string(),
                        step.to_string(),
                        format!("{loss}"),
                    ])?;
                }
                let fl = crate::util::stats::mean(&seed_finals);
                summary.row(&[
                    n.to_string(),
                    b.to_string(),
                    (n * b).to_string(),
                    agg.to_string(),
                    format!("{fl}"),
                ])?;
                finals.push((agg, fl));
            }
            let ratio = finals[0].1 / finals[1].1;
            println!(
                "  N={n:<3} b={b:<4} eff={:<5} -> Sum/AdaCons final-loss ratio {ratio:.3} {}",
                n * b,
                if ratio >= 1.0 { "(AdaCons wins)" } else { "" }
            );
        }
    }
    curves.flush()?;
    summary.flush()?;
    Ok(())
}
