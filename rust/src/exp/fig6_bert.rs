//! Fig. 6 / Fig. 11 — language-model pretraining (BERT-Large substitute):
//! Sum vs AdaCons training-loss curves in the baseline setting and the
//! 20%-fewer-iterations setting; reports minimum loss and the
//! speedup-to-baseline-minimum (the paper: 3% lower loss, 14% speedup).

use crate::util::error::Result;
use std::sync::Arc;

use super::common;
use crate::config::TrainConfig;
use crate::optim::Schedule;
use crate::runtime::Runtime;
use crate::util::argparse::Args;

pub fn run(rt: Arc<Runtime>, args: &Args) -> Result<()> {
    let out = common::out_dir(args);
    let base_steps = common::scale_steps(args, 140);
    let workers = args.usize_or("workers", 4)?;
    let seed = args.u64_or("seed", 4)?;

    let make = |agg: &str, steps: usize| TrainConfig {
        artifact: "tfm_sm_b8".into(),
        workers,
        aggregator: agg.into(),
        optimizer: "adamw".into(),
        schedule: Schedule::WarmupCosine {
            lr: 3e-3,
            warmup: steps / 10,
            total: steps,
            final_frac: 0.1,
        },
        steps,
        seed,
        ..TrainConfig::default()
    };

    let mut all = Vec::new();
    for (setting, steps) in [("full", base_steps), ("short", base_steps * 4 / 5)] {
        let mut min_losses = Vec::new();
        for agg in ["mean", "adacons"] {
            let res = common::run(rt.clone(), make(agg, steps), &format!("{setting} {agg}"))?;
            min_losses.push((
                agg,
                res.train_loss.iter().cloned().fold(f64::INFINITY, f64::min),
            ));
            all.push((format!("{setting}_{agg}"), res));
        }
        println!(
            "  {setting}: min loss Sum {:.4} vs AdaCons {:.4}",
            min_losses[0].1, min_losses[1].1
        );
        // Speedup: steps AdaCons needs to reach Sum's final (EMA) loss.
        let sum_res = &all[all.len() - 2].1;
        let ada_res = &all[all.len() - 1].1;
        let target = sum_res.final_train_loss(10);
        if let Some(s) = ada_res.steps_to_loss(target) {
            println!(
                "  {setting}: AdaCons reaches Sum's final loss at step {s}/{} ({:.0}% speedup)",
                steps,
                100.0 * (1.0 - s as f64 / steps as f64)
            );
        }
    }
    let refs: Vec<(String, &crate::coordinator::TrainResult)> =
        all.iter().map(|(n, r)| (n.clone(), r)).collect();
    common::write_loss_curves(out.join("fig6_loss.csv"), &refs)?;
    Ok(())
}
