//! Fig. 7 — subspace-coefficient statistics (mean ± std) at the three
//! pipeline stages: (a) raw first-order coefficients, (b) after the sorted
//! EMA momentum, (c) after the unbiasing normalization — logged from the
//! detection task like the paper.

use crate::util::error::Result;
use std::sync::Arc;

use super::common;
use crate::config::TrainConfig;
use crate::metrics::CsvWriter;
use crate::optim::Schedule;
use crate::runtime::Runtime;
use crate::util::argparse::Args;

pub fn run(rt: Arc<Runtime>, args: &Args) -> Result<()> {
    let out = common::out_dir(args);
    let steps = common::scale_steps(args, 100);
    let workers = args.usize_or("workers", 16)?;

    let cfg = TrainConfig {
        artifact: "det_b32".into(),
        workers,
        aggregator: "adacons".into(),
        optimizer: "adam".into(),
        schedule: Schedule::WarmupCosine {
            lr: 0.004,
            warmup: steps / 10,
            total: steps,
            final_frac: 0.05,
        },
        steps,
        log_every: 1, // capture coefficient stages every step
        seed: args.u64_or("seed", 2)?,
        ..TrainConfig::default()
    };
    let res = common::run(rt, cfg, &format!("N={workers} adacons"))?;

    let mut w = CsvWriter::create(
        out.join("fig7_coeff_stages.csv"),
        &[
            "step",
            "raw_mean",
            "raw_std",
            "momentum_mean",
            "momentum_std",
            "final_mean",
            "final_std",
        ],
    )?;
    for (step, st) in &res.coeff_log {
        w.row(&[step.to_string(), st.csv_row()].join(",").split(',').map(String::from).collect::<Vec<_>>())?;
    }
    w.flush()?;

    // Paper-shaped summary: the EMA shrinks step-to-step std; the
    // normalization rescales means to ~1/N.
    let avg = |f: fn(&crate::aggregation::CoeffStages) -> f64| {
        crate::util::stats::mean(&res.coeff_log.iter().map(|(_, s)| f(s)).collect::<Vec<_>>())
    };
    println!(
        "  stage averages over {} steps: raw mean {:.4} std {:.4} | momentum std {:.4} | final mean {:.4} std {:.4}",
        res.coeff_log.len(),
        avg(|s| s.raw_mean),
        avg(|s| s.raw_std),
        avg(|s| s.momentum_std.unwrap_or(f64::NAN)),
        avg(|s| s.final_mean),
        avg(|s| s.final_std),
    );
    println!("  (expect final_mean ≈ 1/N = {:.4})", 1.0 / workers as f64);
    Ok(())
}
