//! Fig. 8 — transformer training under perturbed gradients, with and
//! without global-norm clipping (ViT-32 substitute).
//!
//! Paper shape: with clipping both methods are close; removing clipping
//! under heavy-tailed gradient noise is catastrophic for Sum but AdaCons
//! absorbs it (its consensus weights already damp the outlier worker),
//! flipping the ranking decisively toward AdaCons (paper: +5.26% top-1).

use crate::util::error::Result;
use std::sync::Arc;

use super::common;
use crate::config::TrainConfig;
use crate::data::GradInjector;
use crate::optim::Schedule;
use crate::runtime::Runtime;
use crate::util::argparse::Args;

pub fn run(rt: Arc<Runtime>, args: &Args) -> Result<()> {
    let out = common::out_dir(args);
    let steps = common::scale_steps(args, 100);
    let workers = args.usize_or("workers", 8)?;
    let seed = args.u64_or("seed", 5)?;

    // Two of the eight workers emit heavy-tailed perturbed gradients —
    // the "perturbed gradients" regime of §5.4.
    let injectors = vec![
        (
            0usize,
            GradInjector::Intermittent {
                p: 0.25,
                inner: Box::new(GradInjector::HeavyTail {
                    dof: 2.0,
                    scale: 0.02,
                }),
            },
        ),
        (
            1usize,
            GradInjector::Intermittent {
                p: 0.25,
                inner: Box::new(GradInjector::Scale(8.0)),
            },
        ),
    ];

    let mut all = Vec::new();
    for (clip_name, clip) in [("clip", Some(1.0)), ("noclip", None)] {
        for agg in ["mean", "adacons"] {
            let cfg = TrainConfig {
                artifact: "tfm_sm_b8".into(),
                workers,
                aggregator: agg.into(),
                optimizer: "adamw".into(),
                schedule: Schedule::WarmupCosine {
                    lr: 3e-3,
                    warmup: steps / 5, // the paper's long warmup
                    total: steps,
                    final_frac: 0.1,
                },
                steps,
                clip,
                injectors: injectors.clone(),
                seed,
                ..TrainConfig::default()
            };
            let res = common::run(rt.clone(), cfg, &format!("{clip_name} {agg}"))?;
            all.push((format!("{clip_name}_{agg}"), res));
        }
    }
    let refs: Vec<(String, &crate::coordinator::TrainResult)> =
        all.iter().map(|(n, r)| (n.clone(), r)).collect();
    common::write_loss_curves(out.join("fig8_loss.csv"), &refs)?;

    println!("final train loss (lower is better):");
    for clip_name in ["clip", "noclip"] {
        let f = |agg: &str| {
            all.iter()
                .find(|(n, _)| n == &format!("{clip_name}_{agg}"))
                .map(|(_, r)| r.final_train_loss(10))
                .unwrap_or(f64::NAN)
        };
        println!(
            "  {clip_name:>7}: Sum {:.4}  AdaCons {:.4}",
            f("mean"),
            f("adacons")
        );
    }
    Ok(())
}
