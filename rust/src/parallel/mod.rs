//! Parallel sharded execution engine for the L3 aggregation hot path.
//!
//! Three pieces (see EXPERIMENTS.md §Perf):
//!
//! * [`WorkerPool`] — a persistent std-only scoped thread pool; the
//!   trainer builds it once and reuses it every step.
//! * [`plan_shards`] — a deterministic column-shard planner aligned to the
//!   serial kernels' `CHUNK` grid; the plan never depends on the thread
//!   count, so partial reductions have a fixed shape at any parallelism.
//! * [`ParallelCtx`] — policy + pool, with the two execution primitives
//!   every aggregator is built from: [`ParallelCtx::map_reduce`]
//!   (per-shard partials folded by a fixed-order pairwise tree — bitwise
//!   reproducible regardless of threads) and
//!   [`ParallelCtx::for_each_out_shard`] (disjoint output slices, one per
//!   shard, trivially order-independent).
//! * [`task`] — non-blocking submission ([`TaskScope::submit`] +
//!   [`TaskHandle::join`]) layered on the same pool, used by the
//!   pipelined step executor to overlap per-bucket aggregation work with
//!   gradient arrival.

pub mod plan;
pub mod pool;
pub mod task;

pub use plan::{plan_shards, shard_elems, MAX_SHARDS};
pub use pool::{Job, WorkerPool};
pub use task::{TaskHandle, TaskScope};

/// Default minimum shard width: 64K f32 columns = 256 KiB per worker row
/// slice, big enough that queue traffic is noise next to the member work.
pub const DEFAULT_MIN_SHARD_ELEMS: usize = 64 * 1024;

/// User-facing knobs for the engine (config surface: `par_threads`,
/// `par_min_shard_elems`; CLI: `--par-threads`, `--par-min-shard-elems`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelPolicy {
    /// Compute lanes; 0 = auto (all available cores).
    pub threads: usize,
    /// Minimum columns per shard (rounded up to the kernel CHUNK).
    pub min_shard_elems: usize,
}

impl Default for ParallelPolicy {
    fn default() -> Self {
        ParallelPolicy {
            threads: 0,
            min_shard_elems: DEFAULT_MIN_SHARD_ELEMS,
        }
    }
}

impl ParallelPolicy {
    /// Single-lane policy (the default for standalone library calls).
    pub fn serial() -> Self {
        ParallelPolicy {
            threads: 1,
            ..ParallelPolicy::default()
        }
    }

    /// `threads` with 0 resolved to the host's available parallelism.
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        }
    }
}

/// What the engine actually chose for a range — recorded in `AggInfo` so
/// timing harnesses (exp/table1) can report it next to the numbers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParPlan {
    pub threads: usize,
    pub shards: usize,
    pub shard_elems: usize,
}

/// A policy bound to a live pool: the execution context threaded through
/// `Aggregator::aggregate_ctx` and the `GradSet` kernels.
///
/// The pool is behind an `Arc` so the context is `Clone`: the trainer
/// builds one pool and hands a clone to every rank thread, and all ranks
/// shard their backward over the same lanes (`WorkerPool::run_scope` is
/// safe under concurrent scopes — callers drain each other's jobs, the
/// shared pending counter only makes a scope wait a little longer).
pub struct ParallelCtx {
    policy: ParallelPolicy,
    pool: std::sync::Arc<WorkerPool>,
}

impl Clone for ParallelCtx {
    fn clone(&self) -> ParallelCtx {
        ParallelCtx {
            policy: self.policy,
            pool: std::sync::Arc::clone(&self.pool),
        }
    }
}

impl ParallelCtx {
    pub fn new(policy: ParallelPolicy) -> ParallelCtx {
        let pool = std::sync::Arc::new(WorkerPool::new(policy.resolved_threads()));
        ParallelCtx { policy, pool }
    }

    /// One-lane context; jobs run inline on the caller. Cheap to build
    /// (no threads are spawned), used by the serial convenience wrappers.
    pub fn serial() -> ParallelCtx {
        ParallelCtx::new(ParallelPolicy::serial())
    }

    pub fn policy(&self) -> ParallelPolicy {
        self.policy
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The shard plan this context produces for `[lo, hi)`.
    pub fn plan(&self, lo: usize, hi: usize) -> Vec<(usize, usize)> {
        plan_shards(lo, hi, self.policy.min_shard_elems)
    }

    /// Plan summary for a `d`-column range (AggInfo reporting).
    pub fn par_plan(&self, d: usize) -> ParPlan {
        let shards = self.plan(0, d);
        ParPlan {
            threads: self.threads(),
            shards: shards.len(),
            shard_elems: shards.first().map(|&(a, b)| b - a).unwrap_or(0),
        }
    }

    /// Run pre-built jobs on the pool (blocks until all finish).
    pub fn run<'scope>(&self, jobs: Vec<Job<'scope>>) {
        self.pool.run_scope(jobs);
    }

    /// Open a non-blocking submission window on the pool (see
    /// [`task::TaskScope::submit`]): the pipelined executor hands each
    /// ready bucket's aggregation work to the pool here and keeps
    /// processing later buckets while it runs.
    pub fn task_scope<'env, F, R>(&'env self, f: F) -> R
    where
        F: for<'scope> FnOnce(&'scope TaskScope<'scope, 'env>) -> R,
    {
        self.pool.task_scope(f)
    }

    /// Policy for work running *inside* a submitted task: one lane (a
    /// nested fan-out from a pool worker would deadlock the pool), same
    /// `min_shard_elems` so the shard plan — and therefore the fixed-order
    /// partial reduction — is bit-identical to this context's.
    pub fn intra_task_policy(&self) -> ParallelPolicy {
        ParallelPolicy {
            threads: 1,
            min_shard_elems: self.policy.min_shard_elems,
        }
    }

    /// Map every shard of `[lo, hi)` to a partial value (in parallel),
    /// then fold the partials with a **fixed-shape pairwise tree** over
    /// the shard index. The tree shape depends only on the shard plan, so
    /// the folded result is bitwise-identical at every thread count.
    /// Returns `None` for an empty range.
    pub fn map_reduce<T, M, R>(&self, lo: usize, hi: usize, map: M, combine: R) -> Option<T>
    where
        T: Send,
        M: Fn(usize, usize) -> T + Sync,
        R: Fn(T, T) -> T,
    {
        let shards = self.plan(lo, hi);
        if shards.is_empty() {
            return None;
        }
        if shards.len() == 1 {
            return Some(map(shards[0].0, shards[0].1));
        }
        let mut slots: Vec<Option<T>> = Vec::with_capacity(shards.len());
        slots.resize_with(shards.len(), || None);
        {
            let map_ref = &map;
            let jobs: Vec<Job<'_>> = slots
                .iter_mut()
                .zip(&shards)
                .map(|(slot, &(a, b))| {
                    Box::new(move || {
                        *slot = Some(map_ref(a, b));
                    }) as Job<'_>
                })
                .collect();
            self.run(jobs);
        }
        let mut level: Vec<T> = slots
            .into_iter()
            .map(|s| s.expect("pool dropped a shard job"))
            .collect();
        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            let mut it = level.into_iter();
            while let Some(a) = it.next() {
                match it.next() {
                    Some(b) => next.push(combine(a, b)),
                    None => next.push(a),
                }
            }
            level = next;
        }
        level.pop()
    }

    /// Run `f(shard_lo, shard_hi, out_slice)` for every shard of
    /// `[lo, hi)`, handing each job the disjoint slice of `out` its
    /// columns own (`out[k]` corresponds to column `lo + k`). Column
    /// outputs are independent, so this is bitwise-identical to the
    /// serial loop at any thread count.
    pub fn for_each_out_shard<F>(&self, lo: usize, hi: usize, out: &mut [f32], f: F)
    where
        F: Fn(usize, usize, &mut [f32]) + Sync,
    {
        assert_eq!(out.len(), hi - lo);
        let shards = self.plan(lo, hi);
        if shards.is_empty() {
            return;
        }
        if shards.len() == 1 {
            f(lo, hi, out);
            return;
        }
        // Interior shards are uniform by construction, so chunks_mut
        // yields exactly the per-shard output slices, disjointly.
        let width = shards[0].1 - shards[0].0;
        let f_ref = &f;
        let jobs: Vec<Job<'_>> = out
            .chunks_mut(width)
            .zip(&shards)
            .map(|(oc, &(a, b))| {
                debug_assert_eq!(oc.len(), b - a);
                Box::new(move || f_ref(a, b, oc)) as Job<'_>
            })
            .collect();
        self.run(jobs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_reduce_is_bitwise_stable_across_thread_counts() {
        // Sum of ill-conditioned f64 terms: any reduction-order change
        // shows up in the low bits, so exact equality is a real check.
        let data: Vec<f64> = (0..40_000)
            .map(|i| ((i * 2654435761usize % 1000) as f64 - 500.0) * 1e-7 + 1.0)
            .collect();
        let sum_with = |threads: usize| {
            let ctx = ParallelCtx::new(ParallelPolicy {
                threads,
                min_shard_elems: 1024,
            });
            ctx.map_reduce(
                0,
                data.len(),
                |lo, hi| data[lo..hi].iter().sum::<f64>(),
                |a, b| a + b,
            )
            .unwrap()
        };
        let s1 = sum_with(1);
        assert_eq!(s1.to_bits(), sum_with(2).to_bits());
        assert_eq!(s1.to_bits(), sum_with(7).to_bits());
    }

    #[test]
    fn map_reduce_empty_range() {
        let ctx = ParallelCtx::serial();
        assert!(ctx.map_reduce(5, 5, |_, _| 1.0f64, |a, b| a + b).is_none());
    }

    #[test]
    fn for_each_out_shard_writes_every_column() {
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: 3,
            min_shard_elems: 1024,
        });
        let (lo, hi) = (100usize, 100 + 5 * 1024 + 321);
        let mut out = vec![0.0f32; hi - lo];
        ctx.for_each_out_shard(lo, hi, &mut out, |a, b, oc| {
            for (k, v) in oc.iter_mut().enumerate() {
                *v = (a + k) as f32;
            }
            assert_eq!(a + oc.len(), b);
        });
        for (k, &v) in out.iter().enumerate() {
            assert_eq!(v, (lo + k) as f32);
        }
    }

    #[test]
    fn par_plan_reports_choices() {
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: 2,
            min_shard_elems: 2048,
        });
        let p = ctx.par_plan(10_000);
        assert_eq!(p.threads, 2);
        assert_eq!(p.shard_elems, 2048);
        assert_eq!(p.shards, 5);
    }
}
