//! Deterministic column-shard planner for the aggregation hot path.
//!
//! The plan is a pure function of the column range and the policy's
//! `min_shard_elems` — **never** of the thread count — so the shape of the
//! per-shard partial reduction is fixed at any parallelism and results are
//! bitwise-reproducible whether a range runs on 1 thread or 64 (see
//! EXPERIMENTS.md §Perf). Shard boundaries fall on the serial kernels'
//! `CHUNK`-element grid measured from the range start, so every shard job
//! sees exactly the chunk sequence the single-threaded loop would.

use crate::tensor::ops::CHUNK;

/// Upper bound on shards per range: keeps the fixed-order tree reduction
/// and the per-shard scratch negligible even at d = 10^9.
pub const MAX_SHARDS: usize = 256;

/// Uniform shard size (in elements) for a `len`-column range: at least
/// `min_shard_elems`, rounded up to a multiple of `CHUNK`, grown if needed
/// so the shard count stays within [`MAX_SHARDS`].
pub fn shard_elems(len: usize, min_shard_elems: usize) -> usize {
    let mut elems = min_shard_elems.max(CHUNK).div_ceil(CHUNK) * CHUNK;
    let floor = len.div_ceil(MAX_SHARDS);
    if elems < floor {
        elems = floor.div_ceil(CHUNK) * CHUNK;
    }
    elems
}

/// Split `[lo, hi)` into uniform shards of [`shard_elems`] columns, the
/// last shard ragged up to `hi`. Returns `(lo, hi)` pairs in column order;
/// all shards except the last have identical width (callers rely on this
/// to hand out disjoint `chunks_mut` output slices).
pub fn plan_shards(lo: usize, hi: usize, min_shard_elems: usize) -> Vec<(usize, usize)> {
    assert!(lo <= hi);
    let len = hi - lo;
    if len == 0 {
        return Vec::new();
    }
    let elems = shard_elems(len, min_shard_elems);
    let mut shards = Vec::with_capacity(len.div_ceil(elems));
    let mut start = lo;
    while start < hi {
        let end = (start + elems).min(hi);
        shards.push((start, end));
        start = end;
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_range_exactly_with_uniform_shards() {
        for (lo, hi, min) in [
            (0usize, 10_000usize, 1024usize),
            (5, 5, 1024),
            (0, 1023, 1024),
            (0, 1024, 1024),
            (100, 100_000, 4096),
            (0, 3 * 1024 + 17, 1),
        ] {
            let shards = plan_shards(lo, hi, min);
            if lo == hi {
                assert!(shards.is_empty());
                continue;
            }
            let w = shards[0].1 - shards[0].0;
            let mut x = lo;
            for (i, &(a, b)) in shards.iter().enumerate() {
                assert_eq!(a, x, "gap at shard {i}");
                assert!(b > a);
                if i + 1 < shards.len() {
                    assert_eq!(b - a, w, "non-uniform interior shard {i}");
                }
                x = b;
            }
            assert_eq!(x, hi);
            assert!(w % CHUNK == 0 || hi - lo <= w);
        }
    }

    #[test]
    fn plan_is_thread_count_free_and_chunk_aligned() {
        let shards = plan_shards(0, 1_000_000, 65_536);
        assert!(shards.len() > 1);
        for &(a, _) in &shards {
            assert_eq!(a % CHUNK, 0);
        }
        // Same inputs, same plan — nothing else feeds the planner.
        assert_eq!(shards, plan_shards(0, 1_000_000, 65_536));
    }

    #[test]
    fn shard_count_is_capped() {
        let shards = plan_shards(0, 1_000_000_000, 1);
        assert!(shards.len() <= MAX_SHARDS, "{}", shards.len());
    }

    #[test]
    fn min_shard_rounds_up_to_chunk() {
        assert_eq!(shard_elems(10_000_000, 1), CHUNK);
        assert_eq!(shard_elems(10_000_000, CHUNK + 1), 2 * CHUNK);
        assert_eq!(shard_elems(10_000, 65_536), 65_536);
    }
}
