//! Persistent scoped worker pool (std-only; rayon is not vendored
//! offline).
//!
//! Threads are spawned once and reused across scopes, so the per-step cost
//! of a parallel region is one mutex-guarded queue push per shard — no
//! thread spawn on the training hot path. Jobs may borrow stack data:
//! [`WorkerPool::run_scope`] blocks until every submitted job has finished
//! (the count is decremented by a drop guard even if a job unwinds), which
//! is what makes the `'scope → 'static` transmute below sound.
//!
//! The calling thread participates in draining the queue, so a pool built
//! for `threads` compute lanes spawns `threads - 1` OS threads; a
//! one-thread pool runs every job inline on the caller, giving a serial
//! path that shares 100% of the code with the parallel one.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of work submitted to the pool. Jobs only need to live as long as
/// the `run_scope` call that submits them.
pub type Job<'a> = Box<dyn FnOnce() + Send + 'a>;

struct Queue {
    jobs: VecDeque<Job<'static>>,
    shutdown: bool,
}

struct PoolState {
    queue: Mutex<Queue>,
    job_ready: Condvar,
    pending: Mutex<usize>,
    all_done: Condvar,
    job_panicked: AtomicBool,
}

impl PoolState {
    fn pop_job(&self) -> Option<Job<'static>> {
        let mut q = self.queue.lock().unwrap();
        q.jobs.pop_front()
    }

    /// Run one job, decrementing `pending` even if the job unwinds.
    fn run_job(&self, job: Job<'static>) {
        struct Done<'a>(&'a PoolState);
        impl Drop for Done<'_> {
            fn drop(&mut self) {
                let mut p = self.0.pending.lock().unwrap();
                *p -= 1;
                if *p == 0 {
                    self.0.all_done.notify_all();
                }
            }
        }
        let _done = Done(self);
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
            self.job_panicked.store(true, Ordering::SeqCst);
        }
    }
}

/// Persistent pool of `threads - 1` workers plus the calling thread.
pub struct WorkerPool {
    threads: usize,
    state: Arc<PoolState>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Build a pool with `threads` compute lanes (clamped to >= 1).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let state = Arc::new(PoolState {
            queue: Mutex::new(Queue {
                jobs: VecDeque::new(),
                shutdown: false,
            }),
            job_ready: Condvar::new(),
            pending: Mutex::new(0),
            all_done: Condvar::new(),
            job_panicked: AtomicBool::new(false),
        });
        let handles = (0..threads - 1)
            .map(|_| {
                let state = state.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        let mut q = state.queue.lock().unwrap();
                        loop {
                            if let Some(job) = q.jobs.pop_front() {
                                break Some(job);
                            }
                            if q.shutdown {
                                break None;
                            }
                            q = state.job_ready.wait(q).unwrap();
                        }
                    };
                    match job {
                        Some(job) => state.run_job(job),
                        None => return,
                    }
                })
            })
            .collect();
        WorkerPool {
            threads,
            state,
            handles,
        }
    }

    /// Compute lanes this pool was built for (workers + caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Enqueue one type-erased job without waiting for it.
    ///
    /// # Safety
    /// The caller must guarantee the job runs to completion before any
    /// borrow it holds expires — the task-scope layer does this by
    /// refusing to return until its pending count is zero. Must not be
    /// called on a one-lane pool (no workers exist to drain the queue).
    pub(crate) unsafe fn push_job<'a>(&self, job: Job<'a>) {
        debug_assert!(self.threads > 1, "push_job on a one-lane pool");
        {
            let mut p = self.state.pending.lock().unwrap();
            *p += 1;
        }
        let job: Job<'static> = std::mem::transmute::<Job<'a>, Job<'static>>(job);
        let mut q = self.state.queue.lock().unwrap();
        q.jobs.push_back(job);
        self.state.job_ready.notify_one();
    }

    /// Run `jobs` to completion, in parallel across the pool. Blocks until
    /// every job has finished, so jobs may borrow data owned by the caller.
    /// Panics (after draining) if any job panicked on a worker thread.
    pub fn run_scope<'scope>(&self, jobs: Vec<Job<'scope>>) {
        if jobs.is_empty() {
            return;
        }
        if self.threads == 1 || jobs.len() == 1 {
            // Serial fast path: same jobs, same order, no queue traffic.
            for job in jobs {
                job();
            }
            return;
        }
        {
            let mut p = self.state.pending.lock().unwrap();
            *p += jobs.len();
        }
        {
            let mut q = self.state.queue.lock().unwrap();
            for job in jobs {
                // SAFETY: we block below until `pending` returns to zero,
                // i.e. every job pushed here has run to completion (the
                // decrement happens in a drop guard, so it fires even on
                // unwind). No job can outlive the 'scope borrows it holds,
                // which is the only obligation the erased lifetime drops.
                let job: Job<'static> = unsafe {
                    std::mem::transmute::<Job<'scope>, Job<'static>>(job)
                };
                q.jobs.push_back(job);
            }
            self.state.job_ready.notify_all();
        }
        // The caller is a compute lane too: help drain the queue.
        while let Some(job) = self.state.pop_job() {
            self.state.run_job(job);
        }
        // Wait for jobs still in flight on worker threads.
        let mut p = self.state.pending.lock().unwrap();
        while *p != 0 {
            p = self.state.all_done.wait(p).unwrap();
        }
        drop(p);
        if self.state.job_panicked.swap(false, Ordering::SeqCst) {
            panic!("a pool job panicked (see stderr for the worker backtrace)");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.state.queue.lock().unwrap();
            q.shutdown = true;
            self.state.job_ready.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_borrowed_jobs_across_scopes() {
        let pool = WorkerPool::new(4);
        // Reuse the same pool for many scopes — no spawn per scope.
        for round in 0..50usize {
            let mut slots = vec![0usize; 16];
            let jobs: Vec<Job<'_>> = slots
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| Box::new(move || *slot = i + round) as Job<'_>)
                .collect();
            pool.run_scope(jobs);
            for (i, &v) in slots.iter().enumerate() {
                assert_eq!(v, i + round);
            }
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Job<'_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }) as Job<'_>
            })
            .collect();
        pool.run_scope(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn empty_scope_is_a_noop() {
        let pool = WorkerPool::new(2);
        pool.run_scope(Vec::new());
    }

    #[test]
    fn panicking_job_propagates_without_deadlock() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<Job<'_>> = (0..8)
            .map(|i| {
                Box::new(move || {
                    if i == 3 {
                        panic!("job boom");
                    }
                }) as Job<'_>
            })
            .collect();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_scope(jobs);
        }));
        assert!(r.is_err());
        // Pool still usable after a failed scope.
        let mut v = vec![0u32; 4];
        let jobs: Vec<Job<'_>> = v
            .iter_mut()
            .map(|slot| Box::new(move || *slot = 7) as Job<'_>)
            .collect();
        pool.run_scope(jobs);
        assert_eq!(v, vec![7; 4]);
    }
}
