//! Non-blocking task submission on the persistent pool.
//!
//! [`WorkerPool::run_scope`] is a barrier: it blocks until every job in
//! the batch finishes, which is the right shape for a fan-out kernel but
//! the wrong one for pipelining — the pipelined step loop needs to hand a
//! ready bucket's aggregation work to the pool and *keep going* while
//! later buckets are still arriving. [`TaskScope::submit`] provides that:
//! it enqueues one job and returns a [`TaskHandle`] immediately; the
//! caller joins handles later, in whatever order the algorithm needs
//! (the pipelined executor joins in fixed bucket order, which is what
//! keeps results bitwise-identical to the serial path).
//!
//! Soundness mirrors `std::thread::scope`: tasks may borrow anything that
//! outlives the [`WorkerPool::task_scope`] call, because `task_scope`
//! refuses to return (even on unwind) until every submitted task has
//! finished. Handles carry the scope lifetime, so they cannot escape.
//!
//! On a one-lane pool the submitted task runs inline on the caller —
//! the serial path shares 100% of the code with the pipelined one, and a
//! later `join` can never block on workers that do not exist.

use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

use super::pool::{Job, WorkerPool};

enum SlotState<T> {
    Pending,
    Done(T),
    Panicked,
}

struct TaskSlot<T> {
    state: Mutex<SlotState<T>>,
    done: Condvar,
}

impl<T> TaskSlot<T> {
    fn new() -> Self {
        TaskSlot {
            state: Mutex::new(SlotState::Pending),
            done: Condvar::new(),
        }
    }

    fn fill(&self, v: Result<T, ()>) {
        let mut st = self.state.lock().unwrap();
        *st = match v {
            Ok(v) => SlotState::Done(v),
            Err(()) => SlotState::Panicked,
        };
        self.done.notify_all();
    }
}

/// Handle to one in-flight task. Dropping without joining is allowed —
/// the scope still waits for the task before returning.
pub struct TaskHandle<'scope, T> {
    slot: Arc<TaskSlot<T>>,
    _scope: PhantomData<&'scope ()>,
}

impl<T> TaskHandle<'_, T> {
    /// Block until the task finishes and return its result. Panics if the
    /// task panicked (the payload is reported on the worker's stderr).
    pub fn join(self) -> T {
        let mut st = self.slot.state.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, SlotState::Pending) {
                SlotState::Done(v) => return v,
                SlotState::Panicked => panic!("a submitted pool task panicked"),
                SlotState::Pending => st = self.slot.done.wait(st).unwrap(),
            }
        }
    }
}

struct ScopeState {
    pending: Mutex<usize>,
    all_done: Condvar,
}

/// An open submission window on the pool; created by
/// [`WorkerPool::task_scope`].
pub struct TaskScope<'scope, 'env: 'scope> {
    pool: &'scope WorkerPool,
    state: Arc<ScopeState>,
    _scope: PhantomData<&'scope mut &'scope ()>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'scope, 'env> TaskScope<'scope, 'env> {
    /// Enqueue `f` on the pool and return a handle without blocking. On a
    /// one-lane pool `f` runs inline before `submit` returns.
    pub fn submit<T, F>(&'scope self, f: F) -> TaskHandle<'scope, T>
    where
        T: Send + 'scope,
        F: FnOnce() -> T + Send + 'scope,
    {
        let slot = Arc::new(TaskSlot::new());
        if self.pool.threads() == 1 {
            // Inline serial path: no workers exist to drain the queue, and
            // running here keeps the code path identical to the pool one.
            slot.fill(catch_unwind(AssertUnwindSafe(f)).map_err(|_| ()));
            return TaskHandle {
                slot,
                _scope: PhantomData,
            };
        }
        {
            let mut p = self.state.pending.lock().unwrap();
            *p += 1;
        }
        let state = self.state.clone();
        let task_slot = slot.clone();
        let job: Job<'scope> = Box::new(move || {
            // Catch here (not in the pool's run_job) so a task panic is
            // reported through the handle instead of poisoning the pool's
            // scoped-batch panic flag.
            task_slot.fill(catch_unwind(AssertUnwindSafe(f)).map_err(|_| ()));
            let mut p = state.pending.lock().unwrap();
            *p -= 1;
            if *p == 0 {
                state.all_done.notify_all();
            }
        });
        // SAFETY: task_scope waits (even on unwind) until this scope's
        // pending count returns to zero before returning, so the job runs
        // to completion while every 'scope borrow it holds is still live.
        unsafe { self.pool.push_job(job) };
        TaskHandle {
            slot,
            _scope: PhantomData,
        }
    }

    fn wait_all(&self) {
        let mut p = self.state.pending.lock().unwrap();
        while *p != 0 {
            p = self.state.all_done.wait(p).unwrap();
        }
    }
}

impl WorkerPool {
    /// Open a submission window: `f` may [`TaskScope::submit`] tasks that
    /// borrow anything outliving this call; `task_scope` returns only
    /// after every submitted task has finished (unwind-safe, like
    /// `std::thread::scope`).
    pub fn task_scope<'env, F, R>(&'env self, f: F) -> R
    where
        F: for<'scope> FnOnce(&'scope TaskScope<'scope, 'env>) -> R,
    {
        let scope = TaskScope {
            pool: self,
            state: Arc::new(ScopeState {
                pending: Mutex::new(0),
                all_done: Condvar::new(),
            }),
            _scope: PhantomData,
            _env: PhantomData,
        };
        // Wait for stragglers even if `f` unwinds mid-scope — in-flight
        // tasks borrow 'env data, so returning (or unwinding past this
        // frame) before they finish would be unsound.
        let r = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        scope.wait_all();
        match r {
            Ok(v) => v,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn submit_and_join_returns_results() {
        let pool = WorkerPool::new(4);
        let data: Vec<u64> = (0..32).collect();
        let total: u64 = pool.task_scope(|scope| {
            let handles: Vec<_> = data
                .chunks(8)
                .map(|c| scope.submit(move || c.iter().sum::<u64>()))
                .collect();
            handles.into_iter().map(|h| h.join()).sum()
        });
        assert_eq!(total, (0..32).sum::<u64>());
    }

    #[test]
    fn one_lane_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        let mut log = Vec::new();
        pool.task_scope(|scope| {
            for i in 0..4 {
                let h = scope.submit(move || i * 10);
                log.push(h.join());
            }
        });
        assert_eq!(log, vec![0, 10, 20, 30]);
    }

    #[test]
    fn scope_waits_for_unjoined_tasks() {
        let pool = WorkerPool::new(3);
        let hits = AtomicUsize::new(0);
        pool.task_scope(|scope| {
            for _ in 0..16 {
                // Handles dropped without join: the scope must still wait.
                let _ = scope.submit(|| {
                    hits.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(hits.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn panicking_task_propagates_through_join_only() {
        let pool = WorkerPool::new(4);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.task_scope(|scope| {
                let ok = scope.submit(|| 7u32);
                let bad = scope.submit(|| panic!("task boom"));
                assert_eq!(ok.join(), 7);
                bad.join()
            })
        }));
        assert!(r.is_err());
        // The pool's scoped-batch path stays clean after a task panic.
        let mut v = vec![0u32; 4];
        let jobs: Vec<Job<'_>> = v
            .iter_mut()
            .map(|slot| Box::new(move || *slot = 9) as Job<'_>)
            .collect();
        pool.run_scope(jobs);
        assert_eq!(v, vec![9; 4]);
    }

    #[test]
    fn tasks_overlap_with_caller_work() {
        // The caller keeps executing between submit and join; the task's
        // side effect lands by join time at the latest.
        let pool = WorkerPool::new(2);
        let x = pool.task_scope(|scope| {
            let h = scope.submit(|| 21u32);
            let local = 2u32; // caller-side "overlapped" work
            h.join() * local
        });
        assert_eq!(x, 42);
    }

    #[test]
    fn interleaves_with_run_scope_batches() {
        // A task in flight must not corrupt the pending accounting of a
        // concurrent run_scope barrier on the same pool.
        let pool = WorkerPool::new(4);
        pool.task_scope(|scope| {
            let h = scope.submit(|| {
                std::thread::sleep(std::time::Duration::from_millis(5));
                1u32
            });
            let mut v = vec![0u32; 8];
            let jobs: Vec<Job<'_>> = v
                .iter_mut()
                .map(|slot| Box::new(move || *slot = 3) as Job<'_>)
                .collect();
            pool.run_scope(jobs);
            assert_eq!(v, vec![3; 8]);
            assert_eq!(h.join(), 1);
        });
    }
}
