//! Compressed collectives with error feedback (EF).
//!
//! Every bucket transfer historically shipped full-precision f32 columns;
//! on the simulated fabric the inter-node channel dominates exposed comm.
//! This module cuts wire bytes 2–50x without biasing the consensus
//! aggregate: each sender keeps a per-bucket **error-feedback residual**
//! `e`, compresses `x = g + e`, ships the encoded payload, and stores
//! `e' = x - decode(payload)`. Over steps the residual re-injects every
//! bit the codec dropped, so the aggregate of the decoded gradients is
//! unbiased in expectation (EXPERIMENTS.md §Compression has the
//! argument).
//!
//! Three codecs behind the [`Compressor`] trait:
//! - **int8** stochastic quantization — deterministic via `util::prng`
//!   keyed on `(step, rank, bucket)`, so a fixed config is bit-identical
//!   across rank-threads on/off and overlap on/off;
//! - **fp16** round-to-nearest-even truncation (no randomness);
//! - **top-k** sparsification with a deterministic lowest-index
//!   tie-break.
//!
//! A fourth, the **rank-k low-rank sketch** ([`SetCodec`] with
//! [`CompressorKind::LowRank`]), operates on the whole gradient *set* of
//! a bucket (it needs the N×N Gram of the rows), so it runs leader-side:
//! in the flat executor after assembly, or on the node-leader set in
//! hierarchical mode.
//!
//! Reproducibility contract: `--compress none` is a bitwise no-op (the
//! wire format is [`Payload::Raw`], decode is identity), and every codec
//! is a pure function of `(values, residual, seed, step, rank, bucket)`
//! — never of thread count, arrival order, or wall clock.

use crate::tensor::GradSet;
use crate::util::error::{bail, Context, Result};
use crate::util::prng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Which codec to apply to bucket transfers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CompressorKind {
    /// Ship raw f32 columns (bitwise-identical to the uncompressed path).
    None,
    /// Rank-`k` low-rank sketch of the bucket's gradient set (set-level).
    LowRank { k: usize },
    /// Int8 stochastic quantization with a per-payload f32 scale.
    Int8,
    /// IEEE binary16 round-to-nearest-even.
    Fp16,
    /// Keep the `ratio` fraction of largest-magnitude entries.
    TopK { ratio: f64 },
}

impl CompressorKind {
    /// Parse `none|lowrank:<k>|int8|fp16|topk:<ratio>`.
    pub fn parse(s: &str) -> Result<CompressorKind> {
        match s {
            "none" => return Ok(CompressorKind::None),
            "int8" => return Ok(CompressorKind::Int8),
            "fp16" => return Ok(CompressorKind::Fp16),
            _ => {}
        }
        if let Some(k) = s.strip_prefix("lowrank:") {
            let k: usize = k.parse().context("lowrank rank")?;
            if k == 0 {
                bail!("lowrank rank must be >= 1");
            }
            return Ok(CompressorKind::LowRank { k });
        }
        if let Some(r) = s.strip_prefix("topk:") {
            let ratio: f64 = r.parse().context("topk ratio")?;
            if !(ratio > 0.0 && ratio <= 1.0) {
                bail!("topk ratio must be in (0, 1], got {ratio}");
            }
            return Ok(CompressorKind::TopK { ratio });
        }
        bail!("bad compressor {s:?}: want none|lowrank:<k>|int8|fp16|topk:<ratio>")
    }

    pub fn is_none(&self) -> bool {
        matches!(self, CompressorKind::None)
    }

    /// True for codecs that encode one sender's columns independently
    /// (int8/fp16/topk) — these run at the rank source. The low-rank
    /// sketch needs the whole set and runs leader-side instead.
    pub fn is_per_rank(&self) -> bool {
        matches!(
            self,
            CompressorKind::Int8 | CompressorKind::Fp16 | CompressorKind::TopK { .. }
        )
    }

    /// Tag string for bench rows and logs (round-trips through `parse`).
    pub fn tag(&self) -> String {
        match self {
            CompressorKind::None => "none".into(),
            CompressorKind::LowRank { k } => format!("lowrank:{k}"),
            CompressorKind::Int8 => "int8".into(),
            CompressorKind::Fp16 => "fp16".into(),
            CompressorKind::TopK { ratio } => format!("topk:{ratio}"),
        }
    }

    /// The per-row [`Compressor`] for per-rank kinds; `None` for
    /// `None`/`LowRank` (raw passthrough / set-level path).
    pub fn row_compressor(&self) -> Option<Box<dyn Compressor>> {
        match *self {
            CompressorKind::Int8 => Some(Box::new(Int8Quantizer)),
            CompressorKind::Fp16 => Some(Box::new(Fp16Quantizer)),
            CompressorKind::TopK { ratio } => Some(Box::new(TopKSparsifier { ratio })),
            CompressorKind::None | CompressorKind::LowRank { .. } => None,
        }
    }

    /// Modeled wire bytes for one participant's share of a bucket of
    /// `n_cols` columns when `rows` participants take part in the
    /// collective. Used to rewrite `CommOp.bytes` so the timelines price
    /// the compressed transfer (see `collective::cost_model`).
    pub fn bucket_wire_bytes(&self, n_cols: usize, rows: usize) -> usize {
        match *self {
            CompressorKind::None => crate::collective::cost_model::f32_wire_bytes(n_cols),
            // Factored form: U (rows×k) + Uᵀ·X (k×n_cols), both f32.
            CompressorKind::LowRank { k } => {
                let ke = k.min(rows).min(n_cols).max(1);
                4 * (ke * n_cols + rows * ke)
            }
            _ => self
                .row_compressor()
                .expect("per-rank kind")
                .wire_bytes(n_cols, rows),
        }
    }
}

/// Which channels to compress: `All` transfers, or only the slow
/// inter-node fabric (`Inter`). On a flat topology the single ring *is*
/// the bottleneck fabric, so both scopes compress the rank transfers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressScope {
    All,
    Inter,
}

impl CompressScope {
    pub fn parse(s: &str) -> Result<CompressScope> {
        match s {
            "all" => Ok(CompressScope::All),
            "inter" => Ok(CompressScope::Inter),
            _ => bail!("bad compress scope {s:?}: want all|inter"),
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            CompressScope::All => "all",
            CompressScope::Inter => "inter",
        }
    }
}

/// Full compression configuration: codec + which channels it applies to.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompressionSpec {
    pub kind: CompressorKind,
    pub scope: CompressScope,
}

impl Default for CompressionSpec {
    fn default() -> Self {
        CompressionSpec {
            kind: CompressorKind::None,
            scope: CompressScope::All,
        }
    }
}

impl CompressionSpec {
    pub fn is_active(&self) -> bool {
        !self.kind.is_none()
    }
}

// ---------------------------------------------------------------------------
// Wire payloads
// ---------------------------------------------------------------------------

/// One bucket's encoded columns as they cross the (simulated) wire.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Uncompressed f32 columns — the `--compress none` format and the
    /// NaN-transparent escape hatch (non-finite inputs bypass the codec
    /// so poison reaches the aggregator unmodified).
    Raw(Vec<f32>),
    /// binary16 bit patterns, one per column.
    Fp16(Vec<u16>),
    /// Stochastically-rounded int8 codes plus their f32 scale.
    Int8 { scale: f32, codes: Vec<i8> },
    /// Sparse (index, value) pairs; indices strictly increasing.
    TopK {
        n_cols: usize,
        idx: Vec<u32>,
        vals: Vec<f32>,
    },
}

impl Payload {
    /// Width of the decoded column vector.
    pub fn n_cols(&self) -> usize {
        match self {
            Payload::Raw(v) => v.len(),
            Payload::Fp16(c) => c.len(),
            Payload::Int8 { codes, .. } => codes.len(),
            Payload::TopK { n_cols, .. } => *n_cols,
        }
    }

    /// True wire size in bytes of this encoding.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::Raw(v) => 4 * v.len(),
            Payload::Fp16(c) => 2 * c.len(),
            Payload::Int8 { codes, .. } => 4 + codes.len(),
            Payload::TopK { idx, .. } => 4 + 8 * idx.len(),
        }
    }

    /// Decode to f32 columns.
    pub fn decode(&self) -> Vec<f32> {
        match self {
            Payload::Raw(v) => v.clone(),
            Payload::Fp16(codes) => codes.iter().map(|&h| f16_bits_to_f32(h)).collect(),
            Payload::Int8 { scale, codes } => {
                codes.iter().map(|&q| q as f32 * scale).collect()
            }
            Payload::TopK { n_cols, idx, vals } => {
                let mut out = vec![0.0f32; *n_cols];
                for (&i, &v) in idx.iter().zip(vals.iter()) {
                    out[i as usize] = v;
                }
                out
            }
        }
    }

    /// Decode, consuming the payload — zero-copy for `Raw`, so the
    /// `--compress none` path moves the exact bits the sender produced.
    pub fn into_cols(self) -> Vec<f32> {
        match self {
            Payload::Raw(v) => v,
            other => other.decode(),
        }
    }
}

// ---------------------------------------------------------------------------
// binary16 conversion (hand-rolled; the crate is zero-dependency)
// ---------------------------------------------------------------------------

/// f32 → IEEE binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf stays Inf; NaN maps to a quiet NaN.
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → ±Inf
    }
    if e <= 0 {
        // Subnormal (or zero) in f16.
        if e < -10 {
            return sign; // underflows to ±0
        }
        let m = mant | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rem = m & ((1u32 << shift) - 1);
        let mut v = m >> shift;
        if rem > half || (rem == half && (v & 1) == 1) {
            v += 1; // may carry into the smallest normal — bit layout is contiguous
        }
        return sign | v as u16;
    }
    let mut m = mant >> 13;
    let rem = mant & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
        m += 1;
        if m == 0x400 {
            // Mantissa carry bumps the exponent.
            let e2 = e + 1;
            if e2 >= 0x1f {
                return sign | 0x7c00;
            }
            return sign | ((e2 as u16) << 10);
        }
    }
    sign | ((e as u16) << 10) | m as u16
}

/// IEEE binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Normalize the subnormal.
            let mut e = 113u32; // 127 - 15 + 1
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

// ---------------------------------------------------------------------------
// Row compressors
// ---------------------------------------------------------------------------

/// One sender's bucket-column codec. `encode` is a pure function of
/// `(x, rng)` — the caller folds the EF residual into `x` and derives
/// `rng` from `(seed, step, rank, bucket)`, which is what makes the whole
/// path bit-deterministic for a fixed config.
pub trait Compressor: Send + Sync {
    fn name(&self) -> &'static str;
    /// Modeled wire bytes for `n_cols` columns (`rows` participants; only
    /// the low-rank sketch depends on it, but the signature is shared).
    fn wire_bytes(&self, n_cols: usize, rows: usize) -> usize;
    fn encode(&self, x: &[f32], rng: &mut Rng) -> Payload;
}

/// Int8 stochastic quantization: `q = sr(x / scale)` with
/// `scale = max|x| / 127`. Stochastic rounding makes each payload
/// unbiased *per draw*; EF additionally zeroes the realized error over
/// steps.
pub struct Int8Quantizer;

impl Compressor for Int8Quantizer {
    fn name(&self) -> &'static str {
        "int8"
    }

    fn wire_bytes(&self, n_cols: usize, _rows: usize) -> usize {
        4 + n_cols // f32 scale + one code per column
    }

    fn encode(&self, x: &[f32], rng: &mut Rng) -> Payload {
        let mut max_abs = 0.0f32;
        for &v in x {
            max_abs = max_abs.max(v.abs());
        }
        let scale = max_abs / 127.0;
        if scale == 0.0 {
            return Payload::Int8 {
                scale,
                codes: vec![0; x.len()],
            };
        }
        let codes = x
            .iter()
            .map(|&v| {
                let y = (v / scale).clamp(-127.0, 127.0);
                let f = y.floor();
                let frac = y - f;
                let up = rng.uniform_f32() < frac;
                ((f as i32 + i32::from(up)).clamp(-127, 127)) as i8
            })
            .collect();
        Payload::Int8 { scale, codes }
    }
}

/// Plain fp16 truncation (round-to-nearest-even); deterministic, no rng.
pub struct Fp16Quantizer;

impl Compressor for Fp16Quantizer {
    fn name(&self) -> &'static str {
        "fp16"
    }

    fn wire_bytes(&self, n_cols: usize, _rows: usize) -> usize {
        2 * n_cols
    }

    fn encode(&self, x: &[f32], _rng: &mut Rng) -> Payload {
        Payload::Fp16(x.iter().map(|&v| f32_to_f16_bits(v)).collect())
    }
}

/// Top-k sparsification: keep `ceil(ratio · n_cols)` largest-|x| entries.
/// Ties break toward the lower index so selection is deterministic.
pub struct TopKSparsifier {
    pub ratio: f64,
}

/// Kept-entry count for a `n_cols`-wide bucket at `ratio`.
pub fn topk_k(n_cols: usize, ratio: f64) -> usize {
    ((ratio * n_cols as f64).ceil() as usize).clamp(1, n_cols.max(1))
}

impl Compressor for TopKSparsifier {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn wire_bytes(&self, n_cols: usize, _rows: usize) -> usize {
        4 + 8 * topk_k(n_cols, self.ratio) // u32 index + f32 value per kept entry
    }

    fn encode(&self, x: &[f32], _rng: &mut Rng) -> Payload {
        let n = x.len();
        let k = topk_k(n, self.ratio).min(n);
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            let (va, vb) = (x[a as usize].abs(), x[b as usize].abs());
            vb.partial_cmp(&va)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut idx = order[..k].to_vec();
        idx.sort_unstable();
        let vals = idx.iter().map(|&i| x[i as usize]).collect();
        Payload::TopK {
            n_cols: n,
            idx,
            vals,
        }
    }
}

// ---------------------------------------------------------------------------
// Per-rank streaming codec (int8 / fp16 / topk at the gradient source)
// ---------------------------------------------------------------------------

/// One rank's sending codec: per-bucket EF residual + a row compressor.
/// For `None`/`LowRank` kinds it is a raw passthrough (the sketch runs
/// leader-side), so it can be installed unconditionally.
pub struct RankCodec {
    kind: CompressorKind,
    comp: Option<Box<dyn Compressor>>,
    seed: u64,
    rank: usize,
    /// Per-bucket residual, lazily sized to the bucket width (handles
    /// ragged last buckets and re-initializes if widths change).
    residuals: Vec<Vec<f32>>,
}

impl RankCodec {
    pub fn new(kind: CompressorKind, seed: u64, rank: usize, n_buckets: usize) -> RankCodec {
        RankCodec {
            kind,
            comp: kind.row_compressor(),
            seed,
            rank,
            residuals: vec![Vec::new(); n_buckets],
        }
    }

    pub fn kind(&self) -> CompressorKind {
        self.kind
    }

    /// Drop all residual state — called when parameters are re-broadcast
    /// (checkpoint restore), since stale feedback belongs to the old
    /// trajectory.
    pub fn reset(&mut self) {
        for r in &mut self.residuals {
            r.clear();
        }
    }

    /// Snapshot the per-bucket EF residuals for checkpointing — a
    /// compress+resume run is bitwise-continuous only if the accumulated
    /// feedback travels with the params.
    pub fn export_residuals(&self) -> Vec<Vec<f32>> {
        self.residuals.clone()
    }

    /// Restore residuals from [`RankCodec::export_residuals`]. A
    /// bucket-count mismatch (changed bucketing) keeps the fresh empty
    /// residuals, which lazily re-size on the next encode.
    pub fn import_residuals(&mut self, residuals: Vec<Vec<f32>>) {
        if residuals.len() == self.residuals.len() {
            self.residuals = residuals;
        }
    }

    /// Encode one bucket's columns, folding in and updating the EF
    /// residual. Non-finite inputs bypass both codec and residual so
    /// NaN/Inf poison ships unmodified ([`Payload::Raw`]).
    pub fn encode_bucket(&mut self, step: u64, bucket: usize, cols: &[f32]) -> Payload {
        let Some(comp) = &self.comp else {
            return Payload::Raw(cols.to_vec());
        };
        if cols.iter().any(|v| !v.is_finite()) {
            crate::log_debug!(
                "step {step} bucket {bucket}: non-finite gradient, codec bypassed \
                 (poison ships raw; EF residual untouched)"
            );
            return Payload::Raw(cols.to_vec());
        }
        let e = &mut self.residuals[bucket];
        if e.len() != cols.len() {
            e.clear();
            e.resize(cols.len(), 0.0);
        }
        let x: Vec<f32> = cols.iter().zip(e.iter()).map(|(c, r)| c + r).collect();
        let mut rng = Rng::new(self.seed)
            .fork(step)
            .fork(self.rank as u64)
            .fork(bucket as u64);
        let payload = comp.encode(&x, &mut rng);
        let decoded = payload.decode();
        for ((ei, xi), di) in e.iter_mut().zip(x.iter()).zip(decoded.iter()) {
            *ei = xi - di;
        }
        payload
    }
}

// ---------------------------------------------------------------------------
// Set-level codec (low-rank sketch; also per-row codecs at leader level)
// ---------------------------------------------------------------------------

/// Power-iteration sweeps per extracted component.
const POWER_ITERS: usize = 40;

/// Leader-side codec over a whole `GradSet` bucket view. Holds one EF
/// residual bank per bucket behind a `Mutex` so pool tasks working on
/// *different* buckets never serialize on each other; within a bucket the
/// transform is sequential f64 with fixed iteration order, so results are
/// bitwise-identical whether it runs inline (overlap off) or on a pool
/// task (overlap on), and whether the view is the full set at `[lo, hi)`
/// or an owned copy at `[0, w)`.
pub struct SetCodec {
    kind: CompressorKind,
    comp: Option<Box<dyn Compressor>>,
    seed: u64,
    step: AtomicU64,
    banks: Vec<Mutex<Vec<f32>>>,
}

impl SetCodec {
    pub fn new(kind: CompressorKind, seed: u64, n_buckets: usize) -> SetCodec {
        SetCodec {
            kind,
            comp: kind.row_compressor(),
            seed,
            step: AtomicU64::new(0),
            banks: (0..n_buckets).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    pub fn kind(&self) -> CompressorKind {
        self.kind
    }

    /// Advance the step key. Call exactly once per training step, after
    /// every bucket's transform — the counter starts at 0 on a fresh run
    /// (documented: it restarts on a new process, like the pool itself).
    pub fn advance_step(&self) {
        self.step.fetch_add(1, Ordering::SeqCst);
    }

    /// Drop residuals and rewind the step key (param re-broadcast).
    pub fn reset(&self) {
        for b in &self.banks {
            b.lock().unwrap().clear();
        }
        self.step.store(0, Ordering::SeqCst);
    }

    /// Snapshot `(step key, per-bucket residual banks)` for checkpointing.
    pub fn export_state(&self) -> (u64, Vec<Vec<f32>>) {
        let banks = self
            .banks
            .iter()
            .map(|b| b.lock().unwrap().clone())
            .collect();
        (self.step.load(Ordering::SeqCst), banks)
    }

    /// Restore state from [`SetCodec::export_state`]. A bucket-count
    /// mismatch keeps fresh state (banks lazily re-size on next use).
    pub fn import_state(&self, step: u64, banks: Vec<Vec<f32>>) {
        if banks.len() != self.banks.len() {
            return;
        }
        for (slot, bank) in self.banks.iter().zip(banks) {
            *slot.lock().unwrap() = bank;
        }
        self.step.store(step, Ordering::SeqCst);
    }

    /// Compress-then-decompress columns `[lo, hi)` of every row in place,
    /// updating the bucket's EF bank. The aggregator's Gram/statistics
    /// pass then runs on the *decoded* values, which is exactly what the
    /// receivers would reconstruct.
    pub fn transform(&self, bucket: usize, set: &mut GradSet, lo: usize, hi: usize) {
        let m = set.n();
        let w = hi - lo;
        if m == 0 || w == 0 || self.kind.is_none() {
            return;
        }
        match self.kind {
            CompressorKind::LowRank { k } => self.transform_lowrank(bucket, set, lo, hi, k),
            _ => self.transform_rows(bucket, set, lo, hi),
        }
    }

    /// Per-row codecs applied at the set level (hier inter-node scope:
    /// each row is one node leader's reduced gradient).
    fn transform_rows(&self, bucket: usize, set: &mut GradSet, lo: usize, hi: usize) {
        let comp = self.comp.as_ref().expect("per-rank kind");
        let m = set.n();
        let w = hi - lo;
        let step = self.step.load(Ordering::SeqCst);
        let mut bank = self.banks[bucket].lock().unwrap();
        if bank.len() != m * w {
            bank.clear();
            bank.resize(m * w, 0.0);
        }
        for i in 0..m {
            let row = &mut set.row_mut(i)[lo..hi];
            if row.iter().any(|v| !v.is_finite()) {
                continue; // NaN-transparent: row and its residual untouched
            }
            let e = &mut bank[i * w..(i + 1) * w];
            let x: Vec<f32> = row.iter().zip(e.iter()).map(|(c, r)| c + r).collect();
            let mut rng = Rng::new(self.seed)
                .fork(step)
                .fork(i as u64)
                .fork(bucket as u64);
            let payload = comp.encode(&x, &mut rng);
            let decoded = payload.decode();
            for c in 0..w {
                e[c] = x[c] - decoded[c];
                row[c] = decoded[c];
            }
        }
    }

    /// Rank-k sketch: N×N Gram of the EF-corrected rows (sequential f64,
    /// fixed order), top-k eigenvectors by deflated power iteration, then
    /// the projection `Â = U·Uᵀ·X` replaces the rows. Entirely
    /// deterministic — the init vectors are keyed by `(seed, bucket)`
    /// only and the iteration count is fixed.
    fn transform_lowrank(&self, bucket: usize, set: &mut GradSet, lo: usize, hi: usize, k: usize) {
        let m = set.n();
        let w = hi - lo;
        let mut bank = self.banks[bucket].lock().unwrap();
        if bank.len() != m * w {
            bank.clear();
            bank.resize(m * w, 0.0);
        }
        let mut x = vec![0.0f32; m * w];
        let mut finite = true;
        for i in 0..m {
            let row = &set.row(i)[lo..hi];
            for c in 0..w {
                finite &= row[c].is_finite();
                x[i * w + c] = row[c] + bank[i * w + c];
            }
        }
        if !finite {
            return; // NaN-transparent: whole bucket ships raw, bank untouched
        }
        let ke = k.min(m).min(w).max(1);
        // Gram G = X·Xᵀ over the bucket columns.
        let mut gm = vec![0.0f64; m * m];
        for i in 0..m {
            for j in i..m {
                let mut s = 0.0f64;
                for c in 0..w {
                    s += x[i * w + c] as f64 * x[j * w + c] as f64;
                }
                gm[i * m + j] = s;
                gm[j * m + i] = s;
            }
        }
        let mut basis: Vec<Vec<f64>> = Vec::new();
        let mut init = Rng::new(self.seed ^ 0x4c52_4b53).fork(bucket as u64);
        'comp: for _ in 0..ke {
            let mut v: Vec<f64> = (0..m).map(|_| init.normal()).collect();
            if !normalize(&mut v) {
                v[0] = 1.0;
            }
            for _ in 0..POWER_ITERS {
                let mut nv = vec![0.0f64; m];
                for i in 0..m {
                    let mut s = 0.0f64;
                    for j in 0..m {
                        s += gm[i * m + j] * v[j];
                    }
                    nv[i] = s;
                }
                // Re-orthogonalize against extracted components for
                // numerical stability (deflation alone drifts).
                for u in &basis {
                    let d: f64 = u.iter().zip(nv.iter()).map(|(a, b)| a * b).sum();
                    for i in 0..m {
                        nv[i] -= d * u[i];
                    }
                }
                if !normalize(&mut nv) {
                    break 'comp; // remaining spectrum is numerically zero
                }
                v = nv;
            }
            let mut lam = 0.0f64;
            for i in 0..m {
                let mut s = 0.0f64;
                for j in 0..m {
                    s += gm[i * m + j] * v[j];
                }
                lam += v[i] * s;
            }
            if !(lam > 1e-30) {
                break;
            }
            for i in 0..m {
                for j in 0..m {
                    gm[i * m + j] -= lam * v[i] * v[j];
                }
            }
            basis.push(v);
        }
        // Â = U·(Uᵀ·X); with an empty basis the sketch is the zero matrix
        // and EF carries the whole signal to later steps.
        let kb = basis.len();
        let mut p = vec![0.0f64; kb * w];
        for (j, u) in basis.iter().enumerate() {
            for i in 0..m {
                let uji = u[i];
                for c in 0..w {
                    p[j * w + c] += uji * x[i * w + c] as f64;
                }
            }
        }
        for i in 0..m {
            let row = &mut set.row_mut(i)[lo..hi];
            for c in 0..w {
                let mut s = 0.0f64;
                for (j, u) in basis.iter().enumerate() {
                    s += u[i] * p[j * w + c];
                }
                let a = s as f32;
                bank[i * w + c] = x[i * w + c] - a;
                row[c] = a;
            }
        }
    }
}

/// Normalize `v` in place; false if its norm is numerically zero.
fn normalize(v: &mut [f64]) -> bool {
    let n: f64 = v.iter().map(|a| a * a).sum::<f64>().sqrt();
    if n <= 1e-300 {
        return false;
    }
    for a in v.iter_mut() {
        *a /= n;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds_and_scopes() {
        assert_eq!(CompressorKind::parse("none").unwrap(), CompressorKind::None);
        assert_eq!(CompressorKind::parse("int8").unwrap(), CompressorKind::Int8);
        assert_eq!(CompressorKind::parse("fp16").unwrap(), CompressorKind::Fp16);
        assert_eq!(
            CompressorKind::parse("lowrank:3").unwrap(),
            CompressorKind::LowRank { k: 3 }
        );
        assert_eq!(
            CompressorKind::parse("topk:0.05").unwrap(),
            CompressorKind::TopK { ratio: 0.05 }
        );
        for bad in ["lowrank:0", "topk:0", "topk:1.5", "int4", "lowrank:x"] {
            assert!(CompressorKind::parse(bad).is_err(), "{bad}");
        }
        assert_eq!(CompressScope::parse("all").unwrap(), CompressScope::All);
        assert_eq!(CompressScope::parse("inter").unwrap(), CompressScope::Inter);
        assert!(CompressScope::parse("intra").is_err());
        // Tags round-trip so bench rows can be replayed as CLI values.
        for k in ["none", "int8", "fp16", "lowrank:2", "topk:0.01"] {
            let parsed = CompressorKind::parse(k).unwrap();
            assert_eq!(CompressorKind::parse(&parsed.tag()).unwrap(), parsed);
        }
    }

    #[test]
    fn f16_bits_roundtrip_all_patterns() {
        // decode(encode) is identity on every non-NaN f16 bit pattern —
        // zeros, subnormals, normals, ±Inf.
        for h in 0..=u16::MAX {
            let exp = (h >> 10) & 0x1f;
            let mant = h & 0x3ff;
            if exp == 0x1f && mant != 0 {
                let f = f16_bits_to_f32(h);
                assert!(f.is_nan());
                continue;
            }
            let back = f32_to_f16_bits(f16_bits_to_f32(h));
            assert_eq!(back, h, "pattern {h:#06x}");
        }
    }

    #[test]
    fn f16_rounds_to_nearest_even_and_saturates() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16; RNE
        // keeps the even mantissa (1.0).
        assert_eq!(f32_to_f16_bits(1.0 + 0.000_488_281_25), 0x3c00);
        // Just above halfway rounds up.
        assert_eq!(f32_to_f16_bits(1.0 + 0.000_489_f32), 0x3c01);
        assert_eq!(f32_to_f16_bits(70000.0), 0x7c00); // > 65504 → +Inf
        assert_eq!(f32_to_f16_bits(-70000.0), 0xfc00);
        assert_eq!(f32_to_f16_bits(1e-10), 0x0000); // underflow → +0
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn int8_is_deterministic_per_key_and_varies_by_step() {
        let q = Int8Quantizer;
        let x: Vec<f32> = (0..64).map(|i| ((i * 37 % 13) as f32 - 6.0) * 0.1).collect();
        let key = |step: u64| Rng::new(7).fork(step).fork(3).fork(1);
        let a = q.encode(&x, &mut key(5));
        let b = q.encode(&x, &mut key(5));
        let c = q.encode(&x, &mut key(6));
        assert_eq!(a, b);
        assert_ne!(a, c, "different step key must draw different rounding");
    }

    #[test]
    fn int8_error_feedback_is_unbiased_over_steps() {
        // Constant input, EF on: the running mean of the decoded stream
        // converges to the input (residual stays bounded by one quantum).
        let mut codec = RankCodec::new(CompressorKind::Int8, 11, 0, 1);
        let cols = vec![0.031_f32, -0.77, 0.5, 0.123];
        let mut sums = vec![0.0f64; cols.len()];
        let steps = 400;
        for s in 0..steps {
            let d = codec.encode_bucket(s, 0, &cols).decode();
            for (acc, v) in sums.iter_mut().zip(d.iter()) {
                *acc += *v as f64;
            }
        }
        for (acc, &c) in sums.iter().zip(cols.iter()) {
            let mean = *acc / steps as f64;
            // One int8 quantum of the largest entry is 0.77/127 ≈ 6e-3;
            // the time-averaged EF error must be far inside it.
            assert!(
                (mean - c as f64).abs() < 1e-3,
                "mean {mean} vs {c} drifted"
            );
        }
    }

    #[test]
    fn fp16_residual_persists_across_steps() {
        // 0.1 is not representable in binary16; the dropped bits must land
        // in the residual and re-enter the next encode.
        let mut codec = RankCodec::new(CompressorKind::Fp16, 0, 0, 2);
        let cols = vec![0.1_f32; 8];
        let p1 = codec.encode_bucket(0, 1, &cols);
        let d1 = p1.decode();
        assert!((d1[0] - 0.1).abs() > 0.0, "0.1 must quantize inexactly");
        // Second step sees x = 0.1 + e, so its payload differs from a
        // fresh codec's (the residual is live state).
        let p2 = codec.encode_bucket(1, 1, &cols);
        let fresh = RankCodec::new(CompressorKind::Fp16, 0, 0, 2).encode_bucket(1, 1, &cols);
        assert_ne!(p2, fresh);
        // And the two-step decoded sum is closer to the true sum than the
        // no-EF sum.
        let ef_sum = d1[0] + p2.decode()[0];
        let raw_sum = 2.0 * d1[0];
        assert!((ef_sum - 0.2).abs() < (raw_sum - 0.2).abs());
    }

    #[test]
    fn topk_tie_break_is_lowest_index_and_ef_ships_the_tail() {
        let t = TopKSparsifier { ratio: 0.5 };
        let mut rng = Rng::new(0);
        let p = t.encode(&[1.0, 1.0, 1.0, 1.0], &mut rng);
        match &p {
            Payload::TopK { idx, .. } => assert_eq!(idx, &vec![0, 1]),
            _ => panic!("want TopK"),
        }
        // A small entry starved by top-k accumulates in the residual until
        // it outgrows the big one and finally ships.
        let mut codec = RankCodec::new(CompressorKind::TopK { ratio: 0.5 }, 0, 0, 1);
        let cols = vec![1.0_f32, 0.3];
        let mut shipped_small = false;
        for s in 0..8 {
            if let Payload::TopK { idx, .. } = codec.encode_bucket(s, 0, &cols) {
                if idx.contains(&1) {
                    shipped_small = true;
                    break;
                }
            }
        }
        assert!(shipped_small, "EF never released the small coordinate");
    }

    #[test]
    fn residual_reset_and_ragged_width_reinit() {
        let mut codec = RankCodec::new(CompressorKind::Fp16, 0, 2, 3);
        let cols = vec![0.1_f32; 10];
        let first = codec.encode_bucket(0, 0, &cols);
        let _ = codec.encode_bucket(1, 0, &cols); // residual now nonzero
        codec.reset();
        // After reset the codec behaves exactly like a fresh one.
        assert_eq!(codec.encode_bucket(0, 0, &cols), first);
        // A ragged (shorter) last-bucket width re-initializes the bank
        // rather than indexing out of bounds.
        let short = vec![0.1_f32; 7];
        let p = codec.encode_bucket(2, 0, &short);
        assert_eq!(p.n_cols(), 7);
        let again = vec![0.1_f32; 10];
        assert_eq!(codec.encode_bucket(3, 0, &again).n_cols(), 10);
    }

    #[test]
    fn rank_codec_residual_export_import_is_bitwise() {
        // A checkpointed codec must continue exactly where it stopped: the
        // imported residual produces the same payload the uninterrupted
        // codec would.
        let cols = vec![0.1_f32; 10];
        let mut a = RankCodec::new(CompressorKind::Fp16, 3, 1, 2);
        let _ = a.encode_bucket(0, 0, &cols);
        let _ = a.encode_bucket(0, 1, &cols);
        let snapshot = a.export_residuals();
        let mut b = RankCodec::new(CompressorKind::Fp16, 3, 1, 2);
        b.import_residuals(snapshot);
        assert_eq!(a.encode_bucket(1, 0, &cols), b.encode_bucket(1, 0, &cols));
        assert_eq!(a.encode_bucket(1, 1, &cols), b.encode_bucket(1, 1, &cols));
        // A bucket-count mismatch keeps the fresh residuals.
        let mut c = RankCodec::new(CompressorKind::Fp16, 3, 1, 5);
        c.import_residuals(vec![vec![1.0]; 2]);
        assert!(c.export_residuals().iter().all(|r| r.is_empty()));
    }

    #[test]
    fn set_codec_state_export_import_is_bitwise() {
        let mk_set = || {
            let rows: Vec<Vec<f32>> = (0..3)
                .map(|i| (0..8).map(|j| 0.1 * (i * 8 + j) as f32 + 0.05).collect())
                .collect();
            GradSet::from_rows(&rows)
        };
        let a = SetCodec::new(CompressorKind::Int8, 7, 2);
        let mut sa = mk_set();
        a.transform(0, &mut sa, 0, 8);
        a.transform(1, &mut sa, 0, 8);
        a.advance_step();
        let (step, banks) = a.export_state();
        assert_eq!(step, 1);
        let b = SetCodec::new(CompressorKind::Int8, 7, 2);
        b.import_state(step, banks);
        let mut na = mk_set();
        let mut nb = mk_set();
        a.transform(0, &mut na, 0, 8);
        b.transform(0, &mut nb, 0, 8);
        for i in 0..3 {
            assert_eq!(na.row(i), nb.row(i), "row {i}");
        }
        // Mismatched bank count is ignored.
        let c = SetCodec::new(CompressorKind::Int8, 7, 4);
        c.import_state(9, vec![Vec::new(); 2]);
        assert_eq!(c.export_state().0, 0);
    }

    #[test]
    fn nan_payloads_bypass_codec_and_residual() {
        let mut codec = RankCodec::new(CompressorKind::Int8, 0, 0, 1);
        let clean = vec![0.5_f32, -0.25, 0.125];
        let _ = codec.encode_bucket(0, 0, &clean); // seed some residual
        let before = codec.residuals[0].clone();
        let poisoned = vec![0.5_f32, f32::NAN, f32::INFINITY];
        let p = codec.encode_bucket(1, 0, &poisoned);
        match &p {
            Payload::Raw(v) => {
                // Bitwise pass-through, NaN included.
                assert_eq!(v[0].to_bits(), poisoned[0].to_bits());
                assert!(v[1].is_nan());
                assert_eq!(v[2].to_bits(), poisoned[2].to_bits());
            }
            _ => panic!("poisoned bucket must ship Raw"),
        }
        assert_eq!(codec.residuals[0], before, "residual must be untouched");
    }

    #[test]
    fn none_and_lowrank_rank_codecs_are_raw_passthrough() {
        for kind in [CompressorKind::None, CompressorKind::LowRank { k: 2 }] {
            let mut codec = RankCodec::new(kind, 9, 1, 2);
            let cols = vec![0.25_f32, -1.5, 3.0];
            match codec.encode_bucket(4, 1, &cols) {
                Payload::Raw(v) => assert_eq!(v, cols),
                p => panic!("{kind:?} must pass through Raw, got {p:?}"),
            }
        }
    }

    #[test]
    fn payload_wire_bytes_match_the_kind_model() {
        let x: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.01).collect();
        let mut rng = Rng::new(1).fork(0).fork(0).fork(0);
        for kind in [
            CompressorKind::Int8,
            CompressorKind::Fp16,
            CompressorKind::TopK { ratio: 0.07 },
        ] {
            let comp = kind.row_compressor().unwrap();
            let p = comp.encode(&x, &mut rng);
            assert_eq!(p.wire_bytes(), kind.bucket_wire_bytes(x.len(), 8), "{kind:?}");
        }
        // Compression must actually be smaller than f32 for real widths.
        let raw = CompressorKind::None.bucket_wire_bytes(1024, 8);
        assert!(CompressorKind::Fp16.bucket_wire_bytes(1024, 8) < raw);
        assert!(CompressorKind::Int8.bucket_wire_bytes(1024, 8) < raw);
        assert!(CompressorKind::TopK { ratio: 0.01 }.bucket_wire_bytes(1024, 8) < raw);
        assert!(CompressorKind::LowRank { k: 2 }.bucket_wire_bytes(1024, 8) < raw);
    }

    #[test]
    fn payload_decode_matches_n_cols() {
        let p = Payload::TopK {
            n_cols: 6,
            idx: vec![1, 4],
            vals: vec![2.0, -3.0],
        };
        assert_eq!(p.n_cols(), 6);
        assert_eq!(p.decode(), vec![0.0, 2.0, 0.0, 0.0, -3.0, 0.0]);
        let raw = Payload::Raw(vec![1.0, 2.0]);
        assert_eq!(raw.clone().into_cols(), raw.decode());
    }

    fn set_from(rows: &[Vec<f32>]) -> GradSet {
        GradSet::from_rows(rows)
    }

    #[test]
    fn lowrank_reconstructs_genuinely_lowrank_sets() {
        // X = u·vᵀ is exactly rank 1, so a k=1 sketch reproduces it to
        // f32 precision and the residual is ~0.
        let u = [1.0f32, -2.0, 0.5, 3.0];
        let v: Vec<f32> = (0..16).map(|c| (c as f32 * 0.37).sin()).collect();
        let rows: Vec<Vec<f32>> = u
            .iter()
            .map(|&ui| v.iter().map(|&vc| ui * vc).collect())
            .collect();
        let mut set = set_from(&rows);
        let codec = SetCodec::new(CompressorKind::LowRank { k: 1 }, 0, 1);
        codec.transform(0, &mut set, 0, 16);
        for (i, row) in rows.iter().enumerate() {
            for (c, &want) in row.iter().enumerate() {
                let got = set.row(i)[c];
                assert!(
                    (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "({i},{c}): {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn lowrank_offset_invariance_full_range_vs_view() {
        // The executor calls transform either on the full set at [lo, hi)
        // (overlap off) or on an owned per-bucket view at [0, w) (overlap
        // on). Both must produce bitwise-identical columns.
        let mut rng = Rng::new(42);
        let d = 24;
        let (lo, hi) = (8, 19);
        let rows: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..d).map(|_| rng.normal_f32(1.0)).collect())
            .collect();
        let mut full = set_from(&rows);
        let view_rows: Vec<Vec<f32>> = rows.iter().map(|r| r[lo..hi].to_vec()).collect();
        let mut view = set_from(&view_rows);
        let ca = SetCodec::new(CompressorKind::LowRank { k: 2 }, 3, 2);
        let cb = SetCodec::new(CompressorKind::LowRank { k: 2 }, 3, 2);
        // Two steps so the EF bank participates in the comparison.
        for _ in 0..2 {
            ca.transform(1, &mut full, lo, hi);
            cb.transform(1, &mut view, 0, hi - lo);
            ca.advance_step();
            cb.advance_step();
            for i in 0..5 {
                for c in 0..(hi - lo) {
                    assert_eq!(
                        full.row(i)[lo + c].to_bits(),
                        view.row(i)[c].to_bits(),
                        "row {i} col {c}"
                    );
                }
            }
        }
    }

    #[test]
    fn set_codec_rows_match_rank_codec_bits() {
        // The hier inter path runs the same row compressors through
        // SetCodec with the row index as the rank key — given the same
        // (seed, step, row, bucket) key the bits must match RankCodec's.
        let rows: Vec<Vec<f32>> = (0..3)
            .map(|r| (0..12).map(|c| ((r * 12 + c) as f32 * 0.711).cos()).collect())
            .collect();
        let mut set = set_from(&rows);
        let sc = SetCodec::new(CompressorKind::Int8, 5, 4);
        sc.transform(2, &mut set, 0, 12);
        for (r, row) in rows.iter().enumerate() {
            let mut rc = RankCodec::new(CompressorKind::Int8, 5, r, 4);
            let want = rc.encode_bucket(0, 2, row).decode();
            for c in 0..12 {
                assert_eq!(set.row(r)[c].to_bits(), want[c].to_bits(), "({r},{c})");
            }
        }
    }

    #[test]
    fn set_codec_nan_row_is_transparent_per_kind() {
        // Per-row kinds: only the poisoned row bypasses; lowrank: the
        // whole bucket does (the Gram couples all rows).
        let rows = vec![vec![1.0f32, 2.0], vec![f32::NAN, 1.0], vec![0.5, 0.25]];
        let mut set = set_from(&rows);
        let sc = SetCodec::new(CompressorKind::Fp16, 0, 1);
        sc.transform(0, &mut set, 0, 2);
        assert!(set.row(1)[0].is_nan());
        assert_eq!(set.row(1)[1].to_bits(), 1.0f32.to_bits());
        assert_ne!(set.row(0)[0].to_bits(), f32::NAN.to_bits());
        let mut set2 = set_from(&rows);
        let lr = SetCodec::new(CompressorKind::LowRank { k: 1 }, 0, 1);
        lr.transform(0, &mut set2, 0, 2);
        for (i, row) in rows.iter().enumerate() {
            for c in 0..2 {
                assert_eq!(
                    set2.row(i)[c].to_bits(),
                    row[c].to_bits(),
                    "lowrank must leave the poisoned bucket untouched"
                );
            }
        }
    }

    #[test]
    fn set_codec_reset_restores_fresh_behavior() {
        let rows: Vec<Vec<f32>> = (0..2).map(|r| vec![0.1 * (r + 1) as f32; 6]).collect();
        let sc = SetCodec::new(CompressorKind::Fp16, 0, 1);
        let mut a = set_from(&rows);
        sc.transform(0, &mut a, 0, 6);
        sc.advance_step();
        let mut b = set_from(&rows);
        sc.transform(0, &mut b, 0, 6); // residual-laden second step
        sc.reset();
        let mut c = set_from(&rows);
        sc.transform(0, &mut c, 0, 6);
        for i in 0..2 {
            for col in 0..6 {
                assert_eq!(c.row(i)[col].to_bits(), a.row(i)[col].to_bits());
            }
        }
        // (b differed from a — the residual really was live before reset)
        assert!(b.row(0)[0].to_bits() != a.row(0)[0].to_bits()
            || b.row(1)[0].to_bits() != a.row(1)[0].to_bits());
    }
}
