//! Fused flat-vector primitives. These are the only math on the L3 hot
//! path, so they are written to auto-vectorize: fixed-width unrolled loops
//! over `f32` with `f64` block accumulators (accuracy over 10^8-element
//! gradients) — see EXPERIMENTS.md §Perf for the measured numbers.

/// Column chunk size for the fused statistics passes. Swept in the §Perf
/// pass (EXPERIMENTS.md): 1024 f32 = 4 KiB/row keeps a worker row chunk +
/// the mean chunk L1-resident even at N = 32 (2048 ties at N = 8 but is
/// ~11% slower at N = 32; 8192 spills L1 and loses ~25%). The parallel
/// shard planner (`parallel::plan_shards`) aligns shard boundaries to this
/// grid so sharded kernels see the same chunk sequence as the serial loop.
pub const CHUNK: usize = 1024;

/// Dot product with f64 accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] as f64 * b[j] as f64;
        acc[1] += a[j + 1] as f64 * b[j + 1] as f64;
        acc[2] += a[j + 2] as f64 * b[j + 2] as f64;
        acc[3] += a[j + 3] as f64 * b[j + 3] as f64;
    }
    let mut tail = 0.0f64;
    for j in chunks * 4..a.len() {
        tail += a[j] as f64 * b[j] as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// Squared L2 norm with f64 accumulation.
pub fn sqnorm(a: &[f32]) -> f64 {
    dot(a, a)
}

/// L2 norm.
pub fn nrm2(a: &[f32]) -> f64 {
    sqnorm(a).sqrt()
}

/// Fused `(<a,b>, <a,a>)` over one cache-resident chunk.
///
/// Accumulates in 8 f32 lanes (auto-vectorizes; fine for chunk-sized
/// ranges) and returns f64 — callers accumulate the f64 partials across
/// chunks, which keeps the end-to-end error at the f64 level while the
/// inner loop stays pure f32 SIMD. This is the §Perf replacement for
/// calling `dot` + `sqnorm` separately (one read of `a` instead of two,
/// no per-element f64 converts).
pub fn dot_sqnorm_fused(a: &[f32], b: &[f32]) -> (f64, f64) {
    debug_assert_eq!(a.len(), b.len());
    const LANES: usize = 8;
    let mut dot_acc = [0.0f32; LANES];
    let mut sq_acc = [0.0f32; LANES];
    let chunks = a.len() / LANES;
    for i in 0..chunks {
        let j = i * LANES;
        for l in 0..LANES {
            let av = a[j + l];
            dot_acc[l] += av * b[j + l];
            sq_acc[l] += av * av;
        }
    }
    let mut dot_tail = 0.0f64;
    let mut sq_tail = 0.0f64;
    for j in chunks * LANES..a.len() {
        dot_tail += a[j] as f64 * b[j] as f64;
        sq_tail += a[j] as f64 * a[j] as f64;
    }
    (
        dot_acc.iter().map(|&x| x as f64).sum::<f64>() + dot_tail,
        sq_acc.iter().map(|&x| x as f64).sum::<f64>() + sq_tail,
    )
}

/// Fused `(<a,b>, <a,a>, <b,b>)` with f64 accumulation — one read of each
/// operand for the Adasum pairwise rule (vs three separate passes).
pub fn dot3(a: &[f32], b: &[f32]) -> (f64, f64, f64) {
    debug_assert_eq!(a.len(), b.len());
    let mut ab = [0.0f64; 4];
    let mut aa = [0.0f64; 4];
    let mut bb = [0.0f64; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        for l in 0..4 {
            let av = a[j + l] as f64;
            let bv = b[j + l] as f64;
            ab[l] += av * bv;
            aa[l] += av * av;
            bb[l] += bv * bv;
        }
    }
    let (mut ab_t, mut aa_t, mut bb_t) = (0.0f64, 0.0f64, 0.0f64);
    for j in chunks * 4..a.len() {
        let av = a[j] as f64;
        let bv = b[j] as f64;
        ab_t += av * bv;
        aa_t += av * av;
        bb_t += bv * bv;
    }
    (
        ab[0] + ab[1] + ab[2] + ab[3] + ab_t,
        aa[0] + aa[1] + aa[2] + aa[3] + aa_t,
        bb[0] + bb[1] + bb[2] + bb[3] + bb_t,
    )
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// `y = alpha * x` (overwrite).
pub fn scaled_copy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = alpha * xi;
    }
}

/// `x *= alpha`.
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Fill with a constant.
pub fn fill(x: &mut [f32], v: f32) {
    for xi in x.iter_mut() {
        *xi = v;
    }
}

/// Element sum (f64 accumulate).
pub fn sum(a: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for &x in a {
        acc += x as f64;
    }
    acc
}

/// max |x_i|.
pub fn max_abs(a: &[f32]) -> f32 {
    a.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

/// True if every element is finite.
pub fn all_finite(a: &[f32]) -> bool {
    a.iter().all(|x| x.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive_on_odd_len() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.1 - 5.0).collect();
        let b: Vec<f32> = (0..103).map(|i| 1.0 - (i as f32) * 0.01).collect();
        let naive: f64 = a
            .iter()
            .zip(&b)
            .map(|(x, y)| *x as f64 * *y as f64)
            .sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9);
    }

    #[test]
    fn dot3_matches_separate_passes() {
        let a: Vec<f32> = (0..203).map(|i| (i as f32) * 0.05 - 4.0).collect();
        let b: Vec<f32> = (0..203).map(|i| 2.0 - (i as f32) * 0.02).collect();
        let (ab, aa, bb) = dot3(&a, &b);
        assert!((ab - dot(&a, &b)).abs() < 1e-9);
        assert!((aa - sqnorm(&a)).abs() < 1e-9);
        assert!((bb - sqnorm(&b)).abs() < 1e-9);
    }

    #[test]
    fn norms_and_axpy() {
        let x = vec![3.0f32, 4.0];
        assert!((nrm2(&x) - 5.0).abs() < 1e-12);
        let mut y = vec![1.0f32, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        scaled_copy(0.5, &x, &mut y);
        assert_eq!(y, vec![1.5, 2.0]);
        scale(2.0, &mut y);
        assert_eq!(y, vec![3.0, 4.0]);
    }

    #[test]
    fn misc_helpers() {
        let mut x = vec![0.0f32; 3];
        fill(&mut x, 2.5);
        assert!((sum(&x) - 7.5).abs() < 1e-12);
        assert_eq!(max_abs(&[-3.0, 2.0]), 3.0);
        assert!(all_finite(&x));
        assert!(!all_finite(&[1.0, f32::NAN]));
        assert!(!all_finite(&[f32::INFINITY]));
    }
}
