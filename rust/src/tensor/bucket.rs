//! Parameter bucketization — the DDP-style segmentation of the flat
//! gradient used for layer-wise aggregation (the paper aggregates
//! model-wise by default and reports "similar performance" layer-wise;
//! Table 2's ablation bench exercises both via these buckets).

/// Disjoint, ordered column ranges covering `[0, d)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Buckets {
    bounds: Vec<usize>, // len = num_buckets + 1; bounds[0] = 0, last = d
}

impl Buckets {
    /// One bucket covering everything (model-wise aggregation).
    pub fn single(d: usize) -> Self {
        Buckets { bounds: vec![0, d] }
    }

    /// Fixed-size buckets of at most `cap` elements (DDP gradient buckets).
    pub fn fixed(d: usize, cap: usize) -> Self {
        assert!(cap > 0);
        let mut bounds = vec![0];
        let mut x = 0;
        while x < d {
            x = (x + cap).min(d);
            bounds.push(x);
        }
        if d == 0 {
            bounds.push(0);
        }
        Buckets { bounds }
    }

    /// Buckets from explicit segment sizes (e.g. per-layer parameter counts).
    pub fn from_sizes(sizes: &[usize]) -> Self {
        let mut bounds = vec![0];
        let mut acc = 0;
        for &s in sizes {
            acc += s;
            bounds.push(acc);
        }
        Buckets { bounds }
    }

    pub fn len(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn total(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    pub fn range(&self, i: usize) -> (usize, usize) {
        (self.bounds[i], self.bounds[i + 1])
    }

    pub fn iter(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (0..self.len()).map(|i| self.range(i))
    }

    /// Payload bytes of bucket `i` (full-precision f32 columns).
    pub fn bytes(&self, i: usize) -> usize {
        let (lo, hi) = self.range(i);
        crate::collective::cost_model::f32_wire_bytes(hi - lo)
    }
}

/// Arrival bookkeeping for the pipelined executor: bucket `b` becomes
/// *ready* — eligible for its aggregation task and its simulated
/// collective — once every rank has delivered it.
#[derive(Debug, Clone)]
pub struct BucketTracker {
    counts: Vec<usize>,
    ranks: usize,
}

impl BucketTracker {
    pub fn new(n_buckets: usize, n_ranks: usize) -> Self {
        assert!(n_ranks > 0);
        BucketTracker {
            counts: vec![0; n_buckets],
            ranks: n_ranks,
        }
    }

    /// Clear arrivals for the next step.
    pub fn reset(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
    }

    /// Record one rank's delivery of bucket `b`; returns `true` exactly
    /// when this arrival completes the bucket (ready-edge trigger).
    pub fn arrive(&mut self, b: usize) -> bool {
        self.counts[b] += 1;
        assert!(
            self.counts[b] <= self.ranks,
            "bucket {b} delivered more than once per rank"
        );
        self.counts[b] == self.ranks
    }

    /// True once every rank has delivered bucket `b`.
    pub fn ready(&self, b: usize) -> bool {
        self.counts[b] == self.ranks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_covers_all() {
        let b = Buckets::single(100);
        assert_eq!(b.len(), 1);
        assert_eq!(b.range(0), (0, 100));
        assert_eq!(b.total(), 100);
    }

    #[test]
    fn fixed_partitions_exactly() {
        let b = Buckets::fixed(10, 4);
        let ranges: Vec<_> = b.iter().collect();
        assert_eq!(ranges, vec![(0, 4), (4, 8), (8, 10)]);
        // ranges tile [0, d) with no gaps or overlaps
        let mut x = 0;
        for (lo, hi) in b.iter() {
            assert_eq!(lo, x);
            assert!(hi > lo);
            x = hi;
        }
        assert_eq!(x, 10);
    }

    #[test]
    fn from_sizes_matches_layers() {
        let b = Buckets::from_sizes(&[3, 5, 2]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.range(1), (3, 8));
        assert_eq!(b.total(), 10);
    }

    #[test]
    fn fixed_divisible() {
        let b = Buckets::fixed(8, 4);
        assert_eq!(b.len(), 2);
        assert_eq!(b.range(1), (4, 8));
    }

    #[test]
    fn tracker_fires_once_per_bucket() {
        let mut t = BucketTracker::new(2, 3);
        assert!(!t.arrive(0));
        assert!(!t.arrive(0));
        assert!(!t.ready(0));
        assert!(t.arrive(0)); // third rank completes it
        assert!(t.ready(0));
        assert!(!t.ready(1));
        t.reset();
        assert!(!t.ready(0));
        assert!(!t.arrive(0));
    }

    #[test]
    fn bucket_bytes() {
        let b = Buckets::fixed(10, 4);
        assert_eq!(b.bytes(0), 16);
        assert_eq!(b.bytes(2), 8); // ragged tail
    }
}
