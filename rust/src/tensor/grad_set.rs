//! [`GradSet`] — the N worker gradients as one row-major `(N, d)` buffer.
//!
//! This mirrors the Pallas consensus kernel's memory layout (one DMA-able
//! row per worker) and lets the fused statistics pass stream column chunks
//! through L1/L2 cache: for each chunk we compute the chunk mean and
//! immediately the per-row partial dots, so `P` is read **once** per
//! statistics pass instead of twice (mean pass + dot pass).

use super::ops;

/// Row-major (N, d) gradient matrix.
#[derive(Debug, Clone)]
pub struct GradSet {
    data: Vec<f32>,
    n: usize,
    d: usize,
}

/// Per-worker consensus statistics (paper Eq. 7 inputs).
#[derive(Debug, Clone, PartialEq)]
pub struct ConsensusStats {
    /// `dots[i] = <g_i, g_bar>` with `g_bar` the mean gradient.
    pub dots: Vec<f64>,
    /// `sqn[i] = ||g_i||^2`.
    pub sqn: Vec<f64>,
}

/// Column chunk size for the fused statistics pass. Swept in the §Perf
/// pass (EXPERIMENTS.md): 1024 f32 = 4 KiB/row keeps a worker row chunk +
/// the mean chunk L1-resident even at N = 32 (2048 ties at N = 8 but is
/// ~11% slower at N = 32; 8192 spills L1 and loses ~25%).
const CHUNK: usize = 1024;

impl GradSet {
    pub fn zeros(n: usize, d: usize) -> Self {
        GradSet {
            data: vec![0.0; n * d],
            n,
            d,
        }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let n = rows.len();
        assert!(n > 0);
        let d = rows[0].len();
        let mut data = Vec::with_capacity(n * d);
        for r in rows {
            assert_eq!(r.len(), d, "ragged gradient rows");
            data.extend_from_slice(r);
        }
        GradSet { data, n, d }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// Overwrite row `i`.
    pub fn set_row(&mut self, i: usize, src: &[f32]) {
        assert_eq!(src.len(), self.d);
        self.row_mut(i).copy_from_slice(src);
    }

    /// Mean gradient into `out` (the Sum/averaging baseline's entire job).
    pub fn mean_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.d);
        // Chunk over columns so the accumulator stays in L1 instead of
        // streaming the whole d-vector through memory N times (§Perf).
        let inv_n = 1.0 / self.n as f32;
        let mut start = 0;
        while start < self.d {
            let end = (start + CHUNK).min(self.d);
            let oc = &mut out[start..end];
            ops::fill(oc, 0.0);
            for i in 0..self.n {
                ops::axpy(1.0, &self.data[i * self.d + start..i * self.d + end], oc);
            }
            ops::scale(inv_n, oc);
            start = end;
        }
    }

    /// Fused single-pass consensus statistics (Eq. 7): per column chunk,
    /// build the chunk mean then accumulate each row's partial dot and
    /// squared norm. Reads the matrix exactly once.
    pub fn consensus_stats(&self) -> ConsensusStats {
        let mut dots = vec![0.0f64; self.n];
        let mut sqn = vec![0.0f64; self.n];
        let mut mean_chunk = vec![0.0f32; CHUNK.min(self.d.max(1))];
        let inv_n = 1.0 / self.n as f32;
        let mut start = 0;
        while start < self.d {
            let end = (start + CHUNK).min(self.d);
            let w = end - start;
            let mc = &mut mean_chunk[..w];
            ops::fill(mc, 0.0);
            for i in 0..self.n {
                let row = &self.data[i * self.d + start..i * self.d + end];
                ops::axpy(1.0, row, mc);
            }
            ops::scale(inv_n, mc);
            for i in 0..self.n {
                let row = &self.data[i * self.d + start..i * self.d + end];
                let (dt, sq) = ops::dot_sqnorm_fused(row, mc);
                dots[i] += dt;
                sqn[i] += sq;
            }
            start = end;
        }
        ConsensusStats { dots, sqn }
    }

    /// Consensus statistics restricted to a column range (layer-wise /
    /// bucketed aggregation).
    pub fn consensus_stats_range(&self, lo: usize, hi: usize) -> ConsensusStats {
        assert!(lo <= hi && hi <= self.d);
        let mut dots = vec![0.0f64; self.n];
        let mut sqn = vec![0.0f64; self.n];
        let mut mean_chunk = vec![0.0f32; CHUNK.min((hi - lo).max(1))];
        let inv_n = 1.0 / self.n as f32;
        let mut start = lo;
        while start < hi {
            let end = (start + CHUNK).min(hi);
            let w = end - start;
            let mc = &mut mean_chunk[..w];
            ops::fill(mc, 0.0);
            for i in 0..self.n {
                let row = &self.data[i * self.d + start..i * self.d + end];
                ops::axpy(1.0, row, mc);
            }
            ops::scale(inv_n, mc);
            for i in 0..self.n {
                let row = &self.data[i * self.d + start..i * self.d + end];
                let (dt, sq) = ops::dot_sqnorm_fused(row, mc);
                dots[i] += dt;
                sqn[i] += sq;
            }
            start = end;
        }
        ConsensusStats { dots, sqn }
    }

    /// `out = sum_i gamma[i] * g_i` (the Eq. 12 re-projection).
    pub fn weighted_sum_into(&self, gamma: &[f32], out: &mut [f32]) {
        assert_eq!(gamma.len(), self.n);
        assert_eq!(out.len(), self.d);
        self.weighted_sum_range_into(gamma, 0, self.d, out);
    }

    /// Weighted sum over a column range.
    pub fn weighted_sum_range_into(&self, gamma: &[f32], lo: usize, hi: usize, out: &mut [f32]) {
        assert_eq!(gamma.len(), self.n);
        assert_eq!(out.len(), hi - lo);
        // Chunked accumulation: the out-chunk stays in L1 across the N
        // row passes (§Perf — see EXPERIMENTS.md).
        let mut start = lo;
        while start < hi {
            let end = (start + CHUNK).min(hi);
            let oc = &mut out[start - lo..end - lo];
            ops::fill(oc, 0.0);
            for i in 0..self.n {
                let row = &self.data[i * self.d + start..i * self.d + end];
                ops::axpy(gamma[i], row, oc);
            }
            start = end;
        }
    }

    /// Full N x N Gram matrix (preconditioner perspective, Eq. 9); used by
    /// Adasum-style baselines and diagnostics, not the AdaCons hot path.
    pub fn gram(&self) -> Vec<f64> {
        let mut g = vec![0.0f64; self.n * self.n];
        for i in 0..self.n {
            for j in i..self.n {
                let v = ops::dot(self.row(i), self.row(j));
                g[i * self.n + j] = v;
                g[j * self.n + i] = v;
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_set(n: usize, d: usize, seed: u64) -> GradSet {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal_f32(1.0)).collect())
            .collect();
        GradSet::from_rows(&rows)
    }

    #[test]
    fn mean_matches_naive() {
        let gs = random_set(5, 97, 0);
        let mut out = vec![0.0f32; 97];
        gs.mean_into(&mut out);
        for j in 0..97 {
            let naive: f32 = (0..5).map(|i| gs.row(i)[j]).sum::<f32>() / 5.0;
            assert!((out[j] - naive).abs() < 1e-5);
        }
    }

    #[test]
    fn consensus_stats_match_two_pass_naive() {
        // d > CHUNK to exercise the chunked path.
        let gs = random_set(4, 5000, 1);
        let mut mean = vec![0.0f32; 5000];
        gs.mean_into(&mut mean);
        let stats = gs.consensus_stats();
        for i in 0..4 {
            let dn = ops::dot(gs.row(i), &mean);
            let sn = ops::sqnorm(gs.row(i));
            assert!((stats.dots[i] - dn).abs() < 1e-4 * dn.abs().max(1.0));
            assert!((stats.sqn[i] - sn).abs() < 1e-6 * sn);
        }
    }

    #[test]
    fn range_stats_match_full_on_whole_range() {
        let gs = random_set(3, 301, 2);
        let full = gs.consensus_stats();
        let ranged = gs.consensus_stats_range(0, 301);
        for i in 0..3 {
            assert!((full.dots[i] - ranged.dots[i]).abs() < 1e-9);
            assert!((full.sqn[i] - ranged.sqn[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn weighted_sum_uniform_recovers_mean() {
        let gs = random_set(6, 128, 3);
        let mut mean = vec![0.0f32; 128];
        gs.mean_into(&mut mean);
        let gamma = vec![1.0 / 6.0; 6];
        let mut out = vec![0.0f32; 128];
        gs.weighted_sum_into(&gamma, &mut out);
        for j in 0..128 {
            assert!((out[j] - mean[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn gram_is_symmetric_and_diag_is_sqnorm() {
        let gs = random_set(4, 50, 4);
        let g = gs.gram();
        let stats = gs.consensus_stats();
        for i in 0..4 {
            // stats accumulate f32 lanes within chunks (see ops::dot_sqnorm_fused)
            assert!((g[i * 4 + i] - stats.sqn[i]).abs() < 1e-4 * stats.sqn[i]);
            for j in 0..4 {
                assert_eq!(g[i * 4 + j], g[j * 4 + i]);
            }
        }
    }

    #[test]
    fn dots_relate_gram_rows_to_mean() {
        let gs = random_set(5, 64, 5);
        let g = gs.gram();
        let stats = gs.consensus_stats();
        for i in 0..5 {
            let from_gram: f64 = (0..5).map(|j| g[i * 5 + j]).sum::<f64>() / 5.0;
            assert!((stats.dots[i] - from_gram).abs() < 1e-6 * from_gram.abs().max(1.0));
        }
    }
}
