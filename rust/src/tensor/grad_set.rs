//! [`GradSet`] — the N worker gradients as one row-major `(N, d)` buffer.
//!
//! This mirrors the Pallas consensus kernel's memory layout (one DMA-able
//! row per worker) and lets the fused statistics pass stream column chunks
//! through L1/L2 cache: for each chunk we compute the chunk mean and
//! immediately the per-row partial dots, so `P` is read **once** per
//! statistics pass instead of twice (mean pass + dot pass).
//!
//! Every hot-path kernel comes in two forms: a `_ctx` variant that fans
//! column shards out across a [`ParallelCtx`]'s worker pool, and a serial
//! convenience wrapper that runs the same sharded code inline. The shard
//! plan and the fixed-order tree reduction of `(dots, sqn)` partials
//! depend only on the range and the policy's `min_shard_elems` — never on
//! the thread count — so results are bitwise-identical at any parallelism
//! (covered by `tests/parallel_equivalence.rs`).

use super::ops;
use crate::parallel::ParallelCtx;

pub use crate::tensor::ops::CHUNK;

/// Row-major (N, d) gradient matrix.
#[derive(Debug, Clone)]
pub struct GradSet {
    data: Vec<f32>,
    n: usize,
    d: usize,
}

/// Per-worker consensus statistics (paper Eq. 7 inputs).
#[derive(Debug, Clone, PartialEq)]
pub struct ConsensusStats {
    /// `dots[i] = <g_i, g_bar>` with `g_bar` the mean gradient.
    pub dots: Vec<f64>,
    /// `sqn[i] = ||g_i||^2`.
    pub sqn: Vec<f64>,
}

/// One shard of the fused statistics pass: per column chunk, build the
/// chunk mean then accumulate each row's partial dot and squared norm.
/// Reads the shard's columns of the matrix exactly once.
fn stats_shard(
    data: &[f32],
    n: usize,
    d: usize,
    lo: usize,
    hi: usize,
    dots: &mut [f64],
    sqn: &mut [f64],
) {
    let mut mean_chunk = vec![0.0f32; CHUNK.min((hi - lo).max(1))];
    let inv_n = 1.0 / n as f32;
    let mut start = lo;
    while start < hi {
        let end = (start + CHUNK).min(hi);
        let w = end - start;
        let mc = &mut mean_chunk[..w];
        ops::fill(mc, 0.0);
        for i in 0..n {
            let row = &data[i * d + start..i * d + end];
            ops::axpy(1.0, row, mc);
        }
        ops::scale(inv_n, mc);
        for i in 0..n {
            let row = &data[i * d + start..i * d + end];
            let (dt, sq) = ops::dot_sqnorm_fused(row, mc);
            dots[i] += dt;
            sqn[i] += sq;
        }
        start = end;
    }
}

impl GradSet {
    pub fn zeros(n: usize, d: usize) -> Self {
        GradSet {
            data: vec![0.0; n * d],
            n,
            d,
        }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let n = rows.len();
        assert!(n > 0);
        let d = rows[0].len();
        let mut data = Vec::with_capacity(n * d);
        for r in rows {
            assert_eq!(r.len(), d, "ragged gradient rows");
            data.extend_from_slice(r);
        }
        GradSet { data, n, d }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// Overwrite row `i`.
    pub fn set_row(&mut self, i: usize, src: &[f32]) {
        assert_eq!(src.len(), self.d);
        self.row_mut(i).copy_from_slice(src);
    }

    /// Mean gradient into `out` (the Sum/averaging baseline's entire job).
    pub fn mean_into(&self, out: &mut [f32]) {
        self.mean_into_ctx(out, &ParallelCtx::serial());
    }

    /// Sharded mean: each shard owns a disjoint slice of `out`, chunked so
    /// the accumulator stays in L1 instead of streaming the whole d-vector
    /// through memory N times (§Perf).
    pub fn mean_into_ctx(&self, out: &mut [f32], ctx: &ParallelCtx) {
        self.mean_range_into_ctx(0, self.d, out, ctx)
    }

    /// Mean restricted to a column range (per-bucket view). Column outputs
    /// are independent, so this is bitwise-identical to the corresponding
    /// slice of the full-range mean at any shard plan or thread count.
    pub fn mean_range_into_ctx(&self, lo: usize, hi: usize, out: &mut [f32], ctx: &ParallelCtx) {
        assert!(lo <= hi && hi <= self.d);
        assert_eq!(out.len(), hi - lo);
        let inv_n = 1.0 / self.n as f32;
        let (data, n, d) = (&self.data, self.n, self.d);
        ctx.for_each_out_shard(lo, hi, out, |slo, shi, oslice| {
            let mut start = slo;
            while start < shi {
                let end = (start + CHUNK).min(shi);
                let oc = &mut oslice[start - slo..end - slo];
                ops::fill(oc, 0.0);
                for i in 0..n {
                    ops::axpy(1.0, &data[i * d + start..i * d + end], oc);
                }
                ops::scale(inv_n, oc);
                start = end;
            }
        });
    }

    /// Fused single-pass consensus statistics (Eq. 7); serial wrapper.
    pub fn consensus_stats(&self) -> ConsensusStats {
        self.consensus_stats_range_ctx(0, self.d, &ParallelCtx::serial())
    }

    /// Consensus statistics on the given execution context.
    pub fn consensus_stats_ctx(&self, ctx: &ParallelCtx) -> ConsensusStats {
        self.consensus_stats_range_ctx(0, self.d, ctx)
    }

    /// Consensus statistics restricted to a column range (layer-wise /
    /// bucketed aggregation); serial wrapper.
    pub fn consensus_stats_range(&self, lo: usize, hi: usize) -> ConsensusStats {
        self.consensus_stats_range_ctx(lo, hi, &ParallelCtx::serial())
    }

    /// Sharded consensus statistics over `[lo, hi)`: per-shard `(dots,
    /// sqn)` partials computed in parallel, folded by the context's
    /// fixed-order tree reduction (bitwise-reproducible at any thread
    /// count).
    pub fn consensus_stats_range_ctx(
        &self,
        lo: usize,
        hi: usize,
        ctx: &ParallelCtx,
    ) -> ConsensusStats {
        assert!(lo <= hi && hi <= self.d);
        let (data, n, d) = (&self.data, self.n, self.d);
        let folded = ctx.map_reduce(
            lo,
            hi,
            |slo, shi| {
                let mut dots = vec![0.0f64; n];
                let mut sqn = vec![0.0f64; n];
                stats_shard(data, n, d, slo, shi, &mut dots, &mut sqn);
                (dots, sqn)
            },
            |mut a, b| {
                for (x, y) in a.0.iter_mut().zip(&b.0) {
                    *x += *y;
                }
                for (x, y) in a.1.iter_mut().zip(&b.1) {
                    *x += *y;
                }
                a
            },
        );
        match folded {
            Some((dots, sqn)) => ConsensusStats { dots, sqn },
            None => ConsensusStats {
                dots: vec![0.0; n],
                sqn: vec![0.0; n],
            },
        }
    }

    /// `out = scale * Σ_{i in rows} g_i` over columns `[lo, hi)` — the
    /// node-leader reduction of the two-level hierarchical scheme
    /// (`aggregation::hierarchy`). With `scale = G/N` the leader row
    /// carries its group-size weight, so the uniform mean over the G
    /// leaders equals the global N-rank mean (the unbiasedness
    /// invariant). Chunked and sharded exactly like
    /// [`GradSet::mean_range_into_ctx`] (rows accumulated in fixed index
    /// order, then one scalar scale), so the result is bitwise-identical
    /// at any thread count and between a full-matrix view (absolute
    /// `lo..hi`, global row range) and a per-bucket copy (`lo = 0`,
    /// local rows) — the shard plan measures from `lo`.
    pub fn scaled_row_sum_range_into_ctx(
        &self,
        rows: (usize, usize),
        scale: f32,
        lo: usize,
        hi: usize,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) {
        let (r0, r1) = rows;
        assert!(r0 < r1 && r1 <= self.n, "bad row range {r0}..{r1}");
        assert!(lo <= hi && hi <= self.d);
        assert_eq!(out.len(), hi - lo);
        let (data, d) = (&self.data, self.d);
        ctx.for_each_out_shard(lo, hi, out, |slo, shi, oslice| {
            let mut start = slo;
            while start < shi {
                let end = (start + CHUNK).min(shi);
                let oc = &mut oslice[start - slo..end - slo];
                ops::fill(oc, 0.0);
                for i in r0..r1 {
                    ops::axpy(1.0, &data[i * d + start..i * d + end], oc);
                }
                ops::scale(scale, oc);
                start = end;
            }
        });
    }

    /// `out = sum_i gamma[i] * g_i` (the Eq. 12 re-projection).
    pub fn weighted_sum_into(&self, gamma: &[f32], out: &mut [f32]) {
        self.weighted_sum_range_into(gamma, 0, self.d, out);
    }

    /// Weighted sum on the given execution context.
    pub fn weighted_sum_into_ctx(&self, gamma: &[f32], out: &mut [f32], ctx: &ParallelCtx) {
        self.weighted_sum_range_into_ctx(gamma, 0, self.d, out, ctx);
    }

    /// Weighted sum over a column range; serial wrapper.
    pub fn weighted_sum_range_into(&self, gamma: &[f32], lo: usize, hi: usize, out: &mut [f32]) {
        self.weighted_sum_range_into_ctx(gamma, lo, hi, out, &ParallelCtx::serial());
    }

    /// Sharded weighted sum: each shard owns a disjoint slice of `out`,
    /// chunked so the out-chunk stays in L1 across the N row passes
    /// (§Perf — see EXPERIMENTS.md).
    pub fn weighted_sum_range_into_ctx(
        &self,
        gamma: &[f32],
        lo: usize,
        hi: usize,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) {
        assert_eq!(gamma.len(), self.n);
        assert_eq!(out.len(), hi - lo);
        assert!(lo <= hi && hi <= self.d);
        let (data, n, d) = (&self.data, self.n, self.d);
        ctx.for_each_out_shard(lo, hi, out, |slo, shi, oslice| {
            let mut start = slo;
            while start < shi {
                let end = (start + CHUNK).min(shi);
                let oc = &mut oslice[start - slo..end - slo];
                ops::fill(oc, 0.0);
                for i in 0..n {
                    let row = &data[i * d + start..i * d + end];
                    ops::axpy(gamma[i], row, oc);
                }
                start = end;
            }
        });
    }

    /// Full N x N Gram matrix (preconditioner perspective, Eq. 9); used by
    /// Adasum-style baselines and diagnostics, not the AdaCons hot path.
    /// Serial wrapper over the sharded kernel.
    pub fn gram(&self) -> Vec<f64> {
        self.gram_ctx(&ParallelCtx::serial())
    }

    /// Sharded Gram matrix: each shard computes every pair's partial dot
    /// over its columns (upper triangle only), the per-shard `N x N` f64
    /// partials are folded by the context's fixed-order tree, then the
    /// triangle is mirrored. The fold shape depends only on the shard
    /// plan, so the result is bitwise-identical at any thread count
    /// (covered by `tests/parallel_equivalence.rs`).
    pub fn gram_ctx(&self, ctx: &ParallelCtx) -> Vec<f64> {
        let (data, n, d) = (&self.data, self.n, self.d);
        let folded = ctx.map_reduce(
            0,
            d,
            |slo, shi| {
                let mut g = vec![0.0f64; n * n];
                for i in 0..n {
                    let ri = &data[i * d + slo..i * d + shi];
                    for j in i..n {
                        let rj = &data[j * d + slo..j * d + shi];
                        g[i * n + j] = ops::dot(ri, rj);
                    }
                }
                g
            },
            |mut a, b| {
                for (x, y) in a.iter_mut().zip(&b) {
                    *x += *y;
                }
                a
            },
        );
        let mut g = folded.unwrap_or_else(|| vec![0.0f64; n * n]);
        for i in 0..n {
            for j in i + 1..n {
                g[j * n + i] = g[i * n + j];
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::{ParallelCtx, ParallelPolicy};
    use crate::util::prng::Rng;

    fn random_set(n: usize, d: usize, seed: u64) -> GradSet {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal_f32(1.0)).collect())
            .collect();
        GradSet::from_rows(&rows)
    }

    #[test]
    fn mean_matches_naive() {
        let gs = random_set(5, 97, 0);
        let mut out = vec![0.0f32; 97];
        gs.mean_into(&mut out);
        for j in 0..97 {
            let naive: f32 = (0..5).map(|i| gs.row(i)[j]).sum::<f32>() / 5.0;
            assert!((out[j] - naive).abs() < 1e-5);
        }
    }

    #[test]
    fn consensus_stats_match_two_pass_naive() {
        // d > CHUNK to exercise the chunked path.
        let gs = random_set(4, 5000, 1);
        let mut mean = vec![0.0f32; 5000];
        gs.mean_into(&mut mean);
        let stats = gs.consensus_stats();
        for i in 0..4 {
            let dn = ops::dot(gs.row(i), &mean);
            let sn = ops::sqnorm(gs.row(i));
            assert!((stats.dots[i] - dn).abs() < 1e-4 * dn.abs().max(1.0));
            assert!((stats.sqn[i] - sn).abs() < 1e-6 * sn);
        }
    }

    #[test]
    fn range_stats_match_full_on_whole_range() {
        let gs = random_set(3, 301, 2);
        let full = gs.consensus_stats();
        let ranged = gs.consensus_stats_range(0, 301);
        for i in 0..3 {
            assert!((full.dots[i] - ranged.dots[i]).abs() < 1e-9);
            assert!((full.sqn[i] - ranged.sqn[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn weighted_sum_uniform_recovers_mean() {
        let gs = random_set(6, 128, 3);
        let mut mean = vec![0.0f32; 128];
        gs.mean_into(&mut mean);
        let gamma = vec![1.0 / 6.0; 6];
        let mut out = vec![0.0f32; 128];
        gs.weighted_sum_into(&gamma, &mut out);
        for j in 0..128 {
            assert!((out[j] - mean[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn parallel_ctx_kernels_match_serial_wrappers() {
        // Fine shards + several threads vs the serial wrappers; the
        // dedicated bitwise suite lives in tests/parallel_equivalence.rs,
        // this is the in-module smoke.
        let gs = random_set(5, 3 * CHUNK + 123, 7);
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: 4,
            min_shard_elems: CHUNK,
        });
        let st_par = gs.consensus_stats_ctx(&ctx);
        let st_ser = gs.consensus_stats_range_ctx(0, gs.d(), &ParallelCtx::new(ParallelPolicy {
            threads: 1,
            min_shard_elems: CHUNK,
        }));
        assert_eq!(st_par.dots, st_ser.dots);
        assert_eq!(st_par.sqn, st_ser.sqn);
        let mut a = vec![0.0f32; gs.d()];
        let mut b = vec![0.0f32; gs.d()];
        gs.mean_into(&mut a);
        gs.mean_into_ctx(&mut b, &ctx);
        assert_eq!(a, b);
        let gamma: Vec<f32> = (0..5).map(|i| 0.1 + 0.05 * i as f32).collect();
        gs.weighted_sum_into(&gamma, &mut a);
        gs.weighted_sum_into_ctx(&gamma, &mut b, &ctx);
        assert_eq!(a, b);
    }

    #[test]
    fn scaled_row_sum_matches_mean_and_is_view_invariant() {
        let gs = random_set(6, 2 * CHUNK + 77, 9);
        let d = gs.d();
        // scale = 1/rows over the full row range reproduces the mean
        // structure (same chunked accumulate-then-scale sequence).
        let mut mean = vec![0.0f32; d];
        gs.mean_into(&mut mean);
        let mut sum = vec![0.0f32; d];
        gs.scaled_row_sum_range_into_ctx(
            (0, 6),
            1.0 / 6.0,
            0,
            d,
            &mut sum,
            &ParallelCtx::serial(),
        );
        assert_eq!(mean, sum);
        // A row-group reduction over a column sub-range must be bitwise
        // identical between the full matrix (absolute range, global rows)
        // and an owned per-bucket copy (lo = 0, local rows) — what makes
        // the pipelined per-node ingest path equal the inline one.
        let (lo, hi) = (CHUNK + 13, 2 * CHUNK + 50);
        let rows = (2usize, 5usize);
        let mut full_view = vec![0.0f32; hi - lo];
        gs.scaled_row_sum_range_into_ctx(
            rows,
            0.75,
            lo,
            hi,
            &mut full_view,
            &ParallelCtx::serial(),
        );
        let copy = GradSet::from_rows(
            &(rows.0..rows.1)
                .map(|i| gs.row(i)[lo..hi].to_vec())
                .collect::<Vec<_>>(),
        );
        let mut local_view = vec![0.0f32; hi - lo];
        copy.scaled_row_sum_range_into_ctx(
            (0, 3),
            0.75,
            0,
            hi - lo,
            &mut local_view,
            &ParallelCtx::serial(),
        );
        assert_eq!(full_view, local_view);
        // And thread-count free, like every engine kernel.
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: 4,
            min_shard_elems: CHUNK,
        });
        let mut par = vec![0.0f32; hi - lo];
        gs.scaled_row_sum_range_into_ctx(rows, 0.75, lo, hi, &mut par, &ctx);
        assert_eq!(full_view, par);
    }

    #[test]
    fn gram_is_symmetric_and_diag_is_sqnorm() {
        let gs = random_set(4, 50, 4);
        let g = gs.gram();
        let stats = gs.consensus_stats();
        for i in 0..4 {
            // stats accumulate f32 lanes within chunks (see ops::dot_sqnorm_fused)
            assert!((g[i * 4 + i] - stats.sqn[i]).abs() < 1e-4 * stats.sqn[i]);
            for j in 0..4 {
                assert_eq!(g[i * 4 + j], g[j * 4 + i]);
            }
        }
    }

    #[test]
    fn dots_relate_gram_rows_to_mean() {
        let gs = random_set(5, 64, 5);
        let g = gs.gram();
        let stats = gs.consensus_stats();
        for i in 0..5 {
            let from_gram: f64 = (0..5).map(|j| g[i * 5 + j]).sum::<f64>() / 5.0;
            assert!((stats.dots[i] - from_gram).abs() < 1e-6 * from_gram.abs().max(1.0));
        }
    }
}
