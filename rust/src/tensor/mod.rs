//! Flat-tensor substrate: the coordinator's view of parameters/gradients is
//! always a contiguous `f32` vector (flatten/unflatten lives in the L2 JAX
//! graph), so this module provides cache-friendly fused ops over flat
//! buffers plus the row-major [`GradSet`] holding all N worker gradients.

pub mod bucket;
pub mod grad_set;
pub mod ops;

pub use bucket::{BucketTracker, Buckets};
pub use grad_set::GradSet;
