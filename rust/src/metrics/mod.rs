//! Evaluation metrics and result sinks.

pub mod auc;
pub mod map_proxy;
pub mod sink;

pub use auc::auc_from_scores;
pub use map_proxy::map_proxy;
pub use sink::{CsvWriter, JsonlWriter};

/// Top-1 accuracy from a per-example correctness vector (0/1 floats, the
/// eval-artifact output convention).
pub fn accuracy(correct: &[f32]) -> f64 {
    if correct.is_empty() {
        return 0.0;
    }
    correct.iter().map(|&c| c as f64).sum::<f64>() / correct.len() as f64
}

#[cfg(test)]
mod tests {
    #[test]
    fn accuracy_basic() {
        assert_eq!(super::accuracy(&[1.0, 0.0, 1.0, 1.0]), 0.75);
        assert_eq!(super::accuracy(&[]), 0.0);
    }
}
