//! Result sinks: CSV series (one per figure) and JSONL step logs.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::json::Json;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, values: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        writeln!(self.out, "{}", values.join(","))
    }

    pub fn row_display(&mut self, values: &[&dyn std::fmt::Display]) -> std::io::Result<()> {
        let strs: Vec<String> = values.iter().map(|v| v.to_string()).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

impl Drop for CsvWriter {
    /// Best-effort flush: a panic or early return between the last
    /// explicit `flush()` and drop must not truncate the series on disk.
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

/// JSONL step logger.
pub struct JsonlWriter {
    out: BufWriter<File>,
}

impl JsonlWriter {
    pub fn create<P: AsRef<Path>>(path: P) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        Ok(JsonlWriter {
            out: BufWriter::new(File::create(path)?),
        })
    }

    pub fn write(&mut self, record: &Json) -> std::io::Result<()> {
        writeln!(self.out, "{}", record.to_string_compact())
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

impl Drop for JsonlWriter {
    /// Best-effort flush, mirroring [`CsvWriter`]: buffered step records
    /// survive any exit path that drops the writer.
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj};

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("adacons_test_csv");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.row(&["0".into(), "1.5".into()]).unwrap();
            w.row_display(&[&1, &0.75]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n0,1.5\n1,0.75\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("adacons_test_jsonl");
        let path = dir.join("t.jsonl");
        {
            let mut w = JsonlWriter::create(&path).unwrap();
            w.write(&obj(vec![("step", num(1.0)), ("loss", num(0.5))]))
                .unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(text.trim()).unwrap();
        assert_eq!(parsed.get("loss").as_f64().unwrap(), 0.5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writers_flush_on_drop() {
        let dir = std::env::temp_dir().join("adacons_test_sink_drop");
        let csv = dir.join("d.csv");
        let jsonl = dir.join("d.jsonl");
        {
            // No explicit flush: the Drop impls must drain the buffers.
            let mut w = CsvWriter::create(&csv, &["step", "loss"]).unwrap();
            w.row(&["0".into(), "2.25".into()]).unwrap();
            let mut j = JsonlWriter::create(&jsonl).unwrap();
            j.write(&obj(vec![("step", num(0.0)), ("loss", num(2.25))]))
                .unwrap();
        }
        assert_eq!(
            std::fs::read_to_string(&csv).unwrap(),
            "step,loss\n0,2.25\n"
        );
        let text = std::fs::read_to_string(&jsonl).unwrap();
        assert_eq!(
            Json::parse(text.trim()).unwrap().get("loss").as_f64(),
            Some(2.25)
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
