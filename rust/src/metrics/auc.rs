//! ROC AUC via the rank-sum (Mann-Whitney U) estimator, with tie handling
//! by midranks — the DLRM task's target metric.

/// AUC of `scores` against binary `labels` (anything > 0.5 is positive).
pub fn auc_from_scores(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    if n == 0 {
        return 0.5;
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal));
    // midranks for ties
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = mid;
        }
        i = j + 1;
    }
    let pos = labels.iter().filter(|&&l| l > 0.5).count() as f64;
    let neg = n as f64 - pos;
    if pos == 0.0 || neg == 0.0 {
        return 0.5;
    }
    let rank_sum: f64 = (0..n).filter(|&i| labels[i] > 0.5).map(|i| ranks[i]).sum();
    (rank_sum - pos * (pos + 1.0) / 2.0) / (pos * neg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation() {
        let scores = [0.1f32, 0.2, 0.8, 0.9];
        let labels = [0.0f32, 0.0, 1.0, 1.0];
        assert!((auc_from_scores(&scores, &labels) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverted_is_zero() {
        let scores = [0.9f32, 0.8, 0.2, 0.1];
        let labels = [0.0f32, 0.0, 1.0, 1.0];
        assert!(auc_from_scores(&scores, &labels) < 1e-12);
    }

    #[test]
    fn random_is_half() {
        // identical scores => ties => AUC 0.5 by midranks
        let scores = [0.5f32; 10];
        let labels = [1.0f32, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        assert!((auc_from_scores(&scores, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn matches_brute_force_pair_count() {
        let scores = [0.1f32, 0.4, 0.35, 0.8, 0.65, 0.9, 0.5, 0.3];
        let labels = [0.0f32, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0, 0.0];
        // brute force: P(score_pos > score_neg) + 0.5 P(=)
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..8 {
            for j in 0..8 {
                if labels[i] > 0.5 && labels[j] < 0.5 {
                    den += 1.0;
                    if scores[i] > scores[j] {
                        num += 1.0;
                    } else if scores[i] == scores[j] {
                        num += 0.5;
                    }
                }
            }
        }
        assert!((auc_from_scores(&scores, &labels) - num / den).abs() < 1e-12);
    }

    #[test]
    fn degenerate_single_class() {
        assert_eq!(auc_from_scores(&[0.1, 0.9], &[1.0, 1.0]), 0.5);
        assert_eq!(auc_from_scores(&[], &[]), 0.5);
    }
}
