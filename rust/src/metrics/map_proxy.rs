//! mAP-proxy for the synthetic detection task (Fig. 4's metric stand-in).
//!
//! A "detection" at confidence threshold `t` counts as a true positive if
//! the predicted class probability exceeds `t`, the class is correct, and
//! the box L1 error is within `box_tol` (the IoU-gate stand-in). We sweep
//! thresholds, build the precision-recall curve, and integrate — the same
//! shape as COCO-style AP up to the synthetic geometry.

/// `probs`: (B, C) row-major class probabilities; `correct_class`:
/// per-example 0/1 whether argmax == label (precomputed by the eval
/// artifact via `box_l1`-accompanied outputs); here we take the max prob
/// as confidence, `cls_correct[i]` as the match flag.
pub fn map_proxy(max_prob: &[f32], cls_correct: &[f32], box_l1: &[f32], box_tol: f32) -> f64 {
    let n = max_prob.len();
    assert_eq!(n, cls_correct.len());
    assert_eq!(n, box_l1.len());
    if n == 0 {
        return 0.0;
    }
    // Sort by confidence descending; accumulate precision/recall.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| max_prob[b].partial_cmp(&max_prob[a]).unwrap_or(std::cmp::Ordering::Equal));
    let total_gt = n as f64; // one ground-truth object per example
    let mut tp = 0.0f64;
    let mut fp = 0.0f64;
    let mut ap = 0.0f64;
    let mut last_recall = 0.0f64;
    for &i in &idx {
        if cls_correct[i] > 0.5 && box_l1[i] <= box_tol {
            tp += 1.0;
        } else {
            fp += 1.0;
        }
        let precision = tp / (tp + fp);
        let recall = tp / total_gt;
        ap += precision * (recall - last_recall);
        last_recall = recall;
    }
    ap
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_detector_has_ap_one() {
        let probs = [0.9f32, 0.8, 0.7];
        let correct = [1.0f32, 1.0, 1.0];
        let box_l1 = [0.01f32, 0.01, 0.01];
        assert!((map_proxy(&probs, &correct, &box_l1, 0.1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_wrong_is_zero() {
        let probs = [0.9f32, 0.8];
        let correct = [0.0f32, 0.0];
        let box_l1 = [0.01f32, 0.01];
        assert_eq!(map_proxy(&probs, &correct, &box_l1, 0.1), 0.0);
    }

    #[test]
    fn bad_boxes_gate_even_correct_classes() {
        let probs = [0.9f32, 0.8];
        let correct = [1.0f32, 1.0];
        let box_l1 = [10.0f32, 10.0];
        assert_eq!(map_proxy(&probs, &correct, &box_l1, 0.1), 0.0);
    }

    #[test]
    fn confident_mistakes_hurt_more() {
        // Mistake at high confidence lowers AP vs mistake at low confidence.
        let correct_hi = [0.0f32, 1.0, 1.0]; // mistake first (most confident)
        let correct_lo = [1.0f32, 1.0, 0.0]; // mistake last
        let probs = [0.9f32, 0.8, 0.7];
        let boxes = [0.0f32; 3];
        let ap_hi = map_proxy(&probs, &correct_hi, &boxes, 0.1);
        let ap_lo = map_proxy(&probs, &correct_lo, &boxes, 0.1);
        assert!(ap_hi < ap_lo, "{ap_hi} vs {ap_lo}");
    }
}
