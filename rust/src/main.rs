//! `adacons` — the leader binary.
//!
//! Subcommands:
//!   train       — run one training config (JSON file + CLI overrides)
//!   figure      — regenerate a paper figure's series (fig2..fig8 | all)
//!   table       — regenerate a paper table (table1 | table2 | all)
//!   inspect     — list the artifacts in the manifest
//!   trace-check — validate a `--trace-out` Chrome trace (and optionally
//!                 cross-check it against a `--metrics-out` exposition)
//!   help        — this text

use std::sync::Arc;

use adacons::config::TrainConfig;
use adacons::coordinator::{Checkpoint, Trainer};
use adacons::runtime::{Backend, Runtime};
use adacons::util::argparse::Args;
use adacons::util::error::{Context, Result};
use adacons::{bail, ensure};

const USAGE: &str = "\
adacons — Adaptive Consensus Gradients Aggregation (paper reproduction)

USAGE:
  adacons train [--config cfg.json] [--artifact NAME] [--workers N]
                [--backend auto|interp|pjrt]
                [--aggregator mean|adacons|adacons-raw|adacons-momentum|
                 adacons-norm|adasum|grawa|median|trimmed-mean]
                [--optimizer sgd|sgd-momentum|adam|adamw|lamb|linreg-exact]
                [--schedule const:LR|cosine:LR:WARM:TOTAL|step:LR:EVERY:G|invsqrt:LR:WARM]
                [--steps N] [--eval-every N] [--seed S] [--clip C|none]
                [--bucket-cap N] [--overlap on|off] [--rank-threads on|off]
                [--compress none|lowrank:<k>|int8|fp16|topk:<ratio>]
                [--compress-scope all|inter]
                [--topology flat|hier:<nodes>x<gpus>] [--heterogeneity H]
                [--inject RANK:SPEC] [--par-threads N] [--par-min-shard-elems N]
                [--fabric-gbps G] [--save-checkpoint PATH] [--load-checkpoint PATH]
                [--cutoff k-of-n[:grace_ms]|none] [--krum F]
                [--local-steps H|auto:<min>-<max>]
                [--checkpoint-every S --checkpoint-path PATH] [--resume PATH]
                [--csv PATH] [--jsonl PATH]
                [--trace-level off|step|bucket|rank] [--trace-out trace.json]
                [--metrics-out metrics.txt]
                [--log-level error|warn|info|debug|trace]
  adacons figure fig2|fig3|fig4|fig5|fig6|fig7|fig8|all [--out-dir DIR] [--steps-scale F]
  adacons table  table1|table2|all [--out-dir DIR] [--steps-scale F]
  adacons inspect [--backend auto|interp|pjrt]
  adacons trace-check trace.json [--metrics metrics.txt]
  adacons help

The linreg and MLP artifacts run on the native interpreter backend out of
the box; the full artifact set needs `make artifacts` (runs
python/compile/aot.py once) plus a `--features pjrt` build.
";

/// Backend choice for the subcommands that take it straight from Args.
fn backend_arg(args: &Args) -> Result<Backend> {
    match args.str_opt("backend") {
        None => Ok(Backend::Auto),
        Some(v) => {
            Backend::parse(v).with_context(|| format!("--backend {v:?}: want auto|interp|pjrt"))
        }
    }
}

fn main() {
    adacons::util::logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv.remove(0);
    match cmd.as_str() {
        "train" => {
            let args = Args::parse(argv, &[]);
            cmd_train(&args)
        }
        "figure" => {
            ensure!(!argv.is_empty(), "figure id required (fig2..fig8 | all)");
            let id = argv.remove(0);
            let args = Args::parse(argv, &[]);
            let rt = Arc::new(Runtime::open_default_with(backend_arg(&args)?)?);
            adacons::exp::run_figure(rt, &id, &args)
        }
        "table" => {
            ensure!(!argv.is_empty(), "table id required (table1 | table2 | all)");
            let id = argv.remove(0);
            let args = Args::parse(argv, &[]);
            let rt = Arc::new(Runtime::open_default_with(backend_arg(&args)?)?);
            adacons::exp::run_table(rt, &id, &args)
        }
        "inspect" => {
            let args = Args::parse(argv, &[]);
            cmd_inspect(&args)
        }
        "trace-check" => {
            ensure!(!argv.is_empty(), "trace file required (adacons trace-check trace.json)");
            let path = argv.remove(0);
            let args = Args::parse(argv, &[]);
            cmd_trace_check(&path, &args)
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.str_opt("config") {
        Some(path) => TrainConfig::load_file(path)?,
        None => TrainConfig::default(),
    };
    cfg.apply_args(args)?;
    if let Some(s) = &cfg.log_level {
        // validate() already vetted the spec; this override beats ADACONS_LOG.
        let level = adacons::util::logging::Level::parse(s)
            .with_context(|| format!("--log-level {s:?}"))?;
        adacons::util::logging::set_max_level(level);
    }
    let rt = Arc::new(Runtime::open_default_with(cfg.backend)?);
    let mut trainer = Trainer::new(rt, cfg.clone())?;
    if let Some(path) = args.str_opt("resume").or_else(|| args.str_opt("load-checkpoint")) {
        let ck = Checkpoint::load(path)?;
        trainer.restore(&ck).context("restoring checkpoint")?;
        println!("restored checkpoint at step {}", ck.step);
    }
    let res = trainer.run()?;
    println!(
        "{} x{} workers, {} steps: train loss {:.5} -> {:.5}",
        cfg.artifact,
        cfg.workers,
        cfg.steps,
        res.train_loss.first().unwrap_or(&f64::NAN),
        res.final_train_loss(10),
    );
    if let Some(m) = res.final_metric() {
        println!("final {}: {:.4}", res.metric_name, m);
    }
    println!(
        "per-iteration: {:.2} ms wall, {:.3} ms simulated @ {} Gb/s fabric (ranks {})",
        res.wall_iter_s * 1e3,
        res.sim_iter_s * 1e3,
        cfg.fabric_gbps,
        if res.rank_threads {
            "threaded"
        } else {
            "round-robin"
        },
    );
    println!(
        "exposed comm: {:.4} ms/iter (overlap {}; unpipelined {:.4} ms)",
        res.exposed_comm_s * 1e3,
        if res.overlap { "on" } else { "off" },
        res.serial_comm_s * 1e3,
    );
    println!(
        "wire traffic: {} total ({:.1} KiB/step)",
        res.total_wire_bytes,
        res.total_wire_bytes as f64 / cfg.steps.max(1) as f64 / 1024.0,
    );
    if !cfg.local_steps.is_sync() {
        let hs = &res.local_step_trace;
        let (hmin, hmax) = (
            hs.iter().copied().min().unwrap_or(1),
            hs.iter().copied().max().unwrap_or(1),
        );
        println!(
            "local steps: H={} -> {} sync rounds over {} local steps (realized H {}..{})",
            res.local_steps, res.sync_rounds, cfg.steps, hmin, hmax,
        );
    }
    if res.topology != "flat" {
        println!(
            "  topology {}: intra {:.4} ms / inter {:.4} ms exposed",
            res.topology,
            res.exposed_intra_comm_s * 1e3,
            res.exposed_inter_comm_s * 1e3,
        );
    }
    if cfg.compression.is_active() {
        println!(
            "  compression: {} (scope {})",
            cfg.compression.kind.tag(),
            cfg.compression.scope.tag(),
        );
    }
    if cfg.cutoff.is_some() {
        println!(
            "elastic: {} degraded steps, {} rank rejoins",
            res.degraded_steps, res.rejoins,
        );
    }
    print!("{}", res.phases.report());
    if let Some(path) = args.str_opt("save-checkpoint") {
        trainer.checkpoint()?.save(path)?;
        println!("saved checkpoint to {path}");
    }
    if let Some(path) = args.str_opt("csv") {
        let mut w = adacons::metrics::CsvWriter::create(path, &["step", "train_loss"])?;
        for (i, l) in res.train_loss.iter().enumerate() {
            w.row(&[i.to_string(), format!("{l}")])?;
        }
        w.flush()?;
        println!("wrote {path}");
    }
    if let Some(path) = &cfg.trace_out {
        println!("wrote trace {path} (level {})", cfg.trace_level.tag());
    }
    if let Some(path) = &cfg.metrics_out {
        println!("wrote metrics {path}");
    }
    Ok(())
}

/// Validate a `--trace-out` file: parse, structural checks (well-nested
/// spans, monotonic sim tracks), and per-step reconstruction of the
/// exposed-comm accounting from transfer spans. With `--metrics`, also
/// cross-check the trace's step-mark folds against the Prometheus-style
/// exposition bit-for-bit.
fn cmd_trace_check(path: &str, args: &Args) -> Result<()> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = adacons::util::json::Json::parse(&text)
        .map_err(|e| adacons::err!("{path}: {e}"))?;
    let st = adacons::obs::chrome::check_trace(&doc).with_context(|| format!("checking {path}"))?;
    println!(
        "{path}: valid Chrome trace at level {} — {} events ({} spans, {} instants, {} step marks)",
        st.trace_level, st.events, st.spans, st.instants, st.marks,
    );
    println!(
        "  {} transfer spans, {} sim-compute spans, {} bucket-ready instants",
        st.transfer_spans, st.sim_compute_spans, st.bucket_ready_instants,
    );
    println!(
        "  {}/{} steps reconstructed exactly from transfer spans; exposed comm {:.6} s \
         (intra {:.6} s, inter {:.6} s; serial {:.6} s), wire {} bytes",
        st.reconstructed_steps,
        st.marks,
        st.exposed_comm_total,
        st.exposed_intra_total,
        st.exposed_inter_total,
        st.serial_comm_total,
        st.wire_bytes_total,
    );
    if let Some(mpath) = args.str_opt("metrics") {
        let exposition =
            std::fs::read_to_string(mpath).with_context(|| format!("reading {mpath}"))?;
        let n = adacons::obs::chrome::cross_check_metrics(&st, &exposition)
            .with_context(|| format!("cross-checking {mpath}"))?;
        println!("  {mpath}: {n} metric totals match the trace bit-for-bit");
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let rt = Runtime::open_default_with(backend_arg(args)?)?;
    println!("backend: {} ({})", rt.backend(), rt.platform());
    println!(
        "{:<24} {:>6} {:>10} {:>8}  inputs",
        "artifact", "kind", "param_dim", "batch"
    );
    for (name, spec) in &rt.manifest.artifacts {
        let inputs: Vec<String> = spec
            .inputs
            .iter()
            .map(|s| format!("{}:{}{:?}", s.name, s.dtype, s.shape))
            .collect();
        println!(
            "{:<24} {:>6} {:>10} {:>8}  {}",
            name,
            spec.kind,
            spec.param_dim,
            spec.local_batch(),
            inputs.join(" ")
        );
    }
    Ok(())
}
