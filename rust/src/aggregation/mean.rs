//! The averaging baseline — what the paper calls "Sum" (gradient averaging
//! with the learning rate folded in). One ring all-reduce per step.

use super::{AggInfo, Aggregator};
use crate::collective::CollectiveKind;
use crate::parallel::ParallelCtx;
use crate::tensor::{Buckets, GradSet};

#[derive(Debug, Default)]
pub struct MeanAggregator;

impl MeanAggregator {
    pub fn new() -> Self {
        MeanAggregator
    }
}

impl Aggregator for MeanAggregator {
    fn name(&self) -> &'static str {
        "mean"
    }

    fn aggregate_ctx(
        &mut self,
        grads: &GradSet,
        _buckets: &Buckets,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        grads.mean_into_ctx(out, ctx);
        AggInfo {
            gammas: Some(vec![1.0 / grads.n() as f32; grads.n()]),
            coeff_stages: None,
            comm: vec![(CollectiveKind::AllReduce, grads.d() * 4)],
            par: Some(ctx.par_plan(grads.d())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Buckets, GradSet};

    #[test]
    fn mean_of_constant_rows() {
        let gs = GradSet::from_rows(&[vec![1.0; 8], vec![3.0; 8]]);
        let mut out = vec![0.0; 8];
        let info = MeanAggregator::new().aggregate(&gs, &Buckets::single(8), &mut out);
        assert!(out.iter().all(|&x| (x - 2.0).abs() < 1e-6));
        assert_eq!(info.gammas.unwrap(), vec![0.5, 0.5]);
        assert_eq!(info.comm.len(), 1);
    }
}
