//! The averaging baseline — what the paper calls "Sum" (gradient averaging
//! with the learning rate folded in). One ring all-reduce per step.

use super::{
    per_bucket_payload_ops, write_bucket_outputs, AggInfo, Aggregator, BucketWork,
    BucketedAggregator,
};
use crate::collective::CollectiveKind;
use crate::parallel::ParallelCtx;
use crate::tensor::{Buckets, GradSet};

#[derive(Debug, Default)]
pub struct MeanAggregator;

impl MeanAggregator {
    pub fn new() -> Self {
        MeanAggregator
    }
}

impl BucketedAggregator for MeanAggregator {
    fn ingest_bucket(
        &self,
        _b: usize,
        view: &GradSet,
        lo: usize,
        hi: usize,
        ctx: &ParallelCtx,
    ) -> BucketWork {
        // Column-separable: the bucket's slice of the mean is final.
        let mut o = vec![0.0f32; hi - lo];
        view.mean_range_into_ctx(lo, hi, &mut o, ctx);
        BucketWork::Output(o)
    }

    fn finalize(
        &mut self,
        grads: &GradSet,
        buckets: &Buckets,
        work: Vec<BucketWork>,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        write_bucket_outputs(buckets, work, out);
        AggInfo {
            gammas: Some(vec![1.0 / grads.n() as f32; grads.n()]),
            coeff_stages: None,
            // One bucketed ring all-reduce: every transfer overlaps.
            comm: per_bucket_payload_ops(CollectiveKind::AllReduce, buckets),
            par: Some(ctx.par_plan(grads.d())),
        }
    }
}

impl Aggregator for MeanAggregator {
    fn name(&self) -> &'static str {
        "mean"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Buckets, GradSet};

    #[test]
    fn mean_of_constant_rows() {
        let gs = GradSet::from_rows(&[vec![1.0; 8], vec![3.0; 8]]);
        let mut out = vec![0.0; 8];
        let info = MeanAggregator::new().aggregate(&gs, &Buckets::single(8), &mut out);
        assert!(out.iter().all(|&x| (x - 2.0).abs() < 1e-6));
        assert_eq!(info.gammas.unwrap(), vec![0.5, 0.5]);
        assert_eq!(info.comm.len(), 1);
    }
}
