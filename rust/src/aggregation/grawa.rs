//! GRAWA-style baseline [Dimlioglu & Choromanska, AISTATS 2024]: weighted
//! averaging with weights inversely proportional to gradient norms
//! (pulls toward flat regions). Weights are normalized to sum one.

use super::{AggInfo, Aggregator, BucketWork, BucketedAggregator, CommOp};
use crate::collective::CollectiveKind;
use crate::parallel::ParallelCtx;
use crate::tensor::{Buckets, GradSet};

#[derive(Debug, Default)]
pub struct Grawa;

impl Grawa {
    pub fn new() -> Self {
        Grawa
    }
}

impl BucketedAggregator for Grawa {
    fn ingest_bucket(
        &self,
        _b: usize,
        view: &GradSet,
        lo: usize,
        hi: usize,
        ctx: &ParallelCtx,
    ) -> BucketWork {
        // Norm partials are additive over column ranges; each bucket
        // contributes its slice of every worker's squared norm.
        //
        // NOTE: on multi-bucket configs this decomposition is the
        // scheme's *new* canonical form — mathematically equal to the
        // pre-refactor full-range fold but associated differently in
        // f64, so low-order bits differ from binaries before the
        // pipelined executor landed (grawa previously ignored buckets).
        // Bitwise stability across overlap modes and thread counts is
        // what the equivalence suite enforces; single-bucket (the old
        // effective behavior at any bucket_cap) is bit-identical to
        // the pre-refactor path.
        BucketWork::Stats(view.consensus_stats_range_ctx(lo, hi, ctx))
    }

    fn finalize(
        &mut self,
        grads: &GradSet,
        buckets: &Buckets,
        work: Vec<BucketWork>,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        let n = grads.n();
        assert_eq!(work.len(), buckets.len());
        // Sum the per-bucket norm partials in fixed bucket order — the
        // global norms the inverse weighting needs, reproducibly.
        let mut sqn = vec![0.0f64; n];
        for w in work {
            let st = match w {
                BucketWork::Stats(st) => st,
                other => panic!("grawa ingests Stats work, got {other:?}"),
            };
            for (acc, v) in sqn.iter_mut().zip(&st.sqn) {
                *acc += *v;
            }
        }
        let inv: Vec<f64> = sqn
            .iter()
            .map(|&q| {
                let norm = q.sqrt();
                if norm > 1e-30 {
                    1.0 / norm
                } else {
                    0.0
                }
            })
            .collect();
        let total: f64 = inv.iter().sum();
        let gammas: Vec<f32> = if total > 0.0 {
            inv.iter().map(|&w| (w / total) as f32).collect()
        } else {
            vec![1.0 / n as f32; n]
        };
        grads.weighted_sum_into_ctx(&gammas, out, ctx);
        // Per-bucket scalar norm partials (4 B each) overlap the backward;
        // the weighted all-reduce needs the global weights — exposed.
        let mut comm: Vec<CommOp> = (0..buckets.len())
            .map(|b| CommOp {
                kind: CollectiveKind::AllGather,
                bytes: crate::collective::cost_model::f32_wire_bytes(1),
                bucket: Some(b),
                scope: super::CommScope::Global,
            })
            .collect();
        comm.push(CommOp {
            kind: CollectiveKind::AllReduce,
            bytes: crate::collective::cost_model::f32_wire_bytes(grads.d()),
            bucket: None,
            scope: super::CommScope::Global,
        });
        AggInfo {
            gammas: Some(gammas),
            coeff_stages: None,
            comm,
            par: Some(ctx.par_plan(grads.d())),
        }
    }
}

impl Aggregator for Grawa {
    fn name(&self) -> &'static str {
        "grawa"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Buckets, GradSet};

    #[test]
    fn weights_favor_small_norm_and_sum_one() {
        let gs = GradSet::from_rows(&[vec![1.0f32; 16], vec![4.0f32; 16]]);
        let mut out = vec![0.0; 16];
        let info = Grawa::new().aggregate(&gs, &Buckets::single(16), &mut out);
        let g = info.gammas.unwrap();
        assert!(g[0] > g[1]);
        assert!((g.iter().map(|&x| x as f64).sum::<f64>() - 1.0).abs() < 1e-6);
        assert!((g[0] as f64 / g[1] as f64 - 4.0).abs() < 1e-4);
    }

    #[test]
    fn all_zero_gradients_fall_back_to_uniform() {
        let gs = GradSet::from_rows(&vec![vec![0.0f32; 4]; 3]);
        let mut out = vec![0.0; 4];
        let info = Grawa::new().aggregate(&gs, &Buckets::single(4), &mut out);
        let g = info.gammas.unwrap();
        for w in g {
            assert!((w - 1.0 / 3.0).abs() < 1e-6);
        }
    }
}
