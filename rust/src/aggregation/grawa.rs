//! GRAWA-style baseline [Dimlioglu & Choromanska, AISTATS 2024]: weighted
//! averaging with weights inversely proportional to gradient norms
//! (pulls toward flat regions). Weights are normalized to sum one.

use super::{AggInfo, Aggregator};
use crate::collective::CollectiveKind;
use crate::parallel::ParallelCtx;
use crate::tensor::{Buckets, GradSet};

#[derive(Debug, Default)]
pub struct Grawa;

impl Grawa {
    pub fn new() -> Self {
        Grawa
    }
}

impl Aggregator for Grawa {
    fn name(&self) -> &'static str {
        "grawa"
    }

    fn aggregate_ctx(
        &mut self,
        grads: &GradSet,
        _buckets: &Buckets,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        let n = grads.n();
        let st = grads.consensus_stats_ctx(ctx);
        let inv: Vec<f64> = st
            .sqn
            .iter()
            .map(|&q| {
                let norm = q.sqrt();
                if norm > 1e-30 {
                    1.0 / norm
                } else {
                    0.0
                }
            })
            .collect();
        let total: f64 = inv.iter().sum();
        let gammas: Vec<f32> = if total > 0.0 {
            inv.iter().map(|&w| (w / total) as f32).collect()
        } else {
            vec![1.0 / n as f32; n]
        };
        grads.weighted_sum_into_ctx(&gammas, out, ctx);
        AggInfo {
            gammas: Some(gammas),
            coeff_stages: None,
            comm: vec![
                (CollectiveKind::AllGather, 4),
                (CollectiveKind::AllReduce, grads.d() * 4),
            ],
            par: Some(ctx.par_plan(grads.d())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Buckets, GradSet};

    #[test]
    fn weights_favor_small_norm_and_sum_one() {
        let gs = GradSet::from_rows(&[vec![1.0f32; 16], vec![4.0f32; 16]]);
        let mut out = vec![0.0; 16];
        let info = Grawa::new().aggregate(&gs, &Buckets::single(16), &mut out);
        let g = info.gammas.unwrap();
        assert!(g[0] > g[1]);
        assert!((g.iter().map(|&x| x as f64).sum::<f64>() - 1.0).abs() < 1e-6);
        assert!((g[0] as f64 / g[1] as f64 - 4.0).abs() < 1e-4);
    }

    #[test]
    fn all_zero_gradients_fall_back_to_uniform() {
        let gs = GradSet::from_rows(&vec![vec![0.0f32; 4]; 3]);
        let mut out = vec![0.0; 4];
        let info = Grawa::new().aggregate(&gs, &Buckets::single(4), &mut out);
        let g = info.gammas.unwrap();
        for w in g {
            assert!((w - 1.0 / 3.0).abs() < 1e-6);
        }
    }
}
