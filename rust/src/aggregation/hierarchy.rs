//! Two-level hierarchical aggregation: intra-node reduce, inter-node
//! consensus.
//!
//! The paper's testbed is hierarchical — nodes of NVLink-connected GPUs
//! joined by an InfiniBand fabric — and its weighting scheme is designed
//! around exactly that communication asymmetry. [`Hierarchical`] wraps
//! any flat [`Aggregator`] with the scheme AdaSum-style systems use to
//! scale adaptive aggregation past a node:
//!
//! 1. **Intra-node reduce** (cheap, NVLink): node *k*'s leader row is
//!    `L_k = (G/N) · Σ_{i∈k} g_i` — a group-size-weighted mean, since
//!    `L_k = (s_k·G/N) · m_k` with `m_k` the plain node mean.
//! 2. **Inter-node consensus** (the expensive fabric): the base scheme
//!    runs across the G leader rows only, shrinking its Gram/consensus
//!    computation from N×N to G×G and its ring collectives from N to G
//!    participants.
//!
//! **Unbiasedness invariant** (documented here, tested in
//! `tests/parallel_equivalence.rs` and below): the uniform mean over
//! leaders equals the global rank mean, uneven groups included —
//! `(1/G) Σ_k L_k = (1/N) Σ_i g_i` — because each leader carries its
//! group-size weight. Equivalently, every rank's effective weight under
//! mean-of-leaders is exactly `1/N` (weight-sum preserved: for a base
//! scheme reporting weights `Γ` over leaders, the per-rank weights are
//! `γ_i = Γ_{k(i)}·G/N`, and `Σ_k Γ_k = 1 ⇒ Σ_i γ_i·s_{k(i)}/s_{k(i)}`
//! telescopes to 1). So swapping a flat aggregator for its hierarchical
//! form changes the f32 association and the G-vs-N consensus geometry,
//! never the statistical target.
//!
//! Degenerate maps — one node, or one rank per node — have no meaningful
//! two-level split, so the wrapper delegates straight to the base scheme:
//! `hier:1xN` and `hier:Nx1` are **bitwise-identical** to flat (the
//! acceptance criterion the parity suite enforces).

use super::{AggInfo, Aggregator, BucketWork, BucketedAggregator, CommOp, CommScope};
use crate::collective::cost_model::f32_wire_bytes;
use crate::collective::{CollectiveKind, NodeMap};
use crate::compress::{CompressorKind, SetCodec};
use crate::parallel::ParallelCtx;
use crate::tensor::{Buckets, GradSet};

/// A flat aggregation scheme lifted to the two-level node hierarchy.
pub struct Hierarchical {
    base: Box<dyn Aggregator>,
    map: NodeMap,
    /// Leader scale `G/N`: folds the group-size weighting into a single
    /// uniform constant (`L_k = scale · Σ_{i∈k} g_i`).
    scale: f32,
    degenerate: bool,
    /// Inter-node compression: installed via `set_compression`, applied
    /// to the leader rows inside `ingest_leaders` — the single funnel
    /// both the inline path and the grouped executor go through, which
    /// keeps them bitwise-equal under compression. Per-(node, bucket) EF
    /// residuals live in the codec.
    codec: Option<SetCodec>,
}

impl Hierarchical {
    pub fn new(base: Box<dyn Aggregator>, map: NodeMap) -> Hierarchical {
        let g = map.groups() as f64;
        let n = map.n_ranks() as f64;
        let degenerate = map.is_degenerate();
        Hierarchical {
            base,
            map,
            scale: (g / n) as f32,
            degenerate,
            codec: None,
        }
    }

    pub fn map(&self) -> &NodeMap {
        &self.map
    }

    pub fn base_name(&self) -> &'static str {
        self.base.name()
    }
}

impl BucketedAggregator for Hierarchical {
    fn node_map(&self) -> Option<&NodeMap> {
        if self.degenerate {
            None
        } else {
            Some(&self.map)
        }
    }

    fn reduce_group(
        &self,
        node: usize,
        view: &GradSet,
        rows: (usize, usize),
        lo: usize,
        hi: usize,
        ctx: &ParallelCtx,
    ) -> Vec<f32> {
        let _ = node;
        let mut out = vec![0.0f32; hi - lo];
        view.scaled_row_sum_range_into_ctx(rows, self.scale, lo, hi, &mut out, ctx);
        out
    }

    fn ingest_leaders(&self, b: usize, leaders: GradSet, ctx: &ParallelCtx) -> BucketWork {
        let mut leaders = leaders;
        // Compress→decompress the inter-node transfer *before* the base
        // scheme's Gram/statistics pass, so consensus weights are computed
        // on exactly the values the fabric would deliver. The transformed
        // leaders ride in the work to `finalize`, which reassembles them
        // for the base's weighted sums.
        if let Some(codec) = &self.codec {
            codec.transform(b, &mut leaders, 0, leaders.d());
        }
        let inner = self.base.ingest_bucket(b, &leaders, 0, leaders.d(), ctx);
        BucketWork::Hier {
            leaders,
            inner: Box::new(inner),
        }
    }

    fn ingest_bucket(
        &self,
        b: usize,
        view: &GradSet,
        lo: usize,
        hi: usize,
        ctx: &ParallelCtx,
    ) -> BucketWork {
        if self.degenerate {
            return self.base.ingest_bucket(b, view, lo, hi, ctx);
        }
        // Inline decomposition — the per-node-group tasks the pipelined
        // executor runs concurrently, executed here in fixed node order.
        // Both produce the same bits: the reduction kernel is invariant to
        // the view convention and node outputs are independent rows.
        let g = self.map.groups();
        let mut leaders = GradSet::zeros(g, hi - lo);
        for k in 0..g {
            let row = self.reduce_group(k, view, self.map.range(k), lo, hi, ctx);
            leaders.set_row(k, &row);
        }
        self.ingest_leaders(b, leaders, ctx)
    }

    fn finalize(
        &mut self,
        grads: &GradSet,
        buckets: &Buckets,
        work: Vec<BucketWork>,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        if self.degenerate {
            return self.base.finalize(grads, buckets, work, out, ctx);
        }
        let g = self.map.groups();
        let n = self.map.n_ranks();
        assert_eq!(grads.n(), n, "gradient set does not match the node map");
        let d = grads.d();
        // Reassemble the full (G, d) leader set from the per-bucket pieces
        // (fixed bucket order) and unwrap the base scheme's work.
        let mut leaders_full = GradSet::zeros(g, d);
        let mut inner_work = Vec::with_capacity(work.len());
        for ((lo, hi), w) in buckets.iter().zip(work) {
            match w {
                BucketWork::Hier { leaders, inner } => {
                    assert_eq!(leaders.n(), g);
                    assert_eq!(leaders.d(), hi - lo);
                    for k in 0..g {
                        leaders_full.row_mut(k)[lo..hi].copy_from_slice(leaders.row(k));
                    }
                    inner_work.push(*inner);
                }
                other => panic!("hierarchical ingests Hier work, got {other:?}"),
            }
        }
        let info = self.base.finalize(&leaders_full, buckets, inner_work, out, ctx);

        // --- comm plan on the two-level fabric ---
        // Per bucket: every node's intra reduce (concurrent NVLink-class
        // links, overlappable with the backward)...
        let mut comm: Vec<CommOp> = buckets
            .iter()
            .enumerate()
            .map(|(b, (lo, hi))| CommOp {
                kind: CollectiveKind::AllReduce,
                bytes: f32_wire_bytes(hi - lo),
                bucket: Some(b),
                scope: CommScope::Intra,
            })
            .collect();
        // ...then the base scheme's ops run across node leaders on the
        // inter-node fabric (a bucketed inter op additionally waits for
        // that bucket's intra reduces — the executor encodes the
        // dependency through readiness times)...
        comm.extend(info.comm.iter().map(|op| CommOp {
            scope: CommScope::Inter,
            ..*op
        }));
        // ...and the aggregated direction fans back out inside each node.
        comm.push(CommOp {
            kind: CollectiveKind::Broadcast,
            bytes: f32_wire_bytes(d),
            bucket: None,
            scope: CommScope::Intra,
        });
        // One step of inter-node EF is complete; advance the codec's
        // stochastic-rounding key for the next step.
        if let Some(codec) = &self.codec {
            codec.advance_step();
        }

        // Leader weights Γ expand to per-rank effective weights
        // γ_i = Γ_{k(i)} · G/N (out = Σ_k Γ_k L_k = Σ_i γ_i g_i).
        let gammas = info.gammas.as_ref().map(|leader_gammas| {
            let mut per_rank = vec![0.0f32; n];
            for (k, (r0, r1)) in self.map.iter().enumerate() {
                let w = leader_gammas[k] * self.scale;
                for slot in &mut per_rank[r0..r1] {
                    *slot = w;
                }
            }
            per_rank
        });
        AggInfo {
            gammas,
            coeff_stages: info.coeff_stages,
            comm,
            par: info.par,
        }
    }
}

impl Aggregator for Hierarchical {
    fn name(&self) -> &'static str {
        match self.base.name() {
            "mean" => "hier-mean",
            "adacons" => "hier-adacons",
            "adacons-raw" => "hier-adacons-raw",
            "adacons-momentum" => "hier-adacons-momentum",
            "adacons-norm" => "hier-adacons-norm",
            "adasum" => "hier-adasum",
            "grawa" => "hier-grawa",
            "median" => "hier-median",
            "trimmed-mean" => "hier-trimmed-mean",
            _ => "hier",
        }
    }

    fn reset(&mut self) {
        self.base.reset();
    }

    fn set_compression(&mut self, kind: CompressorKind, seed: u64, n_buckets: usize) {
        // Degenerate hierarchies delegate bitwise to the flat scheme and
        // never call `ingest_leaders`, so there is nothing to compress at
        // this level (rank-source codecs still apply under scope `all`).
        if self.degenerate || kind.is_none() {
            return;
        }
        self.codec = Some(SetCodec::new(kind, seed, n_buckets));
    }

    fn reset_compression(&mut self) {
        if let Some(codec) = &self.codec {
            codec.reset();
        }
        self.base.reset_compression();
    }

    fn export_state(&self) -> Vec<Vec<f64>> {
        // The wrapper itself is stateless (the codec's EF residuals are
        // handled separately); only the base scheme's momentum travels.
        self.base.export_state()
    }

    fn import_state(&mut self, state: &[Vec<f64>]) {
        self.base.import_state(state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation;
    use crate::util::prng::Rng;

    fn random_set(n: usize, d: usize, seed: u64) -> GradSet {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal_f32(1.0)).collect())
            .collect();
        GradSet::from_rows(&rows)
    }

    #[test]
    fn hier_mean_is_unbiased_even_and_uneven() {
        // The invariant: mean-of-leaders == global rank mean, any grouping.
        let d = 257;
        for map in [NodeMap::even(3, 2), NodeMap::from_sizes(&[3, 2, 1])] {
            let n = map.n_ranks();
            let gs = random_set(n, d, 42 + map.max_group() as u64);
            let mut flat = vec![0.0f32; d];
            gs.mean_into(&mut flat);
            let mut hier = vec![0.0f32; d];
            let mut agg = aggregation::hierarchical("mean", map.clone(), n).unwrap();
            let info = agg.aggregate(&gs, &Buckets::single(d), &mut hier);
            for j in 0..d {
                assert!(
                    (hier[j] - flat[j]).abs() < 1e-5 * flat[j].abs().max(1.0),
                    "col {j}: {} vs {}",
                    hier[j],
                    flat[j]
                );
            }
            // Weight-sum preserved: every rank's effective weight is 1/N.
            let gammas = info.gammas.unwrap();
            assert_eq!(gammas.len(), n);
            for (rank, &w) in gammas.iter().enumerate() {
                assert!(
                    (w - 1.0 / n as f32).abs() < 1e-7,
                    "rank {rank}: weight {w}"
                );
            }
        }
    }

    #[test]
    fn even_group_leaders_are_the_node_means() {
        let map = NodeMap::even(2, 3);
        let gs = random_set(6, 64, 7);
        let agg = Hierarchical::new(aggregation::by_name("mean", 6).unwrap(), map.clone());
        let ctx = ParallelCtx::serial();
        for (k, (r0, r1)) in map.iter().enumerate() {
            let leader = agg.reduce_group(k, &gs, (r0, r1), 0, 64, &ctx);
            for j in 0..64 {
                let m: f64 =
                    (r0..r1).map(|i| gs.row(i)[j] as f64).sum::<f64>() / (r1 - r0) as f64;
                assert!((leader[j] as f64 - m).abs() < 1e-6, "node {k} col {j}");
            }
        }
    }

    #[test]
    fn degenerate_maps_delegate_bitwise_to_flat() {
        let (n, d) = (5usize, 300usize);
        let gs = random_set(n, d, 11);
        let buckets = Buckets::fixed(d, 77);
        for name in aggregation::ALL_NAMES {
            let mut flat_out = vec![0.0f32; d];
            aggregation::by_name(name, n)
                .unwrap()
                .aggregate(&gs, &buckets, &mut flat_out);
            for map in [NodeMap::even(1, n), NodeMap::even(n, 1)] {
                let mut hier_out = vec![0.0f32; d];
                let mut agg = aggregation::hierarchical(name, map.clone(), n).unwrap();
                agg.aggregate(&gs, &buckets, &mut hier_out);
                assert_eq!(
                    flat_out, hier_out,
                    "{name}: degenerate {map:?} diverged from flat"
                );
            }
        }
    }

    #[test]
    fn shrinks_consensus_to_leader_count_and_scopes_comm() {
        let map = NodeMap::even(2, 3);
        let (n, d) = (6usize, 4 * crate::tensor::ops::CHUNK);
        let gs = random_set(n, d, 3);
        let buckets = Buckets::fixed(d, crate::tensor::ops::CHUNK);
        let mut out = vec![0.0f32; d];
        let mut agg = aggregation::hierarchical("adacons", map, n).unwrap();
        let info = agg.aggregate(&gs, &buckets, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
        // Per-bucket intra reduces + the base's per-bucket inter reduces
        // + exposed inter (gather, reduce) + the final intra broadcast.
        let nb = buckets.len();
        let intra: Vec<&CommOp> = info
            .comm
            .iter()
            .filter(|op| op.scope == CommScope::Intra)
            .collect();
        let inter: Vec<&CommOp> = info
            .comm
            .iter()
            .filter(|op| op.scope == CommScope::Inter)
            .collect();
        assert_eq!(intra.len(), nb + 1); // nb reduces + final broadcast
        assert_eq!(inter.len(), nb + 2); // nb stats reduces + gather + reproject
        assert!(info.comm.iter().all(|op| op.scope != CommScope::Global));
        // Per-rank weights expand from the 2 leader weights.
        let gammas = info.gammas.unwrap();
        assert_eq!(gammas.len(), 6);
        assert_eq!(gammas[0], gammas[2]); // same node
        assert_eq!(gammas[3], gammas[5]);
    }

    #[test]
    fn hier_name_and_reset_pass_through() {
        let mut agg =
            aggregation::hierarchical("adacons", NodeMap::even(2, 2), 4).unwrap();
        assert_eq!(agg.name(), "hier-adacons");
        agg.reset(); // must not panic; clears base momentum
        let agg = aggregation::hierarchical("median", NodeMap::even(2, 2), 4).unwrap();
        assert_eq!(agg.name(), "hier-median");
    }
}
