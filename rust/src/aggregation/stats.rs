//! Subspace-coefficient stage statistics (paper Fig. 7): mean/std of the
//! coefficients (a) at the first-order approximation, (b) after momentum,
//! (c) after the unbiasing normalization.

use crate::util::stats;

#[derive(Debug, Clone, Default)]
pub struct CoeffStages {
    pub raw_mean: f64,
    pub raw_std: f64,
    pub momentum_mean: Option<f64>,
    pub momentum_std: Option<f64>,
    pub final_mean: f64,
    pub final_std: f64,
}

impl CoeffStages {
    pub fn record_raw(&mut self, alpha: &[f64]) {
        self.raw_mean = stats::mean(alpha);
        self.raw_std = stats::std(alpha);
    }

    pub fn record_momentum(&mut self, alpha: &[f64]) {
        self.momentum_mean = Some(stats::mean(alpha));
        self.momentum_std = Some(stats::std(alpha));
    }

    pub fn record_final(&mut self, alpha: &[f64]) {
        self.final_mean = stats::mean(alpha);
        self.final_std = stats::std(alpha);
    }

    /// CSV row: raw_mean,raw_std,mom_mean,mom_std,final_mean,final_std.
    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{}",
            self.raw_mean,
            self.raw_std,
            self.momentum_mean.unwrap_or(f64::NAN),
            self.momentum_std.unwrap_or(f64::NAN),
            self.final_mean,
            self.final_std
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_all_stages() {
        let mut s = CoeffStages::default();
        s.record_raw(&[1.0, 2.0, 3.0]);
        s.record_momentum(&[1.5, 2.0, 2.5]);
        s.record_final(&[0.2, 0.3, 0.5]);
        assert!((s.raw_mean - 2.0).abs() < 1e-12);
        assert!(s.momentum_std.unwrap() < s.raw_std);
        assert!((s.final_mean - 1.0 / 3.0).abs() < 1e-12);
        let row = s.csv_row();
        assert_eq!(row.split(',').count(), 6);
    }

    #[test]
    fn momentum_optional() {
        let mut s = CoeffStages::default();
        s.record_raw(&[1.0, 1.0]);
        s.record_final(&[0.5, 0.5]);
        assert!(s.momentum_mean.is_none());
        assert!(s.csv_row().contains("NaN"));
    }
}
