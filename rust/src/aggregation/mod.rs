//! Gradient aggregation — the paper's contribution surface.
//!
//! An [`Aggregator`] maps the N worker gradients (a [`GradSet`]) to one
//! descent direction, optionally per parameter bucket (model-wise vs
//! layer-wise).  Implementations:
//!
//! * [`mean::MeanAggregator`] — the ubiquitous averaging baseline ("Sum").
//! * [`adacons::AdaCons`] — the paper: subspace first-order coefficients
//!   (Eq. 7/8), sorted-EMA subspace momentum (Eq. 11), sum-one
//!   normalization (Eq. 13), each independently toggleable (Table 2).
//! * [`adasum::Adasum`] — the orthogonality-enhancing baseline [34].
//! * [`grawa::Grawa`] — inverse-gradient-norm weighting [18].
//! * [`robust`] — coordinate median / trimmed mean (Byzantine baselines).

pub mod adacons;
pub mod adasum;
pub mod grawa;
pub mod mean;
pub mod robust;
pub mod stats;

use crate::collective::CollectiveKind;
use crate::parallel::{ParPlan, ParallelCtx};
use crate::tensor::{Buckets, GradSet};

pub use adacons::{AdaCons, AdaConsConfig};
pub use adasum::Adasum;
pub use grawa::Grawa;
pub use mean::MeanAggregator;
pub use robust::{CoordinateMedian, TrimmedMean};
pub use stats::CoeffStages;

/// Metadata returned by one aggregation step.
#[derive(Debug, Clone, Default)]
pub struct AggInfo {
    /// Final per-worker weights γ (first bucket), when the scheme is a
    /// linear combination. `None` for non-linear schemes (median).
    pub gammas: Option<Vec<f32>>,
    /// Subspace-coefficient statistics per stage (Fig. 7), when applicable.
    pub coeff_stages: Option<CoeffStages>,
    /// Communication ops this step would issue on a real fabric
    /// (kind, payload bytes) — charged to the SimClock by the coordinator.
    pub comm: Vec<(CollectiveKind, usize)>,
    /// Thread-count / shard-size choices the parallel engine made for the
    /// full-width range (reported by exp/table1 next to the timings).
    pub par: Option<ParPlan>,
}

/// A synchronous gradient aggregation scheme.
pub trait Aggregator: Send {
    fn name(&self) -> &'static str;

    /// Aggregate `grads` into `out` (length d), bucket by bucket, running
    /// the tensor kernels on `ctx`'s worker pool. Results are
    /// bitwise-identical at any thread count (fixed shard plan +
    /// fixed-order partial reduction — see `parallel`).
    fn aggregate_ctx(
        &mut self,
        grads: &GradSet,
        buckets: &Buckets,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo;

    /// Serial convenience wrapper (one-lane context, jobs run inline).
    fn aggregate(&mut self, grads: &GradSet, buckets: &Buckets, out: &mut [f32]) -> AggInfo {
        self.aggregate_ctx(grads, buckets, out, &ParallelCtx::serial())
    }

    /// Clear step-dependent state (e.g. momentum) between runs.
    fn reset(&mut self) {}
}

/// Build an aggregator by name — the config-file surface.
/// Names: `mean` (aka `sum`), `adacons`, `adacons-raw`, `adacons-momentum`,
/// `adacons-norm`, `adasum`, `grawa`, `median`, `trimmed-mean`.
pub fn by_name(name: &str, n_workers: usize) -> Option<Box<dyn Aggregator>> {
    let _ = n_workers;
    match name {
        "mean" | "sum" | "average" => Some(Box::new(MeanAggregator::new())),
        "adacons" => Some(Box::new(AdaCons::new(AdaConsConfig::full()))),
        "adacons-raw" => Some(Box::new(AdaCons::new(AdaConsConfig::raw()))),
        "adacons-momentum" => Some(Box::new(AdaCons::new(AdaConsConfig::momentum_only()))),
        "adacons-norm" => Some(Box::new(AdaCons::new(AdaConsConfig::norm_only()))),
        "adasum" => Some(Box::new(Adasum::new())),
        "grawa" => Some(Box::new(Grawa::new())),
        "median" => Some(Box::new(CoordinateMedian::new())),
        "trimmed-mean" => Some(Box::new(TrimmedMean::new(0.2))),
        _ => None,
    }
}

/// All aggregator names, for CLI help and sweep harnesses.
pub const ALL_NAMES: &[&str] = &[
    "mean",
    "adacons",
    "adacons-raw",
    "adacons-momentum",
    "adacons-norm",
    "adasum",
    "grawa",
    "median",
    "trimmed-mean",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for name in ALL_NAMES {
            let agg = by_name(name, 4).unwrap_or_else(|| panic!("{name}"));
            assert!(!agg.name().is_empty());
        }
        assert!(by_name("nope", 4).is_none());
    }
}
