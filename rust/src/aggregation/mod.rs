//! Gradient aggregation — the paper's contribution surface.
//!
//! An [`Aggregator`] maps the N worker gradients (a [`GradSet`]) to one
//! descent direction, optionally per parameter bucket (model-wise vs
//! layer-wise).  Implementations:
//!
//! * [`mean::MeanAggregator`] — the ubiquitous averaging baseline ("Sum").
//! * [`adacons::AdaCons`] — the paper: subspace first-order coefficients
//!   (Eq. 7/8), sorted-EMA subspace momentum (Eq. 11), sum-one
//!   normalization (Eq. 13), each independently toggleable (Table 2).
//! * [`adasum::Adasum`] — the orthogonality-enhancing baseline [34].
//! * [`grawa::Grawa`] — inverse-gradient-norm weighting [18].
//! * [`robust`] — coordinate median / trimmed mean (Byzantine baselines).

pub mod adacons;
pub mod adasum;
pub mod grawa;
pub mod hierarchy;
pub mod mean;
pub mod robust;
pub mod stats;

use crate::collective::{CollectiveKind, NodeMap};
use crate::parallel::{ParPlan, ParallelCtx};
use crate::tensor::{grad_set::ConsensusStats, Buckets, GradSet};

pub use adacons::{AdaCons, AdaConsConfig};
pub use adasum::Adasum;
pub use grawa::Grawa;
pub use hierarchy::Hierarchical;
pub use mean::MeanAggregator;
pub use robust::{CoordinateMedian, TrimmedMean};
pub use stats::CoeffStages;

/// Which fabric level a communication op runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommScope {
    /// Flat path: the op spans all N ranks on the modeled bottleneck link
    /// (the historical single-NIC accounting).
    Global,
    /// Within one node group (NVLink-class): every node runs its copy of
    /// the op concurrently on its own intra-node link.
    Intra,
    /// Across node leaders on the inter-node fabric.
    Inter,
}

/// One communication operation a step would issue on a real fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommOp {
    pub kind: CollectiveKind,
    /// Payload bytes (per rank for all-gathers, total for all-reduces —
    /// matching `CostModel::time_s`).
    pub bytes: usize,
    /// `Some(b)`: the payload exists as soon as bucket `b`'s gradients do,
    /// so on a bucketed fabric this transfer may overlap the remaining
    /// backward compute (DDP pipelining). `None`: the op depends on the
    /// full gradient or on the bucketed phase's results — it is exposed.
    pub bucket: Option<usize>,
    /// Fabric level the op is charged to ([`CommScope::Global`] for flat
    /// schemes; the hierarchical wrapper emits `Intra`/`Inter` pairs).
    pub scope: CommScope,
}

/// Metadata returned by one aggregation step.
#[derive(Debug, Clone, Default)]
pub struct AggInfo {
    /// Final per-worker weights γ (first bucket), when the scheme is a
    /// linear combination. `None` for non-linear schemes (median).
    pub gammas: Option<Vec<f32>>,
    /// Subspace-coefficient statistics per stage (Fig. 7), when applicable.
    pub coeff_stages: Option<CoeffStages>,
    /// Communication ops this step would issue on a real fabric — charged
    /// to the step's event timeline by the coordinator (per-bucket ops at
    /// their bucket's readiness, exposed ops after the backward).
    pub comm: Vec<CommOp>,
    /// Thread-count / shard-size choices the parallel engine made for the
    /// full-width range (reported by exp/table1 next to the timings).
    pub par: Option<ParPlan>,
}

/// The per-bucket result of [`BucketedAggregator::ingest_bucket`].
#[derive(Debug, Clone)]
pub enum BucketWork {
    /// Per-worker consensus statistics over the bucket's columns (Eq. 7
    /// restricted to the bucket) — the schemes whose coefficients are
    /// functions of `(dots, sqn)` partials.
    Stats(ConsensusStats),
    /// The bucket's aggregated output columns, already final (schemes
    /// whose math is column-separable: mean, median, trimmed mean).
    Output(Vec<f32>),
    /// Nothing useful can be computed per bucket — the scheme needs the
    /// fully assembled gradient set (Adasum's pairwise tree); all work
    /// happens in `finalize`.
    Deferred,
    /// Two-level hierarchical work: the bucket's `(G, width)` node-leader
    /// columns (group-size-weighted intra means, see
    /// [`hierarchy::Hierarchical`]) plus the base scheme's work over
    /// those leaders.
    Hier {
        leaders: GradSet,
        inner: Box<BucketWork>,
    },
}

/// The two-phase aggregation protocol the pipelined executor drives.
///
/// `ingest_bucket` is phase 1: pure per-bucket work, safe to run
/// concurrently across buckets (it takes `&self` and may execute on a
/// pool task while later buckets are still arriving). `finalize` is
/// phase 2: fold the per-bucket work into `out` in **fixed bucket
/// order**, which is what keeps the pipelined path bitwise-identical to
/// the serial one no matter how the phase-1 tasks interleaved.
pub trait BucketedAggregator: Send + Sync {
    /// Consume bucket `b`'s gradient columns. `view` is either the full
    /// gradient set with `lo..hi` the bucket's absolute column range (the
    /// inline path) or an owned `(N, hi-lo)` per-bucket copy with
    /// `lo = 0` (the pipelined path's per-bucket sends). Every kernel
    /// chunks relative to `lo`, so the result is bitwise-identical either
    /// way (covered by `tests/parallel_equivalence.rs`).
    fn ingest_bucket(
        &self,
        b: usize,
        view: &GradSet,
        lo: usize,
        hi: usize,
        ctx: &ParallelCtx,
    ) -> BucketWork;

    /// Fold the per-bucket work into `out` (length d = `buckets.total()`),
    /// in bucket order. `grads` is the fully assembled gradient set (both
    /// execution paths have it by finalize time); `work[b]` is what
    /// `ingest_bucket` returned for bucket `b`.
    fn finalize(
        &mut self,
        grads: &GradSet,
        buckets: &Buckets,
        work: Vec<BucketWork>,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo;

    /// Rank grouping for two-level hierarchical schemes: `Some(map)` when
    /// this aggregator's `ingest_bucket` decomposes into per-node-group
    /// reduction ([`BucketedAggregator::reduce_group`]) followed by a
    /// leaders-level ingest ([`BucketedAggregator::ingest_leaders`]) —
    /// the pipelined executor then runs the reduction tasks per node
    /// group, each submitted the moment that group's ranks complete the
    /// bucket. `None` (the default, and the hierarchical wrapper's answer
    /// for degenerate maps): flat, one ingest task per bucket.
    fn node_map(&self) -> Option<&NodeMap> {
        None
    }

    /// Two-level phase 1a: reduce rows `rows.0..rows.1` of `view` (node
    /// `node`'s rank group) over columns `[lo, hi)` to that node's leader
    /// columns. `view`/`rows`/`lo` follow the same dual convention as
    /// `ingest_bucket`: the full gradient set with global rows and an
    /// absolute column range, or an owned per-group per-bucket copy with
    /// local rows and `lo = 0` — bitwise-identical either way. Only
    /// meaningful when `node_map` returns `Some`.
    fn reduce_group(
        &self,
        node: usize,
        view: &GradSet,
        rows: (usize, usize),
        lo: usize,
        hi: usize,
        ctx: &ParallelCtx,
    ) -> Vec<f32> {
        let _ = (node, view, rows, lo, hi, ctx);
        panic!("reduce_group called on a flat aggregator")
    }

    /// Two-level phase 1b: ingest bucket `b`'s assembled `(G, width)`
    /// leader columns (ownership transfers so the work can carry them to
    /// `finalize`). Only meaningful when `node_map` returns `Some`.
    fn ingest_leaders(&self, b: usize, leaders: GradSet, ctx: &ParallelCtx) -> BucketWork {
        let _ = (b, leaders, ctx);
        panic!("ingest_leaders called on a flat aggregator")
    }
}

/// A synchronous gradient aggregation scheme.
pub trait Aggregator: BucketedAggregator {
    fn name(&self) -> &'static str;

    /// Aggregate `grads` into `out` (length d), bucket by bucket, running
    /// the tensor kernels on `ctx`'s worker pool. This is the degenerate
    /// unpipelined path: every bucket is ingested inline in order, then
    /// folded. Results are bitwise-identical at any thread count (fixed
    /// shard plan + fixed-order partial reduction — see `parallel`) and
    /// to the pipelined executor (`coordinator::pipeline`).
    fn aggregate_ctx(
        &mut self,
        grads: &GradSet,
        buckets: &Buckets,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        let work: Vec<BucketWork> = buckets
            .iter()
            .enumerate()
            .map(|(b, (lo, hi))| self.ingest_bucket(b, grads, lo, hi, ctx))
            .collect();
        self.finalize(grads, buckets, work, out, ctx)
    }

    /// Serial convenience wrapper (one-lane context, jobs run inline).
    fn aggregate(&mut self, grads: &GradSet, buckets: &Buckets, out: &mut [f32]) -> AggInfo {
        self.aggregate_ctx(grads, buckets, out, &ParallelCtx::serial())
    }

    /// Clear step-dependent state (e.g. momentum) between runs.
    fn reset(&mut self) {}

    /// Install a leader-side compression codec (hierarchical wrapper
    /// only: inter-node transfers are compressed inside
    /// `ingest_leaders`). Flat aggregators ignore this — their
    /// compression runs at the rank source or in the executor.
    fn set_compression(&mut self, kind: crate::compress::CompressorKind, seed: u64, n_buckets: usize) {
        let _ = (kind, seed, n_buckets);
    }

    /// Drop error-feedback residual state (param re-broadcast / restore).
    fn reset_compression(&mut self) {}

    /// Serializable step-dependent state for checkpointing, as flat f64
    /// vectors (e.g. AdaCons' per-bucket sorted-EMA momentum). Stateless
    /// schemes export an empty list.
    fn export_state(&self) -> Vec<Vec<f64>> {
        Vec::new()
    }

    /// Restore state exported by [`Aggregator::export_state`]. An empty
    /// list (v1 checkpoints, stateless schemes) leaves fresh state — the
    /// pre-versioned restore behaviour.
    fn import_state(&mut self, state: &[Vec<f64>]) {
        let _ = state;
    }
}

/// One `CommOp` per bucket: `kind` with the bucket's payload size, ready
/// at that bucket (the DDP-overlappable phase-1 transfers).
pub(crate) fn per_bucket_payload_ops(kind: CollectiveKind, buckets: &Buckets) -> Vec<CommOp> {
    buckets
        .iter()
        .enumerate()
        .map(|(b, (lo, hi))| CommOp {
            kind,
            bytes: crate::collective::cost_model::f32_wire_bytes(hi - lo),
            bucket: Some(b),
            scope: CommScope::Global,
        })
        .collect()
}

/// Copy per-bucket `BucketWork::Output` slices into the full vector.
pub(crate) fn write_bucket_outputs(buckets: &Buckets, work: Vec<BucketWork>, out: &mut [f32]) {
    assert_eq!(out.len(), buckets.total());
    assert_eq!(work.len(), buckets.len());
    for ((lo, hi), w) in buckets.iter().zip(work) {
        match w {
            BucketWork::Output(v) => out[lo..hi].copy_from_slice(&v),
            other => panic!("expected per-bucket Output work, got {other:?}"),
        }
    }
}

/// Build an aggregator by name — the config-file surface.
/// Names: `mean` (aka `sum`), `adacons`, `adacons-raw`, `adacons-momentum`,
/// `adacons-norm`, `adasum`, `grawa`, `median`, `trimmed-mean`.
pub fn by_name(name: &str, n_workers: usize) -> Option<Box<dyn Aggregator>> {
    let _ = n_workers;
    match name {
        "mean" | "sum" | "average" => Some(Box::new(MeanAggregator::new())),
        "adacons" => Some(Box::new(AdaCons::new(AdaConsConfig::full()))),
        "adacons-raw" => Some(Box::new(AdaCons::new(AdaConsConfig::raw()))),
        "adacons-momentum" => Some(Box::new(AdaCons::new(AdaConsConfig::momentum_only()))),
        "adacons-norm" => Some(Box::new(AdaCons::new(AdaConsConfig::norm_only()))),
        "adasum" => Some(Box::new(Adasum::new())),
        "grawa" => Some(Box::new(Grawa::new())),
        "median" => Some(Box::new(CoordinateMedian::new())),
        "trimmed-mean" => Some(Box::new(TrimmedMean::new(0.2))),
        _ => None,
    }
}

/// Build the two-level hierarchical form of a flat aggregator: intra-node
/// group-size-weighted mean reduction, then `name`'s scheme across node
/// leaders only (see [`hierarchy::Hierarchical`] for the unbiasedness
/// invariant). Degenerate maps (one node, or one rank per node) delegate
/// to the flat scheme bitwise.
pub fn hierarchical(name: &str, map: NodeMap, n_workers: usize) -> Option<Box<dyn Aggregator>> {
    let base = by_name(name, n_workers)?;
    Some(Box::new(Hierarchical::new(base, map)))
}

/// All aggregator names, for CLI help and sweep harnesses.
pub const ALL_NAMES: &[&str] = &[
    "mean",
    "adacons",
    "adacons-raw",
    "adacons-momentum",
    "adacons-norm",
    "adasum",
    "grawa",
    "median",
    "trimmed-mean",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for name in ALL_NAMES {
            let agg = by_name(name, 4).unwrap_or_else(|| panic!("{name}"));
            assert!(!agg.name().is_empty());
        }
        assert!(by_name("nope", 4).is_none());
    }

    #[test]
    fn hierarchical_registry_wraps_every_name() {
        let mut seen = std::collections::BTreeSet::new();
        for name in ALL_NAMES {
            let map = NodeMap::even(2, 2);
            let agg = hierarchical(name, map, 4).unwrap_or_else(|| panic!("{name}"));
            assert!(agg.name().starts_with("hier-"), "{}", agg.name());
            // Every registry name must map to a distinct specialized hier
            // name (the generic "hier" fallback would make two schemes
            // indistinguishable in bench labels and JSONL) — adding an
            // aggregator to ALL_NAMES requires extending
            // Hierarchical::name()'s static table.
            assert!(
                agg.name() != "hier" && seen.insert(agg.name()),
                "{name}: hier name {} not specialized/unique",
                agg.name()
            );
            assert!(agg.node_map().is_some());
        }
        assert!(hierarchical("nope", NodeMap::even(2, 2), 4).is_none());
        // Degenerate maps delegate: no grouping surfaces to the executor.
        let deg = hierarchical("mean", NodeMap::even(1, 4), 4).unwrap();
        assert!(deg.node_map().is_none());
    }
}
