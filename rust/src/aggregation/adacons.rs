//! AdaCons — adaptive consensus gradient aggregation (the paper).
//!
//! Pipeline per bucket (Alg. 1):
//!
//! 1. **Consensus statistics** (Eq. 7): `dots_i = <g_i, g_bar>`,
//!    `sqn_i = ||g_i||²` — one fused pass over the gradient matrix (on a
//!    real fabric: the first O(d) all-reduce).
//! 2. **Subspace coefficients**: `α_i = dots_i / ||g_i||` — the first-order
//!    step in the subspace spanned by the *normalized* worker directions
//!    (an O(N) all-gather shares them).
//! 3. **Subspace momentum** (Eq. 11): sort-invariant EMA — sort α, EMA the
//!    sorted vector against the running sorted EMA, scatter back through
//!    the inverse permutation. Decouples the smoothing from worker
//!    identity, since shards are re-dealt every step.
//! 4. **Unbiased normalization** (Eq. 13): scale so Σ α_i = 1, removing
//!    the λ hyper-parameter; without it, the raw Eq. 8 scaling λ/N is used
//!    (λ = 1, Table 2 "AdaCons" column).
//! 5. **Re-projection** (Eq. 12): `out = Σ γ_i g_i` with
//!    `γ_i = α_i / ||g_i||` (the second O(d) all-reduce).

use super::stats::CoeffStages;
use super::{per_bucket_payload_ops, AggInfo, Aggregator, BucketWork, BucketedAggregator};
use crate::collective::CollectiveKind;
use crate::parallel::ParallelCtx;
use crate::tensor::{Buckets, GradSet};

/// Which components of the method are enabled (Table 2 ablation axes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaConsConfig {
    /// EMA momentum over sorted subspace coefficients (Eq. 11). β = 0.99
    /// in the paper.
    pub momentum: Option<f64>,
    /// Sum-one normalization (Eq. 13).
    pub normalize: bool,
    /// λ for the un-normalized variant (Eq. 8; paper ablates λ = 1).
    pub lambda: f64,
}

impl AdaConsConfig {
    /// Full method: momentum + normalization (the paper's "Moment. & Norm.").
    pub fn full() -> Self {
        AdaConsConfig {
            momentum: Some(0.99),
            normalize: true,
            lambda: 1.0,
        }
    }

    /// Basic subspace aggregation, Eq. 8 with λ = 1.
    pub fn raw() -> Self {
        AdaConsConfig {
            momentum: None,
            normalize: false,
            lambda: 1.0,
        }
    }

    pub fn momentum_only() -> Self {
        AdaConsConfig {
            momentum: Some(0.99),
            normalize: false,
            lambda: 1.0,
        }
    }

    pub fn norm_only() -> Self {
        AdaConsConfig {
            momentum: None,
            normalize: true,
            lambda: 1.0,
        }
    }
}

#[derive(Debug)]
pub struct AdaCons {
    cfg: AdaConsConfig,
    /// Running sorted-EMA state, one vector per bucket (lazily sized).
    ema_sorted: Vec<Vec<f64>>,
    /// Scratch reused across steps (no allocation on the hot path).
    alpha: Vec<f64>,
    gamma: Vec<f32>,
    order: Vec<usize>,
}

impl AdaCons {
    pub fn new(cfg: AdaConsConfig) -> Self {
        AdaCons {
            cfg,
            ema_sorted: Vec::new(),
            alpha: Vec::new(),
            gamma: Vec::new(),
            order: Vec::new(),
        }
    }

    pub fn config(&self) -> AdaConsConfig {
        self.cfg
    }

    /// The coefficient pipeline on precomputed statistics; exposed for unit
    /// tests and the property suite. Returns (γ, stages).
    pub fn weights_from_stats(
        &mut self,
        bucket_idx: usize,
        dots: &[f64],
        sqn: &[f64],
    ) -> (Vec<f32>, CoeffStages) {
        let n = dots.len();
        let mut stages = CoeffStages::default();

        // -- subspace coefficients α_i = <g_i, g_bar> / ||g_i|| (Eq. 7) --
        self.alpha.clear();
        for i in 0..n {
            let norm = sqn[i].sqrt();
            self.alpha.push(if norm > 0.0 { dots[i] / norm } else { 0.0 });
        }
        stages.record_raw(&self.alpha);

        // -- non-finite guard: an inf/NaN gradient upstream (overflowed
        // loss, bad rank) makes α_i NaN (inf/inf) or ±inf, which would
        // poison the EMA state and normalization. Fall back to uniform
        // weights (= plain averaging) for this step and leave the
        // momentum state untouched.
        if self.alpha.iter().any(|a| !a.is_finite()) {
            // Record a finite placeholder (the effective uniform mixing
            // weight) so Fig. 7 stage logs are not poisoned by the inf/NaN
            // the guard is here to contain.
            for a in &mut self.alpha {
                *a = 1.0 / n as f64;
            }
            stages.record_final(&self.alpha);
            self.gamma.clear();
            self.gamma.extend(std::iter::repeat(1.0 / n as f32).take(n));
            return (self.gamma.clone(), stages);
        }

        // -- sorted-EMA momentum (Eq. 11) --
        if let Some(beta) = self.cfg.momentum {
            while self.ema_sorted.len() <= bucket_idx {
                self.ema_sorted.push(Vec::new());
            }
            self.order.clear();
            self.order.extend(0..n);
            let alpha = &self.alpha;
            // total_cmp: the guard above keeps NaN out, but a total order
            // keeps the sort panic-free by construction.
            self.order.sort_by(|&a, &b| alpha[a].total_cmp(&alpha[b]));
            let ema = &mut self.ema_sorted[bucket_idx];
            if ema.len() != n {
                // First step (or N changed): seed the EMA with the current
                // sorted coefficients instead of zero so early steps are
                // not artificially shrunk.
                ema.clear();
                ema.extend(self.order.iter().map(|&i| self.alpha[i]));
            } else {
                for (k, &i) in self.order.iter().enumerate() {
                    ema[k] = beta * ema[k] + (1.0 - beta) * self.alpha[i];
                }
            }
            for (k, &i) in self.order.iter().enumerate() {
                self.alpha[i] = ema[k];
            }
            stages.record_momentum(&self.alpha);
        }

        // -- normalization (Eq. 13) or raw λ/N scaling (Eq. 8) --
        if self.cfg.normalize {
            let denom: f64 = self.alpha.iter().sum();
            let scale_ref: f64 = self.alpha.iter().map(|a| a.abs()).sum::<f64>();
            if denom.abs() > 1e-12 * scale_ref.max(1e-30) {
                let inv = 1.0 / denom;
                for a in &mut self.alpha {
                    *a *= inv;
                }
            } else {
                // Degenerate subspace (coefficients cancel): fall back to
                // uniform weights = plain averaging.
                for (i, a) in self.alpha.iter_mut().enumerate() {
                    let norm = sqn[i].sqrt();
                    *a = norm / n as f64; // γ becomes 1/N below
                }
            }
        } else {
            let s = self.cfg.lambda / n as f64;
            for a in &mut self.alpha {
                *a *= s;
            }
        }
        stages.record_final(&self.alpha);

        // -- re-projection weights γ_i = α_i / ||g_i|| (Eq. 12) --
        self.gamma.clear();
        for i in 0..n {
            let norm = sqn[i].sqrt();
            self.gamma
                .push(if norm > 0.0 { (self.alpha[i] / norm) as f32 } else { 0.0 });
        }
        (self.gamma.clone(), stages)
    }
}

impl BucketedAggregator for AdaCons {
    fn ingest_bucket(
        &self,
        _b: usize,
        view: &GradSet,
        lo: usize,
        hi: usize,
        ctx: &ParallelCtx,
    ) -> BucketWork {
        // Phase 1: the bucket's consensus statistics (Eq. 7 restricted to
        // the bucket) — on a real fabric, the bucket's first all-reduce.
        BucketWork::Stats(view.consensus_stats_range_ctx(lo, hi, ctx))
    }

    fn finalize(
        &mut self,
        grads: &GradSet,
        buckets: &Buckets,
        work: Vec<BucketWork>,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        assert_eq!(out.len(), grads.d());
        assert_eq!(work.len(), buckets.len());
        let mut first_gamma = None;
        let mut first_stages = None;
        // Fixed bucket order: the coefficient pipeline (EMA state) and the
        // re-projection run exactly as the serial loop would, however the
        // phase-1 tasks interleaved.
        for (b, ((lo, hi), w)) in buckets.iter().zip(work).enumerate() {
            let st = match w {
                BucketWork::Stats(st) => st,
                other => panic!("adacons ingests Stats work, got {other:?}"),
            };
            let (gamma, stages) = self.weights_from_stats(b, &st.dots, &st.sqn);
            grads.weighted_sum_range_into_ctx(&gamma, lo, hi, &mut out[lo..hi], ctx);
            if b == 0 {
                first_gamma = Some(gamma);
                first_stages = Some(stages);
            }
        }
        // Per-bucket stats all-reduces overlap the backward; the scalar
        // all-gather and the re-weighted-gradient all-reduce need the
        // coefficients, so they are exposed (§5.1's measured overhead).
        let mut comm = per_bucket_payload_ops(CollectiveKind::AllReduce, buckets);
        comm.push(super::CommOp {
            kind: CollectiveKind::AllGather,
            bytes: crate::collective::cost_model::f32_wire_bytes(1),
            bucket: None,
            scope: super::CommScope::Global,
        });
        comm.push(super::CommOp {
            kind: CollectiveKind::AllReduce,
            bytes: crate::collective::cost_model::f32_wire_bytes(grads.d()),
            bucket: None,
            scope: super::CommScope::Global,
        });
        AggInfo {
            gammas: first_gamma,
            coeff_stages: first_stages,
            comm,
            par: Some(ctx.par_plan(grads.d())),
        }
    }
}

impl Aggregator for AdaCons {
    fn name(&self) -> &'static str {
        match (self.cfg.momentum.is_some(), self.cfg.normalize) {
            (true, true) => "adacons",
            (false, false) => "adacons-raw",
            (true, false) => "adacons-momentum",
            (false, true) => "adacons-norm",
        }
    }

    fn reset(&mut self) {
        self.ema_sorted.clear();
    }

    fn export_state(&self) -> Vec<Vec<f64>> {
        self.ema_sorted.clone()
    }

    fn import_state(&mut self, state: &[Vec<f64>]) {
        if !state.is_empty() {
            self.ema_sorted = state.to_vec();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Buckets, GradSet};
    use crate::util::prng::Rng;

    fn random_set(n: usize, d: usize, seed: u64) -> GradSet {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal_f32(1.0)).collect())
            .collect();
        GradSet::from_rows(&rows)
    }

    #[test]
    fn raw_collapses_to_mean_for_identical_gradients() {
        let g: Vec<f32> = (0..64).map(|i| (i as f32) * 0.1 - 3.0).collect();
        let gs = GradSet::from_rows(&vec![g.clone(); 4]);
        let mut out = vec![0.0; 64];
        let mut agg = AdaCons::new(AdaConsConfig::raw());
        agg.aggregate(&gs, &Buckets::single(64), &mut out);
        for j in 0..64 {
            assert!((out[j] - g[j]).abs() < 1e-5, "j={j}");
        }
    }

    #[test]
    fn normalized_weights_have_sum_one_subspace_coeffs() {
        let gs = random_set(8, 200, 1);
        let st = gs.consensus_stats();
        let mut agg = AdaCons::new(AdaConsConfig::norm_only());
        let (gamma, _) = agg.weights_from_stats(0, &st.dots, &st.sqn);
        // Σ γ_i ||g_i|| = Σ α_i = 1 (Eq. 13).
        let s: f64 = gamma
            .iter()
            .zip(&st.sqn)
            .map(|(&g, &q)| g as f64 * q.sqrt())
            .sum();
        assert!((s - 1.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn raw_matches_eq8_closed_form() {
        let gs = random_set(5, 50, 2);
        let st = gs.consensus_stats();
        let mut agg = AdaCons::new(AdaConsConfig::raw());
        let (gamma, _) = agg.weights_from_stats(0, &st.dots, &st.sqn);
        for i in 0..5 {
            let expect = (1.0 / 5.0) * st.dots[i] / st.sqn[i];
            assert!((gamma[i] as f64 - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn momentum_smooths_coefficient_jumps() {
        let mut agg = AdaCons::new(AdaConsConfig::momentum_only());
        let sqn = vec![1.0; 4];
        // Step 1 seeds the EMA.
        let (g1, _) = agg.weights_from_stats(0, &[1.0, 1.0, 1.0, 1.0], &sqn);
        // Step 2: one coefficient spikes; EMA should keep weights near step 1.
        let (g2, _) = agg.weights_from_stats(0, &[1.0, 1.0, 1.0, 100.0], &sqn);
        let jump = (g2[3] - g1[3]).abs();
        assert!(jump < 0.3 * (100.0f32 - 1.0) / 4.0, "jump={jump}");
        // Without momentum the spike passes through.
        let mut raw = AdaCons::new(AdaConsConfig::raw());
        let (r1, _) = raw.weights_from_stats(0, &[1.0, 1.0, 1.0, 1.0], &sqn);
        let (r2, _) = raw.weights_from_stats(0, &[1.0, 1.0, 1.0, 100.0], &sqn);
        assert!((r2[3] - r1[3]).abs() > 10.0 * jump);
    }

    #[test]
    fn momentum_is_order_invariant() {
        // Same multiset of coefficients in different worker order must
        // produce the same multiset of weights (sort trick, Eq. 11).
        let sqn = vec![1.0; 4];
        let mut a = AdaCons::new(AdaConsConfig::momentum_only());
        let mut b = AdaCons::new(AdaConsConfig::momentum_only());
        a.weights_from_stats(0, &[1.0, 2.0, 3.0, 4.0], &sqn);
        b.weights_from_stats(0, &[4.0, 3.0, 2.0, 1.0], &sqn);
        let (ga, _) = a.weights_from_stats(0, &[5.0, 6.0, 7.0, 8.0], &sqn);
        let (gb, _) = b.weights_from_stats(0, &[8.0, 7.0, 6.0, 5.0], &sqn);
        let mut sa = ga.clone();
        let mut sb = gb.clone();
        sa.sort_by(|x, y| x.partial_cmp(y).unwrap());
        sb.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for (x, y) in sa.iter().zip(&sb) {
            assert!((x - y).abs() < 1e-7);
        }
    }

    #[test]
    fn zero_gradient_worker_gets_zero_weight() {
        let mut rows = vec![vec![0.0f32; 32]; 3];
        rows[0] = (0..32).map(|i| i as f32 * 0.1).collect();
        rows[1] = rows[0].iter().map(|x| x * 2.0).collect();
        let gs = GradSet::from_rows(&rows);
        let st = gs.consensus_stats();
        let mut agg = AdaCons::new(AdaConsConfig::full());
        let (gamma, _) = agg.weights_from_stats(0, &st.dots, &st.sqn);
        assert_eq!(gamma[2], 0.0);
        assert!(gamma[0] > 0.0 && gamma[1] > 0.0);
    }

    #[test]
    fn degenerate_cancellation_falls_back_to_mean() {
        // Two exactly-opposed gradients: Σα = 0, Eq. 13 is singular.
        let g: Vec<f32> = (0..16).map(|i| i as f32 - 8.0).collect();
        let neg: Vec<f32> = g.iter().map(|x| -x).collect();
        let gs = GradSet::from_rows(&[g.clone(), neg]);
        let mut out = vec![0.0; 16];
        let mut agg = AdaCons::new(AdaConsConfig::norm_only());
        let info = agg.aggregate(&gs, &Buckets::single(16), &mut out);
        let gam = info.gammas.unwrap();
        assert!((gam[0] - 0.5).abs() < 1e-6 && (gam[1] - 0.5).abs() < 1e-6);
        // Mean of g and -g is zero.
        assert!(out.iter().all(|&x| x.abs() < 1e-5));
    }

    #[test]
    fn bucketed_aggregation_covers_whole_vector() {
        let gs = random_set(4, 100, 3);
        let mut whole = vec![0.0; 100];
        let mut parts = vec![0.0; 100];
        let mut a1 = AdaCons::new(AdaConsConfig::norm_only());
        let mut a2 = AdaCons::new(AdaConsConfig::norm_only());
        a1.aggregate(&gs, &Buckets::single(100), &mut whole);
        a2.aggregate(&gs, &Buckets::fixed(100, 30), &mut parts);
        // Both produce finite, fully-written outputs; bucketed differs in
        // general (per-layer coefficients) but must agree when buckets = 1.
        assert!(parts.iter().all(|x| x.is_finite()));
        let mut again = vec![0.0; 100];
        let mut a3 = AdaCons::new(AdaConsConfig::norm_only());
        a3.aggregate(&gs, &Buckets::single(100), &mut again);
        assert_eq!(whole, again);
    }

    #[test]
    fn descent_direction_positive_correlation_with_mean() {
        // <ψ, g_bar> > 0 for generic same-signed-consensus gradients:
        // the aggregate must remain a descent direction.
        let gs = random_set(8, 300, 4);
        let mut mean = vec![0.0; 300];
        gs.mean_into(&mut mean);
        let mut out = vec![0.0; 300];
        let mut agg = AdaCons::new(AdaConsConfig::full());
        agg.aggregate(&gs, &Buckets::single(300), &mut out);
        let ip = crate::tensor::ops::dot(&out, &mean);
        assert!(ip > 0.0, "ip={ip}");
    }

    #[test]
    fn nan_coefficient_falls_back_to_uniform_without_panic() {
        // Regression: the momentum sort used partial_cmp().unwrap(), which
        // panicked when an inf gradient upstream made α_i = inf/inf = NaN.
        let mut agg = AdaCons::new(AdaConsConfig::full());
        let sqn = vec![1.0, 1.0, f64::INFINITY, 1.0];
        let dots = vec![1.0, 2.0, f64::INFINITY, 0.5];
        let (gamma, _) = agg.weights_from_stats(0, &dots, &sqn);
        assert_eq!(gamma, vec![0.25; 4]);
        // Momentum state stays clean: a following finite step seeds fresh.
        let (g1, _) = agg.weights_from_stats(0, &[1.0; 4], &vec![1.0; 4]);
        let mut fresh = AdaCons::new(AdaConsConfig::full());
        let (g2, _) = fresh.weights_from_stats(0, &[1.0; 4], &vec![1.0; 4]);
        assert_eq!(g1, g2);
    }

    #[test]
    fn inf_gradient_row_does_not_panic_aggregate() {
        let mut rows = vec![vec![1.0f32; 32]; 3];
        rows[1][5] = f32::INFINITY; // bad rank
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0f32; 32];
        let mut agg = AdaCons::new(AdaConsConfig::full());
        let info = agg.aggregate(&gs, &Buckets::single(32), &mut out);
        // Uniform fallback weights, no panic.
        assert_eq!(info.gammas.unwrap(), vec![1.0 / 3.0; 3]);
    }

    #[test]
    fn state_round_trip_restores_momentum_bitwise() {
        // Export mid-run, import into a fresh aggregator: the next step's
        // weights must be bitwise-equal to the uninterrupted run's —
        // without the transfer the fresh EMA reseeds and diverges.
        let sqn = vec![1.0; 4];
        let mut a = AdaCons::new(AdaConsConfig::full());
        a.weights_from_stats(0, &[1.0, 2.0, 3.0, 4.0], &sqn);
        a.weights_from_stats(0, &[2.0, 1.0, 4.0, 3.0], &sqn);
        let state = Aggregator::export_state(&a);
        assert!(!state.is_empty());
        let mut b = AdaCons::new(AdaConsConfig::full());
        Aggregator::import_state(&mut b, &state);
        let (ga, _) = a.weights_from_stats(0, &[5.0, 6.0, 7.0, 8.0], &sqn);
        let (gb, _) = b.weights_from_stats(0, &[5.0, 6.0, 7.0, 8.0], &sqn);
        assert_eq!(ga, gb);
        // Empty state (v1 checkpoint) leaves fresh state untouched.
        let mut c = AdaCons::new(AdaConsConfig::full());
        Aggregator::import_state(&mut c, &[]);
        assert!(Aggregator::export_state(&c).is_empty());
    }

    #[test]
    fn reset_clears_momentum() {
        let sqn = vec![1.0; 3];
        let mut agg = AdaCons::new(AdaConsConfig::full());
        agg.weights_from_stats(0, &[1.0, 2.0, 3.0], &sqn);
        agg.reset();
        // After reset, the next step re-seeds (same result as a fresh one).
        let (g1, _) = agg.weights_from_stats(0, &[3.0, 4.0, 5.0], &sqn);
        let mut fresh = AdaCons::new(AdaConsConfig::full());
        let (g2, _) = fresh.weights_from_stats(0, &[3.0, 4.0, 5.0], &sqn);
        assert_eq!(g1, g2);
    }
}
