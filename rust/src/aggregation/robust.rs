//! Robust aggregation baselines for the Byzantine-worker example (the
//! paper's §1 motivates adaptive aggregation by workers producing
//! computing errors / bad local gradients; these are the classical
//! defenses to compare against).

use super::{
    per_bucket_payload_ops, write_bucket_outputs, AggInfo, Aggregator, BucketWork,
    BucketedAggregator,
};
use crate::collective::CollectiveKind;
use crate::parallel::ParallelCtx;
use crate::tensor::{Buckets, GradSet};

/// Coordinate-wise median. Coordinates are independent, so the column
/// range shards freely across the pool (each shard job carries its own
/// N-element sort scratch); output is bitwise-identical at any thread
/// count.
#[derive(Debug, Default)]
pub struct CoordinateMedian;

impl CoordinateMedian {
    pub fn new() -> Self {
        Self
    }
}

impl BucketedAggregator for CoordinateMedian {
    fn ingest_bucket(
        &self,
        _b: usize,
        view: &GradSet,
        lo: usize,
        hi: usize,
        ctx: &ParallelCtx,
    ) -> BucketWork {
        let n = view.n();
        let mut o = vec![0.0f32; hi - lo];
        ctx.for_each_out_shard(lo, hi, &mut o, |slo, _shi, oc| {
            let mut scratch = vec![0.0f32; n];
            for (k, ov) in oc.iter_mut().enumerate() {
                let j = slo + k;
                for i in 0..n {
                    scratch[i] = view.row(i)[j];
                }
                scratch.sort_by(|a, b| a.total_cmp(b));
                *ov = if n % 2 == 1 {
                    scratch[n / 2]
                } else {
                    0.5 * (scratch[n / 2 - 1] + scratch[n / 2])
                };
            }
        });
        BucketWork::Output(o)
    }

    fn finalize(
        &mut self,
        grads: &GradSet,
        buckets: &Buckets,
        work: Vec<BucketWork>,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        write_bucket_outputs(buckets, work, out);
        AggInfo {
            gammas: None,
            coeff_stages: None,
            // Requires gathering all gradients; each bucket's gather can
            // start as soon as that bucket exists.
            comm: per_bucket_payload_ops(CollectiveKind::AllGather, buckets),
            par: Some(ctx.par_plan(grads.d())),
        }
    }
}

impl Aggregator for CoordinateMedian {
    fn name(&self) -> &'static str {
        "median"
    }
}

/// Coordinate-wise α-trimmed mean: drop the `trim_frac` highest and lowest
/// values per coordinate, average the rest. Column-sharded like the
/// median.
#[derive(Debug)]
pub struct TrimmedMean {
    trim_frac: f64,
}

impl TrimmedMean {
    pub fn new(trim_frac: f64) -> Self {
        assert!((0.0..0.5).contains(&trim_frac));
        TrimmedMean { trim_frac }
    }
}

impl BucketedAggregator for TrimmedMean {
    fn ingest_bucket(
        &self,
        _b: usize,
        view: &GradSet,
        lo: usize,
        hi: usize,
        ctx: &ParallelCtx,
    ) -> BucketWork {
        let n = view.n();
        let k = ((n as f64) * self.trim_frac).floor() as usize;
        let keep = n - 2 * k;
        assert!(keep > 0, "trim fraction leaves no workers");
        let mut o = vec![0.0f32; hi - lo];
        ctx.for_each_out_shard(lo, hi, &mut o, |slo, _shi, oc| {
            let mut scratch = vec![0.0f32; n];
            for (c, ov) in oc.iter_mut().enumerate() {
                let j = slo + c;
                for i in 0..n {
                    scratch[i] = view.row(i)[j];
                }
                scratch.sort_by(|a, b| a.total_cmp(b));
                let s: f64 = scratch[k..n - k].iter().map(|&x| x as f64).sum();
                *ov = (s / keep as f64) as f32;
            }
        });
        BucketWork::Output(o)
    }

    fn finalize(
        &mut self,
        grads: &GradSet,
        buckets: &Buckets,
        work: Vec<BucketWork>,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        write_bucket_outputs(buckets, work, out);
        AggInfo {
            gammas: None,
            coeff_stages: None,
            comm: per_bucket_payload_ops(CollectiveKind::AllGather, buckets),
            par: Some(ctx.par_plan(grads.d())),
        }
    }
}

impl Aggregator for TrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed-mean"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Buckets, GradSet};

    #[test]
    fn median_ignores_one_outlier() {
        let rows = vec![
            vec![1.0f32, 1.0],
            vec![1.1, 0.9],
            vec![0.9, 1.1],
            vec![1e6, -1e6], // Byzantine
            vec![1.0, 1.0],
        ];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 2];
        CoordinateMedian::new().aggregate(&gs, &Buckets::single(2), &mut out);
        assert!((out[0] - 1.0).abs() < 0.11);
        assert!((out[1] - 1.0).abs() < 0.11);
    }

    #[test]
    fn median_even_count_averages_middles() {
        let rows = vec![vec![1.0f32], vec![2.0], vec![3.0], vec![4.0]];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 1];
        CoordinateMedian::new().aggregate(&gs, &Buckets::single(1), &mut out);
        assert!((out[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let rows = vec![
            vec![0.0f32],
            vec![10.0],
            vec![11.0],
            vec![12.0],
            vec![1000.0],
        ];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 1];
        TrimmedMean::new(0.2).aggregate(&gs, &Buckets::single(1), &mut out);
        assert!((out[0] - 11.0).abs() < 1e-5, "{}", out[0]);
    }

    #[test]
    fn trimmed_mean_zero_trim_is_mean() {
        let rows = vec![vec![1.0f32], vec![3.0]];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 1];
        TrimmedMean::new(0.0).aggregate(&gs, &Buckets::single(1), &mut out);
        assert!((out[0] - 2.0).abs() < 1e-6);
    }
}
