//! Robust aggregation baselines for the Byzantine-worker example (the
//! paper's §1 motivates adaptive aggregation by workers producing
//! computing errors / bad local gradients; these are the classical
//! defenses to compare against).

use super::{AggInfo, Aggregator};
use crate::collective::CollectiveKind;
use crate::parallel::ParallelCtx;
use crate::tensor::{Buckets, GradSet};

/// Coordinate-wise median. Coordinates are independent, so the column
/// range shards freely across the pool (each shard job carries its own
/// N-element sort scratch); output is bitwise-identical at any thread
/// count.
#[derive(Debug, Default)]
pub struct CoordinateMedian;

impl CoordinateMedian {
    pub fn new() -> Self {
        Self
    }
}

impl Aggregator for CoordinateMedian {
    fn name(&self) -> &'static str {
        "median"
    }

    fn aggregate_ctx(
        &mut self,
        grads: &GradSet,
        _buckets: &Buckets,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        let n = grads.n();
        ctx.for_each_out_shard(0, grads.d(), out, |lo, _hi, oc| {
            let mut scratch = vec![0.0f32; n];
            for (k, o) in oc.iter_mut().enumerate() {
                let j = lo + k;
                for i in 0..n {
                    scratch[i] = grads.row(i)[j];
                }
                scratch.sort_by(|a, b| a.total_cmp(b));
                *o = if n % 2 == 1 {
                    scratch[n / 2]
                } else {
                    0.5 * (scratch[n / 2 - 1] + scratch[n / 2])
                };
            }
        });
        AggInfo {
            gammas: None,
            coeff_stages: None,
            // Requires gathering all gradients: N x d all-gather cost.
            comm: vec![(CollectiveKind::AllGather, grads.d() * 4)],
            par: Some(ctx.par_plan(grads.d())),
        }
    }
}

/// Coordinate-wise α-trimmed mean: drop the `trim_frac` highest and lowest
/// values per coordinate, average the rest. Column-sharded like the
/// median.
#[derive(Debug)]
pub struct TrimmedMean {
    trim_frac: f64,
}

impl TrimmedMean {
    pub fn new(trim_frac: f64) -> Self {
        assert!((0.0..0.5).contains(&trim_frac));
        TrimmedMean { trim_frac }
    }
}

impl Aggregator for TrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed-mean"
    }

    fn aggregate_ctx(
        &mut self,
        grads: &GradSet,
        _buckets: &Buckets,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        let n = grads.n();
        let k = ((n as f64) * self.trim_frac).floor() as usize;
        let keep = n - 2 * k;
        assert!(keep > 0, "trim fraction leaves no workers");
        ctx.for_each_out_shard(0, grads.d(), out, |lo, _hi, oc| {
            let mut scratch = vec![0.0f32; n];
            for (c, o) in oc.iter_mut().enumerate() {
                let j = lo + c;
                for i in 0..n {
                    scratch[i] = grads.row(i)[j];
                }
                scratch.sort_by(|a, b| a.total_cmp(b));
                let s: f64 = scratch[k..n - k].iter().map(|&x| x as f64).sum();
                *o = (s / keep as f64) as f32;
            }
        });
        AggInfo {
            gammas: None,
            coeff_stages: None,
            comm: vec![(CollectiveKind::AllGather, grads.d() * 4)],
            par: Some(ctx.par_plan(grads.d())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Buckets, GradSet};

    #[test]
    fn median_ignores_one_outlier() {
        let rows = vec![
            vec![1.0f32, 1.0],
            vec![1.1, 0.9],
            vec![0.9, 1.1],
            vec![1e6, -1e6], // Byzantine
            vec![1.0, 1.0],
        ];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 2];
        CoordinateMedian::new().aggregate(&gs, &Buckets::single(2), &mut out);
        assert!((out[0] - 1.0).abs() < 0.11);
        assert!((out[1] - 1.0).abs() < 0.11);
    }

    #[test]
    fn median_even_count_averages_middles() {
        let rows = vec![vec![1.0f32], vec![2.0], vec![3.0], vec![4.0]];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 1];
        CoordinateMedian::new().aggregate(&gs, &Buckets::single(1), &mut out);
        assert!((out[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let rows = vec![
            vec![0.0f32],
            vec![10.0],
            vec![11.0],
            vec![12.0],
            vec![1000.0],
        ];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 1];
        TrimmedMean::new(0.2).aggregate(&gs, &Buckets::single(1), &mut out);
        assert!((out[0] - 11.0).abs() < 1e-5, "{}", out[0]);
    }

    #[test]
    fn trimmed_mean_zero_trim_is_mean() {
        let rows = vec![vec![1.0f32], vec![3.0]];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 1];
        TrimmedMean::new(0.0).aggregate(&gs, &Buckets::single(1), &mut out);
        assert!((out[0] - 2.0).abs() < 1e-6);
    }
}
