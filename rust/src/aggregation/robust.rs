//! Robust aggregation baselines for the Byzantine-worker example (the
//! paper's §1 motivates adaptive aggregation by workers producing
//! computing errors / bad local gradients; these are the classical
//! defenses to compare against).

use super::{AggInfo, Aggregator};
use crate::collective::CollectiveKind;
use crate::tensor::{Buckets, GradSet};

/// Coordinate-wise median.
#[derive(Debug, Default)]
pub struct CoordinateMedian {
    scratch: Vec<f32>,
}

impl CoordinateMedian {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Aggregator for CoordinateMedian {
    fn name(&self) -> &'static str {
        "median"
    }

    fn aggregate(&mut self, grads: &GradSet, _buckets: &Buckets, out: &mut [f32]) -> AggInfo {
        let n = grads.n();
        self.scratch.resize(n, 0.0);
        for j in 0..grads.d() {
            for i in 0..n {
                self.scratch[i] = grads.row(i)[j];
            }
            self.scratch
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            out[j] = if n % 2 == 1 {
                self.scratch[n / 2]
            } else {
                0.5 * (self.scratch[n / 2 - 1] + self.scratch[n / 2])
            };
        }
        AggInfo {
            gammas: None,
            coeff_stages: None,
            // Requires gathering all gradients: N x d all-gather cost.
            comm: vec![(CollectiveKind::AllGather, grads.d() * 4)],
        }
    }
}

/// Coordinate-wise α-trimmed mean: drop the `trim_frac` highest and lowest
/// values per coordinate, average the rest.
#[derive(Debug)]
pub struct TrimmedMean {
    trim_frac: f64,
    scratch: Vec<f32>,
}

impl TrimmedMean {
    pub fn new(trim_frac: f64) -> Self {
        assert!((0.0..0.5).contains(&trim_frac));
        TrimmedMean {
            trim_frac,
            scratch: Vec::new(),
        }
    }
}

impl Aggregator for TrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed-mean"
    }

    fn aggregate(&mut self, grads: &GradSet, _buckets: &Buckets, out: &mut [f32]) -> AggInfo {
        let n = grads.n();
        let k = ((n as f64) * self.trim_frac).floor() as usize;
        let keep = n - 2 * k;
        assert!(keep > 0, "trim fraction leaves no workers");
        self.scratch.resize(n, 0.0);
        for j in 0..grads.d() {
            for i in 0..n {
                self.scratch[i] = grads.row(i)[j];
            }
            self.scratch
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            let s: f64 = self.scratch[k..n - k].iter().map(|&x| x as f64).sum();
            out[j] = (s / keep as f64) as f32;
        }
        AggInfo {
            gammas: None,
            coeff_stages: None,
            comm: vec![(CollectiveKind::AllGather, grads.d() * 4)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Buckets, GradSet};

    #[test]
    fn median_ignores_one_outlier() {
        let rows = vec![
            vec![1.0f32, 1.0],
            vec![1.1, 0.9],
            vec![0.9, 1.1],
            vec![1e6, -1e6], // Byzantine
            vec![1.0, 1.0],
        ];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 2];
        CoordinateMedian::new().aggregate(&gs, &Buckets::single(2), &mut out);
        assert!((out[0] - 1.0).abs() < 0.11);
        assert!((out[1] - 1.0).abs() < 0.11);
    }

    #[test]
    fn median_even_count_averages_middles() {
        let rows = vec![vec![1.0f32], vec![2.0], vec![3.0], vec![4.0]];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 1];
        CoordinateMedian::new().aggregate(&gs, &Buckets::single(1), &mut out);
        assert!((out[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let rows = vec![
            vec![0.0f32],
            vec![10.0],
            vec![11.0],
            vec![12.0],
            vec![1000.0],
        ];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 1];
        TrimmedMean::new(0.2).aggregate(&gs, &Buckets::single(1), &mut out);
        assert!((out[0] - 11.0).abs() < 1e-5, "{}", out[0]);
    }

    #[test]
    fn trimmed_mean_zero_trim_is_mean() {
        let rows = vec![vec![1.0f32], vec![3.0]];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 1];
        TrimmedMean::new(0.0).aggregate(&gs, &Buckets::single(1), &mut out);
        assert!((out[0] - 2.0).abs() < 1e-6);
    }
}
