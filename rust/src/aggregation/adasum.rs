//! Adasum baseline [Maleki et al., MLSys 2021] — the diametric opposite of
//! AdaCons: it *discounts* the common component of paired gradients to
//! emulate sequential SGD steps.
//!
//! Pairwise rule: `adasum(a, b) = (1 - <a,b>/(2||a||²)) a +
//! (1 - <a,b>/(2||b||²)) b`, applied recursively over a binary tree of the
//! workers (odd tails pass through), then scaled by 1/N to stay on the
//! averaging learning-rate scale.

use super::{AggInfo, Aggregator, BucketWork, BucketedAggregator, CommOp};
use crate::collective::CollectiveKind;
use crate::parallel::ParallelCtx;
use crate::tensor::{ops, Buckets, GradSet};

#[derive(Debug, Default)]
pub struct Adasum;

impl Adasum {
    pub fn new() -> Self {
        Adasum
    }

    /// One pairwise combine: the `(<a,b>, ||a||², ||b||²)` reduction and
    /// the elementwise blend both run sharded on the context's pool, with
    /// the dot partials folded in the fixed shard-order tree (so the
    /// result is bitwise-stable across thread counts).
    fn pair(a: &[f32], b: &[f32], out: &mut Vec<f32>, ctx: &ParallelCtx) {
        let (ab, na, nb) = ctx
            .map_reduce(
                0,
                a.len(),
                |lo, hi| ops::dot3(&a[lo..hi], &b[lo..hi]),
                |x, y| (x.0 + y.0, x.1 + y.1, x.2 + y.2),
            )
            .unwrap_or((0.0, 0.0, 0.0));
        let ca = if na > 0.0 { 1.0 - ab / (2.0 * na) } else { 1.0 } as f32;
        let cb = if nb > 0.0 { 1.0 - ab / (2.0 * nb) } else { 1.0 } as f32;
        out.clear();
        out.resize(a.len(), 0.0);
        ctx.for_each_out_shard(0, a.len(), out, |lo, hi, oc| {
            for (k, o) in oc.iter_mut().enumerate() {
                let j = lo + k;
                *o = ca * a[j] + cb * b[j];
            }
            debug_assert_eq!(lo + oc.len(), hi);
        });
    }
}

impl BucketedAggregator for Adasum {
    fn ingest_bucket(
        &self,
        _b: usize,
        _view: &GradSet,
        _lo: usize,
        _hi: usize,
        _ctx: &ParallelCtx,
    ) -> BucketWork {
        // The pairwise tree's deeper levels blend whole vectors, so no
        // per-bucket partial survives recombination — everything runs in
        // finalize on the assembled set (the comm below is exposed).
        BucketWork::Deferred
    }

    fn finalize(
        &mut self,
        grads: &GradSet,
        _buckets: &Buckets,
        _work: Vec<BucketWork>,
        out: &mut [f32],
        ctx: &ParallelCtx,
    ) -> AggInfo {
        let n = grads.n();
        let d = grads.d();
        assert_eq!(out.len(), d);
        let mut level: Vec<Vec<f32>> = (0..n).map(|i| grads.row(i).to_vec()).collect();
        let mut scratch = Vec::with_capacity(d);
        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            let mut it = level.into_iter();
            while let Some(a) = it.next() {
                if let Some(b) = it.next() {
                    Self::pair(&a, &b, &mut scratch, ctx);
                    next.push(scratch.clone());
                } else {
                    next.push(a); // odd tail passes through
                }
            }
            level = next;
        }
        let result = level.pop().unwrap();
        // Normalize to the averaging LR scale (Adasum's recursive sums grow
        // with N; the paper's baselines are compared at fixed LR).
        ops::scaled_copy(1.0 / n as f32, &result, out);
        AggInfo {
            gammas: None, // not a fixed linear combination of the inputs
            coeff_stages: None,
            // log2(N) rounds of pairwise exchanges ≈ one allreduce in cost.
            comm: vec![CommOp {
                kind: CollectiveKind::AllReduce,
                bytes: crate::collective::cost_model::f32_wire_bytes(d),
                bucket: None,
                scope: super::CommScope::Global,
            }],
            par: Some(ctx.par_plan(d)),
        }
    }
}

impl Aggregator for Adasum {
    fn name(&self) -> &'static str {
        "adasum"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Buckets, GradSet};

    #[test]
    fn orthogonal_pair_passes_sum_through() {
        // <a,b> = 0 -> adasum(a,b) = a + b; with 1/N scaling -> mean * 2/2.
        let a = vec![1.0f32, 0.0];
        let b = vec![0.0f32, 1.0];
        let gs = GradSet::from_rows(&[a, b]);
        let mut out = vec![0.0; 2];
        Adasum::new().aggregate(&gs, &Buckets::single(2), &mut out);
        assert!((out[0] - 0.5).abs() < 1e-6 && (out[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn identical_pair_halves_before_scale() {
        // a == b -> coefficients 1 - 1/2 = 1/2 each -> result = a; /N -> a/2.
        let a = vec![2.0f32; 4];
        let gs = GradSet::from_rows(&[a.clone(), a.clone()]);
        let mut out = vec![0.0; 4];
        Adasum::new().aggregate(&gs, &Buckets::single(4), &mut out);
        for x in &out {
            assert!((x - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn odd_worker_count_handled() {
        let rows = vec![vec![1.0f32, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]];
        let gs = GradSet::from_rows(&rows);
        let mut out = vec![0.0; 2];
        Adasum::new().aggregate(&gs, &Buckets::single(2), &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
