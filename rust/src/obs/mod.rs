//! Observability: structured span tracing + a unified metrics registry.
//!
//! One [`Obs`] handle per `Trainer`, shared (`Arc`) with the executor
//! and every rank thread. The [`Tracer`] half records wall- and
//! sim-domain spans for Chrome-trace export ([`chrome`]); the
//! [`Registry`] half is the single source of truth for every counter
//! the trainer reports — `TrainResult` fields, per-step jsonl records,
//! and the `--metrics-out` Prometheus exposition are all derived from
//! it, so sinks can never disagree.
//!
//! Invariant: observation never alters the experiment. Recording reads
//! already-computed values, draws no RNG, and writes nothing into the
//! `SimClock`, so training output is bitwise-identical at every trace
//! level, including `off`.

pub mod chrome;
pub mod registry;
pub mod trace;

use std::sync::Arc;

pub use registry::{HistStat, Registry};
pub use trace::{
    Domain, Event, SpanEvent, SpanKind, SpanScope, StepMark, StepMode, TraceLevel, Tracer,
};

/// Shared observability handle: tracer + metrics registry.
pub struct Obs {
    pub trace: Tracer,
    pub metrics: Registry,
}

impl Obs {
    pub fn new(level: TraceLevel) -> Arc<Obs> {
        Arc::new(Obs {
            trace: Tracer::new(level),
            metrics: Registry::new(),
        })
    }

    /// Tracing off, metrics still collected — the default everywhere a
    /// caller has no `TrainConfig` in hand (benches, unit tests).
    pub fn disabled() -> Arc<Obs> {
        Obs::new(TraceLevel::Off)
    }
}
