//! Chrome trace-event export (Perfetto-loadable) and the `trace-check`
//! validator.
//!
//! Layout: one pid per rank (`pid = 1 + rank`), the leader on pid 0, and
//! a synthetic `sim-timeline` process on pid 1000 carrying every
//! SimClock-domain event (per-rank modeled compute on `tid = rank`,
//! intra-node channels on `tid = 800 + node`, the shared inter/global
//! fabric on `tid = 900`, per-round step marks on `tid = 950`). The
//! `ts`/`dur` microsecond fields are for the viewer; every span also
//! carries its exact `f64` seconds in `args` (`start_s`/`dur_s`), which
//! the in-repo JSON writer emits in shortest-round-trip form — that is
//! what lets [`check_trace`] replay the executor's accounting and match
//! the reported `exposed_{,intra_,inter_}comm_s` bit for bit.

use std::collections::{BTreeMap, BTreeSet};

use crate::util::error::Result;
use crate::util::json::{self, Json};
use crate::{bail, ensure};

use super::trace::{Domain, Event, SpanEvent, SpanKind, SpanScope, StepMark, TraceLevel};

/// Synthetic process id for the SimClock timeline.
const SIM_PID: i64 = 1000;
/// Sim tids: intra channel of node `k` is `INTRA_TID0 + k`.
const INTRA_TID0: i64 = 800;
const INTER_TID: i64 = 900;
const MARK_TID: i64 = 950;
/// Leader-side set-codec encode track for bucket `b` is `ENC_TID0 + b`.
const ENC_TID0: i64 = 10;

/// Tolerance (µs) for the viewer-field well-nestedness check: `ts` and
/// `dur` are `seconds * 1e6`, so shared span edges can disagree by a few
/// ulps after scaling. Exactness lives in `args`, not in `ts`.
const TS_SLACK_US: f64 = 1e-3;

fn span_track(sp: &SpanEvent) -> (i64, i64) {
    match sp.domain {
        Domain::Wall => match sp.kind {
            SpanKind::RankCompute => (1 + sp.rank.max(0), 0),
            SpanKind::Encode if sp.rank >= 0 => (1 + sp.rank, 1),
            // Leader set-codec encode runs on pool threads; give each
            // bucket its own track so spans never interleave on one tid.
            SpanKind::Encode => (0, ENC_TID0 + sp.bucket.max(0)),
            _ => (0, 0),
        },
        Domain::Sim => match sp.kind {
            SpanKind::Transfer => match sp.scope {
                SpanScope::Intra => (SIM_PID, INTRA_TID0 + sp.node.max(0)),
                _ => (SIM_PID, INTER_TID),
            },
            _ => (SIM_PID, sp.rank.max(0)),
        },
    }
}

fn span_name(sp: &SpanEvent) -> String {
    match sp.kind {
        SpanKind::Transfer => match sp.bucket {
            b if b >= 0 => format!("transfer b{b} ({})", sp.scope.tag()),
            _ => format!("transfer ({})", sp.scope.tag()),
        },
        SpanKind::Encode if sp.bucket >= 0 => format!("encode b{}", sp.bucket),
        SpanKind::BucketReady => format!("ready b{}", sp.bucket.max(0)),
        k => k.name().to_string(),
    }
}

fn span_json(sp: &SpanEvent) -> Json {
    let (pid, tid) = span_track(sp);
    let mut args = vec![
        ("kind", json::s(sp.kind.name())),
        ("domain", json::s(sp.domain.tag())),
        ("step", json::num(sp.step as f64)),
        ("start_s", json::num(sp.start_s)),
        ("dur_s", json::num(sp.dur_s)),
    ];
    if sp.rank >= 0 {
        args.push(("rank", json::num(sp.rank as f64)));
    }
    if sp.bucket >= 0 {
        args.push(("bucket", json::num(sp.bucket as f64)));
    }
    if sp.node >= 0 {
        args.push(("node", json::num(sp.node as f64)));
    }
    if sp.scope != SpanScope::None {
        args.push(("scope", json::s(sp.scope.tag())));
    }
    if sp.kind == SpanKind::Transfer {
        // Whether this span's duration entered the executor's serial-comm
        // accumulator (fan-out ops post once per channel but count once).
        args.push(("serial", Json::Bool(sp.serial)));
    }
    let instant = sp.kind == SpanKind::BucketReady;
    let mut fields = vec![
        ("name", json::s(&span_name(sp))),
        ("cat", json::s(sp.domain.tag())),
        ("ph", json::s(if instant { "i" } else { "X" })),
        ("pid", json::num(pid as f64)),
        ("tid", json::num(tid as f64)),
        ("ts", json::num(sp.start_s * 1e6)),
        ("args", json::obj(args)),
    ];
    if instant {
        fields.push(("s", json::s("t")));
    } else {
        fields.push(("dur", json::num(sp.dur_s * 1e6)));
    }
    json::obj(fields)
}

fn mark_json(m: &StepMark) -> Json {
    let args = vec![
        ("kind", json::s("step_mark")),
        ("step", json::num(m.step as f64)),
        ("mode", json::s(m.mode.tag())),
        ("step_start_s", json::num(m.step_start_s)),
        ("compute_end_s", json::num(m.compute_end_s)),
        ("exposed_comm_s", json::num(m.exposed_comm_s)),
        ("exposed_intra_s", json::num(m.exposed_intra_s)),
        ("exposed_inter_s", json::num(m.exposed_inter_s)),
        ("serial_comm_s", json::num(m.serial_comm_s)),
        ("wire_bytes", json::num(m.wire_bytes as f64)),
    ];
    json::obj(vec![
        ("name", json::s(&format!("step {}", m.step))),
        ("cat", json::s("sim")),
        ("ph", json::s("i")),
        ("s", json::s("t")),
        ("pid", json::num(SIM_PID as f64)),
        ("tid", json::num(MARK_TID as f64)),
        ("ts", json::num(m.compute_end_s * 1e6)),
        ("args", json::obj(args)),
    ])
}

fn meta_json(pid: i64, tid: Option<i64>, name: &str) -> Json {
    let mut fields = vec![
        (
            "name",
            json::s(if tid.is_some() {
                "thread_name"
            } else {
                "process_name"
            }),
        ),
        ("ph", json::s("M")),
        ("pid", json::num(pid as f64)),
        ("args", json::obj(vec![("name", json::s(name))])),
    ];
    if let Some(t) = tid {
        fields.push(("tid", json::num(t as f64)));
    }
    json::obj(fields)
}

/// Render a drained event buffer as a Chrome trace-event JSON document.
/// The recording side (coordinator::pipeline) sets `SpanEvent::serial`
/// per transfer span, so the serial-comm accounting survives fan-out
/// ops that post one span per channel.
pub fn chrome_trace(level: TraceLevel, events: &[Event]) -> Json {
    let mut body: Vec<Json> = Vec::with_capacity(events.len() + 8);
    for ev in events {
        match ev {
            Event::Span(sp) => body.push(span_json(sp)),
            Event::Mark(m) => body.push(mark_json(m)),
        }
    }

    // Metadata: name every process/thread that actually appears.
    let mut tracks: BTreeSet<(i64, i64)> = BTreeSet::new();
    for ev in events {
        match ev {
            Event::Span(sp) => {
                tracks.insert(span_track(sp));
            }
            Event::Mark(_) => {
                tracks.insert((SIM_PID, MARK_TID));
            }
        }
    }
    let mut meta: Vec<Json> = Vec::new();
    let pids: BTreeSet<i64> = tracks.iter().map(|&(p, _)| p).collect();
    for pid in pids {
        let pname = match pid {
            0 => "leader".to_string(),
            SIM_PID => "sim-timeline".to_string(),
            p => format!("rank {}", p - 1),
        };
        meta.push(meta_json(pid, None, &pname));
    }
    for &(pid, tid) in &tracks {
        let tname = match (pid, tid) {
            (0, 0) => "step".to_string(),
            (0, t) if t >= ENC_TID0 => format!("set-encode b{}", t - ENC_TID0),
            (SIM_PID, MARK_TID) => "step marks".to_string(),
            (SIM_PID, INTER_TID) => "fabric (inter)".to_string(),
            (SIM_PID, t) if t >= INTRA_TID0 => format!("intra node {}", t - INTRA_TID0),
            (SIM_PID, t) => format!("sim rank {t}"),
            (_, 0) => "compute".to_string(),
            (_, 1) => "encode".to_string(),
            (_, t) => format!("t{t}"),
        };
        meta.push(meta_json(pid, Some(tid), &tname));
    }
    meta.extend(body);

    json::obj(vec![
        ("traceEvents", Json::Arr(meta)),
        ("displayTimeUnit", json::s("ms")),
        (
            "adacons",
            json::obj(vec![
                ("trace_level", json::s(level.tag())),
                ("version", json::num(1.0)),
            ]),
        ),
    ])
}

/// Serialize and write a trace document to `path`.
pub fn write_trace(path: &str, level: TraceLevel, events: &[Event]) -> Result<()> {
    let doc = chrome_trace(level, events);
    std::fs::write(path, doc.to_string_compact())?;
    Ok(())
}

/// What [`check_trace`] verified and summed.
#[derive(Debug, Clone, Default)]
pub struct TraceStats {
    pub events: usize,
    pub spans: usize,
    pub instants: usize,
    pub marks: usize,
    pub transfer_spans: usize,
    pub sim_compute_spans: usize,
    pub bucket_ready_instants: usize,
    /// Steps whose exposed-comm figures were reconstructed from transfer
    /// spans and matched the step mark bit-for-bit (requires a trace
    /// recorded at `bucket` level or above).
    pub reconstructed_steps: usize,
    /// Σ over step marks, in step order (the same fold the trainer's
    /// registry performs) — comparable bitwise to the metrics exposition.
    pub exposed_comm_total: f64,
    pub exposed_intra_total: f64,
    pub exposed_inter_total: f64,
    pub serial_comm_total: f64,
    pub wire_bytes_total: u64,
    pub trace_level: String,
}

struct XSpan {
    ts: f64,
    dur: f64,
    /// Sim-domain spans are emitted in schedule order; wall spans close
    /// (and are recorded) after their children, so only sim tracks are
    /// held to file-order timestamp monotonicity.
    sim: bool,
}

struct TransferArg {
    step: u64,
    scope: String,
    start_s: f64,
    dur_s: f64,
    serial: bool,
}

struct MarkArg {
    step: u64,
    mode: String,
    step_start_s: f64,
    compute_end_s: f64,
    exposed_comm_s: f64,
    exposed_intra_s: f64,
    exposed_inter_s: f64,
    serial_comm_s: f64,
    wire_bytes: u64,
}

fn req_f64(ev: &Json, key: &str, i: usize) -> Result<f64> {
    match ev.get(key).as_f64() {
        Some(v) if v.is_finite() => Ok(v),
        Some(v) => bail!("event {i}: non-finite {key:?}: {v}"),
        None => bail!("event {i}: missing numeric {key:?}"),
    }
}

fn arg_f64(args: &Json, key: &str, i: usize) -> Result<f64> {
    args.get(key)
        .as_f64()
        .ok_or_else(|| crate::util::error::Error::msg(format!("event {i}: missing args.{key}")))
}

/// Validate a Chrome trace-event document produced by this crate:
/// structure (object with `traceEvents`, every event typed and
/// timestamped), per-track monotonic timestamps, well-nested `X` spans,
/// and — when the trace was recorded at `bucket` level or deeper —
/// bit-exact reconstruction of each step's reported
/// `exposed_{,intra_,inter_}comm_s` / `serial_comm_s` from its transfer
/// spans, replaying the executor's accounting branch (`mode` in the
/// step mark).
pub fn check_trace(doc: &Json) -> Result<TraceStats> {
    let evs = match doc.get("traceEvents").as_arr() {
        Some(a) => a,
        None => bail!("not a Chrome trace: no traceEvents array"),
    };
    let mut st = TraceStats {
        events: evs.len(),
        trace_level: doc
            .get("adacons")
            .get("trace_level")
            .as_str()
            .unwrap_or("unknown")
            .to_string(),
        ..TraceStats::default()
    };

    let mut tracks: BTreeMap<(i64, i64), Vec<XSpan>> = BTreeMap::new();
    let mut transfers: Vec<TransferArg> = Vec::new();
    let mut marks: Vec<MarkArg> = Vec::new();

    for (i, ev) in evs.iter().enumerate() {
        let ph = match ev.get("ph").as_str() {
            Some(p) => p,
            None => bail!("event {i}: missing ph"),
        };
        ensure!(!ev.get("name").is_null(), "event {i}: missing name");
        if ph == "M" {
            continue;
        }
        let pid = req_f64(ev, "pid", i)? as i64;
        let tid = req_f64(ev, "tid", i)? as i64;
        let ts = req_f64(ev, "ts", i)?;
        let args = ev.get("args");
        let kind = args.get("kind").as_str().unwrap_or("");
        match ph {
            "X" => {
                let dur = req_f64(ev, "dur", i)?;
                ensure!(dur >= 0.0, "event {i}: negative dur {dur}");
                st.spans += 1;
                let sim = ev.get("cat").as_str() == Some("sim");
                tracks.entry((pid, tid)).or_default().push(XSpan { ts, dur, sim });
                match kind {
                    "transfer" => {
                        st.transfer_spans += 1;
                        transfers.push(TransferArg {
                            step: arg_f64(args, "step", i)? as u64,
                            scope: args.get("scope").as_str().unwrap_or("global").to_string(),
                            start_s: arg_f64(args, "start_s", i)?,
                            dur_s: arg_f64(args, "dur_s", i)?,
                            serial: args.get("serial").as_bool().unwrap_or(true),
                        });
                    }
                    "sim_compute" => st.sim_compute_spans += 1,
                    _ => {}
                }
            }
            "i" => {
                st.instants += 1;
                match kind {
                    "step_mark" => {
                        st.marks += 1;
                        marks.push(MarkArg {
                            step: arg_f64(args, "step", i)? as u64,
                            mode: args
                                .get("mode")
                                .as_str()
                                .unwrap_or("barrier")
                                .to_string(),
                            step_start_s: arg_f64(args, "step_start_s", i)?,
                            compute_end_s: arg_f64(args, "compute_end_s", i)?,
                            exposed_comm_s: arg_f64(args, "exposed_comm_s", i)?,
                            exposed_intra_s: arg_f64(args, "exposed_intra_s", i)?,
                            exposed_inter_s: arg_f64(args, "exposed_inter_s", i)?,
                            serial_comm_s: arg_f64(args, "serial_comm_s", i)?,
                            wire_bytes: arg_f64(args, "wire_bytes", i)? as u64,
                        });
                    }
                    "bucket_ready" => st.bucket_ready_instants += 1,
                    _ => {}
                }
            }
            other => bail!("event {i}: unsupported ph {other:?}"),
        }
    }

    // Per-track: sim-domain timestamps monotonic in file order (they are
    // emitted in schedule order), and X spans well-nested — on the
    // ts-sorted schedule, each span either disjoint from or fully
    // contained in any open ancestor on its track. Wall spans are sorted
    // first because a parent (e.g. the whole-step span) is recorded when
    // it *closes*, i.e. after its children.
    for ((pid, tid), spans) in &tracks {
        let mut prev_ts = f64::NEG_INFINITY;
        for sp in spans.iter().filter(|s| s.sim) {
            ensure!(
                sp.ts + TS_SLACK_US >= prev_ts,
                "track ({pid},{tid}): non-monotonic sim ts {} after {prev_ts}",
                sp.ts
            );
            prev_ts = sp.ts;
        }
        let mut sorted: Vec<&XSpan> = spans.iter().collect();
        sorted.sort_by(|a, b| {
            a.ts.partial_cmp(&b.ts)
                .unwrap()
                .then(b.dur.partial_cmp(&a.dur).unwrap())
        });
        let mut open_ends: Vec<f64> = Vec::new();
        for sp in sorted {
            let end = sp.ts + sp.dur;
            while open_ends
                .last()
                .map(|&e| sp.ts >= e - TS_SLACK_US)
                .unwrap_or(false)
            {
                open_ends.pop();
            }
            if let Some(&e) = open_ends.last() {
                ensure!(
                    end <= e + TS_SLACK_US,
                    "track ({pid},{tid}): span [{}, {end}] not nested in parent ending {e}",
                    sp.ts
                );
            }
            open_ends.push(end);
        }
    }

    // Step-mark totals, folded in file (== step) order: the same
    // accumulation the trainer's registry performs.
    let mut seen_steps: BTreeSet<u64> = BTreeSet::new();
    for m in &marks {
        ensure!(
            seen_steps.insert(m.step),
            "duplicate step mark for step {}",
            m.step
        );
        st.exposed_comm_total += m.exposed_comm_s;
        st.exposed_intra_total += m.exposed_intra_s;
        st.exposed_inter_total += m.exposed_inter_s;
        st.serial_comm_total += m.serial_comm_s;
        st.wire_bytes_total += m.wire_bytes;
    }

    // Bit-exact reconstruction (needs per-bucket transfer spans).
    let reconstruct = matches!(st.trace_level.as_str(), "bucket" | "rank");
    if reconstruct {
        for m in &marks {
            let step_transfers: Vec<&TransferArg> =
                transfers.iter().filter(|t| t.step == m.step).collect();
            let (rec_comm, rec_intra, rec_inter, rec_serial) = match m.mode.as_str() {
                "overlap-hier" => {
                    let mut inter_done = m.step_start_s;
                    let mut intra_done = m.step_start_s;
                    let mut serial = 0.0f64;
                    for t in &step_transfers {
                        let done = t.start_s + t.dur_s;
                        if t.scope == "intra" {
                            intra_done = intra_done.max(done);
                        } else {
                            inter_done = inter_done.max(done);
                        }
                        if t.serial {
                            serial += t.dur_s;
                        }
                    }
                    let comm = (intra_done.max(inter_done) - m.compute_end_s).max(0.0);
                    let intra =
                        (intra_done - m.compute_end_s.max(inter_done)).max(0.0);
                    let inter = (inter_done - m.compute_end_s).max(0.0);
                    (comm, intra, inter, serial)
                }
                "overlap-flat" => {
                    let mut done = m.step_start_s;
                    let mut serial = 0.0f64;
                    for t in &step_transfers {
                        done = done.max(t.start_s + t.dur_s);
                        if t.serial {
                            serial += t.dur_s;
                        }
                    }
                    let e = (done - m.compute_end_s).max(0.0);
                    (e, 0.0, e, serial)
                }
                "barrier" | "elastic" => {
                    let mut serial = 0.0f64;
                    let mut serial_intra = 0.0f64;
                    for t in &step_transfers {
                        if t.serial {
                            serial += t.dur_s;
                            if t.scope == "intra" {
                                serial_intra += t.dur_s;
                            }
                        }
                    }
                    (serial, serial_intra, serial - serial_intra, serial)
                }
                other => bail!("step {}: unknown step-mark mode {other:?}", m.step),
            };
            for (what, rec, reported) in [
                ("exposed_comm_s", rec_comm, m.exposed_comm_s),
                ("exposed_intra_s", rec_intra, m.exposed_intra_s),
                ("exposed_inter_s", rec_inter, m.exposed_inter_s),
                ("serial_comm_s", rec_serial, m.serial_comm_s),
            ] {
                ensure!(
                    rec.to_bits() == reported.to_bits(),
                    "step {} ({}): {} reconstruction mismatch: transfers give {rec:e}, mark reports {reported:e}",
                    m.step,
                    m.mode,
                    what
                );
            }
            st.reconstructed_steps += 1;
        }
    }

    Ok(st)
}

/// Cross-check the trace's step-mark totals against a metrics exposition
/// (`--metrics-out` file). Returns how many series were compared; the
/// comm totals are required, anything else present is ignored.
pub fn cross_check_metrics(st: &TraceStats, exposition: &str) -> Result<usize> {
    let map = super::registry::parse_exposition(exposition);
    let mut checked = 0usize;
    for (key, want) in [
        ("adacons_exposed_comm_s_total", st.exposed_comm_total),
        ("adacons_exposed_intra_comm_s_total", st.exposed_intra_total),
        ("adacons_exposed_inter_comm_s_total", st.exposed_inter_total),
        ("adacons_serial_comm_s_total", st.serial_comm_total),
        ("adacons_wire_bytes_total", st.wire_bytes_total as f64),
    ] {
        match map.get(key) {
            Some(&got) => {
                ensure!(
                    got.to_bits() == want.to_bits(),
                    "metrics mismatch for {key}: exposition has {got:e}, trace marks sum to {want:e}"
                );
                checked += 1;
            }
            None => bail!("metrics exposition is missing {key}"),
        }
    }
    Ok(checked)
}

#[cfg(test)]
mod tests {
    use super::super::trace::{StepMode, Tracer};
    use super::*;

    fn sample_events() -> Vec<Event> {
        let t = Tracer::new(TraceLevel::Rank);
        // One fake "step": two ranks, two buckets, barrier-mode marks.
        let ce = 0.010f64;
        let durs = [0.004f64, 0.002];
        for r in 0..2usize {
            t.span(
                TraceLevel::Rank,
                SpanEvent::new(SpanKind::SimCompute, Domain::Sim, 0, 0.0, ce).rank(r),
            );
            for b in 0..2usize {
                t.span(
                    TraceLevel::Rank,
                    SpanEvent::new(
                        SpanKind::BucketReady,
                        Domain::Sim,
                        0,
                        ce * (b + 1) as f64 / 2.0,
                        0.0,
                    )
                    .rank(r)
                    .bucket(b),
                );
            }
        }
        let mut pos = ce;
        let mut serial = 0.0f64;
        for (b, &d) in durs.iter().enumerate() {
            t.span(
                TraceLevel::Bucket,
                SpanEvent::new(SpanKind::Transfer, Domain::Sim, 0, pos, d)
                    .bucket(b)
                    .scope(SpanScope::Global),
            );
            pos += d;
            serial += d;
        }
        t.span(
            TraceLevel::Step,
            SpanEvent::new(SpanKind::Finalize, Domain::Wall, 0, 0.001, 0.0005),
        );
        t.mark(StepMark {
            step: 0,
            mode: StepMode::Barrier,
            step_start_s: 0.0,
            compute_end_s: ce,
            exposed_comm_s: serial,
            exposed_intra_s: 0.0,
            exposed_inter_s: serial,
            serial_comm_s: serial,
            wire_bytes: 4096,
        });
        t.take_events()
    }

    #[test]
    fn export_parses_and_checks_clean() {
        let evs = sample_events();
        let doc = chrome_trace(TraceLevel::Rank, &evs);
        // Round-trip through text: the on-disk form must parse.
        let text = doc.to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        let st = check_trace(&parsed).unwrap();
        assert_eq!(st.marks, 1);
        assert_eq!(st.sim_compute_spans, 2);
        assert_eq!(st.bucket_ready_instants, 4);
        assert_eq!(st.transfer_spans, 2);
        assert_eq!(st.reconstructed_steps, 1);
        assert_eq!(st.wire_bytes_total, 4096);
        assert_eq!(
            st.exposed_inter_total.to_bits(),
            (0.004f64 + 0.002).to_bits()
        );
        assert_eq!(st.trace_level, "rank");
    }

    #[test]
    fn corrupt_duration_fails_reconstruction() {
        let mut evs = sample_events();
        // Perturb one transfer duration: reconstruction must notice.
        for ev in &mut evs {
            if let Event::Span(sp) = ev {
                if sp.kind == SpanKind::Transfer {
                    sp.dur_s *= 1.0 + 1e-12;
                    break;
                }
            }
        }
        let doc = chrome_trace(TraceLevel::Rank, &evs);
        let err = check_trace(&doc).unwrap_err().to_string();
        assert!(err.contains("reconstruction mismatch"), "{err}");
    }

    #[test]
    fn malformed_documents_are_rejected() {
        assert!(check_trace(&Json::Num(3.0)).is_err());
        let doc = json::obj(vec![(
            "traceEvents",
            json::arr(vec![json::obj(vec![("name", json::s("x"))])]),
        )]);
        let err = check_trace(&doc).unwrap_err().to_string();
        assert!(err.contains("missing ph"), "{err}");
    }

    #[test]
    fn overlapping_spans_on_one_track_are_rejected() {
        // Two X spans on the same track that partially overlap.
        let mk = |ts: f64, dur: f64| {
            json::obj(vec![
                ("name", json::s("a")),
                ("ph", json::s("X")),
                ("pid", json::num(0.0)),
                ("tid", json::num(0.0)),
                ("ts", json::num(ts)),
                ("dur", json::num(dur)),
            ])
        };
        let doc = json::obj(vec![(
            "traceEvents",
            json::arr(vec![mk(0.0, 10.0), mk(5.0, 10.0)]),
        )]);
        let err = check_trace(&doc).unwrap_err().to_string();
        assert!(err.contains("not nested"), "{err}");
    }

    #[test]
    fn metrics_cross_check() {
        let evs = sample_events();
        let doc = chrome_trace(TraceLevel::Rank, &evs);
        let st = check_trace(&doc).unwrap();
        let reg = super::super::registry::Registry::new();
        reg.add_f("exposed_comm_s", 0.004 + 0.002);
        reg.add_f("exposed_intra_comm_s", 0.0);
        reg.add_f("exposed_inter_comm_s", 0.004 + 0.002);
        reg.add_f("serial_comm_s", 0.004 + 0.002);
        reg.add_u("wire_bytes", 4096);
        assert_eq!(cross_check_metrics(&st, &reg.expose()).unwrap(), 5);
        // A perturbed exposition fails.
        let reg2 = super::super::registry::Registry::new();
        reg2.add_f("exposed_comm_s", 0.004 + 0.002 + 1e-15);
        reg2.add_f("exposed_intra_comm_s", 0.0);
        reg2.add_f("exposed_inter_comm_s", 0.004 + 0.002);
        reg2.add_f("serial_comm_s", 0.004 + 0.002);
        reg2.add_u("wire_bytes", 4096);
        assert!(cross_check_metrics(&st, &reg2.expose()).is_err());
    }
}
