//! Unified metrics registry: counters, gauges, and summary histograms
//! behind one mutex, with a Prometheus-style text exposition.
//!
//! The trainer feeds every per-round quantity through here and then
//! *re-derives* the `TrainResult` fields and jsonl records from the
//! registry, so the sinks cannot disagree: a counter's `total` is the
//! exact fold of its `add` calls in call order (bitwise-reproducible for
//! deterministic inputs), and `last` is the most recent addend (what the
//! per-step jsonl line reports).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Mutex;

/// Summary statistics of an observed series (we keep count/sum/min/max
/// rather than bucketed quantiles — enough for dispersion-style metrics
/// without committing to a bucket layout).
#[derive(Debug, Clone, Copy)]
pub struct HistStat {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Default for HistStat {
    fn default() -> HistStat {
        HistStat {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

#[derive(Debug, Clone)]
enum Metric {
    CounterF { total: f64, last: f64 },
    CounterU { total: u64, last: u64 },
    Gauge(f64),
    Hist(HistStat),
}

impl Metric {
    fn type_tag(&self) -> &'static str {
        match self {
            Metric::CounterF { .. } | Metric::CounterU { .. } => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Hist(_) => "histogram",
        }
    }
}

/// The registry. Names are bare (`exposed_comm_s`); the exposition
/// prefixes them with `adacons_` and suffixes by kind (`_total`,
/// `_last`, `_count`, ...).
#[derive(Default)]
pub struct Registry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Add to an f64 counter (creates it at zero first).
    pub fn add_f(&self, name: &str, v: f64) {
        let mut m = self.lock();
        match m.entry(name.to_string()).or_insert(Metric::CounterF {
            total: 0.0,
            last: 0.0,
        }) {
            Metric::CounterF { total, last } => {
                *total += v;
                *last = v;
            }
            other => panic!("metric {name:?} is a {}, not an f64 counter", other.type_tag()),
        }
    }

    /// Add to a u64 counter (creates it at zero first).
    pub fn add_u(&self, name: &str, v: u64) {
        let mut m = self.lock();
        match m.entry(name.to_string()).or_insert(Metric::CounterU {
            total: 0,
            last: 0,
        }) {
            Metric::CounterU { total, last } => {
                *total += v;
                *last = v;
            }
            other => panic!("metric {name:?} is a {}, not a u64 counter", other.type_tag()),
        }
    }

    pub fn set_gauge(&self, name: &str, v: f64) {
        self.lock().insert(name.to_string(), Metric::Gauge(v));
    }

    /// Record one observation into a summary histogram.
    pub fn observe(&self, name: &str, v: f64) {
        let mut m = self.lock();
        match m
            .entry(name.to_string())
            .or_insert(Metric::Hist(HistStat::default()))
        {
            Metric::Hist(h) => {
                h.count += 1;
                h.sum += v;
                h.min = h.min.min(v);
                h.max = h.max.max(v);
            }
            other => panic!("metric {name:?} is a {}, not a histogram", other.type_tag()),
        }
    }

    pub fn total_f(&self, name: &str) -> f64 {
        match self.lock().get(name) {
            Some(Metric::CounterF { total, .. }) => *total,
            _ => 0.0,
        }
    }

    pub fn last_f(&self, name: &str) -> f64 {
        match self.lock().get(name) {
            Some(Metric::CounterF { last, .. }) => *last,
            _ => 0.0,
        }
    }

    pub fn total_u(&self, name: &str) -> u64 {
        match self.lock().get(name) {
            Some(Metric::CounterU { total, .. }) => *total,
            _ => 0,
        }
    }

    pub fn last_u(&self, name: &str) -> u64 {
        match self.lock().get(name) {
            Some(Metric::CounterU { last, .. }) => *last,
            _ => 0,
        }
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        match self.lock().get(name) {
            Some(Metric::Gauge(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn hist(&self, name: &str) -> Option<HistStat> {
        match self.lock().get(name) {
            Some(Metric::Hist(h)) => Some(*h),
            _ => None,
        }
    }

    /// Drop every metric (a fresh `Trainer::run` starts from zero).
    pub fn reset(&self) {
        self.lock().clear();
    }

    /// Prometheus-style text exposition. Counters emit `_total` plus a
    /// `_last` gauge (the most recent per-step addend); histograms emit
    /// `_count`/`_sum`/`_min`/`_max`. `f64`s are written with Rust's
    /// shortest-round-trip `Display`, so parsing a value back yields the
    /// identical bits — `adacons trace-check --metrics` relies on this.
    pub fn expose(&self) -> String {
        let mut out = String::new();
        for (name, metric) in self.lock().iter() {
            let full = format!("adacons_{name}");
            match metric {
                Metric::CounterF { total, last } => {
                    let _ = writeln!(out, "# TYPE {full}_total counter");
                    let _ = writeln!(out, "{full}_total {total}");
                    let _ = writeln!(out, "# TYPE {full}_last gauge");
                    let _ = writeln!(out, "{full}_last {last}");
                }
                Metric::CounterU { total, last } => {
                    let _ = writeln!(out, "# TYPE {full}_total counter");
                    let _ = writeln!(out, "{full}_total {total}");
                    let _ = writeln!(out, "# TYPE {full}_last gauge");
                    let _ = writeln!(out, "{full}_last {last}");
                }
                Metric::Gauge(v) => {
                    let _ = writeln!(out, "# TYPE {full} gauge");
                    let _ = writeln!(out, "{full} {v}");
                }
                Metric::Hist(h) => {
                    let _ = writeln!(out, "# TYPE {full} summary");
                    let _ = writeln!(out, "{full}_count {}", h.count);
                    let _ = writeln!(out, "{full}_sum {}", h.sum);
                    if h.count > 0 {
                        let _ = writeln!(out, "{full}_min {}", h.min);
                        let _ = writeln!(out, "{full}_max {}", h.max);
                    }
                }
            }
        }
        out
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Metric>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Parse a text exposition back into `name -> value` (comment lines
/// skipped). Values round-trip bitwise because [`Registry::expose`]
/// writes shortest-round-trip `Display` forms.
pub fn parse_exposition(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        if let (Some(name), Some(val)) = (it.next(), it.next()) {
            if let Ok(v) = val.parse::<f64>() {
                out.insert(name.to_string(), v);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_total_is_the_exact_fold_and_last_is_the_tail() {
        let r = Registry::new();
        let xs = [0.1f64, 0.2, 0.30000000000000004, 1e-9];
        let mut acc = 0.0f64;
        for &x in &xs {
            r.add_f("exposed_comm_s", x);
            acc += x;
        }
        assert_eq!(r.total_f("exposed_comm_s").to_bits(), acc.to_bits());
        assert_eq!(r.last_f("exposed_comm_s").to_bits(), 1e-9f64.to_bits());
        r.add_u("wire_bytes", 1024);
        r.add_u("wire_bytes", 512);
        assert_eq!(r.total_u("wire_bytes"), 1536);
        assert_eq!(r.last_u("wire_bytes"), 512);
        // Missing names read as zero, not panic.
        assert_eq!(r.total_f("nope"), 0.0);
        assert_eq!(r.total_u("nope"), 0);
    }

    #[test]
    fn gauges_and_hists() {
        let r = Registry::new();
        r.set_gauge("local_step_h", 4.0);
        r.set_gauge("local_step_h", 2.0);
        assert_eq!(r.gauge("local_step_h"), Some(2.0));
        r.observe("gamma_dispersion", 0.5);
        r.observe("gamma_dispersion", 0.1);
        r.observe("gamma_dispersion", 0.3);
        let h = r.hist("gamma_dispersion").unwrap();
        assert_eq!(h.count, 3);
        assert_eq!(h.min, 0.1);
        assert_eq!(h.max, 0.5);
        assert!((h.sum - 0.9).abs() < 1e-12);
    }

    #[test]
    fn exposition_round_trips_bitwise() {
        let r = Registry::new();
        r.add_f("exposed_comm_s", 0.1 + 0.2); // 0.30000000000000004
        r.add_u("wire_bytes", 123456789);
        r.set_gauge("gamma_dispersion_last", 0.07203791469194313);
        r.observe("h", 3.0);
        let text = r.expose();
        let map = parse_exposition(&text);
        assert_eq!(
            map["adacons_exposed_comm_s_total"].to_bits(),
            (0.1f64 + 0.2).to_bits()
        );
        assert_eq!(map["adacons_wire_bytes_total"], 123456789.0);
        assert_eq!(
            map["adacons_gamma_dispersion_last"].to_bits(),
            0.07203791469194313f64.to_bits()
        );
        assert_eq!(map["adacons_h_count"], 1.0);
        // TYPE lines present and skipped by the parser.
        assert!(text.contains("# TYPE adacons_exposed_comm_s_total counter"));
        assert!(!map.contains_key("#"));
    }

    #[test]
    fn reset_clears() {
        let r = Registry::new();
        r.add_f("a", 1.0);
        r.reset();
        assert_eq!(r.total_f("a"), 0.0);
        assert!(r.expose().is_empty());
    }
}
