//! Span tracing: cheap `Instant`-stamped events recorded on the step path.
//!
//! Producers (rank threads, the leader's ingest/finalize path, the
//! simulated-timeline accounting in the executor) batch [`SpanEvent`]s
//! into thread-local `Vec`s and flush them into the shared [`Tracer`]
//! once per step, so the hot path takes one lock per producer per step
//! and allocates nothing at all when tracing is off — every recording
//! site is gated on [`Tracer::enabled`], which is a plain enum compare.
//!
//! Two clock domains coexist and are never mixed in one span:
//! * **Wall** — seconds since the tracer's epoch (`Instant`-derived),
//!   used for real thread activity (rank compute, encode, leader ingest,
//!   finalize, optimizer apply).
//! * **Sim** — the `SimClock`/`StepTimeline` coordinate system, used for
//!   modeled transfers, per-rank simulated compute, and bucket-readiness
//!   instants. Sim spans carry the *exact* `f64`s the accounting used,
//!   which is what lets `obs::chrome::check_trace` reconstruct the
//!   reported exposed-comm figures bit-for-bit.

use std::sync::Mutex;
use std::time::Instant;

/// Trace verbosity. Levels are cumulative: `Bucket` includes everything
/// `Step` records, `Rank` includes everything `Bucket` records.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// No events recorded; every trace call site is a cheap compare.
    Off = 0,
    /// Step-scoped spans: leader ingest, finalize, optimizer apply, the
    /// whole-step span, and one [`StepMark`] per sync round.
    Step = 1,
    /// Adds per-bucket spans: simulated transfers and encode time.
    Bucket = 2,
    /// Adds per-rank spans: rank-thread wall compute, simulated per-rank
    /// compute, and bucket-readiness instants.
    Rank = 3,
}

impl TraceLevel {
    pub fn parse(v: &str) -> Option<TraceLevel> {
        match v {
            "off" | "none" => Some(TraceLevel::Off),
            "step" => Some(TraceLevel::Step),
            "bucket" => Some(TraceLevel::Bucket),
            "rank" => Some(TraceLevel::Rank),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Step => "step",
            TraceLevel::Bucket => "bucket",
            TraceLevel::Rank => "rank",
        }
    }
}

/// Which clock a span's `start_s`/`dur_s` live on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    Wall,
    Sim,
}

impl Domain {
    pub fn tag(self) -> &'static str {
        match self {
            Domain::Wall => "wall",
            Domain::Sim => "sim",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// One whole sync round on the leader (wall).
    Step,
    /// Leader draining/ingesting rank gradients (wall).
    LeaderIngest,
    /// Consensus finalize / aggregate call (wall).
    Finalize,
    /// Optimizer apply incl. clipping (wall).
    OptimizerApply,
    /// One rank thread's step: compute + encode + submit (wall).
    RankCompute,
    /// Codec encode of one bucket (wall; rank-side or leader set-codec).
    Encode,
    /// One modeled collective transfer (sim).
    Transfer,
    /// One rank's modeled backward pass (sim).
    SimCompute,
    /// Instant: bucket `b` of rank `r` became ready (sim).
    BucketReady,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Step => "step",
            SpanKind::LeaderIngest => "leader_ingest",
            SpanKind::Finalize => "finalize",
            SpanKind::OptimizerApply => "optimizer_apply",
            SpanKind::RankCompute => "rank_compute",
            SpanKind::Encode => "encode",
            SpanKind::Transfer => "transfer",
            SpanKind::SimCompute => "sim_compute",
            SpanKind::BucketReady => "bucket_ready",
        }
    }
}

/// Communication scope of a [`SpanKind::Transfer`] span (mirrors
/// `comm::CommScope`, kept separate so `obs` stays dependency-free).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanScope {
    None,
    Global,
    Intra,
    Inter,
}

impl SpanScope {
    pub fn tag(self) -> &'static str {
        match self {
            SpanScope::None => "none",
            SpanScope::Global => "global",
            SpanScope::Intra => "intra",
            SpanScope::Inter => "inter",
        }
    }

    pub fn parse(v: &str) -> Option<SpanScope> {
        match v {
            "none" => Some(SpanScope::None),
            "global" => Some(SpanScope::Global),
            "intra" => Some(SpanScope::Intra),
            "inter" => Some(SpanScope::Inter),
            _ => None,
        }
    }
}

/// One recorded span. `rank`/`bucket`/`node` are `-1` when not
/// applicable (e.g. leader-side spans have `rank == -1`). `serial` is
/// only meaningful on [`SpanKind::Transfer`]: whether this span's
/// duration entered the executor's serial-comm accumulator (a fan-out
/// op posts one span per channel but its duration counts once).
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    pub kind: SpanKind,
    pub domain: Domain,
    pub step: u64,
    pub rank: i64,
    pub bucket: i64,
    pub node: i64,
    pub scope: SpanScope,
    pub start_s: f64,
    pub dur_s: f64,
    pub serial: bool,
}

impl SpanEvent {
    pub fn new(kind: SpanKind, domain: Domain, step: u64, start_s: f64, dur_s: f64) -> SpanEvent {
        SpanEvent {
            kind,
            domain,
            step,
            rank: -1,
            bucket: -1,
            node: -1,
            scope: SpanScope::None,
            start_s,
            dur_s,
            serial: true,
        }
    }

    /// Mark a transfer span as a fan-out repeat whose duration was
    /// already counted by a sibling span.
    pub fn not_serial(mut self) -> SpanEvent {
        self.serial = false;
        self
    }

    pub fn rank(mut self, r: usize) -> SpanEvent {
        self.rank = r as i64;
        self
    }

    pub fn bucket(mut self, b: usize) -> SpanEvent {
        self.bucket = b as i64;
        self
    }

    pub fn node(mut self, k: usize) -> SpanEvent {
        self.node = k as i64;
        self
    }

    pub fn scope(mut self, s: SpanScope) -> SpanEvent {
        self.scope = s;
        self
    }
}

/// Which accounting branch produced a step's comm figures; the trace
/// checker replays the matching arithmetic when reconstructing them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// Overlapped transfers on the two-level `HierTimeline`.
    OverlapHier,
    /// Overlapped transfers on the single-NIC `StepTimeline`.
    OverlapFlat,
    /// Barrier accounting: every op fully exposed, in comm-op order.
    Barrier,
    /// Elastic (cutoff) step: barrier accounting over survivors.
    Elastic,
}

impl StepMode {
    pub fn tag(self) -> &'static str {
        match self {
            StepMode::OverlapHier => "overlap-hier",
            StepMode::OverlapFlat => "overlap-flat",
            StepMode::Barrier => "barrier",
            StepMode::Elastic => "elastic",
        }
    }

    pub fn parse(v: &str) -> Option<StepMode> {
        match v {
            "overlap-hier" => Some(StepMode::OverlapHier),
            "overlap-flat" => Some(StepMode::OverlapFlat),
            "barrier" => Some(StepMode::Barrier),
            "elastic" => Some(StepMode::Elastic),
            _ => None,
        }
    }
}

/// Per-sync-round summary instant carrying the exact comm accounting the
/// executor reported for that round. The Chrome export writes these
/// `f64`s losslessly, so `check_trace` can verify the transfer spans
/// reproduce them to the bit.
#[derive(Debug, Clone, Copy)]
pub struct StepMark {
    pub step: u64,
    pub mode: StepMode,
    pub step_start_s: f64,
    pub compute_end_s: f64,
    pub exposed_comm_s: f64,
    pub exposed_intra_s: f64,
    pub exposed_inter_s: f64,
    pub serial_comm_s: f64,
    pub wire_bytes: u64,
}

#[derive(Debug, Clone, Copy)]
pub enum Event {
    Span(SpanEvent),
    Mark(StepMark),
}

/// Shared trace buffer. Construction pins the wall epoch; producers
/// check [`Tracer::enabled`] (a plain compare) before building any
/// event, batch into local `Vec`s, and flush with
/// [`Tracer::record_batch`] once per step.
pub struct Tracer {
    level: TraceLevel,
    epoch: Instant,
    events: Mutex<Vec<Event>>,
}

impl Tracer {
    pub fn new(level: TraceLevel) -> Tracer {
        Tracer {
            level,
            epoch: Instant::now(),
            events: Mutex::new(Vec::new()),
        }
    }

    pub fn level(&self) -> TraceLevel {
        self.level
    }

    /// True when spans gated at `min` (which must be >= `Step`) should
    /// be recorded.
    #[inline]
    pub fn enabled(&self, min: TraceLevel) -> bool {
        min != TraceLevel::Off && self.level >= min
    }

    /// Wall seconds since the tracer's epoch.
    pub fn now_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Record one span already gated by the caller (no-op when off, so
    /// an ungated call is safe, just wasteful).
    pub fn span(&self, min: TraceLevel, ev: SpanEvent) {
        if self.enabled(min) {
            self.lock().push(Event::Span(ev));
        }
    }

    /// Record one per-round summary mark (gated at `Step`).
    pub fn mark(&self, m: StepMark) {
        if self.enabled(TraceLevel::Step) {
            self.lock().push(Event::Mark(m));
        }
    }

    /// Flush a producer's per-step local buffer: one lock per call.
    pub fn record_batch(&self, evs: Vec<SpanEvent>) {
        if self.level != TraceLevel::Off && !evs.is_empty() {
            self.lock().extend(evs.into_iter().map(Event::Span));
        }
    }

    /// Drain everything recorded so far (leader-side, at export time).
    pub fn take_events(&self) -> Vec<Event> {
        std::mem::take(&mut *self.lock())
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<Event>> {
        // A panicking producer poisons nothing we can't still read.
        self.events.lock().unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(TraceLevel::parse("off"), Some(TraceLevel::Off));
        assert_eq!(TraceLevel::parse("step"), Some(TraceLevel::Step));
        assert_eq!(TraceLevel::parse("bucket"), Some(TraceLevel::Bucket));
        assert_eq!(TraceLevel::parse("rank"), Some(TraceLevel::Rank));
        assert_eq!(TraceLevel::parse("verbose"), None);
        assert!(TraceLevel::Rank > TraceLevel::Bucket);
        assert!(TraceLevel::Bucket > TraceLevel::Step);
        for l in ["off", "step", "bucket", "rank"] {
            assert_eq!(TraceLevel::parse(l).unwrap().tag(), l);
        }
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new(TraceLevel::Off);
        assert!(!t.enabled(TraceLevel::Step));
        assert!(!t.enabled(TraceLevel::Rank));
        t.span(
            TraceLevel::Step,
            SpanEvent::new(SpanKind::Step, Domain::Wall, 0, 0.0, 1.0),
        );
        t.mark(StepMark {
            step: 0,
            mode: StepMode::Barrier,
            step_start_s: 0.0,
            compute_end_s: 0.0,
            exposed_comm_s: 0.0,
            exposed_intra_s: 0.0,
            exposed_inter_s: 0.0,
            serial_comm_s: 0.0,
            wire_bytes: 0,
        });
        assert!(t.take_events().is_empty());
    }

    #[test]
    fn levels_gate_cumulatively() {
        let t = Tracer::new(TraceLevel::Bucket);
        assert!(t.enabled(TraceLevel::Step));
        assert!(t.enabled(TraceLevel::Bucket));
        assert!(!t.enabled(TraceLevel::Rank));
        t.span(
            TraceLevel::Bucket,
            SpanEvent::new(SpanKind::Transfer, Domain::Sim, 3, 1.0, 0.5)
                .bucket(2)
                .scope(SpanScope::Inter),
        );
        t.span(
            TraceLevel::Rank,
            SpanEvent::new(SpanKind::SimCompute, Domain::Sim, 3, 0.0, 1.0).rank(1),
        );
        let evs = t.take_events();
        assert_eq!(evs.len(), 1);
        match evs[0] {
            Event::Span(sp) => {
                assert_eq!(sp.kind, SpanKind::Transfer);
                assert_eq!(sp.bucket, 2);
                assert_eq!(sp.scope, SpanScope::Inter);
            }
            Event::Mark(_) => panic!("expected span"),
        }
        // Drained: the buffer is empty again.
        assert!(t.take_events().is_empty());
    }

    #[test]
    fn batch_flush_preserves_order() {
        let t = Tracer::new(TraceLevel::Rank);
        let mut local = Vec::new();
        for b in 0..3usize {
            local.push(
                SpanEvent::new(SpanKind::Encode, Domain::Wall, 7, b as f64, 0.1)
                    .rank(0)
                    .bucket(b),
            );
        }
        t.record_batch(local);
        let evs = t.take_events();
        assert_eq!(evs.len(), 3);
        for (b, ev) in evs.iter().enumerate() {
            match ev {
                Event::Span(sp) => assert_eq!(sp.bucket, b as i64),
                Event::Mark(_) => panic!("expected span"),
            }
        }
    }
}
