//! Thread-scaling sweep for the aggregation hot path: every engine kernel
//! (`consensus_stats`, `weighted_sum`, `mean`) plus the end-to-end
//! `adacons` aggregate, over a (threads x workers x d) grid, emitting the
//! machine-readable `BENCH_aggregation.json` the perf trajectory is
//! tracked with (EXPERIMENTS.md §Perf).
//!
//! Reproduce with `cargo run --release --bin bench_aggregation`; the
//! `aggregation` bench target and `scripts/ci.sh` (smoke mode) call the
//! same entry points.

use std::collections::BTreeMap;

use crate::aggregation::{self, Aggregator};
use crate::bench::bench_auto;
use crate::collective::{CostModel, HierCostModel, NodeMap, SimClock, Topology, TopologySpec};
use crate::coordinator::pipeline::PipelinedExecutor;
use crate::parallel::{plan_shards, ParallelCtx, ParallelPolicy};
use crate::tensor::ops::CHUNK;
use crate::tensor::{Buckets, GradSet};
use crate::util::error::{bail, Context, Result};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::prng::Rng;

/// Grid + budget for one sweep run.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Target seconds per benchmarked case.
    pub budget_s: f64,
    /// Thread counts; 1 is always measured first (speedup baseline).
    pub threads: Vec<usize>,
    /// Worker counts N.
    pub workers: Vec<usize>,
    /// Gradient dimensions d.
    pub dims: Vec<usize>,
    /// Engine shard knob (passed through to the policy).
    pub min_shard_elems: usize,
    /// Skip gradient matrices larger than this many bytes (logged, never
    /// silent).
    pub max_case_bytes: usize,
    /// Pipelined-step overlap modes to bench (`--overlap` dimension):
    /// each entry adds an `adacons_step` case driving the full
    /// `PipelinedExecutor` (16 buckets) with overlap on or off.
    pub overlap_modes: Vec<bool>,
    /// Interpreter train-step cases (`interp_step`): one real backward
    /// pass per rank on the builtin MLP artifact through the pipelined
    /// executor, in both execution modes (`mode` dimension: `roundrobin`
    /// producer loop vs `threaded` rank threads over the exchange), per
    /// thread count — so backend + threading perf is tracked in
    /// `BENCH_aggregation.json` alongside the pure aggregation kernels.
    pub interp_step: bool,
    /// Hierarchical-topology step cases (`hier_step`): the same pipelined
    /// step with two-level aggregation (per-node leader reduction +
    /// leader-level adacons over an even `<N/4>x4` split), at every
    /// overlap mode — emitted for worker counts divisible by 4 above 4,
    /// which is how the N = 64/128 scale rows get a hier-vs-flat
    /// comparison.
    pub hier_step: bool,
    /// Compressed-collective step cases (`compress_step`): the pipelined
    /// adacons step under each error-feedback compressor (int8 / fp16 /
    /// topk / lowrank, plus the uncompressed reference) on a flat fabric,
    /// and int8 inter-node-only on a `hier:2x4` split — so codec cost on
    /// the hot path is tracked per compressor x scope.
    pub compress_step: bool,
    /// Elastic degraded-step cases (`degraded_step`): the elastic
    /// exchange at full strength (the 8-of-8 anchor), under a 6-of-8
    /// straggler cutoff (two injected stragglers dropped and the
    /// consensus renormalized every step), and in a rejoin storm (one
    /// rank dies and is respawned every step) — so the survivor-ingest
    /// and respawn costs are tracked against the full-barrier anchor.
    pub degraded_step: bool,
    /// Local-step regime cases (`local_step`): full paper-testbed
    /// training runs (`mlp_cls_b32`, `dlrm_lite`, N = 8, adacons) under
    /// `--local-steps` H = 1/4/16 and the adaptive `auto:1-16` policy,
    /// recording total wire bytes and amortized exposed comm per H —
    /// and checking the H = 16 rows against the H = 1 anchors (wire
    /// <= 1/8, exposed strictly lower) where the trajectory is
    /// produced.
    pub local_step: bool,
    /// Tracing-overhead cases (`obs_step`): full `mlp_cls_b32` training
    /// runs (N = 8, adacons, overlap on) at `--trace-level` off / step /
    /// bucket, each repeated and reduced to the median wall seconds per
    /// step — the measured basis for the "tracing is cheap" claim. The
    /// `--compare` gate hard-fails when the bucket-level median exceeds
    /// the untraced one by more than 5%.
    pub obs_step: bool,
}

impl SweepConfig {
    /// The full grid from the perf plan: 1/2/4/8/nproc threads x N in
    /// {4, 8, 32, 64, 128} x d in {1e5, 1e6, 1e7}.
    pub fn full(budget_s: f64) -> SweepConfig {
        let nproc = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        // 8 extends the measured thread ladder past 4 (the ROADMAP
        // perf-trajectory item): on >= 8-core hosts the 4 -> 8 -> nproc
        // scaling knee is now a first-class row, not inferred from the
        // nproc endpoint alone.
        let mut threads = vec![1, 2, 4, 8, nproc];
        threads.sort_unstable();
        threads.dedup();
        SweepConfig {
            budget_s,
            threads,
            // 64/128 extend the grid toward scale (the ROADMAP perf
            // item); their biggest-d cases exceed the byte cap and skip
            // loudly rather than silently shrinking coverage.
            workers: vec![4, 8, 32, 64, 128],
            dims: vec![100_000, 1_000_000, 10_000_000],
            min_shard_elems: crate::parallel::DEFAULT_MIN_SHARD_ELEMS,
            max_case_bytes: 2_000_000_000,
            overlap_modes: vec![false, true],
            interp_step: true,
            hier_step: true,
            compress_step: true,
            degraded_step: true,
            local_step: true,
            obs_step: true,
        }
    }

    /// Tiny grid for CI smoke runs: validates the whole pipeline (grid,
    /// JSON schema, speedup bookkeeping) in a few seconds.
    pub fn smoke(budget_s: f64) -> SweepConfig {
        SweepConfig {
            budget_s,
            threads: vec![1, 2],
            workers: vec![4, 8],
            dims: vec![100_000, 1_000_000],
            min_shard_elems: 16 * 1024,
            max_case_bytes: 2_000_000_000,
            overlap_modes: vec![false, true],
            interp_step: true,
            hier_step: true,
            compress_step: true,
            degraded_step: true,
            local_step: true,
            obs_step: true,
        }
    }
}

fn random_grad_set(n: usize, d: usize, seed: u64) -> GradSet {
    let mut gs = GradSet::zeros(n, d);
    let mut rng = Rng::new(seed);
    for i in 0..n {
        rng.fill_normal_f32(gs.row_mut(i), 1.0);
    }
    gs
}

/// Run the sweep, printing one report line per case, and return the JSON
/// document (callers decide where to write it).
pub fn run_sweep(cfg: &SweepConfig) -> Result<Json> {
    let mut threads = cfg.threads.clone();
    threads.sort_unstable();
    threads.dedup();
    if threads.first() != Some(&1) {
        threads.insert(0, 1);
    }
    let nproc = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "== aggregation thread-scaling sweep (budget {:.3}s/case, host {} cpus) ==",
        cfg.budget_s, nproc
    );
    // mean seconds of the 1-thread baseline per (op, workers, d)
    let mut baseline: BTreeMap<(String, usize, usize), f64> = BTreeMap::new();
    let mut cases: Vec<Json> = Vec::new();
    for &n in &cfg.workers {
        for &d in &cfg.dims {
            let bytes = n * d * 4;
            if bytes > cfg.max_case_bytes {
                println!(
                    "-- skipping N={n}, d={d}: {bytes} B gradient matrix exceeds the \
                     {} B case cap --",
                    cfg.max_case_bytes
                );
                cases.push(obj(vec![
                    ("workers", num(n as f64)),
                    ("d", num(d as f64)),
                    ("skipped", Json::Bool(true)),
                    ("reason", s("matrix exceeds max_case_bytes")),
                ]));
                continue;
            }
            println!("-- N={n}, d={d} ({} MB gradient matrix) --", bytes / 1_000_000);
            // The pipelined step carries two extra (N, d) buffers (full
            // assembly + per-bucket stores); skip its cases loudly — once
            // per (N, d), the cap does not depend on the thread count —
            // rather than tripling the footprint of the biggest cases.
            let step_too_big = !cfg.overlap_modes.is_empty() && 3 * bytes > cfg.max_case_bytes;
            if step_too_big {
                println!(
                    "-- skipping adacons_step N={n}, d={d}: 3x{bytes} B exceeds the \
                     {} B case cap --",
                    cfg.max_case_bytes
                );
                cases.push(obj(vec![
                    ("op", s("adacons_step")),
                    ("workers", num(n as f64)),
                    ("d", num(d as f64)),
                    ("skipped", Json::Bool(true)),
                    ("reason", s("pipelined buffers exceed max_case_bytes")),
                ]));
                // The hier_step cell for this (N, d) is skipped for the
                // same reason — record it so the archived trajectory
                // never silently loses hier coverage at scale.
                if cfg.hier_step && n % 4 == 0 && n > 4 {
                    cases.push(obj(vec![
                        ("op", s("hier_step")),
                        ("workers", num(n as f64)),
                        ("d", num(d as f64)),
                        ("skipped", Json::Bool(true)),
                        ("reason", s("pipelined buffers exceed max_case_bytes")),
                    ]));
                }
            }
            let gs = random_grad_set(n, d, 42);
            let gamma: Vec<f32> = (0..n).map(|i| 0.5 + 0.1 * i as f32).collect();
            let buckets = Buckets::single(d);
            let mut out = vec![0.0f32; d];
            for &t in &threads {
                let policy = ParallelPolicy {
                    threads: t,
                    min_shard_elems: cfg.min_shard_elems,
                };
                let ctx = ParallelCtx::new(policy);
                let plan = plan_shards(0, d, cfg.min_shard_elems);
                let shard_w = plan.first().map(|&(a, b)| b - a).unwrap_or(0);
                let mut agg = aggregation::by_name("adacons", n)
                    .context("adacons not in registry")?;
                let runs: Vec<(&str, crate::bench::BenchResult, usize)> = vec![
                    (
                        "consensus_stats",
                        bench_auto(
                            &format!("consensus_stats N={n} d={d} t={t}"),
                            cfg.budget_s,
                            || {
                                std::hint::black_box(gs.consensus_stats_ctx(&ctx));
                            },
                        ),
                        bytes,
                    ),
                    (
                        "weighted_sum",
                        bench_auto(
                            &format!("weighted_sum    N={n} d={d} t={t}"),
                            cfg.budget_s,
                            || {
                                gs.weighted_sum_into_ctx(&gamma, &mut out, &ctx);
                            },
                        ),
                        bytes + d * 4,
                    ),
                    (
                        "mean",
                        bench_auto(
                            &format!("mean            N={n} d={d} t={t}"),
                            cfg.budget_s,
                            || {
                                gs.mean_into_ctx(&mut out, &ctx);
                            },
                        ),
                        bytes + d * 4,
                    ),
                    (
                        "adacons",
                        bench_auto(
                            &format!("adacons (e2e)   N={n} d={d} t={t}"),
                            cfg.budget_s,
                            || {
                                agg.aggregate_ctx(&gs, &buckets, &mut out, &ctx);
                            },
                        ),
                        2 * bytes + d * 4,
                    ),
                ];
                for (op, r, touched) in runs {
                    let key = (op.to_string(), n, d);
                    if t == 1 {
                        baseline.insert(key.clone(), r.mean_s);
                    }
                    let speedup = baseline.get(&key).map(|&b| b / r.mean_s);
                    println!(
                        "{}   [{:.1} GB/s]{}",
                        r.report_line(),
                        r.throughput_gbps(touched),
                        speedup
                            .map(|x| format!("  [{x:.2}x vs 1t]"))
                            .unwrap_or_default()
                    );
                    cases.push(obj(vec![
                        ("op", s(op)),
                        ("workers", num(n as f64)),
                        ("d", num(d as f64)),
                        ("threads", num(t as f64)),
                        ("shards", num(plan.len() as f64)),
                        ("shard_elems", num(shard_w as f64)),
                        ("iters", num(r.iters as f64)),
                        ("mean_s", num(r.mean_s)),
                        ("p50_s", num(r.p50_s)),
                        ("p99_s", num(r.p99_s)),
                        ("gbps", num(r.throughput_gbps(touched))),
                        (
                            "speedup_vs_1t",
                            speedup.map(num).unwrap_or(Json::Null),
                        ),
                    ]));
                }

                // --- the --overlap dimension: a full pipelined step
                //     (per-bucket arrival -> ingest tasks -> finalize)
                //     with overlap on vs off, 16 buckets ---
                if step_too_big {
                    continue;
                }
                for &overlap in &cfg.overlap_modes {
                    let buckets = Buckets::fixed(d, d.div_ceil(16).max(1));
                    let mut pagg = aggregation::by_name("adacons", n)
                        .context("adacons not in registry")?;
                    let mut pexec = PipelinedExecutor::new(n, buckets.clone(), overlap);
                    let mut pgrads = GradSet::zeros(n, d);
                    let mut pout = vec![0.0f32; d];
                    let mut clock = SimClock::new(n);
                    let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
                    let mode = if overlap { "on" } else { "off" };
                    let r = bench_auto(
                        &format!("adacons step    N={n} d={d} t={t} overlap={mode}"),
                        cfg.budget_s,
                        || {
                            let mut produce = |rank: usize,
                                               deliver: &mut dyn FnMut(usize, &[f32])|
                             -> Result<(f64, f64)> {
                                for (b, (lo, hi)) in buckets.iter().enumerate() {
                                    deliver(b, &gs.row(rank)[lo..hi]);
                                }
                                Ok((0.0, 0.0))
                            };
                            pexec
                                .run_step(
                                    &mut produce,
                                    pagg.as_mut(),
                                    &mut pgrads,
                                    &mut pout,
                                    &ctx,
                                    &mut clock,
                                    &cost,
                                )
                                .expect("pipelined bench step");
                        },
                    );
                    let key = (format!("adacons_step_{mode}"), n, d);
                    if t == 1 {
                        baseline.insert(key.clone(), r.mean_s);
                    }
                    let speedup = baseline.get(&key).map(|&b| b / r.mean_s);
                    println!(
                        "{}{}",
                        r.report_line(),
                        speedup
                            .map(|x| format!("  [{x:.2}x vs 1t]"))
                            .unwrap_or_default()
                    );
                    cases.push(obj(vec![
                        ("op", s("adacons_step")),
                        ("overlap", s(mode)),
                        ("workers", num(n as f64)),
                        ("d", num(d as f64)),
                        ("threads", num(t as f64)),
                        ("buckets", num(buckets.len() as f64)),
                        ("iters", num(r.iters as f64)),
                        ("mean_s", num(r.mean_s)),
                        ("p50_s", num(r.p50_s)),
                        ("p99_s", num(r.p99_s)),
                        (
                            "speedup_vs_1t",
                            speedup.map(num).unwrap_or(Json::Null),
                        ),
                    ]));
                }

                // --- the hier topology dimension: the same pipelined
                //     step under two-level aggregation (per-node leader
                //     reduction + leader-level adacons over an even
                //     <N/4>x4 split) with the two-level timeline ---
                if !cfg.hier_step || n % 4 != 0 || n <= 4 {
                    continue;
                }
                let nodes = n / 4;
                let map = NodeMap::even(nodes, 4);
                let topo = TopologySpec::Hier { nodes, gpus: 4 }.build(n, 100.0);
                for &overlap in &cfg.overlap_modes {
                    let buckets = Buckets::fixed(d, d.div_ceil(16).max(1));
                    let mut hagg = aggregation::hierarchical("adacons", map.clone(), n)
                        .context("adacons not in registry")?;
                    let hier_cost = HierCostModel::from_topology(&topo)
                        .context("hier topology must build a hier cost model")?;
                    let mut hexec = PipelinedExecutor::with_topology(
                        n,
                        buckets.clone(),
                        overlap,
                        Some(map.clone()),
                        Some(hier_cost),
                    );
                    let mut hgrads = GradSet::zeros(n, d);
                    let mut hout = vec![0.0f32; d];
                    let mut clock = SimClock::new(n);
                    let cost = CostModel::from_topology(&topo);
                    let mode = if overlap { "on" } else { "off" };
                    let r = bench_auto(
                        &format!("hier step       N={n} d={d} t={t} nodes={nodes} overlap={mode}"),
                        cfg.budget_s,
                        || {
                            let mut produce = |rank: usize,
                                               deliver: &mut dyn FnMut(usize, &[f32])|
                             -> Result<(f64, f64)> {
                                for (b, (lo, hi)) in buckets.iter().enumerate() {
                                    deliver(b, &gs.row(rank)[lo..hi]);
                                }
                                Ok((0.0, 0.0))
                            };
                            hexec
                                .run_step(
                                    &mut produce,
                                    hagg.as_mut(),
                                    &mut hgrads,
                                    &mut hout,
                                    &ctx,
                                    &mut clock,
                                    &cost,
                                )
                                .expect("hier bench step");
                        },
                    );
                    let key = (format!("hier_step_{mode}"), n, d);
                    if t == 1 {
                        baseline.insert(key.clone(), r.mean_s);
                    }
                    let speedup = baseline.get(&key).map(|&b| b / r.mean_s);
                    println!(
                        "{}{}",
                        r.report_line(),
                        speedup
                            .map(|x| format!("  [{x:.2}x vs 1t]"))
                            .unwrap_or_default()
                    );
                    cases.push(obj(vec![
                        ("op", s("hier_step")),
                        ("overlap", s(mode)),
                        ("topo", s(&format!("hier:{nodes}x4"))),
                        ("nodes", num(nodes as f64)),
                        ("workers", num(n as f64)),
                        ("d", num(d as f64)),
                        ("threads", num(t as f64)),
                        ("buckets", num(buckets.len() as f64)),
                        ("iters", num(r.iters as f64)),
                        ("mean_s", num(r.mean_s)),
                        ("p50_s", num(r.p50_s)),
                        ("p99_s", num(r.p99_s)),
                        (
                            "speedup_vs_1t",
                            speedup.map(num).unwrap_or(Json::Null),
                        ),
                    ]));
                }
            }
        }
    }
    if cfg.interp_step {
        println!("-- interpreter matmul kernels (blocked, pool-sharded) --");
        matmul_kernel_cases(cfg.budget_s, &threads, cfg.min_shard_elems, &mut baseline, &mut cases);
        println!("-- interpreter train step (mlp_cls_b32 / dlrm_lite, roundrobin vs threaded ranks) --");
        interp_step_cases(cfg.budget_s, &threads, cfg.min_shard_elems, &mut baseline, &mut cases)?;
    }
    if cfg.compress_step {
        println!("-- compressed collective step (error-feedback codecs, adacons) --");
        compress_step_cases(cfg.budget_s, &threads, cfg.min_shard_elems, &mut baseline, &mut cases)?;
    }
    if cfg.degraded_step {
        println!("-- elastic degraded step (cutoff / rejoin storm, adacons) --");
        degraded_step_cases(cfg.budget_s, &threads, cfg.min_shard_elems, &mut baseline, &mut cases)?;
    }
    if cfg.local_step {
        println!("-- local-step regime (wire/comm amortization vs H, adacons) --");
        local_step_cases(32, &mut cases)?;
    }
    if cfg.obs_step {
        println!("-- tracing overhead (trace-level off/step/bucket, adacons) --");
        obs_step_cases(24, 3, &mut cases)?;
    }
    Ok(obj(vec![
        ("bench", s("aggregation")),
        ("schema_version", num(1.0)),
        ("chunk", num(CHUNK as f64)),
        ("min_shard_elems", num(cfg.min_shard_elems as f64)),
        ("host_threads", num(nproc as f64)),
        ("budget_s", num(cfg.budget_s)),
        ("cases", arr(cases)),
    ]))
}

/// The `matmul` dimension: GFLOP/s rows for the three blocked,
/// pool-sharded interpreter matmul kernels (forward, dW, dX) on one
/// MLP-sized shape, per thread count. These are the kernels every
/// `interp_step` case spends its compute in; tracking them directly makes
/// a kernel regression attributable before it is diluted by step-loop
/// overhead.
fn matmul_kernel_cases(
    budget_s: f64,
    threads: &[usize],
    min_shard_elems: usize,
    baseline: &mut BTreeMap<(String, usize, usize), f64>,
    cases: &mut Vec<Json>,
) {
    use crate::runtime::interp::ops;

    let (m, k, n) = (128usize, 512, 512);
    let flops = 2.0 * (m * k * n) as f64;
    let mut rng = Rng::new(7);
    let mut x = vec![0.0f32; m * k];
    let mut w = vec![0.0f32; k * n];
    let mut dz = vec![0.0f32; m * n];
    rng.fill_normal_f32(&mut x, 1.0);
    rng.fill_normal_f32(&mut w, 1.0);
    rng.fill_normal_f32(&mut dz, 1.0);
    let mut out = vec![0.0f32; m * n];
    let mut dw = vec![0.0f32; k * n];
    let mut dx = vec![0.0f32; m * k];
    for &t in threads {
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: t,
            min_shard_elems,
        });
        let runs: Vec<(&str, crate::bench::BenchResult)> = vec![
            (
                "fwd",
                bench_auto(&format!("matmul fwd      {m}x{k}x{n} t={t}"), budget_s, || {
                    ops::matmul_ctx(&ctx, &x, m, k, &w, n, &mut out);
                }),
            ),
            (
                "dw",
                bench_auto(&format!("matmul dw       {m}x{k}x{n} t={t}"), budget_s, || {
                    ops::matmul_dw_ctx(&ctx, &x, &dz, m, k, n, &mut dw);
                }),
            ),
            (
                "dx",
                bench_auto(&format!("matmul dx       {m}x{k}x{n} t={t}"), budget_s, || {
                    ops::matmul_dx_ctx(&ctx, &dz, &w, m, k, n, &mut dx);
                }),
            ),
        ];
        for (kernel, r) in runs {
            let key = (format!("matmul_{kernel}"), m, k * n);
            if t == threads[0] {
                baseline.insert(key.clone(), r.mean_s);
            }
            let speedup = baseline.get(&key).map(|&b| b / r.mean_s);
            let gflops = flops / r.p50_s / 1e9;
            println!(
                "{}   [{gflops:.2} GFLOP/s]{}",
                r.report_line(),
                speedup
                    .map(|s| format!("  [{s:.2}x vs 1t]"))
                    .unwrap_or_default()
            );
            cases.push(obj(vec![
                ("op", s("matmul")),
                ("kernel", s(kernel)),
                ("m", num(m as f64)),
                ("k", num(k as f64)),
                ("n", num(n as f64)),
                // The shared-schema keys the validator requires.
                ("workers", num(1.0)),
                ("d", num((m * k * n) as f64)),
                ("threads", num(t as f64)),
                ("iters", num(r.iters as f64)),
                ("mean_s", num(r.mean_s)),
                ("p50_s", num(r.p50_s)),
                ("p99_s", num(r.p99_s)),
                ("gflops", num(gflops)),
                ("speedup_vs_1t", speedup.map(num).unwrap_or(Json::Null)),
            ]));
        }
    }
}

/// The `interp_step` dimension: a full train step — real interpreter
/// backward per rank, streamed bucket arrival, pipelined aggregation
/// (overlap on) — on the builtin `mlp_cls_b32` and `dlrm_lite`
/// artifacts, in both execution modes: `roundrobin` (ranks produced
/// serially on the leader thread) vs `threaded` (a persistent
/// `RankTeam`, one OS thread per rank, buckets ingested in arrival order
/// over the exchange). Tracks what the kernel-only cases cannot: backend
/// compute plus the real threading/transport overhead of the step loop —
/// and, through `dlrm_lite`, the embedding gather/scatter and layernorm
/// paths.
fn interp_step_cases(
    budget_s: f64,
    threads: &[usize],
    min_shard_elems: usize,
    baseline: &mut BTreeMap<(String, usize, usize), f64>,
    cases: &mut Vec<Json>,
) -> Result<()> {
    use crate::coordinator::team::RankTeam;
    use crate::data::GradInjector;
    use crate::runtime::{Backend, Runtime};
    use crate::worker::Worker;

    let n = 4usize;
    let rt = Runtime::create_with(
        std::env::temp_dir().join("adacons_bench_interp"),
        Backend::Interp,
    )?;
    for artifact in ["mlp_cls_b32", "dlrm_lite"] {
        let exe = rt.load(artifact)?;
        let d = exe.spec.param_dim;
        let local_batch = exe.spec.local_batch();
        let params = exe.spec.load_init(0)?;
        let buckets = Buckets::fixed(d, d.div_ceil(8).max(1));
        let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
        let mk_workers = || -> Result<Vec<Worker>> {
            (0..n)
                .map(|rank| {
                    let gen = crate::data::for_model(
                        &exe.spec.model,
                        42,
                        rank as u64,
                        0.0,
                        &exe.spec.meta,
                    )
                    .context("no data generator for the bench artifact")?;
                    Ok(Worker::new(rank, gen, GradInjector::None, 42))
                })
                .collect()
        };
        for &t in threads {
            let ctx = ParallelCtx::new(ParallelPolicy {
                threads: t,
                min_shard_elems,
            });
            for mode in ["roundrobin", "threaded"] {
                let mut agg =
                    aggregation::by_name("adacons", n).context("adacons not in registry")?;
                let mut exec = PipelinedExecutor::new(n, buckets.clone(), true);
                let mut grads = GradSet::zeros(n, d);
                let mut out = vec![0.0f32; d];
                let mut clock = SimClock::new(n);
                let label = format!("interp step     {artifact} N={n} t={t} mode={mode}");
                let r = if mode == "roundrobin" {
                    let mut workers = mk_workers()?;
                    bench_auto(&label, budget_s, || {
                        let mut produce = |rank: usize,
                                           deliver: &mut dyn FnMut(usize, &[f32])|
                         -> Result<(f64, f64)> {
                            let w = &mut workers[rank];
                            w.compute_grad_buckets(
                                &exe,
                                &params,
                                local_batch,
                                &buckets,
                                &ctx,
                                deliver,
                            )?;
                            Ok((w.last_loss as f64, w.last_compute_s))
                        };
                        exec.run_step(
                            &mut produce,
                            agg.as_mut(),
                            &mut grads,
                            &mut out,
                            &ctx,
                            &mut clock,
                            &cost,
                        )
                        .expect("roundrobin bench step");
                    })
                } else {
                    // Spawn once, reuse across every bench iteration — the
                    // deployment shape the trainer uses.
                    let team = RankTeam::spawn(
                        &rt,
                        artifact,
                        mk_workers()?,
                        &buckets,
                        local_batch,
                        &ctx,
                        None,
                        None,
                        crate::obs::Obs::disabled(),
                    )?;
                    let shared = std::sync::Arc::new(params.clone());
                    bench_auto(&label, budget_s, || {
                        team.begin_step(&shared, 0).expect("rank team alive");
                        exec.run_step_exchange(
                            team.exchange(),
                            agg.as_mut(),
                            &mut grads,
                            &mut out,
                            &ctx,
                            &mut clock,
                            &cost,
                        )
                        .expect("threaded bench step");
                    })
                };
                let key = (format!("interp_step_{mode}"), n, d);
                if t == threads[0] {
                    baseline.insert(key.clone(), r.mean_s);
                }
                let speedup = baseline.get(&key).map(|&b| b / r.mean_s);
                println!(
                    "{}{}",
                    r.report_line(),
                    speedup
                        .map(|x| format!("  [{x:.2}x vs 1t]"))
                        .unwrap_or_default()
                );
                cases.push(obj(vec![
                    ("op", s("interp_step")),
                    ("mode", s(mode)),
                    ("artifact", s(artifact)),
                    ("workers", num(n as f64)),
                    ("d", num(d as f64)),
                    ("threads", num(t as f64)),
                    ("buckets", num(buckets.len() as f64)),
                    ("iters", num(r.iters as f64)),
                    ("mean_s", num(r.mean_s)),
                    ("p50_s", num(r.p50_s)),
                    ("p99_s", num(r.p99_s)),
                    ("speedup_vs_1t", speedup.map(num).unwrap_or(Json::Null)),
                ]));
            }
        }
    }
    Ok(())
}

/// The `compress_step` dimension: the full pipelined adacons step under
/// each error-feedback compressor, N = 8, d = 64K, 8 buckets, overlap on.
/// Flat variants exercise the rank-source codec round-trip (encode with
/// residual update, decode at the leader edge) for the per-rank kinds and
/// the executor's leader-side sketch for `lowrank`; the `int8`/`inter`
/// variant runs two-level aggregation on a `hier:2x4` split with the
/// leader-set codec inside the hierarchical aggregator — the wire shape
/// of `--compress int8 --compress-scope inter`. The uncompressed `none`
/// row anchors the codec overhead.
fn compress_step_cases(
    budget_s: f64,
    threads: &[usize],
    min_shard_elems: usize,
    baseline: &mut BTreeMap<(String, usize, usize), f64>,
    cases: &mut Vec<Json>,
) -> Result<()> {
    use crate::compress::{CompressScope, CompressionSpec, CompressorKind, RankCodec};

    const SEED: u64 = 63;
    let n = 8usize;
    let d = 65_536usize;
    let gs = random_grad_set(n, d, SEED);
    let buckets = Buckets::fixed(d, d.div_ceil(8).max(1));
    let variants: &[(&str, &str)] = &[
        ("none", "all"),
        ("int8", "all"),
        ("fp16", "all"),
        ("topk:0.01", "all"),
        ("lowrank:2", "all"),
        ("int8", "inter"),
    ];
    for &t in threads {
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: t,
            min_shard_elems,
        });
        for &(kind_s, scope_s) in variants {
            let kind = CompressorKind::parse(kind_s).context("bench compressor kind")?;
            let scope = CompressScope::parse(scope_s).context("bench compress scope")?;
            let spec = CompressionSpec { kind, scope };
            // The `inter` variant is the hierarchical wire shape; `all`
            // variants run on the flat fabric.
            let hier = scope == CompressScope::Inter;
            let (mut agg, mut exec, cost, topo_tag) = if hier {
                let map = NodeMap::even(2, 4);
                let topo = TopologySpec::Hier { nodes: 2, gpus: 4 }.build(n, 100.0);
                let mut agg = aggregation::hierarchical("adacons", map.clone(), n)
                    .context("adacons not in registry")?;
                agg.set_compression(kind, SEED, buckets.len());
                let hier_cost = HierCostModel::from_topology(&topo)
                    .context("hier topology must build a hier cost model")?;
                let exec = PipelinedExecutor::with_topology(
                    n,
                    buckets.clone(),
                    true,
                    Some(map),
                    Some(hier_cost),
                );
                let cost = CostModel::from_topology(&topo);
                (agg, exec, cost, "hier:2x4".to_string())
            } else {
                let agg =
                    aggregation::by_name("adacons", n).context("adacons not in registry")?;
                let exec = PipelinedExecutor::new(n, buckets.clone(), true);
                let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
                (agg, exec, cost, "flat".to_string())
            };
            exec.set_compression(spec, SEED);
            let mut codecs: Vec<RankCodec> = if kind.is_per_rank() && !hier {
                (0..n)
                    .map(|rank| RankCodec::new(kind, SEED, rank, buckets.len()))
                    .collect()
            } else {
                Vec::new()
            };
            let mut grads = GradSet::zeros(n, d);
            let mut out = vec![0.0f32; d];
            let mut clock = SimClock::new(n);
            let mut step = 0u64;
            let label =
                format!("compress step   N={n} d={d} t={t} c={kind_s} scope={scope_s}");
            let r = bench_auto(&label, budget_s, || {
                let codecs = &mut codecs;
                let mut produce = |rank: usize,
                                   deliver: &mut dyn FnMut(usize, &[f32])|
                 -> Result<(f64, f64)> {
                    for (b, (lo, hi)) in buckets.iter().enumerate() {
                        if codecs.is_empty() {
                            deliver(b, &gs.row(rank)[lo..hi]);
                        } else {
                            // The rank-source wire round-trip the
                            // trainer performs: encode (residual
                            // update) then decode at the leader edge.
                            let cols = codecs[rank]
                                .encode_bucket(step, b, &gs.row(rank)[lo..hi])
                                .into_cols();
                            deliver(b, &cols);
                        }
                    }
                    Ok((0.0, 0.0))
                };
                exec.run_step(
                    &mut produce,
                    agg.as_mut(),
                    &mut grads,
                    &mut out,
                    &ctx,
                    &mut clock,
                    &cost,
                )
                .expect("compress bench step");
                step += 1;
            });
            let key = (format!("compress_step_{kind_s}_{scope_s}"), n, d);
            if t == threads[0] {
                baseline.insert(key.clone(), r.mean_s);
            }
            let speedup = baseline.get(&key).map(|&b| b / r.mean_s);
            println!(
                "{}{}",
                r.report_line(),
                speedup
                    .map(|x| format!("  [{x:.2}x vs 1t]"))
                    .unwrap_or_default()
            );
            cases.push(obj(vec![
                ("op", s("compress_step")),
                ("compress", s(kind_s)),
                ("scope", s(scope_s)),
                ("topo", s(&topo_tag)),
                ("workers", num(n as f64)),
                ("d", num(d as f64)),
                ("threads", num(t as f64)),
                ("buckets", num(buckets.len() as f64)),
                ("iters", num(r.iters as f64)),
                ("mean_s", num(r.mean_s)),
                ("p50_s", num(r.p50_s)),
                ("p99_s", num(r.p99_s)),
                ("speedup_vs_1t", speedup.map(num).unwrap_or(Json::Null)),
            ]));
        }
    }
    Ok(())
}

/// The `degraded_step` dimension: the elastic (fault-tolerant) step on
/// real rank threads, N = 8, mlp artifact, overlap off. Three variants:
///
/// * `full` — 8-of-8 quorum, nothing injected: the elastic exchange at
///   full strength, the anchor the other two are read against;
/// * `cutoff` — 6-of-8 quorum with two injected stragglers (50x
///   reported compute) dropped from the consensus every step, so the
///   survivor-set rebuild + renormalization cost is on the clock;
/// * `rejoin` — 7-of-8 quorum with one rank whose compute dies every
///   step, measuring the death-detection + fresh-worker respawn storm.
fn degraded_step_cases(
    budget_s: f64,
    threads: &[usize],
    min_shard_elems: usize,
    baseline: &mut BTreeMap<(String, usize, usize), f64>,
    cases: &mut Vec<Json>,
) -> Result<()> {
    use crate::coordinator::pipeline::ElasticPolicy;
    use crate::coordinator::team::RankTeam;
    use crate::data::GradInjector;
    use crate::runtime::{Backend, Runtime};
    use crate::worker::Worker;

    const SEED: u64 = 42;
    let n = 8usize;
    let artifact = "mlp_cls_b32";
    let rt = Runtime::create_with(
        std::env::temp_dir().join("adacons_bench_interp"),
        Backend::Interp,
    )?;
    let exe = rt.load(artifact)?;
    let d = exe.spec.param_dim;
    let local_batch = exe.spec.local_batch();
    let params = exe.spec.load_init(0)?;
    let buckets = Buckets::fixed(d, d.div_ceil(8).max(1));
    let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
    let mk_worker = |rank: usize, injector: GradInjector| -> Result<Worker> {
        let gen = crate::data::for_model(&exe.spec.model, SEED, rank as u64, 0.0, &exe.spec.meta)
            .context("no data generator for the bench artifact")?;
        Ok(Worker::new(rank, gen, injector, SEED))
    };
    // (variant, quorum k, per-rank injectors)
    let straggle = GradInjector::DelayProb {
        p: 1.0,
        factor: 50.0,
    };
    let variants: Vec<(&str, usize, Vec<(usize, GradInjector)>)> = vec![
        ("full", 8, Vec::new()),
        ("cutoff", 6, vec![(6, straggle.clone()), (7, straggle)]),
        ("rejoin", 7, vec![(7, GradInjector::PanicProb(1.0))]),
    ];
    for &t in threads {
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: t,
            min_shard_elems,
        });
        for (variant, k, injectors) in &variants {
            let (variant, k) = (*variant, *k);
            let injector_for = |rank: usize| -> GradInjector {
                injectors
                    .iter()
                    .find(|(r, _)| *r == rank)
                    .map(|(_, i)| i.clone())
                    .unwrap_or(GradInjector::None)
            };
            let workers: Vec<Worker> = (0..n)
                .map(|rank| mk_worker(rank, injector_for(rank)))
                .collect::<Result<_>>()?;
            let mut team = RankTeam::spawn_elastic(
                &rt,
                artifact,
                workers,
                &buckets,
                local_batch,
                &ctx,
                None,
                None,
                crate::obs::Obs::disabled(),
            )?;
            let policy = ElasticPolicy {
                k,
                grace_s: 0.0,
                krum_f: 0,
            };
            let mut agg = aggregation::by_name("adacons", n).context("adacons not in registry")?;
            let mut exec = PipelinedExecutor::new(n, buckets.clone(), false);
            let mut grads = GradSet::zeros(n, d);
            let mut out = vec![0.0f32; d];
            let mut clock = SimClock::new(n);
            let shared = std::sync::Arc::new(params.clone());
            let label = format!("degraded step   {artifact} N={n} t={t} v={variant}");
            let r = bench_auto(&label, budget_s, || {
                team.begin_step(&shared, 0).expect("rank team alive");
                let outcome = exec
                    .run_step_elastic(
                        team.exchange(),
                        &policy,
                        agg.as_mut(),
                        "adacons",
                        &mut grads,
                        &mut out,
                        &ctx,
                        &mut clock,
                        &cost,
                    )
                    .expect("elastic bench step");
                // The trainer's rejoin path: every dead rank comes back
                // as a fresh fast-forwarded worker before the next step.
                for &rank in &outcome.dead_ranks {
                    let w = mk_worker(rank, injector_for(rank)).expect("bench worker");
                    team.respawn(&rt, w).expect("elastic respawn");
                }
            });
            let key = (format!("degraded_step_{variant}"), n, d);
            if t == threads[0] {
                baseline.insert(key.clone(), r.mean_s);
            }
            let speedup = baseline.get(&key).map(|&b| b / r.mean_s);
            println!(
                "{}{}",
                r.report_line(),
                speedup
                    .map(|x| format!("  [{x:.2}x vs 1t]"))
                    .unwrap_or_default()
            );
            cases.push(obj(vec![
                ("op", s("degraded_step")),
                ("variant", s(variant)),
                ("quorum", s(&format!("{k}-of-{n}"))),
                ("workers", num(n as f64)),
                ("d", num(d as f64)),
                ("threads", num(t as f64)),
                ("buckets", num(buckets.len() as f64)),
                ("iters", num(r.iters as f64)),
                ("mean_s", num(r.mean_s)),
                ("p50_s", num(r.p50_s)),
                ("p99_s", num(r.p99_s)),
                ("speedup_vs_1t", speedup.map(num).unwrap_or(Json::Null)),
            ]));
        }
    }
    Ok(())
}

/// The `local_step` dimension: the paper-testbed runs behind
/// `--local-step` — `mlp_cls_b32` and `dlrm_lite` trained end to end
/// (N = 8, adacons, plain SGD) under the local-step regime at
/// H = 1 / 4 / 16 and the adaptive `auto:1-16` policy, barrier
/// timeline (overlap off) so every comm second is an exact function of
/// the α-β model. Each row records total wire bytes, the amortized
/// exposed/serial comm per local step and the final train loss; the
/// H = 16 rows are *checked* against the H = 1 anchors where the
/// trajectory is produced — total wire traffic must amortize to
/// <= 1/8 (it is exactly 1/16 at 32 steps: payload bytes are
/// data-independent) and the amortized exposed comm must be strictly
/// lower — rather than eyeballed downstream. `mean_s` is the wall
/// time per *local* step, which is what the perf gate medians track.
fn local_step_cases(steps: usize, cases: &mut Vec<Json>) -> Result<()> {
    use std::sync::Arc;

    use crate::config::{LocalStepSpec, TrainConfig};
    use crate::coordinator::Trainer;
    use crate::optim::Schedule;
    use crate::runtime::{Backend, Runtime};

    let rt = Arc::new(Runtime::open_default_with(Backend::Interp)?);
    let n = 8usize;
    for artifact in ["mlp_cls_b32", "dlrm_lite"] {
        // (spec, total wire bytes, exposed s/local-step, final loss)
        let mut rows: Vec<(String, u64, f64, f64)> = Vec::new();
        for spec in ["1", "4", "16", "auto:1-16"] {
            let mut cfg = TrainConfig::default();
            cfg.artifact = artifact.into();
            cfg.workers = n;
            cfg.aggregator = "adacons".into();
            cfg.optimizer = "sgd".into();
            cfg.schedule = Schedule::Const { lr: 0.005 };
            cfg.steps = steps;
            cfg.seed = 17;
            cfg.overlap = false; // barrier accounting: exact comm seconds
            cfg.local_steps = LocalStepSpec::parse(spec).context("bench local-step spec")?;
            let threads = cfg.parallel.threads;
            let res = Trainer::new(rt.clone(), cfg)?.run()?;
            let d = res.final_params.len();
            let loss = res.final_train_loss(5);
            println!(
                "local step      {artifact} N={n} H={spec:<9} rounds={:>2}  wire {:>12} B  \
                 exposed {:.4} ms/step  loss {loss:.5}",
                res.sync_rounds,
                res.total_wire_bytes,
                res.exposed_comm_s * 1e3,
            );
            cases.push(obj(vec![
                ("op", s("local_step")),
                ("artifact", s(artifact)),
                ("local_steps", s(spec)),
                ("workers", num(n as f64)),
                ("d", num(d as f64)),
                ("threads", num(threads as f64)),
                ("steps", num(steps as f64)),
                ("sync_rounds", num(res.sync_rounds as f64)),
                ("wire_bytes", num(res.total_wire_bytes as f64)),
                ("exposed_comm_s", num(res.exposed_comm_s)),
                ("serial_comm_s", num(res.serial_comm_s)),
                ("final_loss", num(loss)),
                ("iters", num(steps as f64)),
                ("mean_s", num(res.wall_iter_s)),
            ]));
            rows.push((spec.to_string(), res.total_wire_bytes, res.exposed_comm_s, loss));
        }
        let h1 = rows.iter().find(|r| r.0 == "1").expect("H=1 anchor row");
        let h16 = rows.iter().find(|r| r.0 == "16").expect("H=16 row");
        if 8 * h16.1 > h1.1 {
            bail!(
                "{artifact}: H=16 wire traffic {} B is not <= 1/8 of the H=1 anchor {} B",
                h16.1,
                h1.1
            );
        }
        if h16.2 >= h1.2 {
            bail!(
                "{artifact}: H=16 amortized exposed comm {:.6e}s is not strictly below \
                 the H=1 anchor {:.6e}s",
                h16.2,
                h1.2
            );
        }
        println!(
            "local step      {artifact}: wire H16/H1 {:.4} (gate <= 0.125), \
             exposed H16/H1 {:.4}, loss drift H16-H1 {:+.2e}",
            h16.1 as f64 / h1.1 as f64,
            h16.2 / h1.2,
            h16.3 - h1.3,
        );
    }
    Ok(())
}

/// The `obs_step` dimension: tracing overhead on the real step path.
/// `mlp_cls_b32` is trained end to end (N = 8, adacons, overlap on,
/// multi-bucket so the bucket-level spans actually fire) at
/// `--trace-level` off / step / bucket; each level runs `repeats` times
/// and `mean_s` is the **median** wall seconds per step across the
/// repeats, which is what the `--compare` overhead gate reads. Training
/// output is bitwise-identical across levels (tests/observability.rs
/// owns that invariant); this dimension owns the *cost* claim.
fn obs_step_cases(steps: usize, repeats: usize, cases: &mut Vec<Json>) -> Result<()> {
    use std::sync::Arc;

    use crate::config::TrainConfig;
    use crate::coordinator::Trainer;
    use crate::obs::TraceLevel;
    use crate::optim::Schedule;
    use crate::runtime::{Backend, Runtime};

    let rt = Arc::new(Runtime::open_default_with(Backend::Interp)?);
    let n = 8usize;
    let artifact = "mlp_cls_b32";
    let mut medians: Vec<(&str, f64)> = Vec::new();
    for level in ["off", "step", "bucket"] {
        let mut walls: Vec<f64> = Vec::new();
        let mut d = 0usize;
        let mut threads = 0usize;
        for _ in 0..repeats {
            let mut cfg = TrainConfig::default();
            cfg.artifact = artifact.into();
            cfg.workers = n;
            cfg.aggregator = "adacons".into();
            cfg.optimizer = "sgd".into();
            cfg.schedule = Schedule::Const { lr: 0.005 };
            cfg.steps = steps;
            cfg.seed = 17;
            cfg.overlap = true;
            cfg.bucket_cap = Some(4096);
            cfg.trace_level = TraceLevel::parse(level).context("bench trace level")?;
            threads = cfg.parallel.threads;
            let res = Trainer::new(rt.clone(), cfg)?.run()?;
            d = res.final_params.len();
            walls.push(res.wall_iter_s);
        }
        walls.sort_by(|a, b| a.total_cmp(b));
        let median = walls[walls.len() / 2];
        println!(
            "obs step        {artifact} N={n} trace={level:<6} median {:.4} ms/step \
             ({repeats} runs)",
            median * 1e3,
        );
        cases.push(obj(vec![
            ("op", s("obs_step")),
            ("trace", s(level)),
            ("artifact", s(artifact)),
            ("workers", num(n as f64)),
            ("d", num(d as f64)),
            ("threads", num(threads as f64)),
            ("steps", num(steps as f64)),
            ("repeats", num(repeats as f64)),
            ("iters", num((steps * repeats) as f64)),
            ("mean_s", num(median)),
        ]));
        medians.push((level, median));
    }
    let off = medians[0].1;
    for (level, m) in &medians[1..] {
        println!(
            "obs step        {artifact}: trace={level} overhead {:+.2}% vs off \
             (compare gate: bucket <= +5%)",
            (m / off - 1.0) * 100.0,
        );
    }
    Ok(())
}

/// `--compress-sweep`: the ratio-vs-loss table from EXPERIMENTS.md
/// §Compression. Trains the default linreg artifact for `steps` steps
/// under each compressor (scope `all`, flat fabric) and prints the wire
/// size of one full-model gradient bucket next to the final training
/// loss, so bytes saved can be read against accuracy spent. Everything
/// is seeded and runs on the interpreter backend: the table is
/// reproducible bit-for-bit.
pub fn compress_loss_sweep(steps: usize) -> Result<()> {
    use std::sync::Arc;

    use crate::collective::cost_model::f32_wire_bytes;
    use crate::compress::{CompressScope, CompressionSpec, CompressorKind};
    use crate::config::TrainConfig;
    use crate::coordinator::Trainer;
    use crate::runtime::{Backend, Runtime};

    let rt = Arc::new(Runtime::open_default_with(Backend::Interp)?);
    let kinds = [
        "none",
        "lowrank:2",
        "fp16",
        "int8",
        "topk:0.05",
        "topk:0.01",
    ];
    let mut rows: Vec<(String, usize, f64, f64)> = Vec::new();
    for kind_s in kinds {
        let kind = CompressorKind::parse(kind_s).context("sweep compressor kind")?;
        let mut cfg = TrainConfig::default();
        cfg.steps = steps;
        cfg.seed = 11;
        cfg.compression = CompressionSpec {
            kind,
            scope: CompressScope::All,
        };
        let n = cfg.workers;
        let res = Trainer::new(rt.clone(), cfg)?.run()?;
        let d = res.final_params.len();
        let wire = kind.bucket_wire_bytes(d, n);
        let ratio = wire as f64 / f32_wire_bytes(d) as f64;
        rows.push((kind_s.to_string(), wire, ratio, res.final_train_loss(10)));
    }
    let none_loss = rows[0].3;
    println!("\n## Compression ratio vs loss ({} steps, linreg, N=4, scope all)\n", steps);
    println!("| compress | wire bytes | ratio vs f32 | final loss | loss - none |");
    println!("|---|---:|---:|---:|---:|");
    for (tag, wire, ratio, loss) in &rows {
        println!(
            "| {tag} | {wire} | {ratio:.4} | {loss:.6} | {:+.2e} |",
            loss - none_loss
        );
    }
    Ok(())
}

/// Run the sweep and write `path` (pretty JSON).
pub fn run_and_write(cfg: &SweepConfig, path: &str) -> Result<()> {
    let doc = run_sweep(cfg)?;
    std::fs::write(path, doc.to_string_pretty())
        .with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    Ok(())
}

/// Validate that `path` holds a well-formed sweep document (CI gate).
pub fn validate_file(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = Json::parse(&text).map_err(|e| crate::err!("{path}: {e}"))?;
    if doc.get("bench").as_str() != Some("aggregation") {
        bail!("{path}: missing bench=aggregation tag");
    }
    let cases = doc.get("cases").as_arr().context("cases array")?;
    let mut measured = 0usize;
    for (i, c) in cases.iter().enumerate() {
        if c.get("skipped").as_bool() == Some(true) {
            continue;
        }
        for key in ["op", "workers", "d", "threads", "mean_s"] {
            if c.get(key).is_null() {
                bail!("{path}: case {i} missing {key:?}");
            }
        }
        let mean_s = c.get("mean_s").as_f64().context("mean_s")?;
        if !(mean_s.is_finite() && mean_s > 0.0) {
            bail!("{path}: case {i} has bad mean_s {mean_s}");
        }
        measured += 1;
    }
    if measured == 0 {
        bail!("{path}: no measured cases");
    }
    println!("{path}: ok ({measured} measured cases)");
    Ok(())
}

fn load_doc(path: &str) -> Result<Json> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    Json::parse(&text).map_err(|e| crate::err!("{path}: {e}"))
}

/// Median `mean_s` of the measured cases matching `op` and every
/// `(key, value)` tag in `tags` (e.g. `[("overlap", "on")]` or
/// `[("mode", "threaded"), ("artifact", "dlrm_lite")]`). `None` when the
/// document has no matching cases — older baselines predate the
/// `adacons_step`/`interp_step`/`matmul` cases, and the gate must not
/// hard-fail on them.
fn case_median(doc: &Json, op: &str, tags: &[(&str, &str)]) -> Result<Option<f64>> {
    let mut v: Vec<f64> = doc
        .get("cases")
        .as_arr()
        .context("cases array")?
        .iter()
        .filter(|c| {
            c.get("op").as_str() == Some(op)
                && c.get("skipped").as_bool() != Some(true)
                && tags.iter().all(|&(k, m)| c.get(k).as_str() == Some(m))
        })
        .filter_map(|c| c.get("mean_s").as_f64())
        .collect();
    if v.is_empty() {
        return Ok(None);
    }
    v.sort_by(|a, b| a.total_cmp(b));
    Ok(Some(v[v.len() / 2]))
}

fn gate_one(
    label: &str,
    baseline_s: f64,
    current_s: f64,
    max_ratio: f64,
    baseline: &str,
) -> Result<()> {
    let ratio = current_s / baseline_s;
    println!(
        "{label} median: baseline {baseline_s:.6}s, current {current_s:.6}s, \
         ratio {ratio:.3}x (gate {max_ratio:.2}x)"
    );
    if !(ratio.is_finite() && ratio <= max_ratio) {
        bail!("{label} median regressed {ratio:.3}x > {max_ratio:.2}x vs {baseline}");
    }
    Ok(())
}

/// CI perf-history gate: fail if `current` regresses vs the committed
/// `baseline` document (both must come from the same grid, e.g. two
/// smoke runs). Three gated groups:
/// * the `adacons` e2e aggregate-phase median at `max_ratio`;
/// * the `adacons_step` pipelined-step medians (overlap off and on) at
///   `max_step_ratio` — looser, because the full step carries pool
///   scheduling + simulated-timeline work whose variance is higher than
///   the pure kernels' (see EXPERIMENTS.md §Perf for the measured basis);
/// * the `interp_step` backend train-step medians (roundrobin and
///   threaded rank execution, per artifact) at `max_step_ratio` — same
///   rationale plus OS-thread scheduling (EXPERIMENTS.md
///   §Threaded-execution);
/// * the `matmul` kernel medians (fwd/dw/dx) at `max_step_ratio` — the
///   blocked interpreter kernels every interp step spends its compute
///   in;
/// * the `compress_step` compressed-collective medians (one group per
///   compressor x scope) at `max_step_ratio` — codec cost on the hot
///   path is first-class, not only visible through the train step;
/// * the `degraded_step` elastic medians (full-strength anchor, 6-of-8
///   cutoff, rejoin storm) at `max_step_ratio` — the fault-tolerant
///   path must not quietly tax the healthy one;
/// * the `local_step` regime medians (H = 1 and H = 16 anchors per
///   artifact) at `max_step_ratio` — wall time per *local* step of the
///   full training runs, so the periodic-consensus delta path cannot
///   quietly tax the synchronous one it must match at H = 1;
/// * the `obs_step` tracing medians (trace off and bucket) at
///   `max_step_ratio` vs the baseline, **plus** an absolute same-run
///   gate: the current document's bucket-level median must be within
///   5% of its own untraced anchor, or the gate hard-fails.
///
/// A group the **baseline** predates is skipped with an explicit notice
/// (and counted in the summary line) — never silently passed. A group
/// the baseline has but the **current** run lacks is a hard failure:
/// that is lost bench coverage, not an older baseline.
///
/// `history` names the accumulated `bench_history/` archive; when it
/// holds enough documents the step gate is tightened below
/// `max_step_ratio` to the run-to-run spread actually observed there
/// (see [`tightened_step_gate`]).
pub fn compare_files(
    baseline: &str,
    current: &str,
    max_ratio: f64,
    max_step_ratio: f64,
    history: Option<&str>,
) -> Result<()> {
    let base_doc = load_doc(baseline)?;
    let cur_doc = load_doc(current)?;
    let b = case_median(&base_doc, "adacons", &[])?
        .with_context(|| format!("{baseline}: no measured adacons cases"))?;
    let c = case_median(&cur_doc, "adacons", &[])?
        .with_context(|| format!("{current}: no measured adacons cases"))?;
    gate_one("aggregate-phase (adacons)", b, c, max_ratio, baseline)?;
    let step_groups: &[(&str, &[(&str, &str)])] = &[
        ("adacons_step", &[("overlap", "off")]),
        ("adacons_step", &[("overlap", "on")]),
        ("interp_step", &[("mode", "roundrobin"), ("artifact", "mlp_cls_b32")]),
        ("interp_step", &[("mode", "threaded"), ("artifact", "mlp_cls_b32")]),
        ("interp_step", &[("mode", "roundrobin"), ("artifact", "dlrm_lite")]),
        ("interp_step", &[("mode", "threaded"), ("artifact", "dlrm_lite")]),
        ("hier_step", &[("overlap", "off")]),
        ("hier_step", &[("overlap", "on")]),
        ("matmul", &[("kernel", "fwd")]),
        ("matmul", &[("kernel", "dw")]),
        ("matmul", &[("kernel", "dx")]),
        ("compress_step", &[("compress", "none"), ("scope", "all")]),
        ("compress_step", &[("compress", "int8"), ("scope", "all")]),
        ("compress_step", &[("compress", "fp16"), ("scope", "all")]),
        ("compress_step", &[("compress", "topk:0.01"), ("scope", "all")]),
        ("compress_step", &[("compress", "lowrank:2"), ("scope", "all")]),
        ("compress_step", &[("compress", "int8"), ("scope", "inter")]),
        ("degraded_step", &[("variant", "full")]),
        ("degraded_step", &[("variant", "cutoff")]),
        ("degraded_step", &[("variant", "rejoin")]),
        ("local_step", &[("artifact", "mlp_cls_b32"), ("local_steps", "1")]),
        ("local_step", &[("artifact", "mlp_cls_b32"), ("local_steps", "16")]),
        ("local_step", &[("artifact", "dlrm_lite"), ("local_steps", "1")]),
        ("local_step", &[("artifact", "dlrm_lite"), ("local_steps", "16")]),
        ("obs_step", &[("trace", "off")]),
        ("obs_step", &[("trace", "bucket")]),
    ];
    let step_gate = match history {
        Some(dir) => tightened_step_gate(dir, max_step_ratio, step_groups),
        None => max_step_ratio,
    };
    let mut skipped = 0usize;
    for &(op, tags) in step_groups {
        let tag_str = tags
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ");
        let label = format!("pipelined step ({op} {tag_str})");
        match (
            case_median(&base_doc, op, tags)?,
            case_median(&cur_doc, op, tags)?,
        ) {
            (Some(b), Some(c)) => gate_one(&label, b, c, step_gate, baseline)?,
            (Some(_), None) => bail!(
                "{label}: {current} has no cases for a group {baseline} covers — \
                 bench coverage was lost, not skipped"
            ),
            (None, cur) => {
                skipped += 1;
                println!(
                    "{label}: SKIPPED — baseline predates this group (current has cases: {})",
                    cur.is_some()
                );
            }
        }
    }
    // Tracing-overhead gate: within the *current* document, the
    // bucket-level obs_step median must sit within 5% of the untraced
    // anchor. Same-run comparison, so host speed divides out — this is
    // the hard ceiling on what `--trace-level bucket` may cost, gated
    // independently of any baseline drift.
    match (
        case_median(&cur_doc, "obs_step", &[("trace", "off")])?,
        case_median(&cur_doc, "obs_step", &[("trace", "bucket")])?,
    ) {
        (Some(off), Some(bucket)) => gate_one(
            "tracing overhead (obs_step bucket vs off)",
            off,
            bucket,
            1.05,
            "the same run's trace=off anchor",
        )?,
        _ => {
            skipped += 1;
            println!(
                "tracing overhead (obs_step): SKIPPED — {current} has no obs_step cases"
            );
        }
    }
    if skipped > 0 {
        println!(
            "perf gate: ok ({skipped} group(s) skipped because the baseline predates them — \
             refresh bench_history/baseline.json to gate them)"
        );
    } else {
        println!("perf gate: ok");
    }
    Ok(())
}

/// Tighten the step gate from the accumulated `bench_history/` archive.
/// The default step gate must admit the worst plausible run-to-run noise
/// on any host; a history of real runs on *this* host supports a
/// tighter bound. For every gated group with medians in at least 3
/// archived documents, each document's median is compared against the
/// median-of-medians; the gate becomes the largest spread observed
/// anywhere plus a 10% margin, clamped to [1.2, `default`]. With fewer
/// than 3 usable documents the default is kept (no basis to tighten).
fn tightened_step_gate(
    dir: &str,
    default: f64,
    step_groups: &[(&str, &[(&str, &str)])],
) -> f64 {
    let mut paths: Vec<std::path::PathBuf> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("json"))
            .collect(),
        Err(_) => {
            println!("perf-history {dir}: unreadable, keeping step gate {default:.2}x");
            return default;
        }
    };
    paths.sort();
    let docs: Vec<Json> = paths
        .iter()
        .filter_map(|p| p.to_str())
        .filter_map(|p| load_doc(p).ok())
        .filter(|d| d.get("bench").as_str() == Some("aggregation"))
        .collect();
    if docs.len() < 3 {
        println!(
            "perf-history {dir}: {} usable doc(s) (< 3), keeping step gate {default:.2}x",
            docs.len()
        );
        return default;
    }
    let mut worst = 1.0f64;
    for &(op, tags) in step_groups {
        let meds: Vec<f64> = docs
            .iter()
            .filter_map(|d| case_median(d, op, tags).ok().flatten())
            .filter(|&m| m > 0.0)
            .collect();
        if meds.len() < 3 {
            continue;
        }
        let mut sorted = meds.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let center = sorted[sorted.len() / 2];
        for m in meds {
            worst = worst.max((m / center).max(center / m));
        }
    }
    let gate = (worst * 1.1).max(1.2).min(default);
    println!(
        "perf-history {dir}: {} docs, worst observed group spread {worst:.3}x -> \
         step gate {gate:.2}x (default {default:.2}x)",
        docs.len()
    );
    gate
}

/// Render the consensus_stats / weighted_sum scaling rows as a markdown
/// table (for pasting into EXPERIMENTS.md §Perf).
pub fn markdown_table(doc: &Json) -> String {
    let mut out = String::new();
    out.push_str("| op | N | d | threads | mean ms | GB/s | speedup vs 1t |\n");
    out.push_str("|---|---|---|---|---|---|---|\n");
    if let Some(cases) = doc.get("cases").as_arr() {
        for c in cases {
            if c.get("skipped").as_bool() == Some(true) {
                continue;
            }
            let op = c.get("op").as_str().unwrap_or("?");
            if op != "consensus_stats" && op != "weighted_sum" {
                continue;
            }
            out.push_str(&format!(
                "| {} | {} | {} | {} | {:.3} | {:.1} | {} |\n",
                op,
                c.get("workers").as_usize().unwrap_or(0),
                c.get("d").as_usize().unwrap_or(0),
                c.get("threads").as_usize().unwrap_or(0),
                c.get("mean_s").as_f64().unwrap_or(f64::NAN) * 1e3,
                c.get("gbps").as_f64().unwrap_or(f64::NAN),
                c.get("speedup_vs_1t")
                    .as_f64()
                    .map(|x| format!("{x:.2}x"))
                    .unwrap_or_else(|| "-".to_string()),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_sweep_emits_valid_doc() {
        // Microscopic grid: correctness of the plumbing, not the numbers.
        let cfg = SweepConfig {
            budget_s: 0.001,
            threads: vec![1, 2],
            workers: vec![2],
            dims: vec![10_000],
            min_shard_elems: 2048,
            max_case_bytes: 1 << 30,
            overlap_modes: vec![],
            interp_step: false,
            hier_step: false,
            compress_step: false,
            degraded_step: false,
            local_step: false,
            obs_step: false,
        };
        let doc = run_sweep(&cfg).unwrap();
        let cases = doc.get("cases").as_arr().unwrap();
        // 2 thread counts x 4 ops.
        assert_eq!(cases.len(), 8);
        for c in cases {
            assert!(c.get("mean_s").as_f64().unwrap() > 0.0);
            assert!(!c.get("speedup_vs_1t").is_null());
        }
        let md = markdown_table(&doc);
        assert!(md.contains("consensus_stats"));
        // Round-trip through a file and the validator.
        let dir = std::env::temp_dir().join("adacons_sweep_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_aggregation.json");
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
        validate_file(path.to_str().unwrap()).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oversized_cases_are_skipped_loudly() {
        let cfg = SweepConfig {
            budget_s: 0.001,
            threads: vec![1],
            workers: vec![4],
            dims: vec![1_000_000],
            min_shard_elems: 2048,
            max_case_bytes: 1000, // force the skip path
            overlap_modes: vec![false, true],
            interp_step: false,
            hier_step: false,
            compress_step: false,
            degraded_step: false,
            local_step: false,
            obs_step: false,
        };
        let doc = run_sweep(&cfg).unwrap();
        let cases = doc.get("cases").as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("skipped").as_bool(), Some(true));
    }

    #[test]
    fn overlap_dimension_emits_tagged_cases() {
        let cfg = SweepConfig {
            budget_s: 0.001,
            threads: vec![1],
            workers: vec![2],
            dims: vec![8_192],
            min_shard_elems: 2048,
            max_case_bytes: 1 << 30,
            overlap_modes: vec![false, true],
            interp_step: false,
            hier_step: false,
            compress_step: false,
            degraded_step: false,
            local_step: false,
            obs_step: false,
        };
        let doc = run_sweep(&cfg).unwrap();
        let cases = doc.get("cases").as_arr().unwrap();
        // 4 kernel ops + 2 overlap modes.
        assert_eq!(cases.len(), 6);
        let tagged: Vec<&str> = cases
            .iter()
            .filter(|c| c.get("op").as_str() == Some("adacons_step"))
            .filter_map(|c| c.get("overlap").as_str())
            .collect();
        assert_eq!(tagged, vec!["off", "on"]);
    }

    #[test]
    fn interp_step_dimension_emits_both_execution_modes() {
        let cfg = SweepConfig {
            budget_s: 0.001,
            threads: vec![1],
            workers: vec![2],
            dims: vec![8_192],
            min_shard_elems: 2048,
            max_case_bytes: 1 << 30,
            overlap_modes: vec![],
            interp_step: true,
            hier_step: false,
            compress_step: false,
            degraded_step: false,
            local_step: false,
            obs_step: false,
        };
        let doc = run_sweep(&cfg).unwrap();
        let cases = doc.get("cases").as_arr().unwrap();
        // 4 kernel ops + 3 matmul kernels + 2 interp execution modes x 2
        // artifacts.
        assert_eq!(cases.len(), 11);
        let modes: Vec<(&str, &str)> = cases
            .iter()
            .filter(|c| c.get("op").as_str() == Some("interp_step"))
            .map(|c| {
                (
                    c.get("artifact").as_str().unwrap(),
                    c.get("mode").as_str().unwrap(),
                )
            })
            .collect();
        assert_eq!(
            modes,
            vec![
                ("mlp_cls_b32", "roundrobin"),
                ("mlp_cls_b32", "threaded"),
                ("dlrm_lite", "roundrobin"),
                ("dlrm_lite", "threaded"),
            ]
        );
        let matmul: Vec<&str> = cases
            .iter()
            .filter(|c| c.get("op").as_str() == Some("matmul"))
            .filter_map(|c| c.get("kernel").as_str())
            .collect();
        assert_eq!(matmul, vec!["fwd", "dw", "dx"]);
        for c in cases {
            let op = c.get("op").as_str().unwrap();
            if op == "interp_step" || op == "matmul" {
                assert!(c.get("mean_s").as_f64().unwrap() > 0.0);
            }
            if op == "matmul" {
                assert!(c.get("gflops").as_f64().unwrap() > 0.0);
            }
        }
    }

    #[test]
    fn hier_step_dimension_emits_tagged_cases() {
        // N = 8 splits as hier:2x4; N = 2 is below the hier threshold and
        // must emit no hier cases.
        let cfg = SweepConfig {
            budget_s: 0.001,
            threads: vec![1],
            workers: vec![2, 8],
            dims: vec![8_192],
            min_shard_elems: 2048,
            max_case_bytes: 1 << 30,
            overlap_modes: vec![false, true],
            interp_step: false,
            hier_step: true,
            compress_step: false,
            degraded_step: false,
            local_step: false,
            obs_step: false,
        };
        let doc = run_sweep(&cfg).unwrap();
        let cases = doc.get("cases").as_arr().unwrap();
        let hier: Vec<&Json> = cases
            .iter()
            .filter(|c| c.get("op").as_str() == Some("hier_step"))
            .collect();
        assert_eq!(hier.len(), 2, "one hier case per overlap mode");
        for c in &hier {
            assert_eq!(c.get("workers").as_usize(), Some(8));
            assert_eq!(c.get("nodes").as_usize(), Some(2));
            assert_eq!(c.get("topo").as_str(), Some("hier:2x4"));
            assert!(c.get("mean_s").as_f64().unwrap() > 0.0);
        }
        let modes: Vec<&str> = hier
            .iter()
            .filter_map(|c| c.get("overlap").as_str())
            .collect();
        assert_eq!(modes, vec!["off", "on"]);
    }

    #[test]
    fn compress_step_dimension_emits_tagged_cases() {
        let cfg = SweepConfig {
            budget_s: 0.001,
            threads: vec![1],
            workers: vec![2],
            dims: vec![8_192],
            min_shard_elems: 2048,
            max_case_bytes: 1 << 30,
            overlap_modes: vec![],
            interp_step: false,
            hier_step: false,
            compress_step: true,
            degraded_step: false,
            local_step: false,
            obs_step: false,
        };
        let doc = run_sweep(&cfg).unwrap();
        let cases = doc.get("cases").as_arr().unwrap();
        // 4 kernel ops + 6 compressor x scope variants.
        assert_eq!(cases.len(), 10);
        let tagged: Vec<(&str, &str, &str)> = cases
            .iter()
            .filter(|c| c.get("op").as_str() == Some("compress_step"))
            .map(|c| {
                (
                    c.get("compress").as_str().unwrap(),
                    c.get("scope").as_str().unwrap(),
                    c.get("topo").as_str().unwrap(),
                )
            })
            .collect();
        assert_eq!(
            tagged,
            vec![
                ("none", "all", "flat"),
                ("int8", "all", "flat"),
                ("fp16", "all", "flat"),
                ("topk:0.01", "all", "flat"),
                ("lowrank:2", "all", "flat"),
                ("int8", "inter", "hier:2x4"),
            ]
        );
        for c in cases {
            if c.get("op").as_str() == Some("compress_step") {
                assert!(c.get("mean_s").as_f64().unwrap() > 0.0);
                assert!(!c.get("speedup_vs_1t").is_null());
            }
        }
    }

    #[test]
    fn degraded_step_dimension_emits_tagged_cases() {
        let cfg = SweepConfig {
            budget_s: 0.001,
            threads: vec![1],
            workers: vec![2],
            dims: vec![8_192],
            min_shard_elems: 2048,
            max_case_bytes: 1 << 30,
            overlap_modes: vec![],
            interp_step: false,
            hier_step: false,
            compress_step: false,
            degraded_step: true,
            local_step: false,
            obs_step: false,
        };
        let doc = run_sweep(&cfg).unwrap();
        let cases = doc.get("cases").as_arr().unwrap();
        // 4 kernel ops + 3 elastic variants.
        assert_eq!(cases.len(), 7);
        let tagged: Vec<(&str, &str)> = cases
            .iter()
            .filter(|c| c.get("op").as_str() == Some("degraded_step"))
            .map(|c| {
                (
                    c.get("variant").as_str().unwrap(),
                    c.get("quorum").as_str().unwrap(),
                )
            })
            .collect();
        assert_eq!(
            tagged,
            vec![("full", "8-of-8"), ("cutoff", "6-of-8"), ("rejoin", "7-of-8")]
        );
        for c in cases {
            if c.get("op").as_str() == Some("degraded_step") {
                assert!(c.get("mean_s").as_f64().unwrap() > 0.0);
                assert!(!c.get("speedup_vs_1t").is_null());
            }
        }
    }

    #[test]
    fn perf_gate_covers_compress_step_and_hard_fails_on_lost_coverage() {
        let dir = std::env::temp_dir().join("adacons_perf_gate_compress");
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |name: &str, inter_s: f64| -> String {
            let path = dir.join(name);
            let doc = format!(
                r#"{{"bench":"aggregation","cases":[
                    {{"op":"adacons","workers":8,"d":1000,"threads":1,"mean_s":0.010}},
                    {{"op":"compress_step","compress":"none","scope":"all","workers":8,"d":1000,"threads":1,"mean_s":0.020}},
                    {{"op":"compress_step","compress":"int8","scope":"inter","workers":8,"d":1000,"threads":1,"mean_s":{inter_s}}}
                ]}}"#
            );
            std::fs::write(&path, doc).unwrap();
            path.to_str().unwrap().to_string()
        };
        let base = mk("base.json", 0.020);
        let ok = mk("ok.json", 0.024);
        compare_files(&base, &ok, 1.3, 1.5, None).unwrap();
        // A compressed-step regression beyond the step gate fails.
        let bad = mk("bad.json", 0.040);
        assert!(compare_files(&base, &bad, 1.3, 1.5, None).is_err());
        // A current run that DROPS a group the baseline covers is lost
        // bench coverage — a hard failure, never a silent skip.
        let lost = dir.join("lost.json");
        std::fs::write(
            &lost,
            r#"{"bench":"aggregation","cases":[
                {"op":"adacons","workers":8,"d":1000,"threads":1,"mean_s":0.010}
            ]}"#,
        )
        .unwrap();
        assert!(compare_files(&base, lost.to_str().unwrap(), 1.3, 1.5, None).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn perf_gate_covers_local_step_cases() {
        let dir = std::env::temp_dir().join("adacons_perf_gate_local");
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |name: &str, h16_s: f64| -> String {
            let path = dir.join(name);
            let doc = format!(
                r#"{{"bench":"aggregation","cases":[
                    {{"op":"adacons","workers":8,"d":1000,"threads":1,"mean_s":0.010}},
                    {{"op":"local_step","artifact":"mlp_cls_b32","local_steps":"1","workers":8,"d":1000,"threads":1,"mean_s":0.030}},
                    {{"op":"local_step","artifact":"mlp_cls_b32","local_steps":"16","workers":8,"d":1000,"threads":1,"mean_s":{h16_s}}}
                ]}}"#
            );
            std::fs::write(&path, doc).unwrap();
            path.to_str().unwrap().to_string()
        };
        let base = mk("base.json", 0.028);
        let ok = mk("ok.json", 0.033);
        compare_files(&base, &ok, 1.3, 1.5, None).unwrap();
        // A local-step H=16 regression beyond the step gate fails even
        // when the H=1 anchor and the kernels are fine.
        let bad = mk("bad.json", 0.060);
        assert!(compare_files(&base, &bad, 1.3, 1.5, None).is_err());
        // Baselines predating the regime skip its groups cleanly.
        let old = dir.join("old.json");
        std::fs::write(
            &old,
            r#"{"bench":"aggregation","cases":[
                {"op":"adacons","workers":8,"d":1000,"threads":1,"mean_s":0.010}
            ]}"#,
        )
        .unwrap();
        compare_files(old.to_str().unwrap(), &ok, 1.3, 1.5, None).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn perf_gate_covers_obs_step_overhead() {
        let dir = std::env::temp_dir().join("adacons_perf_gate_obs");
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |name: &str, off_s: f64, bucket_s: f64| -> String {
            let path = dir.join(name);
            let doc = format!(
                r#"{{"bench":"aggregation","cases":[
                    {{"op":"adacons","workers":8,"d":1000,"threads":1,"mean_s":0.010}},
                    {{"op":"obs_step","trace":"off","artifact":"mlp_cls_b32","workers":8,"d":1000,"threads":0,"mean_s":{off_s}}},
                    {{"op":"obs_step","trace":"bucket","artifact":"mlp_cls_b32","workers":8,"d":1000,"threads":0,"mean_s":{bucket_s}}}
                ]}}"#
            );
            std::fs::write(&path, doc).unwrap();
            path.to_str().unwrap().to_string()
        };
        let base = mk("base.json", 0.030, 0.0305);
        let ok = mk("ok.json", 0.031, 0.0318);
        compare_files(&base, &ok, 1.3, 1.5, None).unwrap();
        // Bucket-level tracing beyond 5% of the same run's untraced
        // anchor hard-fails, even though 1.07x vs the *baseline* would
        // pass the 1.5x step gate.
        let bad = mk("bad.json", 0.030, 0.033);
        assert!(compare_files(&base, &bad, 1.3, 1.5, None).is_err());
        // Baselines predating the obs cases skip the drift groups; the
        // same-run overhead gate still applies to the current document.
        let old = dir.join("old.json");
        std::fs::write(
            &old,
            r#"{"bench":"aggregation","cases":[
                {"op":"adacons","workers":8,"d":1000,"threads":1,"mean_s":0.010}
            ]}"#,
        )
        .unwrap();
        compare_files(old.to_str().unwrap(), &ok, 1.3, 1.5, None).unwrap();
        assert!(compare_files(old.to_str().unwrap(), &bad, 1.3, 1.5, None).is_err());
        // Dropping the obs cases from the current run is lost coverage
        // when the baseline has them — a hard failure, not a skip.
        compare_files(&base, old.to_str().unwrap(), 1.3, 1.5, None).unwrap_err();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn perf_history_tightens_the_step_gate() {
        let dir = std::env::temp_dir().join("adacons_perf_history");
        let hist = dir.join("hist");
        let thin = dir.join("thin");
        std::fs::create_dir_all(&hist).unwrap();
        std::fs::create_dir_all(&thin).unwrap();
        let mk = |dir: &std::path::Path, name: &str, off_s: f64| -> String {
            let path = dir.join(name);
            let doc = format!(
                r#"{{"bench":"aggregation","cases":[
                    {{"op":"adacons","workers":4,"d":1000,"threads":1,"mean_s":0.010}},
                    {{"op":"adacons_step","overlap":"off","workers":4,"d":1000,"threads":1,"mean_s":{off_s}}}
                ]}}"#
            );
            std::fs::write(&path, doc).unwrap();
            path.to_str().unwrap().to_string()
        };
        // Three archived runs with ~1% run-to-run spread support a gate
        // far below the 1.5x default (clamped at 1.2x).
        mk(&hist, "r1.json", 0.0198);
        mk(&hist, "r2.json", 0.0200);
        mk(&hist, "r3.json", 0.0202);
        let base = mk(&dir, "base.json", 0.020);
        let cur = mk(&dir, "cur.json", 0.026); // 1.3x drift
        // Without history the default 1.5x gate admits the drift...
        compare_files(&base, &cur, 1.3, 1.5, None).unwrap();
        // ...with history the gate tightens to 1.2x and catches it.
        assert!(compare_files(&base, &cur, 1.3, 1.5, Some(hist.to_str().unwrap())).is_err());
        // Fewer than 3 archived runs is no basis to tighten: default kept.
        mk(&thin, "r1.json", 0.0198);
        mk(&thin, "r2.json", 0.0202);
        compare_files(&base, &cur, 1.3, 1.5, Some(thin.to_str().unwrap())).unwrap();
        // An unreadable history directory also keeps the default.
        let missing = dir.join("nope");
        compare_files(&base, &cur, 1.3, 1.5, Some(missing.to_str().unwrap())).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn perf_gate_covers_hier_step_cases() {
        let dir = std::env::temp_dir().join("adacons_perf_gate_hier");
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |name: &str, off_s: f64, on_s: f64| -> String {
            let path = dir.join(name);
            let doc = format!(
                r#"{{"bench":"aggregation","cases":[
                    {{"op":"adacons","workers":8,"d":1000,"threads":1,"mean_s":0.010}},
                    {{"op":"hier_step","overlap":"off","workers":8,"d":1000,"threads":1,"mean_s":{off_s}}},
                    {{"op":"hier_step","overlap":"on","workers":8,"d":1000,"threads":1,"mean_s":{on_s}}}
                ]}}"#
            );
            std::fs::write(&path, doc).unwrap();
            path.to_str().unwrap().to_string()
        };
        let base = mk("base.json", 0.020, 0.018);
        let ok = mk("ok.json", 0.024, 0.022);
        compare_files(&base, &ok, 1.3, 1.5, None).unwrap();
        // A hier-step regression beyond the step gate fails even when the
        // kernels are fine.
        let bad = mk("bad.json", 0.020, 0.040);
        assert!(compare_files(&base, &bad, 1.3, 1.5, None).is_err());
        // Baselines predating hier cases skip the hier groups cleanly.
        let old = dir.join("old.json");
        std::fs::write(
            &old,
            r#"{"bench":"aggregation","cases":[
                {"op":"adacons","workers":8,"d":1000,"threads":1,"mean_s":0.010}
            ]}"#,
        )
        .unwrap();
        compare_files(old.to_str().unwrap(), &ok, 1.3, 1.5, None).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn perf_gate_covers_interp_step_cases() {
        let dir = std::env::temp_dir().join("adacons_perf_gate_interp");
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |name: &str, rr_s: f64, th_s: f64, mm_s: f64| -> String {
            let path = dir.join(name);
            let doc = format!(
                r#"{{"bench":"aggregation","cases":[
                    {{"op":"adacons","workers":4,"d":1000,"threads":1,"mean_s":0.010}},
                    {{"op":"interp_step","mode":"roundrobin","artifact":"mlp_cls_b32","workers":4,"d":1000,"threads":1,"mean_s":{rr_s}}},
                    {{"op":"interp_step","mode":"threaded","artifact":"mlp_cls_b32","workers":4,"d":1000,"threads":1,"mean_s":{th_s}}},
                    {{"op":"matmul","kernel":"fwd","workers":1,"d":1000,"threads":1,"mean_s":{mm_s}}}
                ]}}"#
            );
            std::fs::write(&path, doc).unwrap();
            path.to_str().unwrap().to_string()
        };
        let base = mk("base.json", 0.030, 0.028, 0.050);
        let ok = mk("ok.json", 0.035, 0.033, 0.055);
        compare_files(&base, &ok, 1.3, 1.5, None).unwrap();
        // A threaded-mode regression beyond the step gate fails even when
        // the kernels and the roundrobin mode are fine.
        let bad = mk("bad.json", 0.031, 0.060, 0.050);
        assert!(compare_files(&base, &bad, 1.3, 1.5, None).is_err());
        // So does a matmul kernel regression on its own: the fast kernels
        // are gated as first-class rows, not only via the step they feed.
        let badk = mk("badk.json", 0.031, 0.029, 0.120);
        assert!(compare_files(&base, &badk, 1.3, 1.5, None).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn perf_gate_compares_adacons_medians() {
        let dir = std::env::temp_dir().join("adacons_perf_gate");
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |name: &str, mean_s: f64| -> String {
            let path = dir.join(name);
            let doc = format!(
                r#"{{"bench":"aggregation","cases":[
                    {{"op":"adacons","workers":4,"d":1000,"threads":1,"mean_s":{mean_s}}},
                    {{"op":"mean","workers":4,"d":1000,"threads":1,"mean_s":99.0}}
                ]}}"#
            );
            std::fs::write(&path, doc).unwrap();
            path.to_str().unwrap().to_string()
        };
        let base = mk("base.json", 0.010);
        let ok = mk("ok.json", 0.012);
        let bad = mk("bad.json", 0.020);
        // Baselines without adacons_step cases skip the step gate cleanly.
        compare_files(&base, &ok, 1.3, 1.5, None).unwrap();
        assert!(compare_files(&base, &bad, 1.3, 1.5, None).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn perf_gate_covers_overlap_step_cases() {
        let dir = std::env::temp_dir().join("adacons_perf_gate_step");
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |name: &str, agg_s: f64, off_s: f64, on_s: f64| -> String {
            let path = dir.join(name);
            let doc = format!(
                r#"{{"bench":"aggregation","cases":[
                    {{"op":"adacons","workers":4,"d":1000,"threads":1,"mean_s":{agg_s}}},
                    {{"op":"adacons_step","overlap":"off","workers":4,"d":1000,"threads":1,"mean_s":{off_s}}},
                    {{"op":"adacons_step","overlap":"on","workers":4,"d":1000,"threads":1,"mean_s":{on_s}}}
                ]}}"#
            );
            std::fs::write(&path, doc).unwrap();
            path.to_str().unwrap().to_string()
        };
        let base = mk("base.json", 0.010, 0.020, 0.018);
        // Step regression beyond the step gate fails even when the
        // aggregate median is fine.
        let bad_step = mk("bad_step.json", 0.010, 0.020, 0.040);
        let ok = mk("ok.json", 0.011, 0.024, 0.021);
        compare_files(&base, &ok, 1.3, 1.5, None).unwrap();
        assert!(compare_files(&base, &bad_step, 1.3, 1.5, None).is_err());
        // The step gate is the looser one: a 1.4x step drift passes at
        // 1.5 but would fail the kernel gate.
        let drift = mk("drift.json", 0.010, 0.028, 0.025);
        compare_files(&base, &drift, 1.3, 1.5, None).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validator_rejects_garbage() {
        let dir = std::env::temp_dir().join("adacons_sweep_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, r#"{"bench":"other","cases":[]}"#).unwrap();
        assert!(validate_file(path.to_str().unwrap()).is_err());
        std::fs::write(&path, r#"{"bench":"aggregation","cases":[]}"#).unwrap();
        assert!(validate_file(path.to_str().unwrap()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
