//! Micro-benchmark harness (criterion is not vendored offline): warmup,
//! timed iterations, mean/std/p50/p99 reporting, and a throughput helper.
//! `aggregation_sweep` builds the thread-scaling sweep on top of it.

pub mod aggregation_sweep;

use crate::util::stats::Quantiles;
use crate::util::timer::Timer;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms ± {:>7.3}  (p50 {:>8.3}, p99 {:>8.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.p50_s * 1e3,
            self.p99_s * 1e3,
            self.iters
        )
    }

    /// GB/s given bytes touched per iteration.
    pub fn throughput_gbps(&self, bytes: usize) -> f64 {
        bytes as f64 / self.mean_s / 1e9
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_s());
    }
    summarize(name, &samples)
}

/// Auto-calibrated: choose iteration count targeting ~`budget_s` seconds.
pub fn bench_auto(name: &str, budget_s: f64, mut f: impl FnMut()) -> BenchResult {
    // one probe call for calibration (also serves as warmup)
    let t = Timer::start();
    f();
    let probe = t.elapsed_s().max(1e-9);
    let iters = ((budget_s / probe) as usize).clamp(5, 10_000);
    bench(name, 1, iters, f)
}

fn summarize(name: &str, samples: &[f64]) -> BenchResult {
    let mean = crate::util::stats::mean(samples);
    let std = crate::util::stats::std(samples);
    let mut q = Quantiles::default();
    for &s in samples {
        q.push(s);
    }
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean,
        std_s: std,
        p50_s: q.quantile(0.5),
        p99_s: q.quantile(0.99),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleeps() {
        let r = bench("sleep", 1, 5, || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        assert!(r.mean_s >= 0.0015, "{}", r.mean_s);
        assert_eq!(r.iters, 5);
        assert!(r.report_line().contains("sleep"));
    }

    #[test]
    fn auto_calibration_bounds_iters() {
        let r = bench_auto("noop", 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters <= 10_000 && r.iters >= 5);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 0.001,
            std_s: 0.0,
            p50_s: 0.001,
            p99_s: 0.001,
        };
        assert!((r.throughput_gbps(1_000_000) - 1.0).abs() < 1e-12);
    }
}
