//! Simulated collective-communication substrate.
//!
//! The paper's testbed runs NCCL ring all-reduce over 32 GPUs on 100 Gb/s
//! InfiniBand; none of that hardware exists here, so this module implements
//! the collectives *as algorithms* over in-process rank buffers (ring
//! reduce-scatter + all-gather moving real chunks, tested against direct
//! reductions) and accounts **simulated wall time** through an α-β link
//! cost model.  That is what lets the Table 1 bench report per-iteration
//! overhead for 100 Gb/s and 800 Gb/s fabrics we do not have.

pub mod allreduce;
pub mod cost_model;
pub mod overlap;
pub mod simclock;
pub mod timeline;
pub mod topology;

pub use allreduce::{ring_allgather, ring_allreduce, ring_broadcast};
pub use cost_model::{CollectiveKind, CostModel, HierCostModel};
pub use overlap::{adacons_iteration_overlapped_s, exposed_comm_s, sum_iteration_overlapped_s};
pub use simclock::SimClock;
pub use timeline::{HierTimeline, StepTimeline};
pub use topology::{NodeMap, Topology, TopologySpec};
