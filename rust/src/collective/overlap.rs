//! Communication/computation overlap model.
//!
//! The paper's related work leans on Overlap-SGD-style pipelining and its
//! §5.1 argues AdaCons' second all-reduce becomes negligible on faster
//! fabrics; this model quantifies that: with bucketed gradients, the
//! all-reduce of bucket *k* overlaps the backward computation of bucket
//! *k+1..*, so the exposed communication is only what outlasts the
//! remaining compute (classic DDP pipelining).
//!
//! These closed forms assume uniform bucket readiness. The actual step
//! accounting now runs through [`super::timeline::StepTimeline`], which
//! generalizes the same NIC-serialization recurrence to straggling ranks,
//! ragged buckets, and exposed ops — `timeline`'s tests cross-check that
//! it reproduces `exposed_comm_s` exactly in the uniform case.

use super::cost_model::CostModel;

/// Exposed (non-overlapped) time of a bucketed collective pipeline.
///
/// `compute_s`: total backward time; `bucket_bytes`: per-bucket payload;
/// `n_buckets`: bucket count. Buckets become ready uniformly across the
/// backward pass; each ready bucket's all-reduce runs concurrently with
/// the remaining compute.
pub fn exposed_comm_s(
    model: &CostModel,
    compute_s: f64,
    bucket_bytes: usize,
    n_buckets: usize,
) -> f64 {
    if n_buckets == 0 {
        return 0.0;
    }
    let per_bucket_comm = model.allreduce_s(bucket_bytes);
    let per_bucket_compute = compute_s / n_buckets as f64;
    // Simulate the pipeline: bucket k is ready at (k+1)*per_bucket_compute;
    // the NIC serializes bucket transfers.
    let mut nic_free = 0.0f64;
    for k in 0..n_buckets {
        let ready = (k + 1) as f64 * per_bucket_compute;
        let start = ready.max(nic_free);
        nic_free = start + per_bucket_comm;
    }
    (nic_free - compute_s).max(0.0)
}

/// Iteration time of the Sum baseline with overlapped bucketed all-reduce.
pub fn sum_iteration_overlapped_s(
    model: &CostModel,
    compute_s: f64,
    d: usize,
    n_buckets: usize,
) -> f64 {
    let bucket_bytes = super::cost_model::f32_wire_bytes(d).div_ceil(n_buckets.max(1));
    compute_s + exposed_comm_s(model, compute_s, bucket_bytes, n_buckets)
}

/// Iteration time of AdaCons with overlap (Alg. 1): the **first**
/// all-reduce (consensus dots) overlaps the backward like the baseline's,
/// but the second all-reduce of re-weighted gradients can only start after
/// the coefficients exist — it is exposed, which is exactly why the paper
/// measures ~1.04x on 100 Gb/s and calls it negligible at 800 Gb/s.
pub fn adacons_iteration_overlapped_s(
    model: &CostModel,
    compute_s: f64,
    d: usize,
    n_buckets: usize,
) -> f64 {
    let base = sum_iteration_overlapped_s(model, compute_s, d, n_buckets);
    base + model.allgather_s(super::cost_model::f32_wire_bytes(1))
        + model.allreduce_s(super::cost_model::f32_wire_bytes(d))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::topology::Topology;

    fn model(gbps: f64) -> CostModel {
        CostModel::from_topology(&Topology::ring_gbps(32, gbps))
    }

    #[test]
    fn overlap_hides_comm_when_compute_dominates() {
        let m = model(100.0);
        let d = 25_600_000;
        // 1s of compute vs ~16ms of comm: nearly everything hides.
        let exposed = exposed_comm_s(&m, 1.0, d * 4 / 32, 32);
        assert!(exposed < m.allreduce_s(d * 4 / 32) * 2.0, "{exposed}");
        let total = sum_iteration_overlapped_s(&m, 1.0, d, 32);
        assert!(total < 1.0 + 0.01);
    }

    #[test]
    fn no_overlap_when_compute_is_zero() {
        let m = model(100.0);
        let d = 1_000_000;
        let t = sum_iteration_overlapped_s(&m, 0.0, d, 8);
        // all comm exposed: 8 buckets of d/8 each
        let direct = 8.0 * m.allreduce_s(d * 4 / 8);
        assert!((t - direct).abs() < 1e-9, "{t} vs {direct}");
    }

    #[test]
    fn adacons_overhead_shrinks_with_bandwidth() {
        let d = 25_600_000;
        let compute = 1.0;
        let slow = model(100.0);
        let fast = model(800.0);
        let over_slow = adacons_iteration_overlapped_s(&slow, compute, d, 32)
            / sum_iteration_overlapped_s(&slow, compute, d, 32);
        let over_fast = adacons_iteration_overlapped_s(&fast, compute, d, 32)
            / sum_iteration_overlapped_s(&fast, compute, d, 32);
        // Paper regime: ~1.01-1.05x at 100 Gb/s, -> ~1.00x at 800 Gb/s.
        assert!(over_slow > 1.005 && over_slow < 1.06, "{over_slow}");
        assert!(over_fast < over_slow);
        assert!(over_fast < 1.01, "{over_fast}");
    }

    #[test]
    fn more_buckets_expose_less_tail() {
        let m = model(100.0);
        let d = 25_600_000;
        let few = exposed_comm_s(&m, 0.1, d * 4 / 2, 2);
        let many = exposed_comm_s(&m, 0.1, d * 4 / 64, 64);
        assert!(many <= few, "{many} vs {few}");
    }
}
