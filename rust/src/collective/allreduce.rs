//! Data-moving ring collectives over in-process rank buffers.
//!
//! These execute the *actual* NCCL ring schedule — reduce-scatter then
//! all-gather, chunk by chunk around the ring — so tests can verify the
//! schedule's correctness (every rank ends with the full reduction), and
//! the cost model's step count is grounded in the real data movement.

use super::cost_model::CostModel;
use super::simclock::SimClock;

/// In-place ring all-reduce (sum) across `bufs` (one buffer per rank).
/// Returns the simulated duration charged to `clock` (if provided).
pub fn ring_allreduce(bufs: &mut [Vec<f32>], model: &CostModel, clock: Option<&mut SimClock>) -> f64 {
    let n = bufs.len();
    assert!(n > 0);
    let d = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == d), "ragged rank buffers");
    if n > 1 && d > 0 {
        // Chunk boundaries: chunk c covers [bounds[c], bounds[c+1]).
        let bounds: Vec<usize> = (0..=n).map(|c| c * d / n).collect();

        // Phase 1 — reduce-scatter: in step s, rank r sends chunk
        // (r - s) mod n to rank (r + 1) mod n, which accumulates it.
        for s in 0..n - 1 {
            // Materialize sends first (simultaneous exchange semantics).
            let sends: Vec<(usize, usize, Vec<f32>)> = (0..n)
                .map(|r| {
                    let c = (r + n - s) % n;
                    let (lo, hi) = (bounds[c], bounds[c + 1]);
                    ((r + 1) % n, c, bufs[r][lo..hi].to_vec())
                })
                .collect();
            for (dst, c, chunk) in sends {
                let (lo, _hi) = (bounds[c], bounds[c + 1]);
                for (k, v) in chunk.iter().enumerate() {
                    bufs[dst][lo + k] += v;
                }
            }
        }
        // After n-1 steps rank r owns the fully-reduced chunk (r+1) mod n.

        // Phase 2 — all-gather: circulate the owned chunks.
        for s in 0..n - 1 {
            let sends: Vec<(usize, usize, Vec<f32>)> = (0..n)
                .map(|r| {
                    let c = (r + 1 + n - s) % n;
                    let (lo, hi) = (bounds[c], bounds[c + 1]);
                    ((r + 1) % n, c, bufs[r][lo..hi].to_vec())
                })
                .collect();
            for (dst, c, chunk) in sends {
                let (lo, _hi) = (bounds[c], bounds[c + 1]);
                bufs[dst][lo..lo + chunk.len()].copy_from_slice(&chunk);
            }
        }
    }
    let t = model.allreduce_s(super::cost_model::f32_wire_bytes(d));
    if let Some(c) = clock {
        c.collective(t);
    }
    t
}

/// Ring all-gather of one scalar per rank (the Alg. 1 coefficient exchange).
pub fn ring_allgather(
    values: &[f32],
    model: &CostModel,
    clock: Option<&mut SimClock>,
) -> (Vec<Vec<f32>>, f64) {
    let n = values.len();
    // Every rank starts with its own value and circulates.
    let mut per_rank: Vec<Vec<f32>> = (0..n)
        .map(|r| {
            let mut v = vec![0.0; n];
            v[r] = values[r];
            v
        })
        .collect();
    for s in 0..n.saturating_sub(1) {
        let sends: Vec<(usize, usize, f32)> = (0..n)
            .map(|r| {
                let c = (r + n - s) % n;
                ((r + 1) % n, c, per_rank[r][c])
            })
            .collect();
        for (dst, c, v) in sends {
            per_rank[dst][c] = v;
        }
    }
    let t = model.allgather_s(super::cost_model::f32_wire_bytes(1));
    if let Some(cl) = clock {
        cl.collective(t);
    }
    (per_rank, t)
}

/// Tree broadcast of a buffer from rank 0.
pub fn ring_broadcast(
    src: &[f32],
    n: usize,
    model: &CostModel,
    clock: Option<&mut SimClock>,
) -> (Vec<Vec<f32>>, f64) {
    let out: Vec<Vec<f32>> = (0..n).map(|_| src.to_vec()).collect();
    let t = model.broadcast_s(super::cost_model::f32_wire_bytes(src.len()));
    if let Some(c) = clock {
        c.collective(t);
    }
    (out, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::topology::Topology;
    use crate::util::prng::Rng;

    fn model(n: usize) -> CostModel {
        CostModel::from_topology(&Topology::ring_gbps(n, 100.0))
    }

    #[test]
    fn allreduce_equals_direct_sum() {
        for (n, d) in [(2, 10), (3, 7), (4, 64), (5, 33), (8, 100)] {
            let mut rng = Rng::new(n as u64 * 1000 + d as u64);
            let bufs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| rng.normal_f32(1.0)).collect())
                .collect();
            let expected: Vec<f32> = (0..d)
                .map(|j| bufs.iter().map(|b| b[j]).sum::<f32>())
                .collect();
            let mut work = bufs.clone();
            ring_allreduce(&mut work, &model(n), None);
            for r in 0..n {
                for j in 0..d {
                    assert!(
                        (work[r][j] - expected[j]).abs() <= 1e-4 * expected[j].abs().max(1.0),
                        "n={n} d={d} rank={r} j={j}: {} vs {}",
                        work[r][j],
                        expected[j]
                    );
                }
            }
        }
    }

    #[test]
    fn allreduce_d_smaller_than_n() {
        let mut bufs = vec![vec![1.0f32], vec![2.0], vec![3.0], vec![4.0]];
        ring_allreduce(&mut bufs, &model(4), None);
        for b in &bufs {
            assert_eq!(b[0], 10.0);
        }
    }

    #[test]
    fn allgather_distributes_all_values() {
        let vals = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let (per_rank, _) = ring_allgather(&vals, &model(5), None);
        for r in 0..5 {
            assert_eq!(per_rank[r], vals.to_vec(), "rank {r}");
        }
    }

    #[test]
    fn clock_is_charged() {
        let m = model(4);
        let mut clock = SimClock::new(4);
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.5f32; 1000]).collect();
        let t = ring_allreduce(&mut bufs, &m, Some(&mut clock));
        assert!(t > 0.0);
        assert!((clock.now() - t).abs() < 1e-15);
    }

    #[test]
    fn single_rank_identity() {
        let mut bufs = vec![vec![1.0f32, 2.0]];
        let t = ring_allreduce(&mut bufs, &model(1), None);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
        assert_eq!(t, 0.0);
    }
}
