//! Cluster topologies for the communication simulator.

/// A communication topology over `n` ranks.
///
/// `Ring` is the NCCL-style homogeneous ring the paper's all-reduce runs
/// on.  `Hierarchical` models the paper's actual testbed shape — `nodes`
/// hosts with `gpus_per_node` ranks each, fast intra-node links and a
/// slower inter-node fabric — and is used by the Table 1 sensitivity
/// sweep.
#[derive(Debug, Clone, PartialEq)]
pub enum Topology {
    Ring {
        n: usize,
        /// Per-hop latency (seconds), the α term.
        latency_s: f64,
        /// Link bandwidth (bytes/second), the 1/β term.
        bandwidth_bps: f64,
    },
    Hierarchical {
        nodes: usize,
        gpus_per_node: usize,
        intra_latency_s: f64,
        intra_bandwidth_bps: f64,
        inter_latency_s: f64,
        inter_bandwidth_bps: f64,
    },
}

impl Topology {
    /// The paper's testbed: 8 nodes x 4 A6000 over 100 Gb/s InfiniBand,
    /// NVLink-class intra-node links.
    pub fn paper_testbed() -> Topology {
        Topology::Hierarchical {
            nodes: 8,
            gpus_per_node: 4,
            intra_latency_s: 2e-6,
            intra_bandwidth_bps: 50e9,  // ~400 Gb/s effective intra-node
            inter_latency_s: 5e-6,
            inter_bandwidth_bps: 12.5e9, // 100 Gb/s
        }
    }

    /// Homogeneous ring at a given fabric speed in Gb/s.
    pub fn ring_gbps(n: usize, gbps: f64) -> Topology {
        Topology::Ring {
            n,
            latency_s: 5e-6,
            bandwidth_bps: gbps * 1e9 / 8.0,
        }
    }

    pub fn n_ranks(&self) -> usize {
        match self {
            Topology::Ring { n, .. } => *n,
            Topology::Hierarchical {
                nodes,
                gpus_per_node,
                ..
            } => nodes * gpus_per_node,
        }
    }

    /// The (α, β⁻¹) of the slowest link a ring over all ranks traverses —
    /// the bottleneck that paces every synchronous ring step.
    pub fn bottleneck_link(&self) -> (f64, f64) {
        match self {
            Topology::Ring {
                latency_s,
                bandwidth_bps,
                ..
            } => (*latency_s, *bandwidth_bps),
            Topology::Hierarchical {
                nodes,
                inter_latency_s,
                inter_bandwidth_bps,
                intra_latency_s,
                intra_bandwidth_bps,
                ..
            } => {
                if *nodes > 1 {
                    (*inter_latency_s, *inter_bandwidth_bps)
                } else {
                    (*intra_latency_s, *intra_bandwidth_bps)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_counts() {
        assert_eq!(Topology::paper_testbed().n_ranks(), 32);
        assert_eq!(Topology::ring_gbps(8, 100.0).n_ranks(), 8);
    }

    #[test]
    fn bottleneck_is_inter_node_when_multi_node() {
        let t = Topology::paper_testbed();
        let (lat, bw) = t.bottleneck_link();
        assert_eq!(lat, 5e-6);
        assert_eq!(bw, 12.5e9);
    }

    #[test]
    fn single_node_bottleneck_is_intra() {
        let t = Topology::Hierarchical {
            nodes: 1,
            gpus_per_node: 4,
            intra_latency_s: 1e-6,
            intra_bandwidth_bps: 50e9,
            inter_latency_s: 5e-6,
            inter_bandwidth_bps: 12.5e9,
        };
        assert_eq!(t.bottleneck_link(), (1e-6, 50e9));
    }

    #[test]
    fn ring_gbps_converts_to_bytes() {
        let t = Topology::ring_gbps(4, 800.0);
        let (_, bw) = t.bottleneck_link();
        assert!((bw - 100e9).abs() < 1.0);
    }
}
