//! Cluster topologies for the communication simulator, plus the
//! rank-to-node grouping ([`NodeMap`]) and the config-facing topology
//! spec (`--topology flat|hier:<nodes>x<gpus>`) the hierarchical
//! aggregation subsystem is built on.

use crate::util::error::{bail, Result};

/// A communication topology over `n` ranks.
///
/// `Ring` is the NCCL-style homogeneous ring the paper's all-reduce runs
/// on.  `Hierarchical` models the paper's actual testbed shape — `nodes`
/// hosts with `gpus_per_node` ranks each, fast intra-node links and a
/// slower inter-node fabric — and is used by the Table 1 sensitivity
/// sweep.
#[derive(Debug, Clone, PartialEq)]
pub enum Topology {
    Ring {
        n: usize,
        /// Per-hop latency (seconds), the α term.
        latency_s: f64,
        /// Link bandwidth (bytes/second), the 1/β term.
        bandwidth_bps: f64,
    },
    Hierarchical {
        nodes: usize,
        gpus_per_node: usize,
        intra_latency_s: f64,
        intra_bandwidth_bps: f64,
        inter_latency_s: f64,
        inter_bandwidth_bps: f64,
    },
}

impl Topology {
    /// The paper's testbed: 8 nodes x 4 A6000 over 100 Gb/s InfiniBand,
    /// NVLink-class intra-node links.
    pub fn paper_testbed() -> Topology {
        Topology::Hierarchical {
            nodes: 8,
            gpus_per_node: 4,
            intra_latency_s: 2e-6,
            intra_bandwidth_bps: 50e9,  // ~400 Gb/s effective intra-node
            inter_latency_s: 5e-6,
            inter_bandwidth_bps: 12.5e9, // 100 Gb/s
        }
    }

    /// Homogeneous ring at a given fabric speed in Gb/s.
    pub fn ring_gbps(n: usize, gbps: f64) -> Topology {
        Topology::Ring {
            n,
            latency_s: 5e-6,
            bandwidth_bps: gbps * 1e9 / 8.0,
        }
    }

    pub fn n_ranks(&self) -> usize {
        match self {
            Topology::Ring { n, .. } => *n,
            Topology::Hierarchical {
                nodes,
                gpus_per_node,
                ..
            } => nodes * gpus_per_node,
        }
    }

    /// The (α, β⁻¹) of the slowest link a ring over all ranks traverses —
    /// the bottleneck that paces every synchronous ring step.
    pub fn bottleneck_link(&self) -> (f64, f64) {
        match self {
            Topology::Ring {
                latency_s,
                bandwidth_bps,
                ..
            } => (*latency_s, *bandwidth_bps),
            Topology::Hierarchical {
                nodes,
                inter_latency_s,
                inter_bandwidth_bps,
                intra_latency_s,
                intra_bandwidth_bps,
                ..
            } => {
                if *nodes > 1 {
                    (*inter_latency_s, *inter_bandwidth_bps)
                } else {
                    (*intra_latency_s, *intra_bandwidth_bps)
                }
            }
        }
    }
}

/// Contiguous assignment of ranks to nodes — the grouping the two-level
/// hierarchical aggregation scheme (`aggregation::hierarchy`) reduces
/// over. Node `k` owns the rank range `[bounds[k], bounds[k+1])`;
/// contiguity is load-bearing: the per-node leader reduction sums the
/// group's rows in global rank order, so a per-node copy of the rows
/// (local indices `0..size(k)`) is bitwise-equivalent to the full-matrix
/// view. Groups may be uneven ([`NodeMap::from_sizes`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeMap {
    bounds: Vec<usize>, // len = groups + 1; bounds[0] = 0, last = n_ranks
}

impl NodeMap {
    /// `nodes` groups of `gpus_per_node` ranks each.
    pub fn even(nodes: usize, gpus_per_node: usize) -> NodeMap {
        assert!(nodes > 0 && gpus_per_node > 0, "empty node map");
        NodeMap {
            bounds: (0..=nodes).map(|k| k * gpus_per_node).collect(),
        }
    }

    /// Uneven groups from explicit per-node rank counts.
    pub fn from_sizes(sizes: &[usize]) -> NodeMap {
        assert!(!sizes.is_empty(), "empty node map");
        let mut bounds = vec![0usize];
        let mut acc = 0usize;
        for &s in sizes {
            assert!(s > 0, "node group of zero ranks");
            acc += s;
            bounds.push(acc);
        }
        NodeMap { bounds }
    }

    /// The grouping a topology implies: hierarchical shapes map directly;
    /// a ring is every rank its own (degenerate) node.
    pub fn from_topology(t: &Topology) -> NodeMap {
        match t {
            Topology::Ring { n, .. } => NodeMap::even(*n, 1),
            Topology::Hierarchical {
                nodes,
                gpus_per_node,
                ..
            } => NodeMap::even(*nodes, *gpus_per_node),
        }
    }

    pub fn groups(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn n_ranks(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Node `k`'s rank range `(lo, hi)`.
    pub fn range(&self, k: usize) -> (usize, usize) {
        (self.bounds[k], self.bounds[k + 1])
    }

    pub fn size(&self, k: usize) -> usize {
        self.bounds[k + 1] - self.bounds[k]
    }

    pub fn max_group(&self) -> usize {
        (0..self.groups()).map(|k| self.size(k)).max().unwrap_or(0)
    }

    /// `(node, local index within the node)` of a rank.
    pub fn locate(&self, rank: usize) -> (usize, usize) {
        assert!(rank < self.n_ranks(), "rank {rank} out of the node map");
        let k = self.bounds.partition_point(|&b| b <= rank) - 1;
        (k, rank - self.bounds[k])
    }

    /// Iterate the `(lo, hi)` rank range of every node.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (0..self.groups()).map(|k| self.range(k))
    }

    /// A degenerate hierarchy — one node, or one rank per node — has no
    /// meaningful two-level split: the hierarchical aggregator delegates
    /// straight to its flat base scheme (bitwise-identical to flat).
    pub fn is_degenerate(&self) -> bool {
        self.groups() <= 1 || self.groups() == self.n_ranks()
    }
}

/// The config/CLI topology surface: `flat` (one homogeneous ring, the
/// historical behaviour) or `hier:<nodes>x<gpus>` (the paper's testbed
/// shape: NVLink-class intra-node links joined by the `--fabric-gbps`
/// inter-node fabric, two-level aggregation enabled).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologySpec {
    Flat,
    Hier { nodes: usize, gpus: usize },
}

impl TopologySpec {
    /// Parse `flat` or `hier:<nodes>x<gpus>` (e.g. `hier:8x4`).
    pub fn parse(s: &str) -> Option<TopologySpec> {
        if s == "flat" {
            return Some(TopologySpec::Flat);
        }
        let rest = s.strip_prefix("hier:")?;
        let (a, b) = rest.split_once('x')?;
        let nodes: usize = a.parse().ok()?;
        let gpus: usize = b.parse().ok()?;
        if nodes == 0 || gpus == 0 {
            return None;
        }
        Some(TopologySpec::Hier { nodes, gpus })
    }

    pub fn describe(&self) -> String {
        match self {
            TopologySpec::Flat => "flat".to_string(),
            TopologySpec::Hier { nodes, gpus } => format!("hier:{nodes}x{gpus}"),
        }
    }

    /// The node grouping this spec implies (`None` for flat).
    pub fn node_map(&self) -> Option<NodeMap> {
        match self {
            TopologySpec::Flat => None,
            TopologySpec::Hier { nodes, gpus } => Some(NodeMap::even(*nodes, *gpus)),
        }
    }

    /// Shape-vs-workers consistency (the config validation hook).
    pub fn check_workers(&self, workers: usize) -> Result<()> {
        if let TopologySpec::Hier { nodes, gpus } = self {
            if nodes * gpus != workers {
                bail!(
                    "topology {} needs {} ranks but workers = {workers}",
                    self.describe(),
                    nodes * gpus
                );
            }
        }
        Ok(())
    }

    /// The simulated fabric this spec stands for. Flat: a homogeneous
    /// ring at `fabric_gbps`. Hier: NVLink-class intra-node links (the
    /// paper testbed's constants) joined by a `fabric_gbps` inter-node
    /// fabric.
    pub fn build(&self, workers: usize, fabric_gbps: f64) -> Topology {
        match self {
            TopologySpec::Flat => Topology::ring_gbps(workers, fabric_gbps),
            TopologySpec::Hier { nodes, gpus } => Topology::Hierarchical {
                nodes: *nodes,
                gpus_per_node: *gpus,
                intra_latency_s: 2e-6,
                intra_bandwidth_bps: 50e9,
                inter_latency_s: 5e-6,
                inter_bandwidth_bps: fabric_gbps * 1e9 / 8.0,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_counts() {
        assert_eq!(Topology::paper_testbed().n_ranks(), 32);
        assert_eq!(Topology::ring_gbps(8, 100.0).n_ranks(), 8);
    }

    #[test]
    fn bottleneck_is_inter_node_when_multi_node() {
        let t = Topology::paper_testbed();
        let (lat, bw) = t.bottleneck_link();
        assert_eq!(lat, 5e-6);
        assert_eq!(bw, 12.5e9);
    }

    #[test]
    fn single_node_bottleneck_is_intra() {
        let t = Topology::Hierarchical {
            nodes: 1,
            gpus_per_node: 4,
            intra_latency_s: 1e-6,
            intra_bandwidth_bps: 50e9,
            inter_latency_s: 5e-6,
            inter_bandwidth_bps: 12.5e9,
        };
        assert_eq!(t.bottleneck_link(), (1e-6, 50e9));
    }

    #[test]
    fn ring_gbps_converts_to_bytes() {
        let t = Topology::ring_gbps(4, 800.0);
        let (_, bw) = t.bottleneck_link();
        assert!((bw - 100e9).abs() < 1.0);
    }

    #[test]
    fn node_map_even_and_uneven_shapes() {
        let even = NodeMap::even(3, 4);
        assert_eq!(even.groups(), 3);
        assert_eq!(even.n_ranks(), 12);
        assert_eq!(even.range(1), (4, 8));
        assert_eq!(even.max_group(), 4);
        assert!(!even.is_degenerate());
        let uneven = NodeMap::from_sizes(&[3, 2, 1]);
        assert_eq!(uneven.groups(), 3);
        assert_eq!(uneven.n_ranks(), 6);
        assert_eq!(uneven.range(0), (0, 3));
        assert_eq!(uneven.range(2), (5, 6));
        assert_eq!(uneven.max_group(), 3);
        let ranges: Vec<_> = uneven.iter().collect();
        assert_eq!(ranges, vec![(0, 3), (3, 5), (5, 6)]);
    }

    #[test]
    fn node_map_locate_inverts_ranges() {
        let m = NodeMap::from_sizes(&[2, 3, 1]);
        let expect = [(0, 0), (0, 1), (1, 0), (1, 1), (1, 2), (2, 0)];
        for (rank, &e) in expect.iter().enumerate() {
            assert_eq!(m.locate(rank), e, "rank {rank}");
        }
    }

    #[test]
    fn degenerate_maps_are_flagged() {
        assert!(NodeMap::even(1, 8).is_degenerate()); // one node
        assert!(NodeMap::even(8, 1).is_degenerate()); // one rank per node
        assert!(!NodeMap::even(2, 2).is_degenerate());
        assert!(NodeMap::from_topology(&Topology::ring_gbps(4, 100.0)).is_degenerate());
        let m = NodeMap::from_topology(&Topology::paper_testbed());
        assert_eq!((m.groups(), m.n_ranks()), (8, 32));
        assert!(!m.is_degenerate());
    }

    #[test]
    fn topology_spec_parses_and_validates() {
        assert_eq!(TopologySpec::parse("flat"), Some(TopologySpec::Flat));
        assert_eq!(
            TopologySpec::parse("hier:8x4"),
            Some(TopologySpec::Hier { nodes: 8, gpus: 4 })
        );
        assert!(TopologySpec::parse("hier:0x4").is_none());
        assert!(TopologySpec::parse("hier:8").is_none());
        assert!(TopologySpec::parse("mesh").is_none());
        let spec = TopologySpec::Hier { nodes: 2, gpus: 3 };
        assert_eq!(spec.describe(), "hier:2x3");
        spec.check_workers(6).unwrap();
        assert!(spec.check_workers(8).is_err());
        assert_eq!(spec.node_map().unwrap(), NodeMap::even(2, 3));
        assert!(TopologySpec::Flat.node_map().is_none());
        TopologySpec::Flat.check_workers(5).unwrap();
    }

    #[test]
    fn spec_builds_matching_topologies() {
        let flat = TopologySpec::Flat.build(8, 100.0);
        assert_eq!(flat, Topology::ring_gbps(8, 100.0));
        let hier = TopologySpec::Hier { nodes: 8, gpus: 4 }.build(32, 100.0);
        assert_eq!(hier.n_ranks(), 32);
        let (lat, bw) = hier.bottleneck_link();
        assert_eq!(lat, 5e-6);
        assert_eq!(bw, 12.5e9);
    }
}
