//! α-β cost model for the collectives (Hockney model, the standard
//! closed forms NCCL tuning is reasoned about with).
//!
//! Ring all-reduce of B bytes over n ranks: 2(n-1) steps, each moving
//! B/n bytes over the bottleneck link → `T = 2(n-1)(α + B/(n·bw))`.
//! Ring all-gather of per-rank payload b: (n-1) steps of b bytes.
//! Broadcast (tree): ceil(log2 n) steps of B bytes.

use super::topology::{NodeMap, Topology};

/// Wire width of one uncompressed gradient element (f32).
pub const F32_WIRE_BYTES: usize = 4;

/// Wire bytes of `elems` full-precision f32 elements — the single
/// source of truth for `CommOp.bytes` derivation. Every byte count in
/// the collective path goes through this helper (or a
/// `CompressorKind::bucket_wire_bytes` override), so compressed and
/// full-precision ops can never disagree on accounting.
pub fn f32_wire_bytes(elems: usize) -> usize {
    elems * F32_WIRE_BYTES
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveKind {
    AllReduce,
    AllGather,
    Broadcast,
}

/// Closed-form collective timing over a topology's bottleneck link.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub alpha_s: f64,
    pub bandwidth_bps: f64,
    pub n: usize,
}

impl CostModel {
    pub fn from_topology(t: &Topology) -> Self {
        let (alpha_s, bandwidth_bps) = t.bottleneck_link();
        CostModel {
            alpha_s,
            bandwidth_bps,
            n: t.n_ranks(),
        }
    }

    /// Ring all-reduce of `bytes` total payload.
    pub fn allreduce_s(&self, bytes: usize) -> f64 {
        if self.n <= 1 {
            return 0.0;
        }
        let steps = 2 * (self.n - 1);
        let chunk = bytes as f64 / self.n as f64;
        steps as f64 * (self.alpha_s + chunk / self.bandwidth_bps)
    }

    /// Ring all-gather where each rank contributes `bytes_per_rank`.
    pub fn allgather_s(&self, bytes_per_rank: usize) -> f64 {
        if self.n <= 1 {
            return 0.0;
        }
        (self.n - 1) as f64 * (self.alpha_s + bytes_per_rank as f64 / self.bandwidth_bps)
    }

    /// Binomial-tree broadcast of `bytes`.
    pub fn broadcast_s(&self, bytes: usize) -> f64 {
        if self.n <= 1 {
            return 0.0;
        }
        let steps = (self.n as f64).log2().ceil();
        steps * (self.alpha_s + bytes as f64 / self.bandwidth_bps)
    }

    pub fn time_s(&self, kind: CollectiveKind, bytes: usize) -> f64 {
        match kind {
            CollectiveKind::AllReduce => self.allreduce_s(bytes),
            CollectiveKind::AllGather => self.allgather_s(bytes),
            CollectiveKind::Broadcast => self.broadcast_s(bytes),
        }
    }

    /// Per-iteration communication time of the plain averaging baseline:
    /// one all-reduce of the d-dimensional f32 gradient (Alg. 1 baseline).
    pub fn sum_iteration_s(&self, d: usize) -> f64 {
        self.allreduce_s(f32_wire_bytes(d))
    }

    /// Per-iteration communication time of AdaCons (Alg. 1): one O(d)
    /// all-reduce for `<g_i, g_bar>`, an O(N) all-gather of scalar
    /// coefficients, then the second O(d) all-reduce of the re-weighted
    /// gradients.
    pub fn adacons_iteration_s(&self, d: usize) -> f64 {
        self.allreduce_s(f32_wire_bytes(d))
            + self.allgather_s(f32_wire_bytes(1))
            + self.allreduce_s(f32_wire_bytes(d))
    }
}

/// Two-level cost models for a hierarchical topology: `intra` prices a
/// per-node collective (over the largest node group, on the NVLink-class
/// link — every node runs its copy concurrently on its own link), `inter`
/// prices leader-level collectives (one participant per node, on the
/// inter-node fabric). `map` is the rank grouping both levels share.
#[derive(Debug, Clone)]
pub struct HierCostModel {
    pub intra: CostModel,
    pub inter: CostModel,
    pub map: NodeMap,
}

impl HierCostModel {
    /// `Some` for hierarchical topologies, `None` for rings (flat).
    pub fn from_topology(t: &Topology) -> Option<HierCostModel> {
        match t {
            Topology::Ring { .. } => None,
            Topology::Hierarchical {
                nodes,
                gpus_per_node,
                intra_latency_s,
                intra_bandwidth_bps,
                inter_latency_s,
                inter_bandwidth_bps,
            } => Some(HierCostModel {
                intra: CostModel {
                    alpha_s: *intra_latency_s,
                    bandwidth_bps: *intra_bandwidth_bps,
                    n: *gpus_per_node,
                },
                inter: CostModel {
                    alpha_s: *inter_latency_s,
                    bandwidth_bps: *inter_bandwidth_bps,
                    n: *nodes,
                },
                map: NodeMap::even(*nodes, *gpus_per_node),
            }),
        }
    }

    /// Re-group onto an uneven map: the intra model prices the slowest
    /// (largest) node group, the inter model the leader count.
    pub fn with_map(mut self, map: NodeMap) -> HierCostModel {
        self.intra.n = map.max_group();
        self.inter.n = map.groups();
        self.map = map;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::topology::Topology;

    fn model(n: usize, gbps: f64) -> CostModel {
        CostModel::from_topology(&Topology::ring_gbps(n, gbps))
    }

    #[test]
    fn closed_forms() {
        let m = model(4, 80.0); // 10 GB/s
        // allreduce 40 MB: 6 steps of 10 MB => 6*(5e-6 + 1e-3)
        let t = m.allreduce_s(40_000_000);
        assert!((t - 6.0 * (5e-6 + 1e-3)).abs() < 1e-9, "{t}");
        // allgather of 4 bytes/rank: 3 steps, latency dominated
        let g = m.allgather_s(4);
        assert!((g - 3.0 * (5e-6 + 4.0 / 10e9)).abs() < 1e-12);
        // broadcast 1 MB over 4 ranks: 2 steps
        let b = m.broadcast_s(1_000_000);
        assert!((b - 2.0 * (5e-6 + 1e-4)).abs() < 1e-9);
    }

    #[test]
    fn single_rank_is_free() {
        let m = model(1, 100.0);
        assert_eq!(m.allreduce_s(1 << 20), 0.0);
        assert_eq!(m.allgather_s(4), 0.0);
        assert_eq!(m.broadcast_s(4), 0.0);
    }

    #[test]
    fn adacons_overhead_ratio_matches_table1_regime() {
        // ResNet-50-scale gradient (25.6M params) on the paper's fabric:
        // AdaCons adds one all-reduce -> ~2x comm, but compute dominates
        // the iteration; the *comm-only* ratio must be just above 2x
        // (+ negligible all-gather), and ~1.0x once overlapped at 800 Gb/s
        // relative to the step. Here we check the comm-only ratio bound.
        let m = CostModel::from_topology(&Topology::paper_testbed());
        let d = 25_600_000;
        let sum = m.sum_iteration_s(d);
        let ada = m.adacons_iteration_s(d);
        let ratio = ada / sum;
        assert!(ratio > 1.99 && ratio < 2.05, "ratio={ratio}");
    }

    #[test]
    fn bandwidth_scaling_shrinks_absolute_overhead() {
        let slow = model(32, 100.0);
        let fast = model(32, 800.0);
        let d = 25_600_000;
        let over_slow = fast.adacons_iteration_s(d); // reuse vars below
        let _ = over_slow;
        let abs_slow = slow.adacons_iteration_s(d) - slow.sum_iteration_s(d);
        let abs_fast = fast.adacons_iteration_s(d) - fast.sum_iteration_s(d);
        assert!(abs_fast < abs_slow / 6.0, "{abs_fast} vs {abs_slow}");
    }

    #[test]
    fn hier_model_splits_the_paper_testbed() {
        let h = HierCostModel::from_topology(&Topology::paper_testbed()).unwrap();
        assert_eq!(h.intra.n, 4);
        assert_eq!(h.inter.n, 8);
        assert_eq!(h.intra.bandwidth_bps, 50e9);
        assert_eq!(h.inter.bandwidth_bps, 12.5e9);
        assert_eq!(h.map.groups(), 8);
        // The leader-level all-reduce is strictly cheaper than the flat
        // 32-rank ring over the same bottleneck fabric: fewer ring steps.
        let flat = CostModel::from_topology(&Topology::paper_testbed());
        let d_bytes = 25_600_000 * 4;
        assert!(h.inter.allreduce_s(d_bytes) < flat.allreduce_s(d_bytes));
        assert!(HierCostModel::from_topology(&Topology::ring_gbps(8, 100.0)).is_none());
        // Uneven re-grouping re-prices both levels.
        let h2 = h.with_map(crate::collective::topology::NodeMap::from_sizes(&[5, 3]));
        assert_eq!(h2.intra.n, 5);
        assert_eq!(h2.inter.n, 2);
    }

    #[test]
    fn kind_dispatch() {
        let m = model(8, 100.0);
        assert_eq!(
            m.time_s(CollectiveKind::AllReduce, 100),
            m.allreduce_s(100)
        );
        assert_eq!(m.time_s(CollectiveKind::AllGather, 4), m.allgather_s(4));
        assert_eq!(
            m.time_s(CollectiveKind::Broadcast, 100),
            m.broadcast_s(100)
        );
    }
}
