//! Simulated wall clock, per rank.
//!
//! Synchronous data parallelism advances in barriers: a collective
//! completes on every rank at `max_i(ready_i) + T_collective`.  The clock
//! tracks per-rank simulated time so straggler injection (a rank whose
//! compute takes longer) propagates into iteration time exactly as it
//! would on hardware.

#[derive(Debug, Clone)]
pub struct SimClock {
    t: Vec<f64>, // per-rank simulated seconds
}

impl SimClock {
    pub fn new(n: usize) -> Self {
        SimClock { t: vec![0.0; n] }
    }

    pub fn n(&self) -> usize {
        self.t.len()
    }

    /// Advance one rank by local compute time.
    pub fn advance(&mut self, rank: usize, dt: f64) {
        self.t[rank] += dt;
    }

    /// A synchronous collective: all ranks align to the slowest, then pay
    /// the collective's duration. Returns completion time.
    pub fn collective(&mut self, duration: f64) -> f64 {
        let start = self.t.iter().cloned().fold(0.0, f64::max);
        let done = start + duration;
        for t in &mut self.t {
            *t = done;
        }
        done
    }

    /// Completion barrier at an externally scheduled time (the event
    /// timeline's NIC completion): every rank aligns to the later of its
    /// own time and `done_s`. Returns the common time.
    pub fn align(&mut self, done_s: f64) -> f64 {
        let done = self.t.iter().cloned().fold(done_s, f64::max);
        for t in &mut self.t {
            *t = done;
        }
        done
    }

    pub fn rank_time(&self, rank: usize) -> f64 {
        self.t[rank]
    }

    /// Global time = slowest rank.
    pub fn now(&self) -> f64 {
        self.t.iter().cloned().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_aligns_to_slowest() {
        let mut c = SimClock::new(3);
        c.advance(0, 1.0);
        c.advance(1, 3.0);
        c.advance(2, 2.0);
        let done = c.collective(0.5);
        assert!((done - 3.5).abs() < 1e-12);
        for r in 0..3 {
            assert_eq!(c.rank_time(r), 3.5);
        }
    }

    #[test]
    fn straggler_paces_iteration() {
        let mut c = SimClock::new(2);
        // 10 iterations; rank 1 is 2x slower.
        for _ in 0..10 {
            c.advance(0, 0.1);
            c.advance(1, 0.2);
            c.collective(0.01);
        }
        assert!((c.now() - 10.0 * 0.21).abs() < 1e-9);
    }
}
