//! Event timeline for one training step's communication.
//!
//! Replaces the barrier-only accounting (`SimClock::collective` after the
//! whole backward) with the deployment shape: bucketed transfers are
//! posted at their bucket's readiness time and serialize on a modeled
//! NIC, so bucket *k*'s collective runs while buckets *k+1..* are still
//! being computed. This folds the analytical `overlap::exposed_comm_s`
//! pipeline model into the actual step accounting — for uniform bucket
//! readiness the two agree exactly (see the cross-check test below),
//! but the timeline also handles stragglers, ragged bucket sizes, and
//! exposed (non-overlappable) ops like AdaCons' second all-reduce.

use super::simclock::SimClock;

/// The NIC schedule of one step. Build it at the step's start, post every
/// transfer (bucketed ones at their readiness, exposed ones at backward
/// end), then [`StepTimeline::commit`] the completion barrier to the
/// clock.
#[derive(Debug, Clone)]
pub struct StepTimeline {
    /// When the modeled NIC next becomes free.
    nic_free_s: f64,
    /// Sum of every posted transfer's duration (what a fully serial,
    /// unpipelined schedule would expose).
    serial_s: f64,
}

impl StepTimeline {
    /// A fresh timeline whose NIC is free from `start_s` (the step start,
    /// i.e. the previous barrier's completion time).
    pub fn new(start_s: f64) -> Self {
        StepTimeline {
            nic_free_s: start_s,
            serial_s: 0.0,
        }
    }

    /// Post one transfer whose payload is ready at `ready_s` and occupies
    /// the NIC for `dur_s`. Transfers serialize: this one starts at
    /// `max(ready_s, nic_free)`. Returns its completion time.
    pub fn post(&mut self, ready_s: f64, dur_s: f64) -> f64 {
        let start = ready_s.max(self.nic_free_s);
        self.nic_free_s = start + dur_s;
        self.serial_s += dur_s;
        self.nic_free_s
    }

    /// Completion time of everything posted so far.
    pub fn done_s(&self) -> f64 {
        self.nic_free_s
    }

    /// Total transfer time posted, i.e. the unpipelined (fully exposed)
    /// communication accounting.
    pub fn serial_s(&self) -> f64 {
        self.serial_s
    }

    /// Communication not hidden behind compute: how far the schedule's
    /// completion outlasts `compute_end_s`.
    pub fn exposed_s(&self, compute_end_s: f64) -> f64 {
        (self.done_s() - compute_end_s).max(0.0)
    }

    /// Synchronous completion barrier: every rank aligns to the later of
    /// its own time and the schedule's completion.
    pub fn commit(&self, clock: &mut SimClock) -> f64 {
        clock.align(self.done_s())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::cost_model::CostModel;
    use crate::collective::overlap::exposed_comm_s;
    use crate::collective::topology::Topology;

    #[test]
    fn serializes_on_the_nic() {
        let mut tl = StepTimeline::new(0.0);
        // Ready early, back to back: second waits for the NIC.
        assert_eq!(tl.post(0.0, 1.0), 1.0);
        assert_eq!(tl.post(0.5, 1.0), 2.0);
        // Ready late: NIC idles until the payload exists.
        assert_eq!(tl.post(5.0, 1.0), 6.0);
        assert_eq!(tl.serial_s(), 3.0);
        assert_eq!(tl.exposed_s(5.5), 0.5);
        assert_eq!(tl.exposed_s(10.0), 0.0);
    }

    #[test]
    fn matches_analytical_overlap_model_for_uniform_buckets() {
        // The detached α-β formula (`overlap::exposed_comm_s`) and the
        // event timeline must agree exactly when bucket readiness is
        // uniform — the timeline generalizes the formula, it does not
        // replace its answers.
        let model = CostModel::from_topology(&Topology::ring_gbps(32, 100.0));
        let compute_s = 0.1;
        let d = 25_600_000usize;
        for n_buckets in [1usize, 2, 8, 32] {
            let bucket_bytes = d * 4 / n_buckets;
            let per_bucket_comm = model.allreduce_s(bucket_bytes);
            let per_bucket_compute = compute_s / n_buckets as f64;
            let mut tl = StepTimeline::new(0.0);
            for k in 0..n_buckets {
                tl.post((k + 1) as f64 * per_bucket_compute, per_bucket_comm);
            }
            let formula = exposed_comm_s(&model, compute_s, bucket_bytes, n_buckets);
            let timeline = tl.exposed_s(compute_s);
            assert!(
                (formula - timeline).abs() < 1e-15,
                "buckets={n_buckets}: {formula} vs {timeline}"
            );
        }
    }

    #[test]
    fn commit_aligns_all_ranks_to_completion() {
        let mut clock = SimClock::new(3);
        clock.advance(0, 1.0);
        clock.advance(1, 3.0);
        clock.advance(2, 2.0);
        let mut tl = StepTimeline::new(0.0);
        tl.post(3.0, 0.5); // ready when the slowest rank finishes
        let done = tl.commit(&mut clock);
        assert!((done - 3.5).abs() < 1e-12);
        for r in 0..3 {
            assert_eq!(clock.rank_time(r), 3.5);
        }
    }

    #[test]
    fn barrier_semantics_recovered_when_everything_is_exposed() {
        // Posting every op at compute end reproduces the barrier-only
        // accounting: completion = compute_end + Σ durations.
        let compute_end = 2.0;
        let durs = [0.3, 0.1, 0.2];
        let mut tl = StepTimeline::new(0.0);
        for &d in &durs {
            tl.post(compute_end, d);
        }
        let serial: f64 = durs.iter().sum();
        assert!((tl.done_s() - (compute_end + serial)).abs() < 1e-12);
        assert!((tl.exposed_s(compute_end) - serial).abs() < 1e-12);
        assert_eq!(tl.serial_s(), serial);
    }
}
