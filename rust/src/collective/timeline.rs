//! Event timeline for one training step's communication.
//!
//! Replaces the barrier-only accounting (`SimClock::collective` after the
//! whole backward) with the deployment shape: bucketed transfers are
//! posted at their bucket's readiness time and serialize on a modeled
//! NIC, so bucket *k*'s collective runs while buckets *k+1..* are still
//! being computed. This folds the analytical `overlap::exposed_comm_s`
//! pipeline model into the actual step accounting — for uniform bucket
//! readiness the two agree exactly (see the cross-check test below),
//! but the timeline also handles stragglers, ragged bucket sizes, and
//! exposed (non-overlappable) ops like AdaCons' second all-reduce.

use super::simclock::SimClock;

/// The NIC schedule of one step. Build it at the step's start, post every
/// transfer (bucketed ones at their readiness, exposed ones at backward
/// end), then [`StepTimeline::commit`] the completion barrier to the
/// clock.
#[derive(Debug, Clone)]
pub struct StepTimeline {
    /// When the modeled NIC next becomes free.
    nic_free_s: f64,
    /// Sum of every posted transfer's duration (what a fully serial,
    /// unpipelined schedule would expose).
    serial_s: f64,
}

impl StepTimeline {
    /// A fresh timeline whose NIC is free from `start_s` (the step start,
    /// i.e. the previous barrier's completion time).
    pub fn new(start_s: f64) -> Self {
        StepTimeline {
            nic_free_s: start_s,
            serial_s: 0.0,
        }
    }

    /// Post one transfer whose payload is ready at `ready_s` and occupies
    /// the NIC for `dur_s`. Transfers serialize: this one starts at
    /// `max(ready_s, nic_free)`. Returns its completion time.
    pub fn post(&mut self, ready_s: f64, dur_s: f64) -> f64 {
        self.post_span(ready_s, dur_s).1
    }

    /// [`StepTimeline::post`], also returning the transfer's start time
    /// — `(start, done)` — so span tracing can record the exact schedule
    /// without re-deriving `start = done - dur` (not `f64`-exact). The
    /// arithmetic is identical to the historical `post`.
    pub fn post_span(&mut self, ready_s: f64, dur_s: f64) -> (f64, f64) {
        let start = ready_s.max(self.nic_free_s);
        self.nic_free_s = start + dur_s;
        self.serial_s += dur_s;
        (start, self.nic_free_s)
    }

    /// Completion time of everything posted so far.
    pub fn done_s(&self) -> f64 {
        self.nic_free_s
    }

    /// Total transfer time posted, i.e. the unpipelined (fully exposed)
    /// communication accounting.
    pub fn serial_s(&self) -> f64 {
        self.serial_s
    }

    /// Communication not hidden behind compute: how far the schedule's
    /// completion outlasts `compute_end_s`.
    pub fn exposed_s(&self, compute_end_s: f64) -> f64 {
        (self.done_s() - compute_end_s).max(0.0)
    }

    /// Synchronous completion barrier: every rank aligns to the later of
    /// its own time and the schedule's completion.
    pub fn commit(&self, clock: &mut SimClock) -> f64 {
        clock.align(self.done_s())
    }
}

/// Topology-aware step schedule for a hierarchical cluster: one
/// independent channel per node's intra-node (NVLink-class) link plus one
/// shared inter-node fabric channel. Intra transfers on different nodes
/// overlap freely with each other *and* with inter-node transfers — the
/// deployment behaviour the flat single-NIC [`StepTimeline`] cannot
/// express; causality (a leader-level transfer waiting on every node's
/// reduction) is encoded by the caller through the `ready_s` it posts
/// with. Completion is the max over every channel.
#[derive(Debug, Clone)]
pub struct HierTimeline {
    intra: Vec<StepTimeline>,
    inter: StepTimeline,
}

impl HierTimeline {
    /// A fresh schedule with `nodes` intra channels, all free from
    /// `start_s`.
    pub fn new(start_s: f64, nodes: usize) -> Self {
        assert!(nodes > 0, "hierarchical timeline needs at least one node");
        HierTimeline {
            intra: vec![StepTimeline::new(start_s); nodes],
            inter: StepTimeline::new(start_s),
        }
    }

    pub fn nodes(&self) -> usize {
        self.intra.len()
    }

    /// Post a transfer on node `k`'s intra link; returns its completion.
    pub fn post_intra(&mut self, node: usize, ready_s: f64, dur_s: f64) -> f64 {
        self.intra[node].post(ready_s, dur_s)
    }

    /// [`HierTimeline::post_intra`] returning `(start, done)`.
    pub fn post_intra_span(&mut self, node: usize, ready_s: f64, dur_s: f64) -> (f64, f64) {
        self.intra[node].post_span(ready_s, dur_s)
    }

    /// Post a transfer on the shared inter-node fabric.
    pub fn post_inter(&mut self, ready_s: f64, dur_s: f64) -> f64 {
        self.inter.post(ready_s, dur_s)
    }

    /// [`HierTimeline::post_inter`] returning `(start, done)`.
    pub fn post_inter_span(&mut self, ready_s: f64, dur_s: f64) -> (f64, f64) {
        self.inter.post_span(ready_s, dur_s)
    }

    /// Completion of the slowest intra channel.
    pub fn intra_done_s(&self) -> f64 {
        self.intra
            .iter()
            .map(|t| t.done_s())
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn inter_done_s(&self) -> f64 {
        self.inter.done_s()
    }

    /// Completion of the whole schedule (every channel drained).
    pub fn done_s(&self) -> f64 {
        self.intra_done_s().max(self.inter_done_s())
    }

    /// Total exposed communication past `compute_end_s`.
    pub fn exposed_s(&self, compute_end_s: f64) -> f64 {
        (self.done_s() - compute_end_s).max(0.0)
    }

    /// Exposed time attributable to the intra-node links: the schedule
    /// tail the intra channels add **beyond** the inter fabric's
    /// completion (the result fan-out). Critical-path attribution, so
    /// `exposed_intra_s + exposed_inter_s == exposed_s` — waiting that
    /// inter ops do on earlier intra reduces is charged to the inter
    /// phase, which is what paces it.
    pub fn exposed_intra_s(&self, compute_end_s: f64) -> f64 {
        (self.intra_done_s() - compute_end_s.max(self.inter.done_s())).max(0.0)
    }

    /// Exposed time attributable to the inter-node fabric (completion of
    /// the leader-level schedule past backward end).
    pub fn exposed_inter_s(&self, compute_end_s: f64) -> f64 {
        (self.inter.done_s() - compute_end_s).max(0.0)
    }

    /// Synchronous completion barrier over every channel.
    pub fn commit(&self, clock: &mut SimClock) -> f64 {
        clock.align(self.done_s())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::cost_model::CostModel;
    use crate::collective::overlap::exposed_comm_s;
    use crate::collective::topology::Topology;

    #[test]
    fn serializes_on_the_nic() {
        let mut tl = StepTimeline::new(0.0);
        // Ready early, back to back: second waits for the NIC.
        assert_eq!(tl.post(0.0, 1.0), 1.0);
        assert_eq!(tl.post(0.5, 1.0), 2.0);
        // Ready late: NIC idles until the payload exists.
        assert_eq!(tl.post(5.0, 1.0), 6.0);
        assert_eq!(tl.serial_s(), 3.0);
        assert_eq!(tl.exposed_s(5.5), 0.5);
        assert_eq!(tl.exposed_s(10.0), 0.0);
    }

    #[test]
    fn post_span_is_post_with_the_start_attached() {
        let mut a = StepTimeline::new(0.25);
        let mut b = StepTimeline::new(0.25);
        for (ready, dur) in [(0.0, 1.0), (0.5, 0.125), (7.0, 0.3), (6.9, 0.05)] {
            let done = a.post(ready, dur);
            let (start, done2) = b.post_span(ready, dur);
            assert_eq!(done.to_bits(), done2.to_bits());
            assert_eq!((start + dur).to_bits(), done2.to_bits());
        }
        assert_eq!(a.serial_s().to_bits(), b.serial_s().to_bits());
        assert_eq!(a.done_s().to_bits(), b.done_s().to_bits());
        let mut h = HierTimeline::new(0.0, 2);
        let (s0, d0) = h.post_intra_span(0, 1.0, 0.5);
        assert_eq!((s0, d0), (1.0, 1.5));
        let (s1, d1) = h.post_inter_span(1.5, 1.0);
        assert_eq!((s1, d1), (1.5, 2.5));
    }

    #[test]
    fn matches_analytical_overlap_model_for_uniform_buckets() {
        // The detached α-β formula (`overlap::exposed_comm_s`) and the
        // event timeline must agree exactly when bucket readiness is
        // uniform — the timeline generalizes the formula, it does not
        // replace its answers.
        let model = CostModel::from_topology(&Topology::ring_gbps(32, 100.0));
        let compute_s = 0.1;
        let d = 25_600_000usize;
        for n_buckets in [1usize, 2, 8, 32] {
            let bucket_bytes = d * 4 / n_buckets;
            let per_bucket_comm = model.allreduce_s(bucket_bytes);
            let per_bucket_compute = compute_s / n_buckets as f64;
            let mut tl = StepTimeline::new(0.0);
            for k in 0..n_buckets {
                tl.post((k + 1) as f64 * per_bucket_compute, per_bucket_comm);
            }
            let formula = exposed_comm_s(&model, compute_s, bucket_bytes, n_buckets);
            let timeline = tl.exposed_s(compute_s);
            assert!(
                (formula - timeline).abs() < 1e-15,
                "buckets={n_buckets}: {formula} vs {timeline}"
            );
        }
    }

    #[test]
    fn commit_aligns_all_ranks_to_completion() {
        let mut clock = SimClock::new(3);
        clock.advance(0, 1.0);
        clock.advance(1, 3.0);
        clock.advance(2, 2.0);
        let mut tl = StepTimeline::new(0.0);
        tl.post(3.0, 0.5); // ready when the slowest rank finishes
        let done = tl.commit(&mut clock);
        assert!((done - 3.5).abs() < 1e-12);
        for r in 0..3 {
            assert_eq!(clock.rank_time(r), 3.5);
        }
    }

    #[test]
    fn hier_channels_overlap_independently() {
        let mut tl = HierTimeline::new(0.0, 2);
        // Both nodes reduce concurrently on their own links...
        assert_eq!(tl.post_intra(0, 1.0, 0.5), 1.5);
        assert_eq!(tl.post_intra(1, 1.0, 0.5), 1.5);
        // ...and the leader-level transfer starts as soon as both are done
        // — not after their serialized sum (the single-NIC model's answer).
        assert_eq!(tl.post_inter(1.5, 1.0), 2.5);
        assert_eq!(tl.done_s(), 2.5);
        assert_eq!(tl.intra_done_s(), 1.5);
        assert_eq!(tl.inter_done_s(), 2.5);
        assert_eq!(tl.exposed_s(2.0), 0.5);
        assert_eq!(tl.exposed_inter_s(2.0), 0.5);
        assert_eq!(tl.exposed_intra_s(2.0), 0.0);
        // A fan-out posted after the inter phase becomes an intra tail;
        // the critical-path split stays additive: intra + inter == total.
        tl.post_intra(0, 2.5, 0.25);
        tl.post_intra(1, 2.5, 0.25);
        assert_eq!(tl.exposed_s(2.0), 0.75);
        assert_eq!(tl.exposed_inter_s(2.0), 0.5);
        assert_eq!(tl.exposed_intra_s(2.0), 0.25);
        // The same ops on one NIC serialize: strictly later completion.
        let mut flat = StepTimeline::new(0.0);
        flat.post(1.0, 0.5);
        flat.post(1.0, 0.5);
        flat.post(flat.done_s(), 1.0);
        assert!(flat.done_s() > tl.done_s());
    }

    #[test]
    fn hier_intra_serializes_within_one_node() {
        let mut tl = HierTimeline::new(0.0, 3);
        assert_eq!(tl.post_intra(1, 0.0, 1.0), 1.0);
        assert_eq!(tl.post_intra(1, 0.0, 1.0), 2.0); // same link: queues
        assert_eq!(tl.post_intra(0, 0.0, 1.0), 1.0); // other link: free
        assert_eq!(tl.intra_done_s(), 2.0);
        let mut clock = SimClock::new(2);
        let done = tl.commit(&mut clock);
        assert_eq!(done, 2.0);
        assert_eq!(clock.now(), 2.0);
    }

    #[test]
    fn barrier_semantics_recovered_when_everything_is_exposed() {
        // Posting every op at compute end reproduces the barrier-only
        // accounting: completion = compute_end + Σ durations.
        let compute_end = 2.0;
        let durs = [0.3, 0.1, 0.2];
        let mut tl = StepTimeline::new(0.0);
        for &d in &durs {
            tl.post(compute_end, d);
        }
        let serial: f64 = durs.iter().sum();
        assert!((tl.done_s() - (compute_end + serial)).abs() < 1e-12);
        assert!((tl.exposed_s(compute_end) - serial).abs() < 1e-12);
        assert_eq!(tl.serial_s(), serial);
    }
}
