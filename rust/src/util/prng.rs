//! Deterministic PRNGs: SplitMix64 (seeding) and xoshiro256** (streams).
//!
//! Every stochastic component in the coordinator (data shards, failure
//! injection, property tests) draws from an explicitly-seeded `Rng`, so any
//! run is reproducible from its config seed. Worker `i` derives its stream
//! with [`Rng::fork`], which matches how the paper shards i.i.d. data
//! across ranks.

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Not cryptographic; fast, 2^256-1 period, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (recommended by the xoshiro authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream keyed by `key` (e.g. a worker rank).
    pub fn fork(&self, key: u64) -> Rng {
        let mut sm = self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ self.s[2].rotate_left(31)
            ^ self.s[3].rotate_left(47)
            ^ key.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity; the hot paths draw in bulk anyway).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Normal f32 with mean 0 and the given std.
    #[inline]
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Student-t with `dof` degrees of freedom — the heavy-tailed noise used
    /// by the Fig. 8 gradient-perturbation experiment.
    pub fn student_t(&mut self, dof: f64) -> f64 {
        // t = N / sqrt(ChiSq(dof)/dof); ChiSq via sum of squared normals
        // is fine for small integer dof.
        let n = self.normal();
        let k = dof.max(1.0) as usize;
        let mut chi = 0.0;
        for _ in 0..k {
            let z = self.normal();
            chi += z * z;
        }
        n / (chi / dof).sqrt()
    }

    /// Zipf-distributed integer in [0, n) with exponent `s`, via rejection
    /// sampling (Devroye). Used by the CTR categorical-feature generator.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        if s <= 0.0 {
            return self.below(n);
        }
        let nf = n as f64;
        loop {
            let u = self.uniform();
            let v = self.uniform();
            let x = if (s - 1.0).abs() < 1e-9 {
                nf.powf(u)
            } else {
                let t = (nf.powf(1.0 - s) - 1.0) * u + 1.0;
                t.powf(1.0 / (1.0 - s))
            };
            let k = x.floor().max(1.0);
            let ratio = (k / x).powf(s);
            if v * ratio <= 1.0 {
                return (k as u64 - 1).min(n - 1);
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a slice with U[0,1) f32 — bulk path for data generators.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.uniform_f32();
        }
    }

    /// Fill a slice with N(0, std) f32.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.normal_f32(std);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn fork_streams_are_independent() {
        let root = Rng::new(7);
        let mut w0 = root.fork(0);
        let mut w1 = root.fork(1);
        let v0: Vec<u64> = (0..8).map(|_| w0.next_u64()).collect();
        let v1: Vec<u64> = (0..8).map(|_| w1.next_u64()).collect();
        assert_ne!(v0, v1);
        // Re-deriving the same key reproduces the stream.
        let mut w0b = root.fork(0);
        assert_eq!(v0[0], w0b.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn zipf_is_skewed_toward_small_ids() {
        let mut r = Rng::new(4);
        let mut lo = 0;
        let n = 10_000;
        for _ in 0..n {
            if r.zipf(1000, 1.2) < 10 {
                lo += 1;
            }
        }
        // With s=1.2 the first 10 ids carry far more than 10/1000 of mass.
        assert!(lo > n / 10, "lo={lo}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn student_t_has_heavier_tails_than_normal() {
        let mut r = Rng::new(6);
        let n = 30_000;
        let mut extreme_t = 0;
        let mut extreme_n = 0;
        for _ in 0..n {
            if r.student_t(2.0).abs() > 4.0 {
                extreme_t += 1;
            }
            if r.normal().abs() > 4.0 {
                extreme_n += 1;
            }
        }
        assert!(extreme_t > extreme_n * 5, "t={extreme_t} n={extreme_n}");
    }
}
