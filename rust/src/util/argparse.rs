//! Tiny CLI argument parser (clap is not vendored offline).
//!
//! Grammar: `binary <subcommand...> [--flag] [--key value] [--key=value]
//! [positional...]`. Typed accessors parse on demand and report readable
//! errors.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

#[derive(Debug)]
pub enum ArgError {
    Missing(String),
    Parse(String, String, &'static str),
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgError::Missing(name) => write!(f, "missing required option --{name}"),
            ArgError::Parse(name, value, ty) => {
                write!(f, "option --{name}: cannot parse {value:?} as {ty}")
            }
        }
    }
}

impl std::error::Error for ArgError {}

impl Args {
    /// Parse raw argv items (excluding the program/subcommand names).
    /// `bool_flags` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(items: I, bool_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    pub fn req(&self, name: &str) -> Result<&str, ArgError> {
        self.str_opt(name).ok_or_else(|| ArgError::Missing(name.into()))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, ArgError> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError::Parse(name.into(), v.into(), "usize")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, ArgError> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError::Parse(name.into(), v.into(), "u64")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, ArgError> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError::Parse(name.into(), v.into(), "f64")),
        }
    }

    /// Comma-separated list of usize, e.g. `--workers 4,8,16`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, ArgError> {
        match self.str_opt(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| ArgError::Parse(name.into(), v.into(), "usize list"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["verbose"])
    }

    #[test]
    fn mixes_styles() {
        let a = parse("pos1 --k v --x=3 --verbose pos2 --tail");
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
        assert_eq!(a.str_opt("k"), Some("v"));
        assert_eq!(a.usize_or("x", 0).unwrap(), 3);
        assert!(a.flag("verbose"));
        assert!(a.flag("tail")); // trailing option with no value = flag
    }

    #[test]
    fn typed_accessors_and_errors() {
        let a = parse("--n 8 --lr 0.5 --list 1,2,3");
        assert_eq!(a.usize_or("n", 1).unwrap(), 8);
        assert!((a.f64_or("lr", 0.0).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(a.usize_list_or("list", &[]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!(a.req("absent").is_err());
        let bad = parse("--n x");
        assert!(bad.usize_or("n", 1).is_err());
    }
}
