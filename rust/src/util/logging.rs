//! Std-only leveled logging to stderr (the `log` crate is not vendored
//! offline). A process-global level filter is set from `ADACONS_LOG`
//! (error|warn|info|debug|trace; default info); the `log_error!` /
//! `log_warn!` / `log_info!` / `log_debug!` macros are the call surface.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Log severity, ordered from quietest to noisiest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        }
    }
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(Level::Info as usize);

/// Install the level filter from the environment (idempotent).
pub fn init() {
    let level = match std::env::var("ADACONS_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_max_level(level);
}

pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as usize <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record; the macros below are the intended entry point.
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{} {}] {}", level.tag(), target, args);
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test body: the level filter is process-global, and parallel
    // test threads mutating it would race.
    #[test]
    fn init_and_level_filter() {
        init();
        init();
        crate::log_info!("logging smoke test");
        set_max_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
