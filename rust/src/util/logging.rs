//! Std-only leveled logging to stderr (the `log` crate is not vendored
//! offline). A process-global level filter is set from `--log-level`
//! (falling back to `ADACONS_LOG`; error|warn|info|debug|trace, default
//! info); the `log_error!` / `log_warn!` / `log_info!` / `log_debug!`
//! macros are the call surface.
//!
//! Each record carries wall time elapsed since [`init`] plus any
//! thread-local step/rank context installed via [`set_step_context`] /
//! [`set_rank_context`]:
//!
//! ```text
//! [   12.041 W adacons::comm s37 r2] rank 2 down: channel closed
//! ```

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered from quietest to noisiest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    /// Parse a `--log-level` / `ADACONS_LOG` spec.
    pub fn parse(v: &str) -> Option<Level> {
        match v {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        }
    }
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(Level::Info as usize);

thread_local! {
    static STEP_CTX: Cell<Option<u64>> = Cell::new(None);
    static RANK_CTX: Cell<Option<usize>> = Cell::new(None);
}

/// The process epoch every log line's elapsed time is measured from.
/// First use pins it, so call [`init`] early for meaningful offsets.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Install the level filter from the environment and pin the elapsed-time
/// epoch (idempotent).
pub fn init() {
    let _ = epoch();
    let level = std::env::var("ADACONS_LOG")
        .ok()
        .as_deref()
        .and_then(Level::parse)
        .unwrap_or(Level::Info);
    set_max_level(level);
}

/// Tag this thread's subsequent log lines with a training step (`s<N>`);
/// `None` clears it. The trainer sets this once per round.
pub fn set_step_context(step: Option<u64>) {
    STEP_CTX.with(|c| c.set(step));
}

/// Tag this thread's subsequent log lines with a rank id (`r<N>`);
/// `None` clears it. Rank worker threads set this once at spawn.
pub fn set_rank_context(rank: Option<usize>) {
    RANK_CTX.with(|c| c.set(rank));
}

pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as usize <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record; the macros below are the intended entry point.
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let elapsed = epoch().elapsed().as_secs_f64();
    let mut ctx = String::new();
    if let Some(s) = STEP_CTX.with(|c| c.get()) {
        ctx.push_str(&format!(" s{s}"));
    }
    if let Some(r) = RANK_CTX.with(|c| c.get()) {
        ctx.push_str(&format!(" r{r}"));
    }
    eprintln!("[{elapsed:9.3} {} {}{}] {}", level.tag(), target, ctx, args);
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test body: the level filter is process-global, and parallel
    // test threads mutating it would race.
    #[test]
    fn init_and_level_filter() {
        init();
        init();
        crate::log_info!("logging smoke test");
        set_max_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        // Contexts are thread-local; set + emit + clear must not poison
        // later lines (visual check only — stderr is not captured here).
        set_step_context(Some(7));
        set_rank_context(Some(2));
        crate::log_info!("contextual smoke test");
        set_step_context(None);
        set_rank_context(None);
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("trace"), Some(Level::Trace));
        assert_eq!(Level::parse("loud"), None);
        assert_eq!(Level::parse(""), None);
    }
}
