//! Utility substrates built from scratch (the offline environment vendors
//! only the `xla` crate's dependency closure, so the usual ecosystem crates
//! — rand, serde, clap, criterion — are re-implemented here at the size
//! this project needs).

pub mod argparse;
pub mod error;
pub mod json;
pub mod logging;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod timer;
