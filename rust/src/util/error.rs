//! Minimal error plumbing (anyhow is not vendored offline): a single
//! string-chained [`Error`], a defaulted [`Result`] alias, a [`Context`]
//! extension trait for `Result`/`Option`, and `bail!`/`ensure!`/`err!`
//! macros. The surface deliberately mirrors the anyhow idioms the codebase
//! already uses so call sites read identically.

use std::fmt;

/// A boxed-free, message-carrying error. Context layers are flattened into
/// the message front-to-back (`"outer: inner"`), which is exactly what the
/// CLI prints.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }

    fn wrap(self, ctx: impl fmt::Display) -> Error {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<crate::util::argparse::ArgError> for Error {
    fn from(e: crate::util::argparse::ArgError) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(m: String) -> Error {
        Error::msg(m)
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Error {
        Error::msg(m)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::msg(e.to_string())
    }
}

/// `Result` defaulted to [`Error`], anyhow-style.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(|| ...)` for results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string (anyhow's `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Early-return an error unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

// Make the macros importable alongside the types:
// `use crate::util::error::{bail, Context, Result};`.
pub use crate::{bail, ensure, err};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broken {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broken 42");
        assert_eq!(format!("{e:#}"), "broken 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(30).unwrap_err().to_string(), "x too big: 30");
    }

    #[test]
    fn context_chains_front_to_back() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.context("opening file").unwrap_err();
        assert!(e.to_string().starts_with("opening file: "), "{e}");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn io_error_converts_via_question_mark() {
        fn read_missing() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file/xyz")?;
            Ok(s)
        }
        assert!(read_missing().is_err());
    }

    #[test]
    fn err_macro_builds_error() {
        let e = err!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
