//! Minimal JSON parser/writer (serde is not vendored in this environment).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest, config files, and JSONL metric sinks.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((d + 1) * 2));
                        v.write(out, Some(d + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let Some(d) = indent {
                    if !a.is_empty() {
                        out.push('\n');
                        out.push_str(&" ".repeat(d * 2));
                    }
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat((d + 1) * 2));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(d + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(d) = indent {
                    if !m.is_empty() {
                        out.push('\n');
                        out.push_str(&" ".repeat(d * 2));
                    }
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = &self.b[self.pos..];
                    let st = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let c = st.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert!(j.get("a").as_arr().unwrap()[2].get("b").is_null());
        assert_eq!(j.get("c").as_str().unwrap(), "x");
        assert!(j.get("missing").is_null());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s"],"obj":{"k":true},"z":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string_compact()).unwrap();
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse("\"\\u0041\\u00e9\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version":1,"artifacts":{"m":{"hlo":"m.hlo.txt","param_dim":1000,
            "inputs":[{"name":"x","dtype":"f32","shape":[16,1000]}],"golden":{"loss":0.28}}}}"#;
        let j = Json::parse(src).unwrap();
        let m = j.get("artifacts").get("m");
        assert_eq!(m.get("param_dim").as_usize().unwrap(), 1000);
        let shape = m.get("inputs").as_arr().unwrap()[0].get("shape");
        assert_eq!(shape.as_arr().unwrap()[1].as_usize().unwrap(), 1000);
    }
}
