//! Wall-clock timing helpers.

use std::time::Instant;

/// Scoped stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Accumulates named phase timings across a loop (e.g. grad/agg/opt per step).
#[derive(Debug, Default)]
pub struct PhaseTimer {
    phases: Vec<(String, f64, u64)>, // name, total seconds, count
}

impl PhaseTimer {
    pub fn add(&mut self, name: &str, seconds: f64) {
        if let Some(p) = self.phases.iter_mut().find(|p| p.0 == name) {
            p.1 += seconds;
            p.2 += 1;
        } else {
            self.phases.push((name.to_string(), seconds, 1));
        }
    }

    pub fn time<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> R {
        let t = Timer::start();
        let r = f();
        self.add(name, t.elapsed_s());
        r
    }

    pub fn total(&self, name: &str) -> f64 {
        self.phases
            .iter()
            .find(|p| p.0 == name)
            .map(|p| p.1)
            .unwrap_or(0.0)
    }

    pub fn mean(&self, name: &str) -> f64 {
        self.phases
            .iter()
            .find(|p| p.0 == name)
            .map(|p| if p.2 == 0 { 0.0 } else { p.1 / p.2 as f64 })
            .unwrap_or(0.0)
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, total, count) in &self.phases {
            s.push_str(&format!(
                "{name}: total {total:.3}s over {count} calls (mean {:.3}ms)\n",
                total / (*count).max(1) as f64 * 1e3
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_timer_accumulates() {
        let mut pt = PhaseTimer::default();
        pt.add("a", 0.5);
        pt.add("a", 1.5);
        pt.add("b", 1.0);
        assert!((pt.total("a") - 2.0).abs() < 1e-12);
        assert!((pt.mean("a") - 1.0).abs() < 1e-12);
        assert_eq!(pt.total("missing"), 0.0);
        assert!(pt.report().contains("a:"));
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
