//! Tiny property-testing harness (proptest is not vendored offline).
//!
//! `run_cases(n, seed, |gen| ...)` drives a closure through `n` randomized
//! cases; on failure the panic message carries the case seed so the exact
//! case replays with `replay(case_seed, f)`.

use super::prng::Rng;

/// Case-scoped generator handed to each property.
pub struct Gen {
    pub rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Random f32 vector with entries ~ N(0, scale).
    pub fn vec_normal(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal_f32(scale)).collect()
    }

    /// Random f32 matrix rows (n x d), row-major.
    pub fn grad_matrix(&mut self, n: usize, d: usize, scale: f32) -> Vec<Vec<f32>> {
        (0..n).map(|_| self.vec_normal(d, scale)).collect()
    }
}

/// Run `cases` randomized cases of property `f`. Panics with the case seed
/// embedded on the first failure.
pub fn run_cases(cases: usize, seed: u64, mut f: impl FnMut(&mut Gen)) {
    let mut root = Rng::new(seed);
    for i in 0..cases {
        let case_seed = root.next_u64();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen {
                rng: Rng::new(case_seed),
                case_seed,
            };
            f(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed on case {i} (replay seed {case_seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by its seed.
pub fn replay(case_seed: u64, mut f: impl FnMut(&mut Gen)) {
    let mut g = Gen {
        rng: Rng::new(case_seed),
        case_seed,
    };
    f(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run_cases(50, 1, |g| {
            let n = g.usize_in(1, 10);
            let v = g.vec_normal(n, 1.0);
            assert_eq!(v.len(), n);
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            run_cases(10, 2, |g| {
                let x = g.usize_in(0, 100);
                assert!(x < 101); // passes
                panic!("boom"); // deterministic failure to exercise reporting
            });
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| format!("{err:?}"));
        assert!(msg.contains("replay seed"), "{msg}");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut seen = Vec::new();
        replay(42, |g| seen.push(g.usize_in(0, 1_000_000)));
        let mut seen2 = Vec::new();
        replay(42, |g| seen2.push(g.usize_in(0, 1_000_000)));
        assert_eq!(seen, seen2);
    }
}
