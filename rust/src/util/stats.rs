//! Streaming statistics: Welford moments, quantiles, EMA.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact quantiles over a retained sample (fine for bench-sized data).
#[derive(Debug, Clone, Default)]
pub struct Quantiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Quantiles {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Linear-interpolated quantile, q in [0,1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q.clamp(0.0, 1.0) * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = pos - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }
}

/// Exponential moving average with bias correction (Adam-style).
#[derive(Debug, Clone)]
pub struct Ema {
    beta: f64,
    value: f64,
    steps: u64,
}

impl Ema {
    pub fn new(beta: f64) -> Self {
        Ema {
            beta,
            value: 0.0,
            steps: 0,
        }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        self.steps += 1;
        self.value = self.beta * self.value + (1.0 - self.beta) * x;
        self.get()
    }

    /// Bias-corrected current value.
    pub fn get(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.value / (1.0 - self.beta.powi(self.steps as i32))
        }
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation of a slice.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let m = xs.iter().sum::<f64>() / 5.0;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / 5.0;
        assert!((w.mean() - m).abs() < 1e-12);
        assert!((w.var() - v).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
    }

    #[test]
    fn quantiles_interpolate() {
        let mut q = Quantiles::default();
        for x in [4.0, 1.0, 3.0, 2.0] {
            q.push(x);
        }
        assert_eq!(q.quantile(0.0), 1.0);
        assert_eq!(q.quantile(1.0), 4.0);
        assert!((q.quantile(0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ema_bias_correction() {
        let mut e = Ema::new(0.9);
        // Constant stream: corrected EMA should equal the constant.
        for _ in 0..5 {
            e.push(3.0);
        }
        assert!((e.get() - 3.0).abs() < 1e-9, "{}", e.get());
    }

    #[test]
    fn slice_helpers() {
        assert_eq!(mean(&[]), 0.0);
        assert!((std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138089935).abs() < 1e-6);
    }
}
