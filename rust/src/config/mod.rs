//! Typed run configuration: JSON config files + CLI overrides + presets.
//!
//! Every experiment in `exp/` is a [`TrainConfig`] (or a sweep of them), so
//! any paper run can be reproduced from the command line:
//! `adacons train --config cfg.json --workers 8 --aggregator adacons`.

use crate::collective::TopologySpec;
use crate::compress::{CompressScope, CompressionSpec, CompressorKind};
use crate::data::GradInjector;
use crate::obs::TraceLevel;
use crate::optim::Schedule;
use crate::parallel::ParallelPolicy;
use crate::runtime::Backend;
use crate::util::argparse::Args;
use crate::util::error::{bail, Context, Result};
use crate::util::json::Json;

/// Straggler-cutoff spec (`--cutoff k-of-n[:grace_ms]`): finalize each
/// step once `k` of the `n` configured ranks have delivered all their
/// buckets, granting late ranks a `grace_ms`-millisecond window past
/// the k-th arrival on the simulated timeline before they are cut.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CutoffSpec {
    pub k: usize,
    pub n: usize,
    pub grace_ms: f64,
}

impl CutoffSpec {
    pub fn parse(s: &str) -> Option<CutoffSpec> {
        let (quorum, grace_ms) = match s.split_once(':') {
            Some((q, g)) => (q, g.parse::<f64>().ok()?),
            None => (s, 0.0),
        };
        let (k, n) = quorum.split_once("-of-")?;
        let spec = CutoffSpec {
            k: k.parse().ok()?,
            n: n.parse().ok()?,
            grace_ms,
        };
        (spec.k >= 1 && spec.k <= spec.n && spec.grace_ms >= 0.0).then_some(spec)
    }
}

/// Local-step regime (`--local-steps H|auto:<min>-<max>`): how many
/// optimizer steps each rank takes between consensus rounds. `Fixed(1)`
/// is the historical fully-synchronous path (one aggregation per
/// gradient). `Fixed(H>1)` runs H local SGD steps per rank and then
/// aggregates the accumulated model *delta* (in gradient units) once,
/// cutting collective traffic ~H×. `Auto` adapts H between sync rounds
/// from the consensus-weight dispersion: high dispersion (ranks
/// disagree) shrinks H toward `min`, low dispersion grows it toward
/// `max`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LocalStepSpec {
    Fixed(usize),
    Auto { min: usize, max: usize },
}

impl LocalStepSpec {
    pub fn parse(s: &str) -> Option<LocalStepSpec> {
        if let Some(range) = s.strip_prefix("auto:") {
            let (min, max) = range.split_once('-')?;
            let (min, max) = (min.parse().ok()?, max.parse().ok()?);
            return (min >= 1 && min <= max).then_some(LocalStepSpec::Auto { min, max });
        }
        let h: usize = s.parse().ok()?;
        (h >= 1).then_some(LocalStepSpec::Fixed(h))
    }

    /// True for the fully-synchronous regime (aggregate every gradient) —
    /// the historical path every bitwise invariant anchors to.
    pub fn is_sync(&self) -> bool {
        matches!(self, LocalStepSpec::Fixed(1))
    }

    /// H for the first sync round. Adaptive runs start conservative (at
    /// `min`): communicate eagerly until the dispersion signal earns
    /// longer local phases.
    pub fn initial(&self) -> usize {
        match *self {
            LocalStepSpec::Fixed(h) => h,
            LocalStepSpec::Auto { min, .. } => min,
        }
    }

    /// Human-readable form (config echo / TrainResult).
    pub fn describe(&self) -> String {
        match *self {
            LocalStepSpec::Fixed(h) => h.to_string(),
            LocalStepSpec::Auto { min, max } => format!("auto:{min}-{max}"),
        }
    }
}

/// Full specification of one training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Train artifact name from the manifest (e.g. `mlp_cls_b32`).
    pub artifact: String,
    /// Eval artifact (defaults to `<artifact>__eval` when present).
    pub eval_artifact: Option<String>,
    /// Number of simulated ranks N.
    pub workers: usize,
    /// Aggregator name (see `aggregation::ALL_NAMES`).
    pub aggregator: String,
    /// Optimizer name (see `optim::by_name`).
    pub optimizer: String,
    /// LR schedule spec, e.g. `const:0.1` or `cosine:0.1:100:1000`.
    pub schedule: Schedule,
    pub steps: usize,
    pub eval_every: usize,
    /// Eval batches pooled per evaluation point.
    pub eval_batches: usize,
    /// Data/injection seed.
    pub seed: u64,
    /// Parameter init seed (must exist in the artifact's init blobs).
    pub init_seed: u64,
    /// Global-norm clip; None disables (Fig. 8 toggles this).
    pub clip: Option<f64>,
    /// Layer-wise aggregation bucket capacity; None = model-wise.
    pub bucket_cap: Option<usize>,
    /// Label-skew knob for the classification stream (0 = i.i.d.).
    pub heterogeneity: f64,
    /// Per-rank gradient injectors: (rank, spec).
    pub injectors: Vec<(usize, GradInjector)>,
    /// Simulated fabric speed for the comm cost model (Gb/s).
    pub fabric_gbps: f64,
    /// Cluster topology (`--topology flat|hier:<nodes>x<gpus>`). `flat`
    /// is the historical single-ring path. `hier` groups the workers
    /// into nodes: gradients are mean-reduced intra-node (NVLink-class
    /// links) and the configured aggregator runs across node leaders
    /// only, with the step's comm charged to the two-level timeline
    /// (`nodes * gpus` must equal `workers`).
    pub topology: TopologySpec,
    pub log_every: usize,
    /// Optional JSONL step-log path.
    pub jsonl: Option<String>,
    /// Parallel engine knobs for the aggregation hot path
    /// (`par_threads`: 0 = all cores; `par_min_shard_elems`).
    pub parallel: ParallelPolicy,
    /// Execution backend (`--backend auto|interp|pjrt`): `interp` is the
    /// native interpreter (default offline build), `pjrt` the XLA path
    /// (toolchain images, `--features pjrt`), `auto` picks pjrt when
    /// compiled in and interp otherwise.
    pub backend: Backend,
    /// Comm/compute overlap: pipeline per-bucket aggregation work with
    /// gradient arrival and schedule bucketed collectives on the event
    /// timeline (`--overlap on|off`). Off reproduces the barrier-only
    /// step loop exactly; on is bitwise-identical in output and reports
    /// strictly less exposed communication on multi-bucket configs.
    pub overlap: bool,
    /// Threaded rank execution (`--rank-threads on|off`): each rank is a
    /// real OS thread owning its interpreter executable, streaming
    /// gradient buckets to the leader over `comm::StepExchange` in true
    /// arrival order. Off runs the ranks round-robin on the leader
    /// thread — the equivalence oracle; both modes produce bitwise-equal
    /// aggregated directions (interp backend only).
    pub rank_threads: bool,
    /// Gradient compression on the collective path
    /// (`--compress none|lowrank:<k>|int8|fp16|topk:<ratio>` plus
    /// `--compress-scope all|inter`). Per-rank kinds encode at the rank
    /// source with error feedback; `lowrank` sketches the assembled
    /// set leader-side. Scope `inter` restricts compression to the
    /// inter-node hop on hierarchical topologies (no-op distinction on
    /// flat ones). `none` is bitwise-identical to no compression.
    pub compression: CompressionSpec,
    /// Elastic fault-tolerant stepping (`--cutoff k-of-n[:grace_ms]`):
    /// the leader finalizes each step from the first `k` ranks (plus
    /// any landing within the grace window), consensus weights
    /// renormalized over the survivors; a rank that dies is replaced by
    /// a fresh fast-forwarded worker before the next step. Requires
    /// `--rank-threads on` with `--overlap off`; `n` must equal
    /// `workers`. None = every step is a full barrier.
    pub cutoff: Option<CutoffSpec>,
    /// Krum-style outlier filter on the elastic path (`--krum f`): drop
    /// ranks with non-finite gradients, then the `f` worst krum scores
    /// (sum of the m-f-2 smallest pairwise squared distances). 0
    /// disables; > 0 requires `--cutoff`.
    pub krum_f: usize,
    /// Save a full-state checkpoint every S steps
    /// (`--checkpoint-every S`, to `checkpoint_path`); 0 disables.
    pub checkpoint_every: usize,
    /// Where periodic checkpoints are written (overwritten in place).
    pub checkpoint_path: Option<String>,
    /// Local-step regime (`--local-steps H|auto:<min>-<max>`): ranks take
    /// H local SGD steps between consensus rounds, aggregating the
    /// accumulated model delta (in gradient units) once per round. `1`
    /// (the default) is bitwise-identical to the historical synchronous
    /// path. `cfg.steps` always counts *local* steps (gradient
    /// evaluations per rank), so a 64-step run at H=4 performs 16 sync
    /// rounds.
    pub local_steps: LocalStepSpec,
    /// Span-trace granularity (`--trace-level off|step|bucket|rank`).
    /// `off` (the default) records nothing; `step` adds per-round
    /// leader phase spans + step marks, `bucket` adds per-bucket
    /// encode/transfer spans, `rank` adds per-rank compute spans and
    /// bucket-ready instants. Tracing is purely passive: training
    /// output is bitwise-identical at every level.
    pub trace_level: TraceLevel,
    /// Chrome trace-event JSON output path (`--trace-out trace.json`,
    /// Perfetto-loadable). Requires `trace_level != off`.
    pub trace_out: Option<String>,
    /// Prometheus-style text exposition of the run's metrics registry
    /// (`--metrics-out metrics.txt`), written once after training.
    pub metrics_out: Option<String>,
    /// Stderr log level (`--log-level error|warn|info|debug|trace`).
    /// `None` falls back to the `ADACONS_LOG` environment variable.
    pub log_level: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact: "linreg_b64".into(),
            eval_artifact: None,
            workers: 4,
            aggregator: "adacons".into(),
            optimizer: "sgd".into(),
            schedule: Schedule::Const { lr: 0.05 },
            steps: 100,
            eval_every: 0,
            eval_batches: 4,
            seed: 0,
            init_seed: 0,
            clip: None,
            bucket_cap: None,
            heterogeneity: 0.0,
            injectors: Vec::new(),
            fabric_gbps: 100.0,
            topology: TopologySpec::Flat,
            log_every: 0,
            jsonl: None,
            parallel: ParallelPolicy::default(),
            backend: Backend::Auto,
            overlap: false,
            rank_threads: false,
            compression: CompressionSpec::default(),
            cutoff: None,
            krum_f: 0,
            checkpoint_every: 0,
            checkpoint_path: None,
            local_steps: LocalStepSpec::Fixed(1),
            trace_level: TraceLevel::Off,
            trace_out: None,
            metrics_out: None,
            log_level: None,
        }
    }
}

/// Parse an `on`/`off` switch (also accepts `true`/`false`, `1`/`0`).
fn parse_switch(v: &str) -> Option<bool> {
    match v {
        "on" | "true" | "1" => Some(true),
        "off" | "false" | "0" => Some(false),
        _ => None,
    }
}

impl TrainConfig {
    /// Parse from a JSON object (all keys optional; unknown keys rejected).
    pub fn from_json(j: &Json) -> Result<TrainConfig> {
        let mut cfg = TrainConfig::default();
        let obj = j.as_obj().context("config must be a JSON object")?;
        for (k, v) in obj {
            match k.as_str() {
                "artifact" => cfg.artifact = v.as_str().context("artifact")?.into(),
                "eval_artifact" => {
                    cfg.eval_artifact = Some(v.as_str().context("eval_artifact")?.into())
                }
                "workers" => cfg.workers = v.as_usize().context("workers")?,
                "aggregator" => cfg.aggregator = v.as_str().context("aggregator")?.into(),
                "optimizer" => cfg.optimizer = v.as_str().context("optimizer")?.into(),
                "schedule" => {
                    cfg.schedule = Schedule::parse(v.as_str().context("schedule")?)
                        .context("bad schedule spec")?
                }
                "steps" => cfg.steps = v.as_usize().context("steps")?,
                "eval_every" => cfg.eval_every = v.as_usize().context("eval_every")?,
                "eval_batches" => cfg.eval_batches = v.as_usize().context("eval_batches")?,
                "seed" => cfg.seed = v.as_f64().context("seed")? as u64,
                "init_seed" => cfg.init_seed = v.as_f64().context("init_seed")? as u64,
                "clip" => cfg.clip = v.as_f64(),
                "bucket_cap" => cfg.bucket_cap = v.as_usize(),
                "heterogeneity" => cfg.heterogeneity = v.as_f64().context("heterogeneity")?,
                "fabric_gbps" => cfg.fabric_gbps = v.as_f64().context("fabric_gbps")?,
                "topology" => {
                    let s = v.as_str().context("topology")?;
                    cfg.topology = TopologySpec::parse(s).with_context(|| {
                        format!("topology {s:?}: want flat|hier:<nodes>x<gpus>")
                    })?;
                }
                "log_every" => cfg.log_every = v.as_usize().context("log_every")?,
                "jsonl" => cfg.jsonl = Some(v.as_str().context("jsonl")?.into()),
                "par_threads" => cfg.parallel.threads = v.as_usize().context("par_threads")?,
                "par_min_shard_elems" => {
                    cfg.parallel.min_shard_elems =
                        v.as_usize().context("par_min_shard_elems")?
                }
                "backend" => {
                    let s = v.as_str().context("backend")?;
                    cfg.backend = Backend::parse(s)
                        .with_context(|| format!("backend {s:?}: want auto|interp|pjrt"))?;
                }
                "overlap" => {
                    cfg.overlap = match (v.as_bool(), v.as_str()) {
                        (Some(b), _) => b,
                        (None, Some(s)) => {
                            parse_switch(s).context("overlap must be on|off")?
                        }
                        _ => bail!("overlap must be a bool or \"on\"/\"off\""),
                    }
                }
                "rank_threads" => {
                    cfg.rank_threads = match (v.as_bool(), v.as_str()) {
                        (Some(b), _) => b,
                        (None, Some(s)) => {
                            parse_switch(s).context("rank_threads must be on|off")?
                        }
                        _ => bail!("rank_threads must be a bool or \"on\"/\"off\""),
                    }
                }
                "compress" => {
                    let s = v.as_str().context("compress")?;
                    cfg.compression.kind = CompressorKind::parse(s).with_context(|| {
                        format!("compress {s:?}: want none|lowrank:<k>|int8|fp16|topk:<ratio>")
                    })?;
                }
                "compress_scope" => {
                    let s = v.as_str().context("compress_scope")?;
                    cfg.compression.scope = CompressScope::parse(s)
                        .with_context(|| format!("compress_scope {s:?}: want all|inter"))?;
                }
                "cutoff" => {
                    let s = v.as_str().context("cutoff")?;
                    cfg.cutoff = Some(CutoffSpec::parse(s).with_context(|| {
                        format!("cutoff {s:?}: want k-of-n[:grace_ms]")
                    })?);
                }
                "krum_f" => cfg.krum_f = v.as_usize().context("krum_f")?,
                "checkpoint_every" => {
                    cfg.checkpoint_every = v.as_usize().context("checkpoint_every")?
                }
                "checkpoint_path" => {
                    cfg.checkpoint_path = Some(v.as_str().context("checkpoint_path")?.into())
                }
                "local_steps" => {
                    cfg.local_steps = match (v.as_usize(), v.as_str()) {
                        (Some(h), _) => LocalStepSpec::parse(&h.to_string()),
                        (None, Some(s)) => LocalStepSpec::parse(s),
                        _ => None,
                    }
                    .with_context(|| {
                        format!("local_steps {v:?}: want H>=1 or \"auto:<min>-<max>\"")
                    })?;
                }
                "trace_level" => {
                    let s = v.as_str().context("trace_level")?;
                    cfg.trace_level = TraceLevel::parse(s).with_context(|| {
                        format!("trace_level {s:?}: want off|step|bucket|rank")
                    })?;
                }
                "trace_out" => cfg.trace_out = Some(v.as_str().context("trace_out")?.into()),
                "metrics_out" => {
                    cfg.metrics_out = Some(v.as_str().context("metrics_out")?.into())
                }
                "log_level" => cfg.log_level = Some(v.as_str().context("log_level")?.into()),
                "injectors" => {
                    for item in v.as_arr().context("injectors")? {
                        let rank = item.get("rank").as_usize().context("injector rank")?;
                        let spec = item.get("spec").as_str().context("injector spec")?;
                        cfg.injectors.push((
                            rank,
                            GradInjector::parse(spec).context("bad injector spec")?,
                        ));
                    }
                }
                other => bail!("unknown config key {other:?}"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply CLI overrides on top of the current values.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(a) = args.str_opt("artifact") {
            self.artifact = a.into();
        }
        if let Some(a) = args.str_opt("eval-artifact") {
            self.eval_artifact = Some(a.into());
        }
        self.workers = args.usize_or("workers", self.workers)?;
        if let Some(a) = args.str_opt("aggregator") {
            self.aggregator = a.into();
        }
        if let Some(a) = args.str_opt("optimizer") {
            self.optimizer = a.into();
        }
        if let Some(s) = args.str_opt("schedule") {
            self.schedule = Schedule::parse(s).context("bad --schedule")?;
        }
        self.steps = args.usize_or("steps", self.steps)?;
        self.eval_every = args.usize_or("eval-every", self.eval_every)?;
        self.eval_batches = args.usize_or("eval-batches", self.eval_batches)?;
        self.seed = args.u64_or("seed", self.seed)?;
        self.init_seed = args.u64_or("init-seed", self.init_seed)?;
        if let Some(c) = args.str_opt("clip") {
            self.clip = if c == "none" {
                None
            } else {
                Some(c.parse().context("bad --clip")?)
            };
        }
        if let Some(c) = args.str_opt("bucket-cap") {
            self.bucket_cap = Some(c.parse().context("bad --bucket-cap")?);
        }
        self.heterogeneity = args.f64_or("heterogeneity", self.heterogeneity)?;
        self.fabric_gbps = args.f64_or("fabric-gbps", self.fabric_gbps)?;
        if let Some(s) = args.str_opt("topology") {
            self.topology = TopologySpec::parse(s)
                .with_context(|| format!("--topology {s:?}: want flat|hier:<nodes>x<gpus>"))?;
        }
        self.log_every = args.usize_or("log-every", self.log_every)?;
        self.parallel.threads = args.usize_or("par-threads", self.parallel.threads)?;
        self.parallel.min_shard_elems =
            args.usize_or("par-min-shard-elems", self.parallel.min_shard_elems)?;
        if let Some(v) = args.str_opt("backend") {
            self.backend = Backend::parse(v)
                .with_context(|| format!("--backend {v:?}: want auto|interp|pjrt"))?;
        }
        if let Some(v) = args.str_opt("overlap") {
            self.overlap = parse_switch(v).context("--overlap on|off")?;
        }
        if let Some(v) = args.str_opt("rank-threads") {
            self.rank_threads = parse_switch(v).context("--rank-threads on|off")?;
        }
        if let Some(s) = args.str_opt("compress") {
            self.compression.kind = CompressorKind::parse(s).with_context(|| {
                format!("--compress {s:?}: want none|lowrank:<k>|int8|fp16|topk:<ratio>")
            })?;
        }
        if let Some(s) = args.str_opt("compress-scope") {
            self.compression.scope = CompressScope::parse(s)
                .with_context(|| format!("--compress-scope {s:?}: want all|inter"))?;
        }
        if let Some(p) = args.str_opt("jsonl") {
            self.jsonl = Some(p.into());
        }
        if let Some(s) = args.str_opt("cutoff") {
            self.cutoff = if s == "none" {
                None
            } else {
                Some(
                    CutoffSpec::parse(s)
                        .with_context(|| format!("--cutoff {s:?}: want k-of-n[:grace_ms]"))?,
                )
            };
        }
        self.krum_f = args.usize_or("krum", self.krum_f)?;
        if let Some(s) = args.str_opt("local-steps") {
            self.local_steps = LocalStepSpec::parse(s).with_context(|| {
                format!("--local-steps {s:?}: want H>=1 or auto:<min>-<max>")
            })?;
        }
        self.checkpoint_every = args.usize_or("checkpoint-every", self.checkpoint_every)?;
        if let Some(p) = args.str_opt("checkpoint-path") {
            self.checkpoint_path = Some(p.into());
        }
        if let Some(s) = args.str_opt("trace-level") {
            self.trace_level = TraceLevel::parse(s)
                .with_context(|| format!("--trace-level {s:?}: want off|step|bucket|rank"))?;
        }
        if let Some(p) = args.str_opt("trace-out") {
            self.trace_out = Some(p.into());
        }
        if let Some(p) = args.str_opt("metrics-out") {
            self.metrics_out = Some(p.into());
        }
        if let Some(s) = args.str_opt("log-level") {
            self.log_level = Some(s.into());
        }
        if let Some(spec) = args.str_opt("inject") {
            // --inject rank:spec, e.g. --inject 0:sign-flip
            let (rank, rest) = spec.split_once(':').context("--inject rank:spec")?;
            self.injectors.push((
                rank.parse().context("inject rank")?,
                GradInjector::parse(rest).context("inject spec")?,
            ));
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.steps == 0 {
            bail!("steps must be >= 1");
        }
        if crate::aggregation::by_name(&self.aggregator, self.workers).is_none() {
            bail!(
                "unknown aggregator {:?} (known: {:?})",
                self.aggregator,
                crate::aggregation::ALL_NAMES
            );
        }
        for (rank, _) in &self.injectors {
            if *rank >= self.workers {
                bail!("injector rank {rank} >= workers {}", self.workers);
            }
        }
        if self.parallel.threads > 1024 {
            bail!("par_threads {} is implausible (max 1024)", self.parallel.threads);
        }
        self.topology.check_workers(self.workers)?;
        if let Some(c) = &self.cutoff {
            if c.n != self.workers {
                bail!("cutoff {}-of-{} but the run has {} workers", c.k, c.n, self.workers);
            }
            if !self.rank_threads {
                bail!("--cutoff requires --rank-threads on (the elastic exchange)");
            }
            if self.overlap {
                bail!("--cutoff requires --overlap off (elastic ingest assembles the full set)");
            }
            if !self.compression.kind.is_none() {
                // Per-rank kinds (int8/fp16/topk) encode at the rank
                // source and decode at the elastic wire edge — fine. The
                // leader-side set sketches (flat lowrank; any kind's
                // aggregator-level codec on hier topologies) hold state
                // keyed to the full rank set, which a degraded step
                // cannot honor.
                if self.topology != TopologySpec::Flat {
                    bail!("--cutoff with compression is only supported on flat topologies");
                }
                if matches!(self.compression.kind, CompressorKind::LowRank { .. }) {
                    bail!("--cutoff is incompatible with lowrank compression (leader-side set sketch)");
                }
            }
        } else if self.krum_f > 0 {
            bail!("--krum requires --cutoff (it filters on the elastic path)");
        }
        if self.krum_f >= self.workers && self.krum_f > 0 {
            bail!("krum_f {} must be < workers {}", self.krum_f, self.workers);
        }
        if self.checkpoint_every > 0 && self.checkpoint_path.is_none() {
            bail!("--checkpoint-every needs --checkpoint-path");
        }
        if self.trace_out.is_some() && self.trace_level == TraceLevel::Off {
            bail!("--trace-out needs --trace-level step|bucket|rank (nothing to export at off)");
        }
        if let Some(s) = &self.log_level {
            if crate::util::logging::Level::parse(s).is_none() {
                bail!("--log-level {s:?}: want error|warn|info|debug|trace");
            }
        }
        if !self.local_steps.is_sync() {
            // The elastic path's cutoff grace window is defined per
            // gradient arrival; a sync round delivering one fused delta
            // per rank has no per-step arrival to grant grace against,
            // and krum's pairwise-distance filter is calibrated on
            // single-step gradient geometry. Neither composition has
            // defined semantics yet — reject loudly.
            if self.cutoff.is_some() {
                bail!(
                    "--local-steps {} is incompatible with --cutoff: the straggler \
                     grace window is per-gradient-arrival, not per-sync-round; run \
                     with --local-steps 1 or drop --cutoff",
                    self.local_steps.describe()
                );
            }
            if self.krum_f > 0 {
                bail!(
                    "--local-steps {} is incompatible with --krum: outlier scores are \
                     calibrated on single-step gradient distances, not H-step deltas; \
                     run with --local-steps 1 or drop --krum",
                    self.local_steps.describe()
                );
            }
        }
        Ok(())
    }

    pub fn load_file(path: &str) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let j = Json::parse(&text).map_err(|e| crate::err!("{path}: {e}"))?;
        TrainConfig::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_and_unknown_key() {
        let j = Json::parse(
            r#"{"artifact":"mlp_cls_b32","workers":8,"aggregator":"mean",
                "schedule":"cosine:0.1:10:100","steps":50,"clip":1.0,
                "injectors":[{"rank":2,"spec":"sign-flip"}]}"#,
        )
        .unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.aggregator, "mean");
        assert_eq!(cfg.clip, Some(1.0));
        assert_eq!(cfg.injectors.len(), 1);
        let bad = Json::parse(r#"{"wat": 1}"#).unwrap();
        assert!(TrainConfig::from_json(&bad).is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = TrainConfig::default();
        let args = Args::parse(
            "--workers 16 --aggregator adasum --schedule const:0.01 --clip none --inject 3:zero"
                .split_whitespace()
                .map(String::from),
            &[],
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.workers, 16);
        assert_eq!(cfg.aggregator, "adasum");
        assert_eq!(cfg.clip, None);
        assert_eq!(cfg.injectors[0].0, 3);
    }

    #[test]
    fn parallel_knobs_from_json_and_cli() {
        let j = Json::parse(r#"{"par_threads":4,"par_min_shard_elems":8192}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.parallel.threads, 4);
        assert_eq!(cfg.parallel.min_shard_elems, 8192);
        let mut cfg = TrainConfig::default();
        assert_eq!(cfg.parallel.threads, 0); // auto
        let args = Args::parse(
            "--par-threads 2 --par-min-shard-elems 2048"
                .split_whitespace()
                .map(String::from),
            &[],
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.parallel.threads, 2);
        assert_eq!(cfg.parallel.min_shard_elems, 2048);
    }

    #[test]
    fn overlap_knob_from_json_and_cli() {
        assert!(!TrainConfig::default().overlap);
        let j = Json::parse(r#"{"overlap":"on"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).unwrap().overlap);
        let j = Json::parse(r#"{"overlap":false}"#).unwrap();
        assert!(!TrainConfig::from_json(&j).unwrap().overlap);
        let j = Json::parse(r#"{"overlap":"sideways"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let mut cfg = TrainConfig::default();
        let args = Args::parse("--overlap on".split_whitespace().map(String::from), &[]);
        cfg.apply_args(&args).unwrap();
        assert!(cfg.overlap);
        let args = Args::parse("--overlap off".split_whitespace().map(String::from), &[]);
        cfg.apply_args(&args).unwrap();
        assert!(!cfg.overlap);
    }

    #[test]
    fn rank_threads_knob_from_json_and_cli() {
        assert!(!TrainConfig::default().rank_threads);
        let j = Json::parse(r#"{"rank_threads":"on"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).unwrap().rank_threads);
        let j = Json::parse(r#"{"rank_threads":false}"#).unwrap();
        assert!(!TrainConfig::from_json(&j).unwrap().rank_threads);
        let j = Json::parse(r#"{"rank_threads":"sideways"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let mut cfg = TrainConfig::default();
        let args = Args::parse(
            "--rank-threads on".split_whitespace().map(String::from),
            &[],
        );
        cfg.apply_args(&args).unwrap();
        assert!(cfg.rank_threads);
        let args = Args::parse(
            "--rank-threads off".split_whitespace().map(String::from),
            &[],
        );
        cfg.apply_args(&args).unwrap();
        assert!(!cfg.rank_threads);
    }

    #[test]
    fn topology_knob_from_json_and_cli() {
        assert_eq!(TrainConfig::default().topology, TopologySpec::Flat);
        let j = Json::parse(r#"{"workers":8,"topology":"hier:2x4"}"#).unwrap();
        assert_eq!(
            TrainConfig::from_json(&j).unwrap().topology,
            TopologySpec::Hier { nodes: 2, gpus: 4 }
        );
        // Shape must match the worker count.
        let j = Json::parse(r#"{"workers":6,"topology":"hier:2x4"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"topology":"mesh"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let mut cfg = TrainConfig::default();
        cfg.workers = 32;
        let args = Args::parse(
            "--topology hier:8x4".split_whitespace().map(String::from),
            &[],
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.topology, TopologySpec::Hier { nodes: 8, gpus: 4 });
        let args = Args::parse("--topology flat".split_whitespace().map(String::from), &[]);
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.topology, TopologySpec::Flat);
        let args = Args::parse(
            "--topology hier:3x3".split_whitespace().map(String::from),
            &[],
        );
        assert!(cfg.apply_args(&args).is_err()); // 9 != 32
    }

    #[test]
    fn compress_knob_from_json_and_cli() {
        let dflt = TrainConfig::default();
        assert!(dflt.compression.kind.is_none());
        assert_eq!(dflt.compression.scope, CompressScope::All);
        let j = Json::parse(r#"{"compress":"topk:0.05","compress_scope":"inter"}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.compression.kind, CompressorKind::TopK { ratio: 0.05 });
        assert_eq!(cfg.compression.scope, CompressScope::Inter);
        let j = Json::parse(r#"{"compress":"zip"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"compress_scope":"intra"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let mut cfg = TrainConfig::default();
        let args = Args::parse(
            "--compress lowrank:2 --compress-scope all"
                .split_whitespace()
                .map(String::from),
            &[],
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.compression.kind, CompressorKind::LowRank { k: 2 });
        assert_eq!(cfg.compression.scope, CompressScope::All);
        let args = Args::parse("--compress int8".split_whitespace().map(String::from), &[]);
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.compression.kind, CompressorKind::Int8);
        let args = Args::parse(
            "--compress topk:0".split_whitespace().map(String::from),
            &[],
        );
        assert!(cfg.apply_args(&args).is_err());
    }

    #[test]
    fn backend_knob_from_json_and_cli() {
        assert_eq!(TrainConfig::default().backend, Backend::Auto);
        let j = Json::parse(r#"{"backend":"interp"}"#).unwrap();
        assert_eq!(TrainConfig::from_json(&j).unwrap().backend, Backend::Interp);
        let j = Json::parse(r#"{"backend":"tpu"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let mut cfg = TrainConfig::default();
        let args = Args::parse("--backend pjrt".split_whitespace().map(String::from), &[]);
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.backend, Backend::Pjrt);
    }

    #[test]
    fn cutoff_knob_parses_and_validates() {
        assert_eq!(
            CutoffSpec::parse("6-of-8"),
            Some(CutoffSpec { k: 6, n: 8, grace_ms: 0.0 })
        );
        assert_eq!(
            CutoffSpec::parse("3-of-4:250"),
            Some(CutoffSpec { k: 3, n: 4, grace_ms: 250.0 })
        );
        assert!(CutoffSpec::parse("0-of-4").is_none());
        assert!(CutoffSpec::parse("5-of-4").is_none());
        assert!(CutoffSpec::parse("3of4").is_none());
        assert!(CutoffSpec::parse("3-of-4:x").is_none());
        // Elastic stepping needs rank threads without overlap, and the
        // quorum's n must match the worker count.
        let j = Json::parse(r#"{"workers":4,"rank_threads":"on","cutoff":"3-of-4:100"}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.cutoff, Some(CutoffSpec { k: 3, n: 4, grace_ms: 100.0 }));
        let j = Json::parse(r#"{"workers":4,"cutoff":"3-of-4"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err()); // rank_threads off
        let j = Json::parse(r#"{"workers":8,"rank_threads":"on","cutoff":"3-of-4"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err()); // n mismatch
        let j = Json::parse(
            r#"{"workers":4,"rank_threads":"on","overlap":"on","cutoff":"3-of-4"}"#,
        )
        .unwrap();
        assert!(TrainConfig::from_json(&j).is_err()); // overlap on
        let j = Json::parse(
            r#"{"workers":4,"rank_threads":"on","cutoff":"3-of-4","compress":"lowrank:2"}"#,
        )
        .unwrap();
        assert!(TrainConfig::from_json(&j).is_err()); // flat lowrank
        let j = Json::parse(r#"{"workers":4,"krum_f":1}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err()); // krum without cutoff
        let mut cfg = TrainConfig::default();
        cfg.rank_threads = true;
        let args = Args::parse(
            "--cutoff 3-of-4:50 --krum 1".split_whitespace().map(String::from),
            &[],
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.cutoff, Some(CutoffSpec { k: 3, n: 4, grace_ms: 50.0 }));
        assert_eq!(cfg.krum_f, 1);
        let args = Args::parse("--cutoff none".split_whitespace().map(String::from), &[]);
        assert!(cfg.apply_args(&args).is_err()); // krum survives, cutoff gone
    }

    #[test]
    fn checkpoint_knobs_validate() {
        let j = Json::parse(r#"{"checkpoint_every":5}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err()); // no path
        let j =
            Json::parse(r#"{"checkpoint_every":5,"checkpoint_path":"/tmp/ck.bin"}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.checkpoint_every, 5);
        assert_eq!(cfg.checkpoint_path.as_deref(), Some("/tmp/ck.bin"));
        let mut cfg = TrainConfig::default();
        let args = Args::parse(
            "--checkpoint-every 10 --checkpoint-path /tmp/x.ckpt"
                .split_whitespace()
                .map(String::from),
            &[],
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.checkpoint_every, 10);
    }

    #[test]
    fn local_steps_knob_from_json_and_cli() {
        assert_eq!(TrainConfig::default().local_steps, LocalStepSpec::Fixed(1));
        assert!(TrainConfig::default().local_steps.is_sync());
        // JSON accepts a bare number or the auto:<min>-<max> string.
        let j = Json::parse(r#"{"local_steps":4}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.local_steps, LocalStepSpec::Fixed(4));
        assert!(!cfg.local_steps.is_sync());
        let j = Json::parse(r#"{"local_steps":"auto:2-16"}"#).unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.local_steps, LocalStepSpec::Auto { min: 2, max: 16 });
        assert_eq!(cfg.local_steps.initial(), 2);
        assert_eq!(cfg.local_steps.describe(), "auto:2-16");
        let j = Json::parse(r#"{"local_steps":0}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"local_steps":"auto:8-2"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err()); // min > max
        let j = Json::parse(r#"{"local_steps":"auto:0-4"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err()); // min < 1
        let mut cfg = TrainConfig::default();
        let args = Args::parse(
            "--local-steps 8".split_whitespace().map(String::from),
            &[],
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.local_steps, LocalStepSpec::Fixed(8));
        let args = Args::parse(
            "--local-steps auto:1-32".split_whitespace().map(String::from),
            &[],
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.local_steps, LocalStepSpec::Auto { min: 1, max: 32 });
        let args = Args::parse(
            "--local-steps zero".split_whitespace().map(String::from),
            &[],
        );
        assert!(cfg.apply_args(&args).is_err());
    }

    #[test]
    fn local_steps_fences_unsupported_compositions() {
        // local-steps > 1 has no defined cutoff/krum semantics — the
        // fences must fire with actionable messages, and H=1 (the
        // synchronous regime) must keep composing with both.
        let j = Json::parse(
            r#"{"workers":4,"rank_threads":"on","cutoff":"3-of-4","local_steps":4}"#,
        )
        .unwrap();
        let e = TrainConfig::from_json(&j).unwrap_err().to_string();
        assert!(e.contains("--cutoff"), "{e}");
        let j = Json::parse(
            r#"{"workers":4,"rank_threads":"on","cutoff":"3-of-4","krum_f":1,
                "local_steps":"auto:2-8"}"#,
        )
        .unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let j = Json::parse(
            r#"{"workers":4,"rank_threads":"on","cutoff":"3-of-4","local_steps":1}"#,
        )
        .unwrap();
        TrainConfig::from_json(&j).unwrap(); // H=1 composes fine
    }

    #[test]
    fn observability_knobs_from_json_and_cli() {
        let dflt = TrainConfig::default();
        assert_eq!(dflt.trace_level, TraceLevel::Off);
        assert!(dflt.trace_out.is_none());
        assert!(dflt.metrics_out.is_none());
        assert!(dflt.log_level.is_none());
        let j = Json::parse(
            r#"{"trace_level":"bucket","trace_out":"/tmp/t.json",
                "metrics_out":"/tmp/m.txt","log_level":"debug"}"#,
        )
        .unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.trace_level, TraceLevel::Bucket);
        assert_eq!(cfg.trace_out.as_deref(), Some("/tmp/t.json"));
        assert_eq!(cfg.metrics_out.as_deref(), Some("/tmp/m.txt"));
        assert_eq!(cfg.log_level.as_deref(), Some("debug"));
        // trace_out without tracing enabled is a silent no-op trap — reject.
        let j = Json::parse(r#"{"trace_out":"/tmp/t.json"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"trace_level":"verbose"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"log_level":"loud"}"#).unwrap();
        assert!(TrainConfig::from_json(&j).is_err());
        // metrics_out stands alone: the registry is always populated.
        let j = Json::parse(r#"{"metrics_out":"/tmp/m.txt"}"#).unwrap();
        TrainConfig::from_json(&j).unwrap();
        let mut cfg = TrainConfig::default();
        let args = Args::parse(
            "--trace-level rank --trace-out /tmp/t2.json --metrics-out /tmp/m2.txt \
             --log-level warn"
                .split_whitespace()
                .map(String::from),
            &[],
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.trace_level, TraceLevel::Rank);
        assert_eq!(cfg.trace_out.as_deref(), Some("/tmp/t2.json"));
        assert_eq!(cfg.metrics_out.as_deref(), Some("/tmp/m2.txt"));
        assert_eq!(cfg.log_level.as_deref(), Some("warn"));
        let mut cfg = TrainConfig::default();
        let args = Args::parse(
            "--trace-out /tmp/t.json".split_whitespace().map(String::from),
            &[],
        );
        assert!(cfg.apply_args(&args).is_err()); // level still off
    }

    #[test]
    fn validation_rejects_bad_values() {
        let mut cfg = TrainConfig::default();
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.aggregator = "nope".into();
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::default();
        cfg.injectors.push((99, GradInjector::None));
        assert!(cfg.validate().is_err());
    }
}
