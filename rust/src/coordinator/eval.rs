//! Held-out evaluation: runs the model's eval artifact on a dedicated
//! shard (a rank id no trainer worker uses) and reduces the outputs to the
//! task's paper metric.

use std::sync::Arc;

use crate::util::error::{Context, Result};

use crate::data::{Array, DataGen};
use crate::metrics;
use crate::runtime::{Executable, Runtime};

/// Rank id reserved for the evaluation stream.
pub const EVAL_RANK: u64 = 1 << 40;

#[derive(Debug, Clone, PartialEq)]
pub struct EvalOutcome {
    pub loss: f64,
    /// The task metric (accuracy / AUC / mAP-proxy / loss).
    pub metric: f64,
    pub metric_name: &'static str,
}

pub struct Evaluator {
    exe: Arc<Executable>,
    gen: Box<dyn DataGen>,
    model: String,
    batches: usize,
}

impl Evaluator {
    /// Build the evaluator for a train artifact, if it has an eval twin.
    pub fn for_artifact(
        rt: &Runtime,
        train_artifact: &str,
        eval_artifact: Option<&str>,
        seed: u64,
        batches: usize,
    ) -> Result<Option<Evaluator>> {
        let name = match eval_artifact {
            Some(n) => n.to_string(),
            None => format!("{train_artifact}__eval"),
        };
        if rt.manifest.get(&name).is_err() {
            return Ok(None);
        }
        let exe = rt.load(&name)?;
        let model = exe.spec.model.clone();
        let gen = crate::data::for_model(&model, seed, EVAL_RANK, 0.0, &exe.spec.meta)
            .with_context(|| format!("no data generator for model {model}"))?;
        Ok(Some(Evaluator {
            exe,
            gen,
            model,
            batches,
        }))
    }

    pub fn metric_name(&self) -> &'static str {
        match self.model.as_str() {
            "mlp_cls" => "accuracy",
            "dlrm" => "auc",
            "det" => "map_proxy",
            _ => "loss",
        }
    }

    /// Evaluate `params`, pooling `self.batches` held-out batches.
    pub fn evaluate(&mut self, params: &[f32]) -> Result<EvalOutcome> {
        let b = self.exe.spec.local_batch();
        let mut losses = Vec::new();
        let mut pooled_correct = Vec::new();
        let mut pooled_scores = Vec::new();
        let mut pooled_labels = Vec::new();
        let mut pooled_maxprob = Vec::new();
        let mut pooled_clscorrect = Vec::new();
        let mut pooled_boxl1 = Vec::new();
        for _ in 0..self.batches {
            let batch = self.gen.next_batch(b);
            let outs = self.exe.run(Some(params), &batch)?;
            let loss = outs[0]
                .as_f32()
                .and_then(|v| v.first().copied())
                .context("eval output 0 must be loss")? as f64;
            losses.push(loss);
            match self.model.as_str() {
                "mlp_cls" => {
                    pooled_correct.extend_from_slice(outs[1].as_f32().context("correct")?);
                }
                "dlrm" => {
                    pooled_scores.extend_from_slice(outs[1].as_f32().context("score")?);
                    // labels are the third batch array
                    pooled_labels.extend_from_slice(batch[2].as_f32().context("y")?);
                }
                "det" => {
                    let probs = outs[1].as_f32().context("probs")?;
                    let box_l1 = outs[2].as_f32().context("box_l1")?;
                    let labels = batch[1].as_i32().context("y")?;
                    let c = self.exe.spec.outputs[1].shape[1];
                    for i in 0..b {
                        let row = &probs[i * c..(i + 1) * c];
                        let (argmax, &maxp) = row
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .unwrap();
                        pooled_maxprob.push(maxp);
                        pooled_clscorrect
                            .push(if argmax as i32 == labels[i] { 1.0 } else { 0.0 });
                        pooled_boxl1.push(box_l1[i]);
                    }
                }
                _ => {}
            }
        }
        let loss = crate::util::stats::mean(&losses);
        let (metric, metric_name) = match self.model.as_str() {
            "mlp_cls" => (metrics::accuracy(&pooled_correct), "accuracy"),
            "dlrm" => (
                metrics::auc_from_scores(&pooled_scores, &pooled_labels),
                "auc",
            ),
            "det" => (
                metrics::map_proxy(&pooled_maxprob, &pooled_clscorrect, &pooled_boxl1, 0.5),
                "map_proxy",
            ),
            _ => (loss, "loss"),
        };
        Ok(EvalOutcome {
            loss,
            metric,
            metric_name,
        })
    }
}

// Silence an unused-import warning path for Array in non-test builds.
#[allow(unused)]
fn _keep(_a: Array) {}
