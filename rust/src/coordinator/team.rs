//! Persistent rank-thread team: real N-thread training.
//!
//! A [`RankTeam`] spawns one OS thread per rank **once** (Trainer
//! construction), runs every step on those threads, and joins them on
//! drop. Each rank thread owns its [`Worker`] (data stream + injector
//! state) and its own [`Executable`] instance
//! ([`Runtime::load_owned`] — interpreter programs are plain data, so
//! per-rank ownership is cheap and `Send`), computes its backward pass
//! locally, and streams gradient buckets to the leader over its
//! [`RankPort`] the moment the backward finalizes them. The leader drives
//! aggregation with [`PipelinedExecutor::run_step_exchange`], ingesting
//! buckets in true arrival order.
//!
//! Step protocol: the leader broadcasts the step's parameters over
//! per-rank command channels ([`RankTeam::begin_step`]); each rank
//! computes, submits its buckets plus a `Done { loss, compute_s }`
//! (compute measured **on the rank thread**, feeding the `SimClock`), and
//! blocks on the next command. A rank can therefore never run ahead into
//! step *s+1* before the leader has fully drained step *s*, so steps
//! never interleave on the wire. Failure is never a hang: a panicking
//! rank thread's port reports it down (the leader's ingest errors with
//! the rank id), a compute error is reported explicitly, and dropping the
//! team closes the command channels so every thread exits and is joined.
//!
//! [`PipelinedExecutor::run_step_exchange`]:
//! crate::coordinator::pipeline::PipelinedExecutor::run_step_exchange

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::collective::NodeMap;
use crate::comm::{RankPort, StepExchange};
use crate::compress::{CompressorKind, RankCodec};
use crate::obs::{Domain, Obs, SpanEvent, SpanKind, TraceLevel};
use crate::parallel::ParallelCtx;
use crate::runtime::{Executable, Runtime};
use crate::tensor::Buckets;
use crate::util::error::{ensure, Context, Result};
use crate::worker::Worker;

/// One leader-to-rank command.
enum TeamCmd {
    /// Run one step against these parameters. `step` keys the rank's
    /// compression PRNG so stochastic rounding is reproducible at any
    /// thread interleaving. `local_lrs` selects the execution regime:
    /// `None` is the historical synchronous single-gradient step (live
    /// bucket streaming); `Some(lrs)` runs a local-step sync round of
    /// `lrs.len()` plain-SGD passes (pass `p` at `lrs[p]` — the rank
    /// threads hold no schedule, so the leader ships the resolved rates)
    /// and streams the round's accumulated delta buckets instead.
    Step {
        params: Arc<Vec<f32>>,
        step: u64,
        local_lrs: Option<Arc<Vec<f32>>>,
    },
    /// Drop compression error-feedback residuals (parameter
    /// re-broadcast from a checkpoint).
    Reset,
    /// Send back this rank's per-bucket error-feedback residuals
    /// (checkpoint capture).
    ExportResiduals(Sender<Vec<Vec<f32>>>),
    /// Replace this rank's error-feedback residuals (checkpoint restore).
    ImportResiduals(Vec<Vec<f32>>),
    /// Replay `steps` steps' worth of RNG draws on the rank's worker
    /// without computing (checkpoint resume: the data stream and
    /// injector state must sit exactly where the original run left
    /// them).
    FastForward {
        steps: u64,
        local_batch: usize,
        d: usize,
    },
}

/// Everything an elastic team must remember to rebuild one rank thread
/// after a death (the spawn inputs `RankTeam::spawn` otherwise discards).
#[derive(Clone)]
struct ElasticCfg {
    artifact: String,
    buckets: Buckets,
    local_batch: usize,
    par: ParallelCtx,
    compress: Option<(CompressorKind, u64)>,
    obs: Arc<Obs>,
}

/// N persistent rank threads plus the leader's exchange half.
pub struct RankTeam {
    exchange: StepExchange,
    cmds: Vec<Sender<TeamCmd>>,
    handles: Vec<JoinHandle<()>>,
    /// `Some` on elastic teams ([`RankTeam::spawn_elastic`]): the spawn
    /// inputs retained so [`RankTeam::respawn`] can rebuild a rank.
    elastic: Option<ElasticCfg>,
}

impl RankTeam {
    /// Spawn one thread per worker. Each rank gets its own `Executable`
    /// for `artifact` (interp backend; `load_owned` refuses PJRT with
    /// guidance). Threads idle on their command channel until
    /// [`RankTeam::begin_step`] and exit when the team is dropped.
    ///
    /// With `map`, rank threads are grouped per node on a grouped
    /// exchange (thread names carry the node id, ports know their group,
    /// and the leader can ingest node-level buckets) — the deployment
    /// shape of the hierarchical two-level aggregation path.
    ///
    /// Every rank thread gets a clone of `par` (sharing one worker pool),
    /// so intra-rank kernel sharding composes with rank threading; the
    /// kernels are bitwise invariant to the pool width, so any `par`
    /// (including [`ParallelCtx::serial`]) yields identical training.
    ///
    /// With `compress = Some((kind, seed))` each rank thread owns a
    /// [`RankCodec`] and ships **encoded** bucket payloads (int8 / fp16 /
    /// top-k with per-bucket error feedback); the leader's wire edge
    /// decodes them before aggregation. `None` ships raw columns —
    /// bitwise-identical to the uncompressed path.
    ///
    /// `obs` is the shared observability handle each rank thread records
    /// wall-domain compute/encode spans into (pass [`Obs::disabled`]
    /// when no tracing is wanted — recording is level-gated and the
    /// training output is bitwise-identical either way).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        rt: &Runtime,
        artifact: &str,
        workers: Vec<Worker>,
        buckets: &Buckets,
        local_batch: usize,
        par: &ParallelCtx,
        map: Option<&NodeMap>,
        compress: Option<(CompressorKind, u64)>,
        obs: Arc<Obs>,
    ) -> Result<RankTeam> {
        Self::spawn_inner(
            rt, artifact, workers, buckets, local_batch, par, map, compress, obs, false,
        )
    }

    /// Like [`RankTeam::spawn`], but on an elastic exchange: a rank that
    /// dies mid-step can be rebuilt in place with [`RankTeam::respawn`]
    /// (the spawn inputs are retained). The fault-tolerant training path
    /// (`--cutoff`) runs on this.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_elastic(
        rt: &Runtime,
        artifact: &str,
        workers: Vec<Worker>,
        buckets: &Buckets,
        local_batch: usize,
        par: &ParallelCtx,
        map: Option<&NodeMap>,
        compress: Option<(CompressorKind, u64)>,
        obs: Arc<Obs>,
    ) -> Result<RankTeam> {
        Self::spawn_inner(
            rt, artifact, workers, buckets, local_batch, par, map, compress, obs, true,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn spawn_inner(
        rt: &Runtime,
        artifact: &str,
        workers: Vec<Worker>,
        buckets: &Buckets,
        local_batch: usize,
        par: &ParallelCtx,
        map: Option<&NodeMap>,
        compress: Option<(CompressorKind, u64)>,
        obs: Arc<Obs>,
        elastic: bool,
    ) -> Result<RankTeam> {
        let n = workers.len();
        if let Some(m) = map {
            ensure!(
                m.n_ranks() == n,
                "node map covers {} ranks but the team has {n} workers",
                m.n_ranks()
            );
        }
        let (exchange, ports) = if elastic {
            StepExchange::new_elastic(n, map)
        } else {
            match map {
                Some(m) => StepExchange::new_grouped(m),
                None => StepExchange::new(n),
            }
        };
        let mut cmds = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (worker, port) in workers.into_iter().zip(ports) {
            let rank = worker.rank;
            assert_eq!(
                rank,
                port.rank(),
                "workers must be passed in rank order (worker {rank} vs port {})",
                port.rank()
            );
            let (tx, h) = spawn_rank(
                rt,
                artifact,
                worker,
                port,
                buckets,
                local_batch,
                par,
                compress,
                obs.clone(),
            )?;
            cmds.push(tx);
            handles.push(h);
        }
        Ok(RankTeam {
            exchange,
            cmds,
            handles,
            elastic: elastic.then(|| ElasticCfg {
                artifact: artifact.to_string(),
                buckets: buckets.clone(),
                local_batch,
                par: par.clone(),
                compress,
                obs,
            }),
        })
    }

    /// Rebuild one dead rank's thread on an elastic team: mint a fresh
    /// port, spawn a new thread around `worker` (typically a fresh
    /// [`Worker`] fast-forwarded past the completed steps), and join the
    /// old thread's corpse. The new rank's codec starts with zero
    /// error-feedback residuals — its old error state died with it, which
    /// is exactly the semantics of a re-provisioned machine.
    pub fn respawn(&mut self, rt: &Runtime, worker: Worker) -> Result<()> {
        let rank = worker.rank;
        let cfg = self
            .elastic
            .clone()
            .ok_or_else(|| crate::err!("respawn needs an elastic team"))?;
        ensure!(rank < self.cmds.len(), "respawn: unknown rank {rank}");
        let port = self.exchange.respawn_port(rank)?;
        let (tx, h) = spawn_rank(
            rt,
            &cfg.artifact,
            worker,
            port,
            &cfg.buckets,
            cfg.local_batch,
            &cfg.par,
            cfg.compress,
            cfg.obs,
        )?;
        self.cmds[rank] = tx;
        let old = std::mem::replace(&mut self.handles[rank], h);
        // The dead thread already exited (or is unwinding); join its
        // corpse so it is not orphaned until team drop.
        let _ = old.join();
        Ok(())
    }

    pub fn n(&self) -> usize {
        self.cmds.len()
    }

    /// The leader half the pipelined executor ingests from.
    pub fn exchange(&self) -> &StepExchange {
        &self.exchange
    }

    /// Broadcast this step's parameters; every rank thread starts its
    /// backward immediately. `step` keys the compression PRNG (ignored
    /// by uncompressed codecs). Errors if a rank thread is already gone
    /// (its death reason surfaced, or will, on the exchange).
    pub fn begin_step(&self, params: &Arc<Vec<f32>>, step: u64) -> Result<()> {
        self.begin_round(params, step, None)
    }

    /// Broadcast one sync round: `local_lrs = None` is a synchronous
    /// single-gradient step (identical to [`RankTeam::begin_step`]);
    /// `Some(lrs)` has every rank run `lrs.len()` local SGD passes and
    /// stream the round's delta buckets. `step` is the round's first
    /// *local* step index (it keys the compression PRNG).
    pub fn begin_round(
        &self,
        params: &Arc<Vec<f32>>,
        step: u64,
        local_lrs: Option<Arc<Vec<f32>>>,
    ) -> Result<()> {
        for (rank, tx) in self.cmds.iter().enumerate() {
            tx.send(TeamCmd::Step {
                params: params.clone(),
                step,
                local_lrs: local_lrs.clone(),
            })
            .map_err(|_| crate::err!("rank {rank}'s thread is gone (exited or panicked)"))?;
        }
        Ok(())
    }

    /// Tell every rank thread to drop its compression error-feedback
    /// residuals — required when parameters are re-broadcast from a
    /// checkpoint, since the residual refers to the abandoned iterate.
    pub fn reset_codecs(&self) -> Result<()> {
        for (rank, tx) in self.cmds.iter().enumerate() {
            tx.send(TeamCmd::Reset)
                .map_err(|_| crate::err!("rank {rank}'s thread is gone (exited or panicked)"))?;
        }
        Ok(())
    }

    /// Collect every rank's per-bucket error-feedback residuals (rank ->
    /// bucket -> residual columns) for checkpoint capture. Uncompressed
    /// codecs report empty residual vectors.
    pub fn export_residuals(&self) -> Result<Vec<Vec<Vec<f32>>>> {
        let mut out = Vec::with_capacity(self.cmds.len());
        for (rank, tx) in self.cmds.iter().enumerate() {
            let (rtx, rrx) = channel();
            tx.send(TeamCmd::ExportResiduals(rtx))
                .map_err(|_| crate::err!("rank {rank}'s thread is gone (exited or panicked)"))?;
            out.push(
                rrx.recv()
                    .map_err(|_| crate::err!("rank {rank} died exporting residuals"))?,
            );
        }
        Ok(out)
    }

    /// Fast-forward every rank's worker past `steps` completed steps
    /// (checkpoint resume): replays each worker's per-step RNG draw
    /// sequence so the continuation samples the exact batches and
    /// injector draws the uninterrupted run would have.
    pub fn fast_forward(&self, steps: u64, local_batch: usize, d: usize) -> Result<()> {
        for (rank, tx) in self.cmds.iter().enumerate() {
            tx.send(TeamCmd::FastForward {
                steps,
                local_batch,
                d,
            })
            .map_err(|_| crate::err!("rank {rank}'s thread is gone (exited or panicked)"))?;
        }
        Ok(())
    }

    /// Restore every rank's error-feedback residuals from a checkpoint
    /// (shape-mismatched entries are ignored by the codec).
    pub fn import_residuals(&self, residuals: Vec<Vec<Vec<f32>>>) -> Result<()> {
        ensure!(
            residuals.len() == self.cmds.len(),
            "residual sets for {} ranks but the team has {}",
            residuals.len(),
            self.cmds.len()
        );
        for ((rank, tx), r) in self.cmds.iter().enumerate().zip(residuals) {
            tx.send(TeamCmd::ImportResiduals(r))
                .map_err(|_| crate::err!("rank {rank}'s thread is gone (exited or panicked)"))?;
        }
        Ok(())
    }
}

/// Build one rank thread: its own executable, codec, command channel.
#[allow(clippy::too_many_arguments)]
fn spawn_rank(
    rt: &Runtime,
    artifact: &str,
    worker: Worker,
    port: RankPort,
    buckets: &Buckets,
    local_batch: usize,
    par: &ParallelCtx,
    compress: Option<(CompressorKind, u64)>,
    obs: Arc<Obs>,
) -> Result<(Sender<TeamCmd>, JoinHandle<()>)> {
    let rank = worker.rank;
    let exe = rt
        .load_owned(artifact)
        .with_context(|| format!("building rank {rank}'s executable"))?;
    let (tx, rx) = channel();
    let bk = buckets.clone();
    let rank_par = par.clone();
    let name = match port.node() {
        0 => format!("rank-{rank}"),
        node => format!("node{node}-rank{rank}"),
    };
    let codec = match compress {
        Some((kind, seed)) => RankCodec::new(kind, seed, rank, buckets.len()),
        None => RankCodec::new(CompressorKind::None, 0, rank, buckets.len()),
    };
    let h = std::thread::Builder::new()
        .name(name)
        .spawn(move || rank_main(worker, exe, port, bk, local_batch, rank_par, codec, obs, rx))
        .with_context(|| format!("spawning rank {rank} thread"))?;
    Ok((tx, h))
}

impl Drop for RankTeam {
    fn drop(&mut self) {
        // Closing the command channels is the shutdown signal; every
        // healthy thread's recv errors and it exits cleanly. Panicked
        // threads already died (and reported Down) — ignore their join
        // payloads, the step that observed the death surfaced the error.
        self.cmds.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Body of one rank thread: wait for a step command, run the backward,
/// stream buckets live (encoded through the rank's codec — `Raw`
/// passthrough when compression is off), report completion; repeat
/// until shutdown.
#[allow(clippy::too_many_arguments)]
fn rank_main(
    mut worker: Worker,
    exe: Executable,
    port: RankPort,
    buckets: Buckets,
    local_batch: usize,
    par: ParallelCtx,
    mut codec: RankCodec,
    obs: Arc<Obs>,
    rx: Receiver<TeamCmd>,
) {
    let rank = port.rank();
    crate::util::logging::set_rank_context(Some(rank));
    loop {
        match rx.recv() {
            Ok(TeamCmd::Step {
                params,
                step,
                local_lrs,
            }) => {
                let codec = &mut codec;
                // Wall-domain rank spans batch locally and flush in one
                // lock per step; level-gated so the untraced path takes
                // no timestamps and allocates nothing.
                let tracer = &obs.trace;
                let rank_tr = tracer.enabled(TraceLevel::Rank);
                let t0 = if rank_tr { tracer.now_s() } else { 0.0 };
                let mut spans: Vec<SpanEvent> = Vec::new();
                // Compressed payloads charge their measured encode
                // wall-time to the rank's timeline: each bucket reads as
                // ready only after the encode work spent up to and
                // including it (the transfer cannot start earlier).
                // Uncompressed runs skip the timing entirely, keeping
                // the historical path untouched.
                let timed = !codec.kind().is_none();
                let enc_tr = timed && tracer.enabled(TraceLevel::Bucket);
                let mut encode_s = 0.0f64;
                let mut encode_ready = vec![0.0f64; buckets.len()];
                let mut deliver = |port: &RankPort, b: usize, cols: &[f32]| {
                    if timed {
                        let enc_t0 = if enc_tr { tracer.now_s() } else { 0.0 };
                        let t = crate::util::timer::Timer::start();
                        let payload = codec.encode_bucket(step, b, cols);
                        let dt = t.elapsed_s();
                        encode_s += dt;
                        encode_ready[b] = encode_s;
                        if enc_tr {
                            spans.push(
                                SpanEvent::new(SpanKind::Encode, Domain::Wall, step, enc_t0, dt)
                                    .rank(rank)
                                    .bucket(b),
                            );
                        }
                        port.submit_payload(b, payload);
                    } else {
                        port.submit_payload(b, codec.encode_bucket(step, b, cols));
                    }
                };
                let r = match &local_lrs {
                    // Synchronous regime: live per-bucket streaming off
                    // the backward — the H=1 bitwise anchor.
                    None => worker.compute_grad_buckets(
                        &exe,
                        &params,
                        local_batch,
                        &buckets,
                        &par,
                        &mut |b, cols| deliver(&port, b, cols),
                    ),
                    // Local-step round: lrs.len() local passes, then the
                    // accumulated delta streams bucket by bucket.
                    Some(lrs) => worker.compute_delta_round(
                        &exe,
                        &params,
                        local_batch,
                        &buckets,
                        &par,
                        lrs,
                        &mut |b, cols| deliver(&port, b, cols),
                    ),
                };
                match r {
                    Ok(()) => {
                        if rank_tr {
                            spans.push(
                                SpanEvent::new(
                                    SpanKind::RankCompute,
                                    Domain::Wall,
                                    step,
                                    t0,
                                    tracer.now_s() - t0,
                                )
                                .rank(rank),
                            );
                        }
                        if !spans.is_empty() {
                            tracer.record_batch(std::mem::take(&mut spans));
                        }
                        if timed {
                            obs.metrics.add_f("rank_encode_s", encode_s);
                        }
                        let mut bucket_s = worker.last_bucket_s().to_vec();
                        if timed {
                            for (s, e) in bucket_s.iter_mut().zip(&encode_ready) {
                                *s += e;
                            }
                        }
                        port.done_timed(
                            worker.last_loss as f64,
                            worker.last_compute_s + encode_s,
                            bucket_s,
                        )
                    }
                    Err(e) => {
                        // Explicit failure beats the guard's generic reason.
                        port.report_down(&format!("compute failed: {e}"));
                        return;
                    }
                }
            }
            Ok(TeamCmd::Reset) => codec.reset(),
            Ok(TeamCmd::ExportResiduals(tx)) => {
                let _ = tx.send(codec.export_residuals());
            }
            Ok(TeamCmd::ImportResiduals(r)) => codec.import_residuals(r),
            Ok(TeamCmd::FastForward {
                steps,
                local_batch,
                d,
            }) => worker.fast_forward(steps, local_batch, d),
            Err(_) => break,
        }
    }
    port.complete();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::GradInjector;
    use crate::runtime::Backend;

    fn interp_runtime() -> Runtime {
        let dir = std::env::temp_dir().join("adacons_team_test");
        Runtime::create_with(dir, Backend::Interp).unwrap()
    }

    fn mk_workers(rt: &Runtime, artifact: &str, n: usize) -> Vec<Worker> {
        let spec = rt.manifest.get(artifact).unwrap();
        (0..n)
            .map(|rank| {
                let gen =
                    crate::data::for_model(&spec.model, 7, rank as u64, 0.0, &spec.meta).unwrap();
                Worker::new(rank, gen, GradInjector::None, 7)
            })
            .collect()
    }

    #[test]
    fn team_streams_identical_grads_to_roundrobin() {
        // One step, same seeds: the bucket matrix assembled from N rank
        // threads must be bitwise what the round-robin loop computes.
        let rt = interp_runtime();
        let artifact = "linreg_b16";
        let exe = rt.load(artifact).unwrap();
        let d = exe.spec.param_dim;
        let local_batch = exe.spec.local_batch();
        let params = Arc::new(exe.spec.load_init(0).unwrap());
        let buckets = Buckets::fixed(d, 129); // ragged tail
        // Round-robin reference rows.
        let mut reference = vec![vec![0.0f32; d]; 3];
        let serial = ParallelCtx::serial();
        for (rank, worker) in mk_workers(&rt, artifact, 3).iter_mut().enumerate() {
            worker
                .compute_grad_buckets(&exe, &params, local_batch, &buckets, &serial, &mut |b, cols| {
                    let (lo, hi) = buckets.range(b);
                    reference[rank][lo..hi].copy_from_slice(cols);
                })
                .unwrap();
        }
        // Threaded team, same worker seeds; a real shared pool must not
        // change a single bit relative to the serial reference rows.
        let par = ParallelCtx::new(crate::parallel::ParallelPolicy {
            threads: 2,
            min_shard_elems: 256,
        });
        let team = RankTeam::spawn(
            &rt,
            artifact,
            mk_workers(&rt, artifact, 3),
            &buckets,
            local_batch,
            &par,
            None,
            None,
            Obs::disabled(),
        )
        .unwrap();
        team.begin_step(&params, 0).unwrap();
        let mut rows = vec![vec![0.0f32; d]; 3];
        let reports = team
            .exchange()
            .leader_ingest(&buckets, true, &mut |rank, b, cols| {
                let (lo, hi) = buckets.range(b);
                rows[rank][lo..hi].copy_from_slice(&cols);
            })
            .unwrap();
        assert_eq!(rows, reference);
        assert!(reports.iter().all(|r| r.loss.is_finite() && r.compute_s >= 0.0));
    }

    #[test]
    fn dropping_the_team_joins_all_threads() {
        let rt = interp_runtime();
        let artifact = "linreg_b16";
        let exe = rt.load(artifact).unwrap();
        let buckets = Buckets::single(exe.spec.param_dim);
        let team = RankTeam::spawn(
            &rt,
            artifact,
            mk_workers(&rt, artifact, 4),
            &buckets,
            exe.spec.local_batch(),
            &ParallelCtx::serial(),
            None,
            None,
            Obs::disabled(),
        )
        .unwrap();
        assert_eq!(team.n(), 4);
        drop(team); // must not hang
    }

    #[test]
    fn grouped_team_reports_observed_bucket_readiness() {
        // A node-grouped team runs on a grouped exchange and every Done
        // report carries monotone per-bucket completion offsets bounded
        // by the rank's compute time.
        let rt = interp_runtime();
        let artifact = "linreg_b16";
        let exe = rt.load(artifact).unwrap();
        let d = exe.spec.param_dim;
        let buckets = Buckets::fixed(d, 300);
        let map = NodeMap::even(2, 2);
        let team = RankTeam::spawn(
            &rt,
            artifact,
            mk_workers(&rt, artifact, 4),
            &buckets,
            exe.spec.local_batch(),
            &ParallelCtx::serial(),
            Some(&map),
            None,
            Obs::disabled(),
        )
        .unwrap();
        assert_eq!(team.exchange().map(), Some(&map));
        let params = Arc::new(exe.spec.load_init(0).unwrap());
        team.begin_step(&params, 0).unwrap();
        let mut node_done = 0usize;
        let reports = team
            .exchange()
            .leader_ingest_nodes(&buckets, true, &mut |_, _, _| {}, &mut |_, _| {
                node_done += 1;
            })
            .unwrap();
        assert_eq!(node_done, map.groups() * buckets.len());
        for r in &reports {
            assert_eq!(r.bucket_s.len(), buckets.len());
            for w in r.bucket_s.windows(2) {
                // linreg streams one segment: offsets are monotone
                // non-decreasing in bucket order regardless.
                assert!(w[0] <= w[1] + 1e-12);
            }
            assert!(r.bucket_s.iter().all(|&s| s >= 0.0 && s <= r.compute_s + 1e-9));
        }
    }

    #[test]
    fn elastic_team_respawns_a_dead_rank() {
        // Rank 1 carries `panic-at:0`: its compute errors on the first
        // step, the elastic ingest completes from the survivors, and a
        // fresh fast-forwarded worker rejoins for a full-strength step.
        let rt = interp_runtime();
        let artifact = "linreg_b16";
        let exe = rt.load(artifact).unwrap();
        let d = exe.spec.param_dim;
        let local_batch = exe.spec.local_batch();
        let buckets = Buckets::fixed(d, 300);
        let spec = rt.manifest.get(artifact).unwrap();
        let mut workers = mk_workers(&rt, artifact, 3);
        workers[1] = Worker::new(
            1,
            crate::data::for_model(&spec.model, 7, 1, 0.0, &spec.meta).unwrap(),
            GradInjector::parse("panic-at:0").unwrap(),
            7,
        );
        let mut team = RankTeam::spawn_elastic(
            &rt,
            artifact,
            workers,
            &buckets,
            local_batch,
            &ParallelCtx::serial(),
            None,
            None,
            Obs::disabled(),
        )
        .unwrap();
        let params = Arc::new(exe.spec.load_init(0).unwrap());
        team.begin_step(&params, 0).unwrap();
        let rep = team
            .exchange()
            .leader_ingest_elastic(&buckets, 1, &mut |_, _, _| {})
            .unwrap();
        assert_eq!(rep.live(), 2);
        assert_eq!(rep.dead.len(), 1);
        assert_eq!(rep.dead[0].0, 1);
        assert!(rep.dead[0].1.contains("injected panic"), "{}", rep.dead[0].1);
        // Rejoin: fresh healthy worker, fast-forwarded past step 0.
        let gen = crate::data::for_model(&spec.model, 7, 1, 0.0, &spec.meta).unwrap();
        let mut w = Worker::new(1, gen, GradInjector::None, 7);
        w.fast_forward(1, local_batch, d);
        team.respawn(&rt, w).unwrap();
        team.begin_step(&params, 1).unwrap();
        let rep = team
            .exchange()
            .leader_ingest_elastic(&buckets, 3, &mut |_, _, _| {})
            .unwrap();
        assert_eq!(rep.live(), 3);
        assert!(rep.dead.is_empty());
    }

    #[test]
    fn respawn_rejects_non_elastic_team() {
        let rt = interp_runtime();
        let artifact = "linreg_b16";
        let exe = rt.load(artifact).unwrap();
        let buckets = Buckets::single(exe.spec.param_dim);
        let spec = rt.manifest.get(artifact).unwrap();
        let mut team = RankTeam::spawn(
            &rt,
            artifact,
            mk_workers(&rt, artifact, 2),
            &buckets,
            exe.spec.local_batch(),
            &ParallelCtx::serial(),
            None,
            None,
            Obs::disabled(),
        )
        .unwrap();
        let gen = crate::data::for_model(&spec.model, 7, 0, 0.0, &spec.meta).unwrap();
        let w = Worker::new(0, gen, GradInjector::None, 7);
        assert!(team.respawn(&rt, w).unwrap_err().to_string().contains("elastic"));
    }

    #[test]
    fn grouped_spawn_rejects_mismatched_map() {
        let rt = interp_runtime();
        let artifact = "linreg_b16";
        let exe = rt.load(artifact).unwrap();
        let buckets = Buckets::single(exe.spec.param_dim);
        let err = RankTeam::spawn(
            &rt,
            artifact,
            mk_workers(&rt, artifact, 3),
            &buckets,
            exe.spec.local_batch(),
            &ParallelCtx::serial(),
            Some(&NodeMap::even(2, 2)), // 4 ranks vs 3 workers
            None,
            Obs::disabled(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("node map"), "{err}");
    }
}
