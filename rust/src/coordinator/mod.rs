//! The training coordinator — the L3 leader.
//!
//! Owns the master parameters, drives the synchronous step loop
//! (local gradients → aggregation → optimizer → broadcast), charges the
//! communication cost model to the simulated clock, evaluates, logs, and
//! checkpoints.

pub mod checkpoint;
pub mod eval;
pub mod pipeline;
pub mod team;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use eval::{EvalOutcome, Evaluator};
pub use pipeline::{PipelinedExecutor, StepOutcome};
pub use team::RankTeam;
pub use trainer::{TrainResult, Trainer};
