//! The synchronous data-parallel training loop (Alg. 1 embedding).
//!
//! Per step: every rank draws its shard batch and computes a local
//! gradient, delivering it bucket by bucket to the
//! [`PipelinedExecutor`] — either round-robin on the leader thread
//! (`--rank-threads off`, the equivalence oracle) or from a persistent
//! [`RankTeam`] of real rank threads streaming buckets over
//! `comm::StepExchange` in true arrival order (`--rank-threads on`); the
//! aggregator combines them (AdaCons or a baseline) — with `overlap` on,
//! each bucket's phase-1 statistics run on the worker pool while later
//! buckets are still arriving; optional global-norm clipping; the
//! optimizer steps the master parameters.  Compute and communication are
//! charged to a [`SimClock`] through the α-β cost model and the per-step
//! event timeline (per-rank compute measured on-thread in threaded
//! mode), so iteration timing *and exposed communication* can be
//! reported for fabrics we do not have (Table 1, §5.1). Both execution
//! modes produce bitwise-identical aggregated directions
//! (`tests/train_end_to_end.rs`).

use std::sync::Arc;

use crate::aggregation::{self, Aggregator, CoeffStages};
use crate::collective::{CostModel, HierCostModel, SimClock};
use crate::compress::{CompressScope, RankCodec};
use crate::config::{LocalStepSpec, TrainConfig};
use crate::coordinator::eval::{EvalOutcome, Evaluator};
use crate::coordinator::pipeline::{ElasticPolicy, PipelinedExecutor};
use crate::coordinator::team::RankTeam;
use crate::coordinator::Checkpoint;
use crate::obs::{Domain, Obs, SpanEvent, SpanKind, TraceLevel};
use crate::optim::{self, clip_global_norm, Optimizer};
use crate::parallel::{ParPlan, ParallelCtx};
use crate::runtime::{Executable, Runtime};
use crate::tensor::{Buckets, GradSet};
use crate::util::error::{ensure, Context, Result};
use crate::util::timer::{PhaseTimer, Timer};
use crate::worker::Worker;

/// One evaluation point.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    pub outcome: EvalOutcome,
}

/// Everything a run produces.
#[derive(Debug)]
pub struct TrainResult {
    /// Per-step mean local train loss.
    pub train_loss: Vec<f64>,
    pub evals: Vec<EvalPoint>,
    pub metric_name: &'static str,
    /// Coefficient-stage statistics per logged step (Fig. 7).
    pub coeff_log: Vec<(usize, CoeffStages)>,
    /// Simulated seconds per iteration (compute + comm on the modeled
    /// fabric), averaged.
    pub sim_iter_s: f64,
    /// Measured wall seconds per iteration on this host.
    pub wall_iter_s: f64,
    /// Phase breakdown (grad / aggregate / optimize).
    pub phases: PhaseTimer,
    pub final_params: Vec<f32>,
    /// Effective batch = workers * local batch.
    pub effective_batch: usize,
    /// Thread/shard choices the aggregation engine made (last step).
    pub agg_par: Option<ParPlan>,
    /// Whether the step loop ran with comm/compute overlap.
    pub overlap: bool,
    /// Whether ranks ran as real OS threads (`--rank-threads on`).
    pub rank_threads: bool,
    /// Mean simulated communication per step not hidden behind compute
    /// (event-timeline accounting; == `serial_comm_s` with overlap off).
    pub exposed_comm_s: f64,
    /// Mean simulated communication per step under the unpipelined
    /// accounting (every transfer exposed).
    pub serial_comm_s: f64,
    /// Mean exposed communication attributable to intra-node
    /// (NVLink-class) links; 0 on flat topologies.
    pub exposed_intra_comm_s: f64,
    /// Mean exposed communication attributable to the inter-node fabric
    /// (== `exposed_comm_s` on flat topologies).
    pub exposed_inter_comm_s: f64,
    /// The run's topology (`flat` or `hier:<nodes>x<gpus>`).
    pub topology: String,
    /// Steps finalized from a strict subset of ranks (straggler cutoff,
    /// krum filtering, or a rank death); 0 without `--cutoff`.
    pub degraded_steps: usize,
    /// Dead ranks replaced mid-run by fresh fast-forwarded workers.
    pub rejoins: usize,
    /// Total modeled wire traffic across the run: the sum of every
    /// collective op's payload bytes (post-compression), over all sync
    /// rounds. At fixed `steps`, local-step training divides this by ~H.
    pub total_wire_bytes: u64,
    /// The configured local-step regime (`"1"`, `"16"`, `"auto:2-32"`).
    pub local_steps: String,
    /// Number of sync rounds the run performed (== `steps` when H=1).
    pub sync_rounds: usize,
    /// Realized H per sync round — the adaptive-H trace (constant for
    /// fixed H except a possibly clamped final round).
    pub local_step_trace: Vec<usize>,
}

impl TrainResult {
    pub fn final_train_loss(&self, window: usize) -> f64 {
        let n = self.train_loss.len();
        let lo = n.saturating_sub(window.max(1));
        crate::util::stats::mean(&self.train_loss[lo..])
    }

    pub fn final_metric(&self) -> Option<f64> {
        self.evals.last().map(|e| e.outcome.metric)
    }

    /// First step whose train loss EMA drops below `target` (speedup metric
    /// in the BERT comparison); None if never reached.
    pub fn steps_to_loss(&self, target: f64) -> Option<usize> {
        let mut ema = crate::util::stats::Ema::new(0.9);
        for (i, &l) in self.train_loss.iter().enumerate() {
            if ema.push(l) < target {
                return Some(i);
            }
        }
        None
    }
}

/// How the N ranks execute their backward passes each step.
enum Ranks {
    /// Serial round-robin on the leader thread (the `--rank-threads off`
    /// mode and bitwise oracle).
    RoundRobin(Vec<Worker>),
    /// Persistent rank threads (spawned once, joined on drop) streaming
    /// buckets over the exchange.
    Threaded(RankTeam),
}

/// The coordinator.
pub struct Trainer {
    pub cfg: TrainConfig,
    rt: Arc<Runtime>,
    exe: Arc<Executable>,
    ranks: Ranks,
    aggregator: Box<dyn Aggregator>,
    optimizer: Box<dyn Optimizer>,
    evaluator: Option<Evaluator>,
    buckets: Buckets,
    cost: CostModel,
    /// Two-level comm models + node grouping on hierarchical topologies.
    hier: Option<HierCostModel>,
    /// Persistent parallel context: the worker pool is spawned once here
    /// and reused by every aggregation step (no per-step thread spawn).
    par: ParallelCtx,
    /// Round-robin per-rank compression codecs (empty when no per-rank
    /// kind applies; threaded mode's codecs live on the rank threads).
    codecs: Vec<RankCodec>,
    pub params: Vec<f32>,
    start_step: usize,
    /// Flat set-codec state in transit: inbound from `restore()` (the
    /// executor that owns the codec is built inside `run()`), outbound
    /// captured from the executor when `run()` finishes so
    /// [`Trainer::checkpoint`] can persist it.
    set_codec_state: Option<(u64, Vec<Vec<f32>>)>,
    /// Adaptive-H carry: the H the next sync round would use. Inbound
    /// from `restore()` (so a resumed `auto` run continues the
    /// controller state instead of resetting to `min`), outbound
    /// captured when `run()` finishes so [`Trainer::checkpoint`] can
    /// persist it. None for fixed-H runs and legacy checkpoints.
    adaptive_h: Option<usize>,
    /// Shared observability handle: span tracer + the metrics registry
    /// every reported counter is derived from (`TrainResult`, jsonl,
    /// `--metrics-out` all read the same folds, so sinks cannot
    /// disagree).
    obs: Arc<Obs>,
}

impl Trainer {
    pub fn new(rt: Arc<Runtime>, cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        let exe = rt.load(&cfg.artifact)?;
        let d = exe.spec.param_dim;
        ensure!(d > 0, "{} is not a trainable artifact", cfg.artifact);
        let params = exe.spec.load_init(cfg.init_seed)?;
        let model = exe.spec.model.clone();
        let workers = (0..cfg.workers)
            .map(|rank| {
                let gen = crate::data::for_model(
                    &model,
                    cfg.seed,
                    rank as u64,
                    cfg.heterogeneity,
                    &exe.spec.meta,
                )
                .with_context(|| format!("no data generator for model {model}"))?;
                let injector = cfg
                    .injectors
                    .iter()
                    .find(|(r, _)| *r == rank)
                    .map(|(_, i)| i.clone())
                    .unwrap_or(crate::data::GradInjector::None);
                Ok(Worker::new(rank, gen, injector, cfg.seed))
            })
            .collect::<Result<Vec<_>>>()?;
        // Topology: flat = the historical single ring; hier = intra-node
        // reduce + inter-node consensus (the aggregator is wrapped in its
        // two-level hierarchical form and the comm accounting runs on the
        // two-level timeline).
        let topo = cfg.topology.build(cfg.workers, cfg.fabric_gbps);
        let hier = HierCostModel::from_topology(&topo);
        let mut aggregator = match &hier {
            Some(h) => aggregation::hierarchical(&cfg.aggregator, h.map.clone(), cfg.workers)
                .context("unknown aggregator")?,
            None => aggregation::by_name(&cfg.aggregator, cfg.workers)
                .context("unknown aggregator")?,
        };
        let optimizer = optim::by_name(&cfg.optimizer, d).context("unknown optimizer")?;
        let evaluator = Evaluator::for_artifact(
            &rt,
            &cfg.artifact,
            cfg.eval_artifact.as_deref(),
            cfg.seed,
            cfg.eval_batches,
        )?;
        let buckets = match cfg.bucket_cap {
            Some(cap) => Buckets::fixed(d, cap),
            None => Buckets::single(d),
        };
        // Compression placement by (kind, scope, topology):
        //  * per-rank kinds (int8/fp16/topk) encode at the rank source —
        //    always on flat fabrics (the single NIC carries the rank
        //    transfers under either scope), only under scope `all` on
        //    hierarchical ones (`inter` leaves the NVLink hop alone);
        //  * on hierarchical topologies the leader-level consensus
        //    transfer is additionally compressed through the
        //    aggregator's set codec (low-rank sketches always live
        //    there — the Gram structure needs the assembled set).
        // Flat low-rank is installed on the executor inside `run()`.
        let spec = cfg.compression;
        let per_rank_active =
            spec.kind.is_per_rank() && (hier.is_none() || spec.scope == CompressScope::All);
        if hier.is_some() && !spec.kind.is_none() {
            aggregator.set_compression(spec.kind, cfg.seed, buckets.len());
        }
        let codecs = if per_rank_active && !cfg.rank_threads {
            (0..cfg.workers)
                .map(|rank| RankCodec::new(spec.kind, cfg.seed, rank, buckets.len()))
                .collect()
        } else {
            Vec::new()
        };
        let cost = CostModel::from_topology(&topo);
        let par = ParallelCtx::new(cfg.parallel);
        let obs = Obs::new(cfg.trace_level);
        let ranks = if cfg.rank_threads {
            // Spawn the rank threads once; they persist across every step
            // of the run and join when the trainer drops. On hierarchical
            // topologies the team is grouped per node. With `--cutoff`
            // the team is elastic: dead ranks can be respawned in place.
            let team = if cfg.cutoff.is_some() {
                RankTeam::spawn_elastic(
                    &rt,
                    &cfg.artifact,
                    workers,
                    &buckets,
                    exe.spec.local_batch(),
                    &par,
                    hier.as_ref().map(|h| &h.map),
                    per_rank_active.then_some((spec.kind, cfg.seed)),
                    obs.clone(),
                )?
            } else {
                RankTeam::spawn(
                    &rt,
                    &cfg.artifact,
                    workers,
                    &buckets,
                    exe.spec.local_batch(),
                    &par,
                    hier.as_ref().map(|h| &h.map),
                    per_rank_active.then_some((spec.kind, cfg.seed)),
                    obs.clone(),
                )?
            };
            Ranks::Threaded(team)
        } else {
            Ranks::RoundRobin(workers)
        };
        Ok(Trainer {
            cfg,
            rt,
            exe,
            ranks,
            aggregator,
            optimizer,
            evaluator,
            buckets,
            cost,
            hier,
            par,
            codecs,
            params,
            start_step: 0,
            set_codec_state: None,
            adaptive_h: None,
            obs,
        })
    }

    /// The run's shared observability handle (tracer + metrics
    /// registry). Totals are valid after [`Trainer::run`] returns.
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// Resume from a checkpoint: restore the **complete** training
    /// state — parameters + step counter, optimizer slots, aggregator
    /// momentum, and the compression error-feedback residuals the v2
    /// format captures (the former residual-discarding restore silently
    /// perturbed every compressed continuation; the v1 fallback still
    /// resets them, since that format never recorded any). Every
    /// worker's data stream and injector RNG is fast-forwarded past the
    /// completed steps, so a fault-free continuation replays the
    /// original run bitwise.
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        ensure!(
            ck.params.len() == self.params.len(),
            "checkpoint dim mismatch"
        );
        let d = self.exe.spec.param_dim;
        let local_batch = self.exe.spec.local_batch();
        self.params = ck.params.clone();
        self.start_step = ck.step as usize;
        self.optimizer.import_state(ck.opt_t, &ck.opt_slots);
        self.aggregator.import_state(&ck.agg_state);
        let have_residuals = ck.rank_residuals.len() == self.cfg.workers;
        match &mut self.ranks {
            Ranks::RoundRobin(workers) => {
                if have_residuals {
                    for (codec, r) in self.codecs.iter_mut().zip(&ck.rank_residuals) {
                        codec.import_residuals(r.clone());
                    }
                } else {
                    for codec in &mut self.codecs {
                        codec.reset();
                    }
                }
                for w in workers.iter_mut() {
                    w.fast_forward(ck.step, local_batch, d);
                }
            }
            Ranks::Threaded(team) => {
                if have_residuals {
                    team.import_residuals(ck.rank_residuals.clone())?;
                } else {
                    team.reset_codecs()?;
                }
                team.fast_forward(ck.step, local_batch, d)?;
            }
        }
        // The flat low-rank set codec lives on the executor, which is
        // built inside `run()` — stash its state until then. The
        // aggregator-level set codec (hier compression) is not in the
        // checkpoint format; drop its residuals as before.
        self.set_codec_state = ck.set_codec.clone();
        if ck.set_codec.is_none() {
            self.aggregator.reset_compression();
        }
        // Adaptive-H controller state (trailing v2 section; None for
        // legacy files and fixed-H runs — `run()` then falls back to the
        // spec's initial H).
        self.adaptive_h = ck.local_h.map(|h| h as usize);
        Ok(())
    }

    /// Capture the complete training state after `step` completed local
    /// steps, with `set_codec` supplied by whoever holds the executor
    /// and `local_h` the adaptive-H carry (None for fixed-H runs).
    fn snapshot(
        &self,
        step: u64,
        set_codec: Option<(u64, Vec<Vec<f32>>)>,
        local_h: Option<u64>,
    ) -> Result<Checkpoint> {
        let (opt_t, opt_slots) = self.optimizer.export_state();
        let rank_residuals = match &self.ranks {
            Ranks::RoundRobin(_) => self.codecs.iter().map(|c| c.export_residuals()).collect(),
            Ranks::Threaded(team) => team.export_residuals()?,
        };
        Ok(Checkpoint {
            step,
            params: self.params.clone(),
            opt_t,
            opt_slots,
            agg_state: self.aggregator.export_state(),
            rank_residuals,
            set_codec,
            local_h,
        })
    }

    /// Full-state checkpoint of the trainer as it stands — intended
    /// after [`Trainer::run`] returns (the recorded step is the total
    /// completed step count, and the set-codec state is the one the
    /// finished run exported).
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        self.snapshot(
            (self.start_step + self.cfg.steps) as u64,
            self.set_codec_state.clone(),
            self.adaptive_h.map(|h| h as u64),
        )
    }

    pub fn local_batch(&self) -> usize {
        self.exe.spec.local_batch()
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// Run the configured number of steps.
    pub fn run(&mut self) -> Result<TrainResult> {
        let d = self.exe.spec.param_dim;
        let n = self.cfg.workers;
        let mut grads = GradSet::zeros(n, d);
        let mut agg = vec![0.0f32; d];
        let mut clock = SimClock::new(n);
        let mut phases = PhaseTimer::default();
        let mut train_loss = Vec::with_capacity(self.cfg.steps);
        let mut coeff_log = Vec::new();
        let mut evals = Vec::new();
        let mut metric_name: &'static str = "loss";
        let mut agg_par: Option<ParPlan> = None;
        let local_batch = self.local_batch();
        let mut jsonl = match &self.cfg.jsonl {
            Some(p) => Some(crate::metrics::JsonlWriter::create(p)?),
            None => None,
        };
        let mut exec = PipelinedExecutor::with_topology(
            n,
            self.buckets.clone(),
            self.cfg.overlap,
            self.hier.as_ref().map(|h| h.map.clone()),
            self.hier.clone(),
        );
        exec.set_compression(self.cfg.compression, self.cfg.seed);
        exec.set_obs(self.obs.clone());
        // Fresh totals for this run: every reported counter below is
        // derived from the registry, so a re-run must not inherit folds.
        self.obs.metrics.reset();
        if let Some((cstep, banks)) = self.set_codec_state.take() {
            exec.import_set_codec(cstep, banks);
        }
        let policy = self.cfg.cutoff.map(|c| ElasticPolicy {
            k: c.k,
            grace_s: c.grace_ms / 1000.0,
            krum_f: self.cfg.krum_f,
        });
        let model = self.exe.spec.model.clone();
        // --- local-step regime: `cfg.steps` counts *local* steps
        //     (gradient evaluations per rank); the loop below advances
        //     one *sync round* of H local steps at a time. H=1 takes the
        //     historical synchronous path verbatim (`local_lrs` stays
        //     None end to end), so it is bitwise-identical to the
        //     pre-local-step trainer. Under `auto:<min>-<max>` the
        //     controller re-picks H each round from the consensus-weight
        //     dispersion (see `weight_dispersion`).
        let end = self.start_step + self.cfg.steps;
        let adaptive = matches!(self.cfg.local_steps, LocalStepSpec::Auto { .. });
        let mut cur_h = match (self.adaptive_h.take(), self.cfg.local_steps) {
            // Resumed `auto` run: continue the controller where the
            // checkpointed run left it (clamped in case the spec's
            // bounds changed across the restart).
            (Some(carry), LocalStepSpec::Auto { min, max }) => carry.clamp(min, max),
            _ => self.cfg.local_steps.initial(),
        };
        let mut local_step_trace: Vec<usize> = Vec::new();
        let wall = Timer::start();

        let mut step = self.start_step;
        while step < end {
            // --- event-driven sync round: ranks deliver gradients (H=1)
            //     or H-step model deltas in gradient units (H>1) bucket
            //     by bucket (round-robin on this 1-CPU host, parallel on
            //     real hardware); ready buckets' statistics run on the
            //     worker pool while later buckets arrive; compute + comm
            //     are charged to the sim clock through the event
            //     timeline — comm once per round, so H amortizes it.
            let h = cur_h.min(end - step);
            let last = step + h - 1;
            // Per-pass learning rates for the H local SGD steps; the
            // leader resolves the schedule (rank threads hold none) and
            // ships them with the round broadcast.
            let local_lrs: Option<Arc<Vec<f32>>> = (h > 1).then(|| {
                Arc::new(
                    (step..step + h)
                        .map(|s| self.cfg.schedule.lr(s) as f32)
                        .collect::<Vec<f32>>(),
                )
            });
            crate::util::logging::set_step_context(Some(step as u64));
            exec.set_trace_step(step as u64);
            let t_step = self
                .obs
                .trace
                .enabled(TraceLevel::Step)
                .then(|| self.obs.trace.now_s());
            let step_t = Timer::start();
            let mut grad_s = 0.0f64;
            let outcome = match &mut self.ranks {
                Ranks::RoundRobin(workers) => {
                    let (exe, params, buckets, par) =
                        (&self.exe, &self.params, &self.buckets, &self.par);
                    let codecs = &mut self.codecs;
                    let local_lrs = &local_lrs;
                    let mut produce = |rank: usize,
                                       deliver: &mut dyn FnMut(usize, &[f32])|
                     -> Result<(f64, f64)> {
                        let t = Timer::start();
                        let w = &mut workers[rank];
                        let mut encode_s = 0.0f64;
                        if codecs.is_empty() {
                            match local_lrs {
                                None => w.compute_grad_buckets(
                                    exe, params, local_batch, buckets, par, deliver,
                                )?,
                                Some(lrs) => w.compute_delta_round(
                                    exe, params, local_batch, buckets, par, lrs, deliver,
                                )?,
                            }
                        } else {
                            // Emulate the wire round-trip the threaded
                            // path performs: encode at the rank source
                            // (updating its error-feedback residual),
                            // decode at the leader edge — so both modes
                            // aggregate identical bits. The measured
                            // encode wall-time is charged to this rank's
                            // compute, mirroring the on-thread timing.
                            let codec = &mut codecs[rank];
                            let enc = &mut encode_s;
                            let mut wire = |b: usize,
                                            cols: &[f32],
                                            deliver: &mut dyn FnMut(usize, &[f32])| {
                                let et = Timer::start();
                                let payload = codec.encode_bucket(step as u64, b, cols);
                                *enc += et.elapsed_s();
                                let decoded = payload.into_cols();
                                deliver(b, &decoded);
                            };
                            match local_lrs {
                                None => w.compute_grad_buckets(
                                    exe,
                                    params,
                                    local_batch,
                                    buckets,
                                    par,
                                    &mut |b, cols| wire(b, cols, deliver),
                                )?,
                                Some(lrs) => w.compute_delta_round(
                                    exe,
                                    params,
                                    local_batch,
                                    buckets,
                                    par,
                                    lrs,
                                    &mut |b, cols| wire(b, cols, deliver),
                                )?,
                            }
                        }
                        grad_s += t.elapsed_s();
                        Ok((w.last_loss as f64, w.last_compute_s + encode_s))
                    };
                    exec.run_step(
                        &mut produce,
                        self.aggregator.as_mut(),
                        &mut grads,
                        &mut agg,
                        &self.par,
                        &mut clock,
                        &self.cost,
                    )?
                }
                Ranks::Threaded(team) => {
                    // Broadcast this round's parameters (plus the local
                    // lr slice when H>1); the rank threads compute
                    // concurrently while the leader ingests their
                    // buckets in arrival order. With `--cutoff` the step
                    // runs elastically: the leader finalizes from the
                    // quorum, cutting stragglers and surviving deaths
                    // (fenced to H=1 by `TrainConfig::validate`).
                    let params = Arc::new(self.params.clone());
                    team.begin_round(&params, step as u64, local_lrs.clone())?;
                    let outcome = match &policy {
                        Some(p) => exec.run_step_elastic(
                            team.exchange(),
                            p,
                            self.aggregator.as_mut(),
                            &self.cfg.aggregator,
                            &mut grads,
                            &mut agg,
                            &self.par,
                            &mut clock,
                            &self.cost,
                        )?,
                        None => exec.run_step_exchange(
                            team.exchange(),
                            self.aggregator.as_mut(),
                            &mut grads,
                            &mut agg,
                            &self.par,
                            &mut clock,
                            &self.cost,
                        )?,
                    };
                    // Wall grad phase = the slowest rank's on-thread
                    // compute: the ranks ran concurrently (with each
                    // other and the leader's aggregation work), so their
                    // times overlap rather than add.
                    grad_s = outcome
                        .rank_compute_s
                        .iter()
                        .cloned()
                        .fold(0.0, f64::max);
                    outcome
                }
            };
            // --- rank rejoin: replace every rank that died this step
            //     with a fresh worker fast-forwarded past the completed
            //     steps (its data stream and injector RNG land exactly
            //     where the dead rank's would have), so the team is back
            //     at full strength before the next broadcast.
            if outcome.survivors < n {
                self.obs.metrics.add_u("degraded_steps", 1);
            }
            if !outcome.dead_ranks.is_empty() {
                if let Ranks::Threaded(team) = &mut self.ranks {
                    for &rank in &outcome.dead_ranks {
                        if self.cfg.log_every > 0 {
                            crate::log_info!("step {step}: rank {rank} died; respawning");
                        }
                        let gen = crate::data::for_model(
                            &model,
                            self.cfg.seed,
                            rank as u64,
                            self.cfg.heterogeneity,
                            &self.exe.spec.meta,
                        )
                        .with_context(|| format!("no data generator for model {model}"))?;
                        let injector = self
                            .cfg
                            .injectors
                            .iter()
                            .find(|(r, _)| *r == rank)
                            .map(|(_, i)| i.clone())
                            .unwrap_or(crate::data::GradInjector::None);
                        let mut w = Worker::new(rank, gen, injector, self.cfg.seed);
                        w.fast_forward(step as u64 + 1, local_batch, d);
                        team.respawn(&self.rt, w)?;
                        self.obs.metrics.add_u("rejoins", 1);
                    }
                }
            }
            phases.add("grad", grad_s);
            phases.add("aggregate", (step_t.elapsed_s() - grad_s).max(0.0));
            train_loss.push(outcome.mean_loss);
            // The registry is the single accumulator: counter totals are
            // the exact in-order fold of these adds, so they carry the
            // same bits the former local `+=` accumulators did.
            let m = &self.obs.metrics;
            m.add_f("exposed_comm_s", outcome.exposed_comm_s);
            m.add_f("serial_comm_s", outcome.serial_comm_s);
            m.add_f("exposed_intra_comm_s", outcome.exposed_intra_comm_s);
            m.add_f("exposed_inter_comm_s", outcome.exposed_inter_comm_s);
            m.add_u("wire_bytes", outcome.wire_bytes);
            m.add_u("sync_rounds", 1);
            m.observe("local_step_h", h as f64);
            if let Some(g) = outcome.info.gammas.as_deref() {
                m.observe("gamma_dispersion", coeff_of_variation(g));
            }
            local_step_trace.push(h);
            // Round-aligned cadence: a periodic event fires at this
            // round's boundary iff its local-step interval [step, step+h)
            // contains a qualifying index — exactly the historical
            // per-step behavior when H=1.
            let due = |every: usize| every > 0 && (step..step + h).any(|s| s % every == 0);
            let log_due = due(self.cfg.log_every);
            if outcome.info.par.is_some() {
                agg_par = outcome.info.par;
            }
            // --- adaptive H: re-pick next round's H from how much the
            //     consensus weights disagree across ranks. High
            //     dispersion means the local models are drifting apart
            //     (the aggregator is down-weighting outliers), so sync
            //     more often; near-uniform weights mean the deltas
            //     agree, so communication can be stretched further.
            //     Deterministic: driven only by aggregation outputs.
            if adaptive {
                let disp = weight_dispersion(outcome.info.gammas.as_deref(), &grads, n);
                if let LocalStepSpec::Auto { min, max } = self.cfg.local_steps {
                    if disp > 0.5 {
                        cur_h = (cur_h / 2).max(min);
                    } else if disp < 0.15 {
                        cur_h = (cur_h * 2).min(max);
                    }
                }
            }
            if let Some(stages) = outcome.info.coeff_stages {
                if log_due {
                    coeff_log.push((last, stages));
                }
            }

            // --- clip + optimize: one outer step per sync round, at the
            //     round-start learning rate (the per-pass rates already
            //     shaped the delta).
            let t_opt = t_step.map(|_| self.obs.trace.now_s());
            phases.time("optimize", || {
                if let Some(max_norm) = self.cfg.clip {
                    clip_global_norm(&mut agg, max_norm);
                }
                let lr = self.cfg.schedule.lr(step) as f32;
                self.optimizer.step(&mut self.params, &agg, lr);
            });
            if let Some(t0) = t_opt {
                self.obs.trace.span(
                    TraceLevel::Step,
                    SpanEvent::new(
                        SpanKind::OptimizerApply,
                        Domain::Wall,
                        step as u64,
                        t0,
                        self.obs.trace.now_s() - t0,
                    ),
                );
            }
            if let Some(t0) = t_step {
                self.obs.trace.span(
                    TraceLevel::Step,
                    SpanEvent::new(
                        SpanKind::Step,
                        Domain::Wall,
                        step as u64,
                        t0,
                        self.obs.trace.now_s() - t0,
                    ),
                );
            }

            // --- eval
            if self.cfg.eval_every > 0 && (due(self.cfg.eval_every) || step + h == end) {
                if let Some(ev) = &mut self.evaluator {
                    let outcome = ev.evaluate(&self.params)?;
                    metric_name = outcome.metric_name;
                    if self.cfg.log_every > 0 {
                        crate::log_info!(
                            "step {last}: loss {:.4} {} {:.4}",
                            outcome.loss,
                            outcome.metric_name,
                            outcome.metric
                        );
                    }
                    evals.push(EvalPoint {
                        step: last,
                        outcome,
                    });
                }
            }
            if log_due {
                crate::log_debug!("step {last}: train loss {:.5}", train_loss.last().unwrap());
            }
            // --- periodic full-state checkpoint (round-aligned: fires
            //     at the first round boundary covering the configured
            //     multiple, recording the completed local-step count)
            if self.cfg.checkpoint_every > 0
                && (step..step + h).any(|s| (s + 1) % self.cfg.checkpoint_every == 0)
            {
                if let Some(path) = self.cfg.checkpoint_path.clone() {
                    self.snapshot(
                        (step + h) as u64,
                        exec.export_set_codec(),
                        adaptive.then_some(cur_h as u64),
                    )?
                    .save(&path)?;
                    // A checkpoint marks a resumable point: make the
                    // metrics stream durable up to it too, so a crash
                    // right after the save cannot strand buffered
                    // records behind the checkpoint's step counter.
                    if let Some(w) = &mut jsonl {
                        w.flush()?;
                    }
                }
            }
            if let Some(w) = &mut jsonl {
                use crate::util::json::{num, obj, s};
                // Per-step comm figures read back from the registry (the
                // `_last` slots hold exactly this round's adds), so the
                // jsonl stream and the `--metrics-out` exposition can
                // never drift apart.
                let m = &self.obs.metrics;
                let mut rec = vec![
                    ("step", num(last as f64)),
                    ("train_loss", num(*train_loss.last().unwrap())),
                    ("lr", num(self.cfg.schedule.lr(step))),
                    ("sim_time_s", num(clock.now())),
                    ("exposed_comm_s", num(m.last_f("exposed_comm_s"))),
                    ("exposed_intra_comm_s", num(m.last_f("exposed_intra_comm_s"))),
                    ("exposed_inter_comm_s", num(m.last_f("exposed_inter_comm_s"))),
                    ("wire_bytes", num(m.last_u("wire_bytes") as f64)),
                    ("local_steps", num(h as f64)),
                    ("aggregator", s(&self.cfg.aggregator)),
                ];
                if let Some(e) = evals.last() {
                    if e.step == last {
                        rec.push(("eval_loss", num(e.outcome.loss)));
                        rec.push(("metric", num(e.outcome.metric)));
                    }
                }
                w.write(&obj(rec))?;
            }
            step += h;
        }
        if let Some(w) = &mut jsonl {
            w.flush()?;
        }
        crate::util::logging::set_step_context(None);
        self.set_codec_state = exec.export_set_codec();
        self.adaptive_h = adaptive.then_some(cur_h);

        // Observability exports: drain the span buffer into a Chrome
        // trace and write the Prometheus exposition. Both happen after
        // the last step, so neither can perturb training.
        if let Some(path) = &self.cfg.trace_out {
            let events = self.obs.trace.take_events();
            crate::obs::chrome::write_trace(path, self.obs.trace.level(), &events)
                .with_context(|| format!("writing trace to {path}"))?;
        }
        if let Some(path) = &self.cfg.metrics_out {
            std::fs::write(path, self.obs.metrics.expose())
                .with_context(|| format!("writing metrics to {path}"))?;
        }

        // Amortized per-*local-step* metrics: dividing by `cfg.steps`
        // (not sync rounds) is what makes H>1 show its win — the same
        // number of gradient evaluations, the comm charged 1/H as often.
        // Comm totals read back from the registry — the same in-order
        // folds the jsonl stream and `--metrics-out` report.
        let steps = self.cfg.steps.max(1) as f64;
        let m = &self.obs.metrics;
        Ok(TrainResult {
            train_loss,
            evals,
            metric_name,
            coeff_log,
            sim_iter_s: clock.now() / steps,
            wall_iter_s: wall.elapsed_s() / steps,
            phases,
            final_params: self.params.clone(),
            effective_batch: n * local_batch,
            agg_par,
            overlap: self.cfg.overlap,
            rank_threads: self.cfg.rank_threads,
            exposed_comm_s: m.total_f("exposed_comm_s") / steps,
            serial_comm_s: m.total_f("serial_comm_s") / steps,
            exposed_intra_comm_s: m.total_f("exposed_intra_comm_s") / steps,
            exposed_inter_comm_s: m.total_f("exposed_inter_comm_s") / steps,
            topology: self.cfg.topology.describe(),
            degraded_steps: m.total_u("degraded_steps") as usize,
            rejoins: m.total_u("rejoins") as usize,
            total_wire_bytes: m.total_u("wire_bytes"),
            local_steps: self.cfg.local_steps.describe(),
            sync_rounds: local_step_trace.len(),
            local_step_trace,
        })
    }
}

/// Coefficient of variation (std/|mean|) of the aggregator's reported
/// per-rank consensus weights — the γ-dispersion series the registry
/// keeps per aggregator run. Cheap (N values), so it is recorded every
/// round; degenerate means read as maximal disagreement, matching
/// [`weight_dispersion`]'s convention.
fn coeff_of_variation(vals: &[f32]) -> f64 {
    if vals.len() < 2 {
        return 0.0;
    }
    let vals: Vec<f64> = vals.iter().map(|&x| x as f64).collect();
    let mean = vals.iter().sum::<f64>() / vals.len() as f64;
    if !mean.is_finite() || mean.abs() < 1e-300 {
        return 1.0;
    }
    let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
    var.sqrt() / mean.abs()
}

/// Dispersion of the consensus weights across ranks — the adaptive-H
/// control signal. Coefficient of variation (std/|mean|) of the
/// aggregator's per-rank weights when it reports them (`AggInfo::
/// gammas`: AdaCons' Eq. 7/12 coefficients); for weight-free
/// aggregators the fallback is the CV of the per-rank delta row norms,
/// which measures the same drift directly on the assembled set. Both
/// signals are deterministic functions of aggregation inputs, so the
/// realized H trace is reproducible run to run.
fn weight_dispersion(gammas: Option<&[f32]>, grads: &GradSet, n: usize) -> f64 {
    let vals: Vec<f64> = match gammas {
        Some(g) if g.len() > 1 => g.iter().map(|&x| x as f64).collect(),
        _ => (0..n)
            .map(|r| {
                grads
                    .row(r)
                    .iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum::<f64>()
                    .sqrt()
            })
            .collect(),
    };
    if vals.len() < 2 {
        return 0.0;
    }
    let mean = vals.iter().sum::<f64>() / vals.len() as f64;
    if !mean.is_finite() || mean.abs() < 1e-300 {
        // Degenerate weights (all-zero or non-finite): treat as maximal
        // disagreement so the controller falls back to frequent syncs.
        return 1.0;
    }
    let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
    var.sqrt() / mean.abs()
}

/// Convenience: build a trainer on the default runtime and run it.
pub fn run_config(rt: Arc<Runtime>, cfg: TrainConfig) -> Result<TrainResult> {
    Trainer::new(rt, cfg)?.run()
}
