//! Event-driven pipelined step executor.
//!
//! The serial loop runs grad → aggregate → optimize as three phases; this
//! executor dissolves the first barrier. Ranks deliver their gradients
//! **bucket by bucket** — either round-robin on the leader thread via a
//! [`GradProducer`] callback, or live from N rank threads streaming over
//! [`comm::StepExchange`] ([`PipelinedExecutor::run_step_exchange`]), in
//! which case the leader ingests `(rank, bucket, cols)` messages **in
//! arrival order**. Whatever the source, the moment a bucket has arrived
//! from every rank its phase-1 aggregation work
//! (`BucketedAggregator::ingest_bucket`) is submitted to the persistent
//! pool as a non-blocking task ([`TaskScope::submit`]), so bucket *k*'s
//! consensus statistics run while buckets *k+1..* are still arriving.
//! Phase 2 (`finalize`) joins the task handles in **fixed bucket order**,
//! which — together with the thread-count-free shard plan — makes the
//! pipelined output bitwise-identical to `Aggregator::aggregate_ctx`'s
//! serial path at any arrival interleaving (enforced by
//! `tests/parallel_equivalence.rs`).
//!
//! Simulated time is charged through the [`StepTimeline`]: per-bucket
//! collectives post at their bucket's readiness and serialize on the
//! modeled NIC (the paper's §5.1 overlap argument, previously only an
//! analytical side-car in `collective::overlap`), while `overlap = false`
//! reproduces the barrier-only `SimClock` accounting exactly.
//!
//! [`TaskScope::submit`]: crate::parallel::TaskScope::submit
//! [`comm::StepExchange`]: crate::comm::StepExchange

use std::collections::HashMap;
use std::sync::Arc;

use crate::aggregation::{AggInfo, Aggregator, BucketWork, CommScope};
use crate::collective::cost_model::f32_wire_bytes;
use crate::collective::{CostModel, HierCostModel, HierTimeline, NodeMap, SimClock, StepTimeline};
use crate::comm::StepExchange;
use crate::compress::{CompressScope, CompressionSpec, CompressorKind, SetCodec};
use crate::obs::{Domain, Obs, SpanEvent, SpanKind, SpanScope, StepMark, StepMode, TraceLevel};
use crate::parallel::ParallelCtx;
use crate::tensor::{BucketTracker, Buckets, GradSet};
use crate::util::error::{bail, ensure, Result};

/// Per-rank gradient production: compute rank `rank`'s local gradient and
/// deliver it through `deliver(bucket, columns)` in bucket order; return
/// `(local_loss, compute_seconds)`.
pub type GradProducer<'a> =
    dyn FnMut(usize, &mut dyn FnMut(usize, &[f32])) -> Result<(f64, f64)> + 'a;

/// Where one step's bucket arrivals come from.
enum Arrivals<'a, 'p> {
    /// Serial round-robin: the executor calls each rank's producer in
    /// turn on the leader thread (the `off` mode and equivalence oracle).
    Producer(&'a mut GradProducer<'p>),
    /// Threaded: rank threads stream buckets over the exchange; the
    /// leader drains them in arrival order plus one `Done` per rank.
    Exchange(&'a StepExchange),
}

/// What one executed step reports beyond the aggregation metadata.
#[derive(Debug)]
pub struct StepOutcome {
    pub info: AggInfo,
    /// Mean local train loss across ranks.
    pub mean_loss: f64,
    /// Simulated communication time not hidden behind compute this step.
    pub exposed_comm_s: f64,
    /// The unpipelined accounting for the same ops: the sum of every
    /// transfer's duration (== `exposed_comm_s` when overlap is off).
    pub serial_comm_s: f64,
    /// Exposed communication attributable to intra-node (NVLink-class)
    /// links under the hierarchical timeline; 0 on flat topologies.
    pub exposed_intra_comm_s: f64,
    /// Exposed communication attributable to the inter-node fabric (==
    /// `exposed_comm_s` on flat topologies, where the single modeled NIC
    /// plays the inter-node bottleneck).
    pub exposed_inter_comm_s: f64,
    /// Per-rank wall compute seconds this step — measured on the rank
    /// thread in exchange mode — as charged to the `SimClock`.
    pub rank_compute_s: Vec<f64>,
    /// Ranks that died this step (elastic path only; empty otherwise).
    pub dead_ranks: Vec<usize>,
    /// How many ranks' gradients entered the aggregation (== N outside
    /// the elastic path; < N on a degraded step).
    pub survivors: usize,
    /// Total modeled wire traffic this step: the sum of every
    /// [`CommOp`](crate::aggregation::CommOp)'s payload bytes, after any
    /// compression rewrite — the measurable counterpart of every
    /// comm-reduction claim (`--compress`, `--local-steps`).
    pub wire_bytes: u64,
}

/// Fault-tolerance policy for [`PipelinedExecutor::run_step_elastic`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ElasticPolicy {
    /// K-of-N quorum: the leader finalizes once `k` ranks have delivered
    /// all buckets; slower ranks beyond the grace window are dropped from
    /// this step's consensus (their compute is cancelled at the barrier).
    pub k: usize,
    /// Straggler grace, simulated seconds: a rank finishing within
    /// `grace_s` of the K-th fastest still makes the step.
    pub grace_s: f64,
    /// Krum-style outlier filter: among the on-time ranks, drop the `f`
    /// with the largest outlier scores (sum of the `m - f - 2` smallest
    /// pairwise squared distances); non-finite (NaN/inf) gradients are
    /// always excluded first. 0 disables the filter.
    pub krum_f: usize,
}

/// The reusable per-run state of the pipelined step loop: bucket arrival
/// bookkeeping plus one `(N, bucket_width)` assembly buffer per bucket
/// (the "per-bucket sends"), allocated once and reused every step. On a
/// hierarchical topology ([`PipelinedExecutor::with_topology`]) the
/// per-bucket stores are partitioned per node group instead, so each
/// node's intra reduction can start — as its own pool task — the moment
/// that group's ranks complete the bucket, and the step's simulated time
/// is charged through the two-level [`HierTimeline`].
pub struct PipelinedExecutor {
    buckets: Buckets,
    overlap: bool,
    tracker: BucketTracker,
    /// Per-bucket `(N, width)` stores — the flat overlap path.
    assembly: Vec<GradSet>,
    /// Per-bucket, per-node `(group_size, width)` stores — the grouped
    /// overlap path (`map` is `Some`).
    node_assembly: Vec<Vec<GradSet>>,
    /// Per-(bucket, node) arrival counts, flattened `b * groups + k`.
    node_counts: Vec<usize>,
    /// Non-degenerate node grouping: overlap-mode ingest runs per node
    /// group (requires a matching hierarchical aggregator).
    map: Option<NodeMap>,
    /// Topology-aware accounting: scoped ops priced on the intra/inter
    /// models and scheduled on the two-level timeline.
    hier_cost: Option<HierCostModel>,
    /// Step-compression config. The executor rewrites per-bucket
    /// [`CommOp`](crate::aggregation::CommOp) bytes to the compressed
    /// wire size; the codecs themselves run at the rank source
    /// (per-rank kinds) or the leader set level (low-rank).
    compression: CompressionSpec,
    /// Flat low-rank set codec (leader-side sketch + error feedback).
    /// `None` for per-rank kinds; on hierarchical runs the equivalent
    /// codec lives inside `aggregation::Hierarchical`.
    set_codec: Option<SetCodec>,
    /// Survivor-set aggregators for the elastic path, keyed by the sorted
    /// survivor rank list (each keeps its own momentum state — AdaCons
    /// reseeds its EMA on a worker-count change anyway).
    elastic_aggs: HashMap<Vec<usize>, Box<dyn Aggregator>>,
    /// Shared observability handle (tracing + metrics). Dormant
    /// (`Obs::disabled`) until `set_obs` installs the trainer's; every
    /// recording site is gated on the trace level, so the untraced step
    /// path is bitwise-identical to the pre-observability executor.
    obs: Arc<Obs>,
    /// Step id stamped onto trace events — plain bookkeeping the trainer
    /// sets before each step; never read by the execution path.
    trace_step: u64,
    n: usize,
}

/// Map an op's communication scope onto the trace-span scope tag.
fn span_scope(s: CommScope) -> SpanScope {
    match s {
        CommScope::Global => SpanScope::Global,
        CommScope::Intra => SpanScope::Intra,
        CommScope::Inter => SpanScope::Inter,
    }
}

impl PipelinedExecutor {
    pub fn new(n_ranks: usize, buckets: Buckets, overlap: bool) -> Self {
        Self::with_topology(n_ranks, buckets, overlap, None, None)
    }

    /// Hierarchical construction. `map` (when non-degenerate) switches
    /// the overlap-mode ingest to per-node-group tasks; `hier_cost`
    /// switches the simulated-time accounting to the two-level timeline.
    /// A degenerate map (one node, or one rank per node) is dropped —
    /// the flat path is bitwise-identical there and the hierarchical
    /// aggregator delegates anyway.
    pub fn with_topology(
        n_ranks: usize,
        buckets: Buckets,
        overlap: bool,
        map: Option<NodeMap>,
        hier_cost: Option<HierCostModel>,
    ) -> Self {
        if let Some(m) = &map {
            assert_eq!(m.n_ranks(), n_ranks, "node map does not cover every rank");
        }
        let map = map.filter(|m| !m.is_degenerate());
        // The per-bucket stores are a second full (N, d) matrix (whole in
        // the flat path, partitioned per node group in the grouped one);
        // the overlap-off path never touches them, so only pay for them
        // when pipelining is actually on.
        let assembly = if overlap && map.is_none() {
            buckets
                .iter()
                .map(|(lo, hi)| GradSet::zeros(n_ranks, hi - lo))
                .collect()
        } else {
            Vec::new()
        };
        let node_assembly = match (&map, overlap) {
            (Some(m), true) => buckets
                .iter()
                .map(|(lo, hi)| {
                    m.iter()
                        .map(|(r0, r1)| GradSet::zeros(r1 - r0, hi - lo))
                        .collect()
                })
                .collect(),
            _ => Vec::new(),
        };
        let node_counts =
            vec![0usize; buckets.len() * map.as_ref().map(|m| m.groups()).unwrap_or(0)];
        let tracker = BucketTracker::new(buckets.len(), n_ranks);
        PipelinedExecutor {
            buckets,
            overlap,
            tracker,
            assembly,
            node_assembly,
            node_counts,
            map,
            hier_cost,
            compression: CompressionSpec::default(),
            set_codec: None,
            elastic_aggs: HashMap::new(),
            obs: Obs::disabled(),
            trace_step: 0,
            n: n_ranks,
        }
    }

    /// Install the trainer's shared observability handle.
    pub fn set_obs(&mut self, obs: Arc<Obs>) {
        self.obs = obs;
    }

    /// Stamp subsequent trace events with this step id (the trainer sets
    /// it to the round's first global step). Pure bookkeeping — the
    /// execution path never reads it.
    pub fn set_trace_step(&mut self, step: u64) {
        self.trace_step = step;
    }

    pub fn overlap(&self) -> bool {
        self.overlap
    }

    pub fn buckets(&self) -> &Buckets {
        &self.buckets
    }

    /// Install the step-compression config. Flat low-rank sketching is
    /// applied here, leader-side, over the assembled bucket set (the
    /// hierarchical leader-level equivalent is installed on the
    /// aggregator via `Aggregator::set_compression`); per-rank kinds
    /// encode at the rank source and decode at the wire edge, so the
    /// executor's only job for them is the byte rewrite.
    pub fn set_compression(&mut self, spec: CompressionSpec, seed: u64) {
        self.compression = spec;
        self.set_codec = match spec.kind {
            k @ CompressorKind::LowRank { .. } if self.map.is_none() => {
                Some(SetCodec::new(k, seed, self.buckets.len()))
            }
            _ => None,
        };
    }

    /// Export the flat low-rank set codec's state (stochastic-rounding
    /// step + per-bucket error-feedback banks) for checkpoint capture;
    /// `None` when no set codec is installed.
    pub fn export_set_codec(&self) -> Option<(u64, Vec<Vec<f32>>)> {
        self.set_codec.as_ref().map(|c| c.export_state())
    }

    /// Restore the set codec's state from a checkpoint. A no-op when no
    /// set codec is installed (the checkpoint's compression config does
    /// not match this run's — the caller validates that).
    pub fn import_set_codec(&self, step: u64, banks: Vec<Vec<f32>>) {
        if let Some(codec) = &self.set_codec {
            codec.import_state(step, banks);
        }
    }

    /// Drop accumulated error-feedback residuals (parameter
    /// re-broadcast: the compression error no longer refers to the
    /// restored iterate) and rewind the codec's step counter.
    pub fn reset_compression(&self) {
        if let Some(codec) = &self.set_codec {
            codec.reset();
        }
    }

    /// Rewrite per-bucket op bytes to the compressed wire size. Only
    /// full-width bucket payloads qualify (`bytes == 4·width` with
    /// `bucket: Some(b)`), which excludes grawa's 4-byte scalar-partial
    /// AllGathers (except in the degenerate width-1 bucket case) and
    /// the exposed `bucket: None` ops, neither of which is compressed.
    fn rewrite_compressed_bytes(&self, info: &mut AggInfo) {
        let spec = self.compression;
        let hier = self.map.is_some();
        for op in &mut info.comm {
            let Some(b) = op.bucket else { continue };
            let (lo, hi) = self.buckets.range(b);
            let w = hi - lo;
            if op.bytes != f32_wire_bytes(w) {
                continue;
            }
            let rows = match (hier, op.scope) {
                // Flat: the single modeled NIC carries the rank
                // transfers, so both scopes compress them.
                (false, CommScope::Global) => self.n,
                // Hierarchical: the leader-level consensus transfer is
                // compressed under either scope…
                (true, CommScope::Inter) => self.map.as_ref().unwrap().groups(),
                // …while the NVLink-class intra reduce only shrinks
                // when scope `all` puts codecs at the rank source
                // (low-rank stays a leader-set transform by design).
                (true, CommScope::Intra)
                    if spec.scope == CompressScope::All && spec.kind.is_per_rank() =>
                {
                    self.map.as_ref().unwrap().max_group()
                }
                _ => continue,
            };
            op.bytes = spec.kind.bucket_wire_bytes(w, rows);
        }
    }

    /// Run one step fed by the round-robin producer callback (the serial
    /// execution mode; also the bitwise oracle the threaded mode is
    /// checked against).
    pub fn run_step(
        &mut self,
        produce: &mut GradProducer<'_>,
        agg: &mut dyn Aggregator,
        grads: &mut GradSet,
        out: &mut [f32],
        ctx: &ParallelCtx,
        clock: &mut SimClock,
        cost: &CostModel,
    ) -> Result<StepOutcome> {
        self.run_step_on(Arrivals::Producer(produce), agg, grads, out, ctx, clock, cost)
    }

    /// Run one step fed by rank threads over `exchange`: the leader
    /// ingests `(rank, bucket, cols)` messages in arrival order and one
    /// `Done { loss, compute_s }` per rank (the threaded execution mode —
    /// callers broadcast the step's parameters to the rank threads
    /// first, e.g. `RankTeam::begin_step`). A rank that dies mid-step
    /// fails the step with its id instead of deadlocking.
    pub fn run_step_exchange(
        &mut self,
        exchange: &StepExchange,
        agg: &mut dyn Aggregator,
        grads: &mut GradSet,
        out: &mut [f32],
        ctx: &ParallelCtx,
        clock: &mut SimClock,
        cost: &CostModel,
    ) -> Result<StepOutcome> {
        self.run_step_on(Arrivals::Exchange(exchange), agg, grads, out, ctx, clock, cost)
    }

    /// Shared step driver: assemble arrivals into `grads`, aggregate into
    /// `out`, and charge compute + communication to the simulated clock.
    ///
    /// `grads` is the full `(N, d)` assembly both paths maintain (the
    /// aggregators' `finalize` needs it); `out` receives the aggregated
    /// direction. With `overlap = false` this degenerates to the serial
    /// grad-then-aggregate loop with barrier collectives — same code
    /// surface, bitwise-identical output.
    fn run_step_on(
        &mut self,
        source: Arrivals<'_, '_>,
        agg: &mut dyn Aggregator,
        grads: &mut GradSet,
        out: &mut [f32],
        ctx: &ParallelCtx,
        clock: &mut SimClock,
        cost: &CostModel,
    ) -> Result<StepOutcome> {
        assert_eq!(grads.n(), self.n);
        assert_eq!(grads.d(), self.buckets.total());
        assert_eq!(out.len(), grads.d());
        let n = self.n;
        let nb = self.buckets.len();
        let start_s: Vec<f64> = (0..n).map(|r| clock.rank_time(r)).collect();
        let mut loss_sum = 0.0f64;
        let mut compute_s = vec![0.0f64; n];
        // Observed per-rank bucket completion offsets (exchange mode; the
        // producer path and legacy senders leave this empty).
        let mut bucket_obs: Vec<Vec<f64>> = Vec::new();
        // Measured leader-side set-codec (flat lowrank) transform seconds
        // per bucket — charged to the timeline as compute ahead of that
        // bucket's transfer, so sketching no longer runs free on
        // wall-clock threads. Stays all-zero without a set codec.
        let mut set_encode_s = vec![0.0f64; nb];

        let obs = self.obs.clone();
        let step_id = self.trace_step;
        // Wall-domain phase spans (leader ingest → consensus finalize).
        // `t_phase` is Some only when tracing, so the untraced path takes
        // no timestamps at all.
        let t_phase = obs
            .trace
            .enabled(TraceLevel::Step)
            .then(|| obs.trace.now_s());

        let mut info = if self.overlap {
            let work = if self.map.is_some() {
                self.ingest_grouped(
                    source,
                    &*agg,
                    grads,
                    ctx,
                    &mut loss_sum,
                    &mut compute_s,
                    &mut bucket_obs,
                )?
            } else {
                self.ingest_flat(
                    source,
                    &*agg,
                    grads,
                    ctx,
                    &mut loss_sum,
                    &mut compute_s,
                    &mut bucket_obs,
                    &mut set_encode_s,
                )?
            };
            let t_fin = t_phase.map(|t0| {
                let t1 = obs.trace.now_s();
                obs.trace.span(
                    TraceLevel::Step,
                    SpanEvent::new(SpanKind::LeaderIngest, Domain::Wall, step_id, t0, t1 - t0),
                );
                t1
            });
            let info = agg.finalize(grads, &self.buckets, work, out, ctx);
            if let Some(t0) = t_fin {
                obs.trace.span(
                    TraceLevel::Step,
                    SpanEvent::new(
                        SpanKind::Finalize,
                        Domain::Wall,
                        step_id,
                        t0,
                        obs.trace.now_s() - t0,
                    ),
                );
            }
            info
        } else {
            match source {
                Arrivals::Producer(produce) => {
                    for rank in 0..n {
                        let mut deliver = |b: usize, cols: &[f32]| {
                            let (lo, hi) = self.buckets.range(b);
                            grads.row_mut(rank)[lo..hi].copy_from_slice(cols);
                        };
                        let (loss, cs) = produce(rank, &mut deliver)?;
                        loss_sum += loss;
                        compute_s[rank] = cs;
                    }
                }
                Arrivals::Exchange(ex) => {
                    let buckets = &self.buckets;
                    let reports = ex.leader_ingest(buckets, true, &mut |rank, b, cols| {
                        let (lo, hi) = buckets.range(b);
                        grads.row_mut(rank)[lo..hi].copy_from_slice(&cols);
                    })?;
                    for (rank, rep) in reports.iter().enumerate() {
                        loss_sum += rep.loss;
                        compute_s[rank] = rep.compute_s;
                    }
                    bucket_obs = reports.into_iter().map(|r| r.bucket_s).collect();
                }
            }
            // Off-overlap leader-side sketch: transform the assembled
            // set in place, bucket by bucket, before aggregation — the
            // same fixed order (and, by offset invariance, the same
            // bits) as the overlap path's per-task transforms.
            if let Some(codec) = &self.set_codec {
                let enc_tr = obs.trace.enabled(TraceLevel::Bucket);
                for (b, (lo, hi)) in self.buckets.iter().enumerate() {
                    let enc_t0 = if enc_tr { obs.trace.now_s() } else { 0.0 };
                    let t = crate::util::timer::Timer::start();
                    codec.transform(b, grads, lo, hi);
                    set_encode_s[b] = t.elapsed_s();
                    if enc_tr {
                        obs.trace.span(
                            TraceLevel::Bucket,
                            SpanEvent::new(
                                SpanKind::Encode,
                                Domain::Wall,
                                step_id,
                                enc_t0,
                                set_encode_s[b],
                            )
                            .bucket(b),
                        );
                    }
                }
            }
            let t_fin = t_phase.map(|t0| {
                let t1 = obs.trace.now_s();
                obs.trace.span(
                    TraceLevel::Step,
                    SpanEvent::new(SpanKind::LeaderIngest, Domain::Wall, step_id, t0, t1 - t0),
                );
                t1
            });
            let info = agg.aggregate_ctx(grads, &self.buckets, out, ctx);
            if let Some(t0) = t_fin {
                obs.trace.span(
                    TraceLevel::Step,
                    SpanEvent::new(
                        SpanKind::Finalize,
                        Domain::Wall,
                        step_id,
                        t0,
                        obs.trace.now_s() - t0,
                    ),
                );
            }
            info
        };
        if self.compression.is_active() {
            self.rewrite_compressed_bytes(&mut info);
        }
        let wire_bytes: u64 = info.comm.iter().map(|op| op.bytes as u64).sum();
        if let Some(codec) = &self.set_codec {
            codec.advance_step();
        }

        // --- simulated-time accounting ---
        for (r, &cs) in compute_s.iter().enumerate() {
            clock.advance(r, cs);
        }
        let compute_end = clock.now();
        // Bucket readiness: observed on-thread completion offsets when the
        // rank threads measured them (`--rank-threads on`), else the
        // uniform-emission model — the backward finalizes the *end* of
        // the flat parameter vector first (last layers), so bucket
        // readiness runs in descending index order, the same order
        // `Worker::compute_grad_buckets` streams live off the interpreter
        // backend.
        let total = self.buckets.total().max(1);
        let fracs: Vec<f64> = (0..nb)
            .map(|b| {
                let (lo, _) = self.buckets.range(b);
                (total - lo) as f64 / total as f64
            })
            .collect();
        let observed: Option<&Vec<Vec<f64>>> =
            if bucket_obs.len() == n && bucket_obs.iter().all(|v| v.len() == nb) {
                Some(&bucket_obs)
            } else {
                None
            };
        let rank_ready = |r: usize, b: usize| -> f64 {
            match observed {
                Some(ob) => start_s[r] + ob[r][b].max(0.0).min(compute_s[r]),
                None => start_s[r] + fracs[b] * compute_s[r],
            }
        };
        let step_start = start_s.iter().cloned().fold(0.0, f64::max);
        let bucket_tr = obs.trace.enabled(TraceLevel::Bucket);
        // Sim-domain trace events batch into a local buffer and flush in
        // one `record_batch` at step end — no allocation when tracing is
        // off (`Vec::new` does not allocate until the first push).
        let mut sim_evs: Vec<SpanEvent> = Vec::new();
        if obs.trace.enabled(TraceLevel::Rank) {
            for (r, &cs) in compute_s.iter().enumerate() {
                sim_evs.push(
                    SpanEvent::new(SpanKind::SimCompute, Domain::Sim, step_id, start_s[r], cs)
                        .rank(r),
                );
            }
            if self.overlap {
                for r in 0..n {
                    for b in 0..nb {
                        sim_evs.push(
                            SpanEvent::new(
                                SpanKind::BucketReady,
                                Domain::Sim,
                                step_id,
                                rank_ready(r, b),
                                0.0,
                            )
                            .rank(r)
                            .bucket(b),
                        );
                    }
                }
            }
        }
        let (exposed_comm_s, serial_comm_s, exposed_intra_comm_s, exposed_inter_comm_s) =
            if self.overlap {
                match &self.hier_cost {
                    Some(hier) => {
                        // Two-level schedule: every node's intra reduce runs
                        // on its own NVLink-class channel (ready when that
                        // node's ranks emitted the bucket); a bucket's
                        // leader-level transfer waits for its intra reduces
                        // on every node; exposed ops post at backward end.
                        let g = hier.map.groups();
                        let mut tl = HierTimeline::new(step_start, g);
                        let mut intra_done: Vec<Option<f64>> = vec![None; nb];
                        let mut serial = 0.0f64;
                        for op in &info.comm {
                            match op.scope {
                                CommScope::Intra => {
                                    let dur = hier.intra.time_s(op.kind, op.bytes);
                                    serial += dur;
                                    match op.bucket {
                                        Some(b) => {
                                            let mut done = step_start;
                                            for (k, (r0, r1)) in hier.map.iter().enumerate() {
                                                let ready = (r0..r1)
                                                    .map(|r| rank_ready(r, b))
                                                    .fold(0.0, f64::max);
                                                let (t0, dk) = tl.post_intra_span(k, ready, dur);
                                                if bucket_tr {
                                                    // One op, g concurrent channel
                                                    // posts: only the first carries
                                                    // the serial-time charge.
                                                    let mut ev = SpanEvent::new(
                                                        SpanKind::Transfer,
                                                        Domain::Sim,
                                                        step_id,
                                                        t0,
                                                        dur,
                                                    )
                                                    .bucket(b)
                                                    .node(k)
                                                    .scope(SpanScope::Intra);
                                                    if k > 0 {
                                                        ev = ev.not_serial();
                                                    }
                                                    sim_evs.push(ev);
                                                }
                                                done = done.max(dk);
                                            }
                                            intra_done[b] = Some(match intra_done[b] {
                                                Some(x) => x.max(done),
                                                None => done,
                                            });
                                        }
                                        None => {
                                            // Exposed intra op (the result
                                            // fan-out broadcast): its payload
                                            // is the inter-level consensus
                                            // output, so it cannot start
                                            // before every inter op posted so
                                            // far has completed (ops are
                                            // emitted in dependency order).
                                            let ready =
                                                compute_end.max(tl.inter_done_s());
                                            for k in 0..g {
                                                let (t0, _) =
                                                    tl.post_intra_span(k, ready, dur);
                                                if bucket_tr {
                                                    let mut ev = SpanEvent::new(
                                                        SpanKind::Transfer,
                                                        Domain::Sim,
                                                        step_id,
                                                        t0,
                                                        dur,
                                                    )
                                                    .node(k)
                                                    .scope(SpanScope::Intra);
                                                    if k > 0 {
                                                        ev = ev.not_serial();
                                                    }
                                                    sim_evs.push(ev);
                                                }
                                            }
                                        }
                                    }
                                }
                                CommScope::Inter | CommScope::Global => {
                                    let dur = match op.scope {
                                        CommScope::Inter => hier.inter.time_s(op.kind, op.bytes),
                                        _ => cost.time_s(op.kind, op.bytes),
                                    };
                                    serial += dur;
                                    let ready = match op.bucket {
                                        Some(b) => intra_done[b].unwrap_or_else(|| {
                                            (0..n)
                                                .map(|r| rank_ready(r, b))
                                                .fold(0.0, f64::max)
                                        }),
                                        None => compute_end,
                                    };
                                    let (t0, _) = tl.post_inter_span(ready, dur);
                                    if bucket_tr {
                                        let mut ev = SpanEvent::new(
                                            SpanKind::Transfer,
                                            Domain::Sim,
                                            step_id,
                                            t0,
                                            dur,
                                        )
                                        .scope(span_scope(op.scope));
                                        if let Some(b) = op.bucket {
                                            ev = ev.bucket(b);
                                        }
                                        sim_evs.push(ev);
                                    }
                                }
                            }
                        }
                        let exposed = tl.exposed_s(compute_end);
                        let intra = tl.exposed_intra_s(compute_end);
                        let inter = tl.exposed_inter_s(compute_end);
                        tl.commit(clock);
                        (exposed, serial, intra, inter)
                    }
                    None => {
                        let mut tl = StepTimeline::new(step_start);
                        for op in &info.comm {
                            let dur = cost.time_s(op.kind, op.bytes);
                            let ready = match op.bucket {
                                // A set-sketched bucket's transfer starts
                                // only after its leader-side encode.
                                Some(b) => {
                                    (0..n).map(|r| rank_ready(r, b)).fold(0.0, f64::max)
                                        + set_encode_s[b]
                                }
                                None => compute_end,
                            };
                            let (t0, _) = tl.post_span(ready, dur);
                            if bucket_tr {
                                let mut ev = SpanEvent::new(
                                    SpanKind::Transfer,
                                    Domain::Sim,
                                    step_id,
                                    t0,
                                    dur,
                                )
                                .scope(span_scope(op.scope));
                                if let Some(b) = op.bucket {
                                    ev = ev.bucket(b);
                                }
                                sim_evs.push(ev);
                            }
                        }
                        let exposed = tl.exposed_s(compute_end);
                        tl.commit(clock);
                        (exposed, tl.serial_s(), 0.0, exposed)
                    }
                }
            } else {
                // Barrier semantics, op by op — exactly the pre-pipeline
                // accounting (every transfer is exposed). On a
                // hierarchical topology scoped ops are still priced on
                // their own level's model (every node's intra reduce runs
                // concurrently, so one collective charge covers them all).
                let mut serial = 0.0;
                let mut serial_intra = 0.0;
                // Leader-side set-codec encode precedes every transfer
                // under barrier semantics: charge it as one serial
                // compute span (it advances the clock but is not comm).
                let encode_total: f64 = set_encode_s.iter().sum();
                if encode_total > 0.0 {
                    clock.collective(encode_total);
                }
                for op in &info.comm {
                    let dur = match (&self.hier_cost, op.scope) {
                        (Some(h), CommScope::Intra) => h.intra.time_s(op.kind, op.bytes),
                        (Some(h), CommScope::Inter) => h.inter.time_s(op.kind, op.bytes),
                        _ => cost.time_s(op.kind, op.bytes),
                    };
                    if op.scope == CommScope::Intra {
                        serial_intra += dur;
                    }
                    if bucket_tr {
                        // Barrier collectives start where the aligned
                        // clock stands (`now` is a pure read).
                        let mut ev = SpanEvent::new(
                            SpanKind::Transfer,
                            Domain::Sim,
                            step_id,
                            clock.now(),
                            dur,
                        )
                        .scope(span_scope(op.scope));
                        if let Some(b) = op.bucket {
                            ev = ev.bucket(b);
                        }
                        sim_evs.push(ev);
                    }
                    clock.collective(dur);
                    serial += dur;
                }
                (serial, serial, serial_intra, serial - serial_intra)
            };

        if obs.trace.enabled(TraceLevel::Step) {
            let mode = if self.overlap {
                if self.hier_cost.is_some() {
                    StepMode::OverlapHier
                } else {
                    StepMode::OverlapFlat
                }
            } else {
                StepMode::Barrier
            };
            obs.trace.record_batch(sim_evs);
            obs.trace.mark(StepMark {
                step: step_id,
                mode,
                step_start_s: step_start,
                compute_end_s: compute_end,
                exposed_comm_s,
                exposed_intra_s: exposed_intra_comm_s,
                exposed_inter_s: exposed_inter_comm_s,
                serial_comm_s,
                wire_bytes,
            });
        }

        Ok(StepOutcome {
            info,
            mean_loss: loss_sum / n as f64,
            exposed_comm_s,
            serial_comm_s,
            exposed_intra_comm_s,
            exposed_inter_comm_s,
            rank_compute_s: compute_s,
            dead_ranks: Vec::new(),
            survivors: n,
            wire_bytes,
        })
    }

    /// Run one fault-tolerant step over an **elastic** exchange.
    ///
    /// The leader drains arrivals until every rank has delivered or died
    /// (in-process transport makes the physical drain cheap); the K-of-N
    /// cutoff is then applied on the **simulated** timeline — exactly
    /// where a real K-of-N barrier would bite. Survivor selection, in
    /// order: ranks that died are out; ranks whose simulated finish
    /// exceeds the K-th fastest by more than the grace window are cut;
    /// with `krum_f > 0`, non-finite gradients and the `f` largest
    /// outlier scores are filtered. A full-strength step (every rank
    /// survives) aggregates through `agg` — bitwise-identical to the
    /// non-elastic path; a degraded step renormalizes by aggregating the
    /// survivor rows through a cached survivor-set instance of
    /// `agg_name` (consensus weights are computed over — and sum to one
    /// across — the survivors, so the degraded direction stays an
    /// unbiased combination of unbiased per-rank estimates).
    ///
    /// Simulated time: only survivors' compute reaches the clock — a cut
    /// straggler's step is cancelled at the barrier, which is the entire
    /// point of the cutoff — then the step's collectives run as barrier
    /// ops. Runs with overlap off (the elastic ingest assembles the full
    /// matrix before aggregating).
    #[allow(clippy::too_many_arguments)]
    pub fn run_step_elastic(
        &mut self,
        exchange: &StepExchange,
        policy: &ElasticPolicy,
        agg: &mut dyn Aggregator,
        agg_name: &str,
        grads: &mut GradSet,
        out: &mut [f32],
        ctx: &ParallelCtx,
        clock: &mut SimClock,
        cost: &CostModel,
    ) -> Result<StepOutcome> {
        ensure!(!self.overlap, "the elastic step path runs with overlap off");
        ensure!(
            self.set_codec.is_none(),
            "elastic steps do not support the set-sketch (lowrank) compressor"
        );
        ensure!(
            policy.k >= 1 && policy.k <= self.n,
            "cutoff quorum {} out of range for {} ranks",
            policy.k,
            self.n
        );
        assert_eq!(grads.n(), self.n);
        assert_eq!(grads.d(), self.buckets.total());
        assert_eq!(out.len(), grads.d());
        let n = self.n;
        let start_s: Vec<f64> = (0..n).map(|r| clock.rank_time(r)).collect();
        let obs = self.obs.clone();
        let step_id = self.trace_step;
        let t_phase = obs
            .trace
            .enabled(TraceLevel::Step)
            .then(|| obs.trace.now_s());
        let buckets = &self.buckets;
        let rep = exchange.leader_ingest_elastic(buckets, policy.k, &mut |rank, b, cols| {
            let (lo, hi) = buckets.range(b);
            grads.row_mut(rank)[lo..hi].copy_from_slice(&cols);
        })?;
        let dead_ranks: Vec<usize> = rep.dead.iter().map(|(r, _)| *r).collect();
        let mut compute_s = vec![0.0f64; n];
        let mut loss_sum = 0.0f64;
        let mut live = 0usize;
        for (r, report) in rep.reports.iter().enumerate() {
            if let Some(rr) = report {
                compute_s[r] = rr.compute_s;
                loss_sum += rr.loss;
                live += 1;
            }
        }
        // Cutoff + krum + survivor aggregation all count as the leader's
        // consensus work: ingest span ends here, finalize span covers
        // the rest of the leader phase.
        let t_agg = t_phase.map(|t0| {
            let t1 = obs.trace.now_s();
            obs.trace.span(
                TraceLevel::Step,
                SpanEvent::new(SpanKind::LeaderIngest, Domain::Wall, step_id, t0, t1 - t0),
            );
            t1
        });

        // --- straggler cutoff on the simulated timeline ---
        let mut candidates: Vec<usize> =
            (0..n).filter(|&r| rep.reports[r].is_some()).collect();
        if candidates.len() > policy.k {
            let mut finishes: Vec<f64> = candidates
                .iter()
                .map(|&r| start_s[r] + compute_s[r])
                .collect();
            finishes.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let deadline = finishes[policy.k - 1] + policy.grace_s;
            candidates.retain(|&r| start_s[r] + compute_s[r] <= deadline);
        }

        // --- krum-style outlier filter ---
        if policy.krum_f > 0 {
            candidates.retain(|&r| grads.row(r).iter().all(|x| x.is_finite()));
            let m = candidates.len();
            let f = policy.krum_f;
            if m >= f + 3 {
                // score_i = sum of the (m - f - 2) smallest squared
                // distances to the other candidates (Blanchard et al.'s
                // krum score); drop the f largest. Fixed-order f64
                // accumulation keeps the scores deterministic.
                let mut scored: Vec<(f64, usize)> = candidates
                    .iter()
                    .map(|&i| {
                        let mut d2: Vec<f64> = candidates
                            .iter()
                            .filter(|&&j| j != i)
                            .map(|&j| {
                                grads
                                    .row(i)
                                    .iter()
                                    .zip(grads.row(j))
                                    .map(|(a, b)| {
                                        let e = (*a - *b) as f64;
                                        e * e
                                    })
                                    .sum::<f64>()
                            })
                            .collect();
                        d2.sort_by(|a, b| a.partial_cmp(b).unwrap());
                        (d2.iter().take(m - f - 2).sum::<f64>(), i)
                    })
                    .collect();
                scored.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.1.cmp(&b.1))
                });
                candidates = scored[..m - f].iter().map(|&(_, i)| i).collect();
                candidates.sort_unstable();
            }
        }
        if candidates.is_empty() {
            bail!("no survivors after cutoff/filter (dead: {dead_ranks:?})");
        }

        // --- aggregate over the survivor set ---
        let mut info = if candidates.len() == n {
            // Full strength: the normal aggregator, bitwise-identical to
            // the non-elastic barrier path.
            agg.aggregate_ctx(grads, buckets, out, ctx)
        } else {
            let m = candidates.len();
            let mut sgs = GradSet::zeros(m, grads.d());
            for (i, &r) in candidates.iter().enumerate() {
                sgs.row_mut(i).copy_from_slice(grads.row(r));
            }
            let surv_agg = match self.elastic_aggs.entry(candidates.clone()) {
                std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                std::collections::hash_map::Entry::Vacant(e) => {
                    let built = match &self.map {
                        Some(map) => {
                            // Survivor node grouping: per-group survivor
                            // counts, empty groups dropped (survivors are
                            // sorted, and groups cover contiguous rank
                            // ranges, so order is preserved).
                            let mut sizes: Vec<usize> = Vec::new();
                            for (r0, r1) in map.iter() {
                                let c = candidates
                                    .iter()
                                    .filter(|&&r| r >= r0 && r < r1)
                                    .count();
                                if c > 0 {
                                    sizes.push(c);
                                }
                            }
                            crate::aggregation::hierarchical(
                                agg_name,
                                NodeMap::from_sizes(&sizes),
                                m,
                            )
                        }
                        None => crate::aggregation::by_name(agg_name, m),
                    }
                    .ok_or_else(|| crate::err!("unknown aggregator {agg_name}"))?;
                    e.insert(built)
                }
            };
            surv_agg.aggregate_ctx(&sgs, buckets, out, ctx)
        };
        if self.compression.is_active() {
            self.rewrite_compressed_bytes(&mut info);
        }
        let wire_bytes: u64 = info.comm.iter().map(|op| op.bytes as u64).sum();
        if let Some(t0) = t_agg {
            obs.trace.span(
                TraceLevel::Step,
                SpanEvent::new(
                    SpanKind::Finalize,
                    Domain::Wall,
                    step_id,
                    t0,
                    obs.trace.now_s() - t0,
                ),
            );
        }

        // --- simulated time: survivors' compute, then barrier ops ---
        for &r in &candidates {
            clock.advance(r, compute_s[r]);
        }
        let step_start = start_s.iter().cloned().fold(0.0, f64::max);
        let compute_end = clock.now();
        let bucket_tr = obs.trace.enabled(TraceLevel::Bucket);
        let mut sim_evs: Vec<SpanEvent> = Vec::new();
        if obs.trace.enabled(TraceLevel::Rank) {
            // Only survivors' compute reaches the clock — a cut
            // straggler's step is cancelled at the barrier — so only
            // survivors get sim-compute spans.
            for &r in &candidates {
                sim_evs.push(
                    SpanEvent::new(
                        SpanKind::SimCompute,
                        Domain::Sim,
                        step_id,
                        start_s[r],
                        compute_s[r],
                    )
                    .rank(r),
                );
            }
        }
        let mut serial = 0.0f64;
        let mut serial_intra = 0.0f64;
        for op in &info.comm {
            let dur = match (&self.hier_cost, op.scope) {
                (Some(h), CommScope::Intra) => h.intra.time_s(op.kind, op.bytes),
                (Some(h), CommScope::Inter) => h.inter.time_s(op.kind, op.bytes),
                _ => cost.time_s(op.kind, op.bytes),
            };
            if op.scope == CommScope::Intra {
                serial_intra += dur;
            }
            if bucket_tr {
                let mut ev =
                    SpanEvent::new(SpanKind::Transfer, Domain::Sim, step_id, clock.now(), dur)
                        .scope(span_scope(op.scope));
                if let Some(b) = op.bucket {
                    ev = ev.bucket(b);
                }
                sim_evs.push(ev);
            }
            clock.collective(dur);
            serial += dur;
        }
        if obs.trace.enabled(TraceLevel::Step) {
            obs.trace.record_batch(sim_evs);
            obs.trace.mark(StepMark {
                step: step_id,
                mode: StepMode::Elastic,
                step_start_s: step_start,
                compute_end_s: compute_end,
                exposed_comm_s: serial,
                exposed_intra_s: serial_intra,
                exposed_inter_s: serial - serial_intra,
                serial_comm_s: serial,
                wire_bytes,
            });
        }

        Ok(StepOutcome {
            info,
            mean_loss: loss_sum / (live.max(1)) as f64,
            exposed_comm_s: serial,
            serial_comm_s: serial,
            exposed_intra_comm_s: serial_intra,
            exposed_inter_comm_s: serial - serial_intra,
            rank_compute_s: compute_s,
            dead_ranks,
            survivors: candidates.len(),
            wire_bytes,
        })
    }

    /// Flat overlap-mode ingest: one store per bucket; the bucket's
    /// phase-1 aggregation task is submitted at the arrival that
    /// completes it across all ranks. `set_encode_s[b]` receives the
    /// measured leader-side set-codec transform seconds for bucket `b`
    /// (zero without a set codec) for the caller's timeline charge.
    #[allow(clippy::too_many_arguments)]
    fn ingest_flat(
        &mut self,
        source: Arrivals<'_, '_>,
        agg: &dyn Aggregator,
        grads: &mut GradSet,
        ctx: &ParallelCtx,
        loss_sum: &mut f64,
        compute_s: &mut [f64],
        bucket_obs: &mut Vec<Vec<f64>>,
        set_encode_s: &mut [f64],
    ) -> Result<Vec<BucketWork>> {
        let n = self.n;
        let nb = self.buckets.len();
        self.tracker.reset();
        let buckets = &self.buckets;
        let tracker = &mut self.tracker;
        let assembly = &mut self.assembly;
        let codec = self.set_codec.as_ref();
        let obs = self.obs.clone();
        let step_id = self.trace_step;
        let enc_tr = codec.is_some() && obs.trace.enabled(TraceLevel::Bucket);
        // Ingest tasks run on pool workers, so their kernels must not
        // fan out again (a nested barrier would deadlock the pool);
        // one lane with the same min_shard_elems keeps the shard plan
        // — and the result bits — identical.
        let ictx = ParallelCtx::new(ctx.intra_task_policy());
        let scope_result = ctx.task_scope(|scope| -> Result<Vec<BucketWork>> {
            let ictx_ref = &ictx;
            let tracer = &obs.trace;
            let mut handles: Vec<_> = (0..nb).map(|_| None).collect();
            {
                let handles = &mut handles;
                let grads = &mut *grads;
                // One arrival sink for both sources: copy the bucket
                // into the full assembly and the per-bucket store;
                // when the arrival completes the bucket, hand its
                // stats work to the pool and keep receiving later
                // buckets.
                let mut sink = |rank: usize, b: usize, cols: &[f32]| {
                    let (lo, hi) = buckets.range(b);
                    grads.row_mut(rank)[lo..hi].copy_from_slice(cols);
                    assembly[b].set_row(rank, cols);
                    if tracker.arrive(b) {
                        let view = std::mem::replace(&mut assembly[b], GradSet::zeros(0, 0));
                        handles[b] = Some(scope.submit(move || {
                            let mut view = view;
                            // Leader-side sketch (flat low-rank): the
                            // transform runs inside the bucket's task,
                            // overlapped with later arrivals; the
                            // transformed rows ride back via the view
                            // and are mirrored into `grads` at join so
                            // finalize sees the compressed set. Its
                            // measured seconds ride back too: the
                            // timeline delays the bucket's transfer by
                            // them (encode is not free).
                            let mut enc_s = 0.0f64;
                            let mut enc_t0 = 0.0f64;
                            if let Some(codec) = codec {
                                if enc_tr {
                                    enc_t0 = tracer.now_s();
                                }
                                let t = crate::util::timer::Timer::start();
                                codec.transform(b, &mut view, 0, view.d());
                                enc_s = t.elapsed_s();
                            }
                            let w = agg.ingest_bucket(b, &view, 0, view.d(), ictx_ref);
                            (w, view, enc_s, enc_t0)
                        }));
                    }
                };
                match source {
                    Arrivals::Producer(produce) => {
                        for rank in 0..n {
                            let mut deliver = |b: usize, cols: &[f32]| sink(rank, b, cols);
                            let (loss, cs) = produce(rank, &mut deliver)?;
                            *loss_sum += loss;
                            compute_s[rank] = cs;
                        }
                    }
                    Arrivals::Exchange(ex) => {
                        let reports =
                            ex.leader_ingest(buckets, true, &mut |rank, b, cols| {
                                sink(rank, b, &cols)
                            })?;
                        for (rank, rep) in reports.iter().enumerate() {
                            *loss_sum += rep.loss;
                            compute_s[rank] = rep.compute_s;
                        }
                        *bucket_obs = reports.into_iter().map(|r| r.bucket_s).collect();
                    }
                }
            }
            // Join in fixed bucket order — the only ordering finalize
            // ever sees — and recover the assembly buffers for reuse.
            let mut work = Vec::with_capacity(nb);
            for (b, h) in handles.into_iter().enumerate() {
                let h = h.unwrap_or_else(|| panic!("bucket {b} never became ready"));
                let (w, view, enc_s, enc_t0) = h.join();
                set_encode_s[b] = enc_s;
                if enc_tr {
                    tracer.span(
                        TraceLevel::Bucket,
                        SpanEvent::new(SpanKind::Encode, Domain::Wall, step_id, enc_t0, enc_s)
                            .bucket(b),
                    );
                }
                if codec.is_some() {
                    let (lo, hi) = buckets.range(b);
                    for r in 0..n {
                        grads.row_mut(r)[lo..hi].copy_from_slice(view.row(r));
                    }
                }
                assembly[b] = view;
                work.push(w);
            }
            Ok(work)
        });
        match scope_result {
            Ok(work) => Ok(work),
            Err(e) => {
                // A producer error or a dead rank can leave bucket stores
                // moved into tasks that were never joined; rebuild them so
                // the executor stays reusable for a clean retry step.
                for (b, (lo, hi)) in self.buckets.iter().enumerate() {
                    if self.assembly[b].d() != hi - lo {
                        self.assembly[b] = GradSet::zeros(self.n, hi - lo);
                    }
                }
                Err(e)
            }
        }
    }

    /// Grouped (hierarchical) overlap-mode ingest: stores are partitioned
    /// per node group, and **two** layers of tasks pipeline with arrival:
    ///
    /// * phase 1a — node `k`'s leader reduction of bucket `b`
    ///   (`reduce_group`), submitted the moment that node's ranks
    ///   complete the bucket, while other nodes' ranks are still
    ///   streaming (the leader ingests node-level buckets);
    /// * phase 1b — the base scheme's leaders-level ingest
    ///   (`ingest_leaders`), submitted when every node's reduction for
    ///   the bucket has been joined (fixed node order, so the assembled
    ///   leader set is deterministic at any arrival interleaving).
    fn ingest_grouped(
        &mut self,
        source: Arrivals<'_, '_>,
        agg: &dyn Aggregator,
        grads: &mut GradSet,
        ctx: &ParallelCtx,
        loss_sum: &mut f64,
        compute_s: &mut [f64],
        bucket_obs: &mut Vec<Vec<f64>>,
    ) -> Result<Vec<BucketWork>> {
        let n = self.n;
        let nb = self.buckets.len();
        let map = self.map.clone().expect("grouped ingest needs a node map");
        let g = map.groups();
        ensure!(
            agg.node_map() == Some(&map),
            "hierarchical executor needs an aggregator grouped by the same node map \
             (build it with aggregation::hierarchical)"
        );
        self.tracker.reset();
        self.node_counts.iter_mut().for_each(|c| *c = 0);
        let buckets = &self.buckets;
        let tracker = &mut self.tracker;
        let node_assembly = &mut self.node_assembly;
        let node_counts = &mut self.node_counts;
        let ictx = ParallelCtx::new(ctx.intra_task_policy());
        let scope_result = ctx.task_scope(|scope| -> Result<Vec<BucketWork>> {
            let ictx_ref = &ictx;
            let map_ref = &map;
            let mut intra: Vec<Vec<Option<_>>> =
                (0..nb).map(|_| (0..g).map(|_| None).collect()).collect();
            let mut inner: Vec<Option<_>> = (0..nb).map(|_| None).collect();
            {
                let intra = &mut intra;
                let inner = &mut inner;
                let grads = &mut *grads;
                let mut sink = |rank: usize, b: usize, cols: &[f32]| {
                    let (lo, hi) = buckets.range(b);
                    grads.row_mut(rank)[lo..hi].copy_from_slice(cols);
                    let (k, slot) = map_ref.locate(rank);
                    node_assembly[b][k].set_row(slot, cols);
                    node_counts[b * g + k] += 1;
                    if node_counts[b * g + k] == map_ref.size(k) {
                        // Node-level bucket complete: start this node's
                        // leader reduction now (phase 1a).
                        let view =
                            std::mem::replace(&mut node_assembly[b][k], GradSet::zeros(0, 0));
                        intra[b][k] = Some(scope.submit(move || {
                            let rows = (0, view.n());
                            let row = agg.reduce_group(k, &view, rows, 0, view.d(), ictx_ref);
                            (row, view)
                        }));
                    }
                    if tracker.arrive(b) {
                        // Last group's arrival completes the bucket: join
                        // the G reductions in node order (they were
                        // submitted as groups finished; these joins are
                        // short and later arrivals queue on the channel
                        // meanwhile), then hand the leaders to phase 1b.
                        let mut leaders = GradSet::zeros(g, hi - lo);
                        for (k, h) in intra[b].iter_mut().enumerate() {
                            let (row, view) = h
                                .take()
                                .expect("every group completed this bucket")
                                .join();
                            leaders.set_row(k, &row);
                            node_assembly[b][k] = view;
                        }
                        inner[b] =
                            Some(scope.submit(move || agg.ingest_leaders(b, leaders, ictx_ref)));
                    }
                };
                match source {
                    Arrivals::Producer(produce) => {
                        for rank in 0..n {
                            let mut deliver = |b: usize, cols: &[f32]| sink(rank, b, cols);
                            let (loss, cs) = produce(rank, &mut deliver)?;
                            *loss_sum += loss;
                            compute_s[rank] = cs;
                        }
                    }
                    Arrivals::Exchange(ex) => {
                        let reports =
                            ex.leader_ingest(buckets, true, &mut |rank, b, cols| {
                                sink(rank, b, &cols)
                            })?;
                        for (rank, rep) in reports.iter().enumerate() {
                            *loss_sum += rep.loss;
                            compute_s[rank] = rep.compute_s;
                        }
                        *bucket_obs = reports.into_iter().map(|r| r.bucket_s).collect();
                    }
                }
            }
            // Join the leaders-level work in fixed bucket order.
            let mut work = Vec::with_capacity(nb);
            for (b, h) in inner.into_iter().enumerate() {
                let h = h.unwrap_or_else(|| panic!("bucket {b} never became ready"));
                work.push(h.join());
            }
            Ok(work)
        });
        match scope_result {
            Ok(work) => Ok(work),
            Err(e) => {
                // Rebuild any per-node stores moved into tasks that were
                // never joined (the scope waited for them before
                // returning), so the executor stays reusable.
                for (b, (lo, hi)) in self.buckets.iter().enumerate() {
                    for (k, (r0, r1)) in map.iter().enumerate() {
                        let gs = &mut self.node_assembly[b][k];
                        if gs.n() != r1 - r0 || gs.d() != hi - lo {
                            *gs = GradSet::zeros(r1 - r0, hi - lo);
                        }
                    }
                }
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation;
    use crate::collective::Topology;
    use crate::comm::StepExchange;
    use crate::parallel::ParallelPolicy;
    use crate::tensor::ops::CHUNK;
    use crate::util::prng::Rng;

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.normal_f32(1.0)).collect())
            .collect()
    }

    /// Producer replaying fixed rows with fixed per-rank compute times.
    fn replay_producer<'a>(
        rows: &'a [Vec<f32>],
        buckets: &'a Buckets,
        compute_s: &'a [f64],
    ) -> impl FnMut(usize, &mut dyn FnMut(usize, &[f32])) -> Result<(f64, f64)> + 'a {
        move |rank, deliver| {
            for (b, (lo, hi)) in buckets.iter().enumerate() {
                deliver(b, &rows[rank][lo..hi]);
            }
            Ok((0.0, compute_s[rank]))
        }
    }

    fn run_mode(
        overlap: bool,
        threads: usize,
        name: &str,
        rows_data: &[Vec<f32>],
        buckets: &Buckets,
        compute: &[f64],
    ) -> (Vec<f32>, StepOutcome, SimClock) {
        let n = rows_data.len();
        let d = buckets.total();
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads,
            min_shard_elems: CHUNK,
        });
        let mut agg = aggregation::by_name(name, n).unwrap();
        let mut exec = PipelinedExecutor::new(n, buckets.clone(), overlap);
        let mut grads = GradSet::zeros(n, d);
        let mut out = vec![0.0f32; d];
        let mut clock = SimClock::new(n);
        let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
        let mut produce = replay_producer(rows_data, buckets, compute);
        let outcome = exec
            .run_step(
                &mut produce,
                agg.as_mut(),
                &mut grads,
                &mut out,
                &ctx,
                &mut clock,
                &cost,
            )
            .unwrap();
        (out, outcome, clock)
    }

    #[test]
    fn overlap_on_equals_off_bitwise_smoke() {
        let d = 3 * CHUNK + 77;
        let data = rows(4, d, 11);
        let buckets = Buckets::fixed(d, CHUNK + 13); // ragged, unaligned
        let compute = vec![0.01; 4];
        for name in ["adacons", "mean", "median"] {
            let (on, _, _) = run_mode(true, 3, name, &data, &buckets, &compute);
            let (off, _, _) = run_mode(false, 3, name, &data, &buckets, &compute);
            assert_eq!(on, off, "{name}");
        }
    }

    #[test]
    fn overlap_exposes_less_comm_than_serial_accounting() {
        let d = 4 * CHUNK;
        let data = rows(4, d, 5);
        let buckets = Buckets::fixed(d, CHUNK);
        let compute = vec![0.05; 4];
        let (_, on, _) = run_mode(true, 2, "adacons", &data, &buckets, &compute);
        assert!(
            on.exposed_comm_s < on.serial_comm_s,
            "{} vs {}",
            on.exposed_comm_s,
            on.serial_comm_s
        );
        let (_, off, _) = run_mode(false, 2, "adacons", &data, &buckets, &compute);
        assert!((off.exposed_comm_s - off.serial_comm_s).abs() < 1e-15);
        assert!((on.serial_comm_s - off.serial_comm_s).abs() < 1e-12);
    }

    #[test]
    fn grouped_ingest_matches_inline_hierarchical_bitwise() {
        // The per-node-group task decomposition (phase 1a reductions +
        // phase 1b leaders ingest) must produce the exact bits of the
        // hierarchical aggregator's inline path, uneven groups included.
        let (n, d) = (6usize, 3 * CHUNK + 41);
        let data = rows(n, d, 31);
        let gs = GradSet::from_rows(&data);
        let buckets = Buckets::fixed(d, CHUNK + 11);
        let map = crate::collective::NodeMap::from_sizes(&[3, 2, 1]);
        let mut oracle = vec![0.0f32; d];
        aggregation::hierarchical("adacons", map.clone(), n)
            .unwrap()
            .aggregate_ctx(
                &gs,
                &buckets,
                &mut oracle,
                &ParallelCtx::new(ParallelPolicy {
                    threads: 1,
                    min_shard_elems: CHUNK,
                }),
            );
        for threads in [1usize, 3] {
            let ctx = ParallelCtx::new(ParallelPolicy {
                threads,
                min_shard_elems: CHUNK,
            });
            let mut agg = aggregation::hierarchical("adacons", map.clone(), n).unwrap();
            let mut exec = PipelinedExecutor::with_topology(
                n,
                buckets.clone(),
                true,
                Some(map.clone()),
                None,
            );
            let mut grads = GradSet::zeros(n, d);
            let mut out = vec![0.0f32; d];
            let mut clock = SimClock::new(n);
            let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
            let compute = vec![0.01; n];
            let mut produce = replay_producer(&data, &buckets, &compute);
            exec.run_step(
                &mut produce,
                agg.as_mut(),
                &mut grads,
                &mut out,
                &ctx,
                &mut clock,
                &cost,
            )
            .unwrap();
            assert_eq!(out, oracle, "threads={threads}");
            // The full (N, d) assembly is still maintained for finalize.
            assert_eq!(grads.row(2), &data[2][..]);
        }
    }

    #[test]
    fn grouped_executor_rejects_flat_aggregator() {
        let (n, d) = (4usize, 2 * CHUNK);
        let data = rows(n, d, 13);
        let buckets = Buckets::fixed(d, CHUNK);
        let map = crate::collective::NodeMap::even(2, 2);
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: 1,
            min_shard_elems: CHUNK,
        });
        let mut agg = aggregation::by_name("mean", n).unwrap();
        let mut exec =
            PipelinedExecutor::with_topology(n, buckets.clone(), true, Some(map), None);
        let mut grads = GradSet::zeros(n, d);
        let mut out = vec![0.0f32; d];
        let mut clock = SimClock::new(n);
        let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
        let mut produce = replay_producer(&data, &buckets, &[0.01; 4]);
        let err = exec
            .run_step(
                &mut produce,
                agg.as_mut(),
                &mut grads,
                &mut out,
                &ctx,
                &mut clock,
                &cost,
            )
            .unwrap_err();
        assert!(err.to_string().contains("hierarchical executor"), "{err}");
    }

    #[test]
    fn exchange_reports_feed_the_clock_and_outcome() {
        // The threaded path's per-rank compute seconds come from the
        // ranks' Done messages, measured on-thread; they must drive the
        // SimClock exactly like the producer path's returned values.
        let d = 2 * CHUNK;
        let n = 2;
        let data = rows(n, d, 9);
        let buckets = Buckets::fixed(d, CHUNK);
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: 1,
            min_shard_elems: CHUNK,
        });
        let mut agg = aggregation::by_name("mean", n).unwrap();
        let mut exec = PipelinedExecutor::new(n, buckets.clone(), false);
        let mut grads = GradSet::zeros(n, d);
        let mut out = vec![0.0f32; d];
        let mut clock = SimClock::new(n);
        let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
        let (exchange, ports) = StepExchange::new(n);
        let mut handles = Vec::new();
        for port in ports {
            let row = data[port.rank()].clone();
            let bk = buckets.clone();
            let cs = 0.1 * (port.rank() + 1) as f64;
            handles.push(std::thread::spawn(move || {
                port.submit(&bk, &row);
                port.done(1.0 + port.rank() as f64, cs);
                port.complete();
            }));
        }
        let outcome = exec
            .run_step_exchange(
                &exchange,
                agg.as_mut(),
                &mut grads,
                &mut out,
                &ctx,
                &mut clock,
                &cost,
            )
            .unwrap();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(outcome.rank_compute_s, vec![0.1, 0.2]);
        assert!((outcome.mean_loss - 1.5).abs() < 1e-12);
        // Clock: ranks advanced by their own compute, then the barrier
        // collective aligned both to the straggler plus comm time.
        assert!(clock.now() >= 0.2);
        let mut expect = vec![0.0f32; d];
        GradSet::from_rows(&data).mean_into(&mut expect);
        assert_eq!(out, expect);
    }

    #[test]
    fn exchange_rank_down_fails_step_with_rank_id() {
        let d = 2 * CHUNK;
        let n = 3;
        let data = rows(n, d, 21);
        let buckets = Buckets::fixed(d, CHUNK);
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: 2,
            min_shard_elems: CHUNK,
        });
        let mut agg = aggregation::by_name("adacons", n).unwrap();
        let mut exec = PipelinedExecutor::new(n, buckets.clone(), true);
        let mut grads = GradSet::zeros(n, d);
        let mut out = vec![0.0f32; d];
        let mut clock = SimClock::new(n);
        let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
        let (exchange, ports) = StepExchange::new(n);
        let mut handles = Vec::new();
        for port in ports {
            let rank = port.rank();
            let row = data[rank].clone();
            let bk = buckets.clone();
            handles.push(std::thread::spawn(move || {
                if rank == 1 {
                    // Dies after one bucket: the armed port reports Down.
                    let (lo, hi) = bk.range(0);
                    port.submit_bucket(0, row[lo..hi].to_vec());
                    panic!("injected rank failure");
                }
                port.submit(&bk, &row);
                port.done(0.0, 0.01);
                port.complete();
            }));
        }
        let err = exec
            .run_step_exchange(
                &exchange,
                agg.as_mut(),
                &mut grads,
                &mut out,
                &ctx,
                &mut clock,
                &cost,
            )
            .unwrap_err();
        assert!(err.to_string().contains("rank 1"), "{err}");
        for (rank, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().is_err(), rank == 1);
        }
        // The executor stays reusable after the failed step: a clean
        // producer-fed retry aggregates correctly.
        let mut retry = replay_producer(&data, &buckets, &[0.01, 0.01, 0.01]);
        let mut agg2 = aggregation::by_name("mean", n).unwrap();
        exec.run_step(
            &mut retry,
            agg2.as_mut(),
            &mut grads,
            &mut out,
            &ctx,
            &mut clock,
            &cost,
        )
        .unwrap();
        let mut expect = vec![0.0f32; d];
        GradSet::from_rows(&data).mean_into(&mut expect);
        assert_eq!(out, expect);
    }

    /// Spawn `n` sender threads over an elastic exchange: each submits
    /// `rows[r]` with compute time `compute[r]`; ranks listed in `die`
    /// panic after a partial delivery instead.
    fn elastic_fixture(
        rows_data: &[Vec<f32>],
        buckets: &Buckets,
        compute: &[f64],
        die: &[usize],
    ) -> (StepExchange, Vec<std::thread::JoinHandle<()>>) {
        let n = rows_data.len();
        let (exchange, ports) = StepExchange::new_elastic(n, None);
        let mut handles = Vec::new();
        for port in ports {
            let rank = port.rank();
            let row = rows_data[rank].clone();
            let bk = buckets.clone();
            let cs = compute[rank];
            let dies = die.contains(&rank);
            handles.push(std::thread::spawn(move || {
                if dies {
                    let (lo, hi) = bk.range(0);
                    port.submit_bucket(0, row[lo..hi].to_vec());
                    panic!("injected rank failure");
                }
                port.submit(&bk, &row);
                port.done(1.0, cs);
                port.complete();
            }));
        }
        (exchange, handles)
    }

    fn elastic_run(
        policy: &ElasticPolicy,
        name: &str,
        rows_data: &[Vec<f32>],
        buckets: &Buckets,
        compute: &[f64],
        die: &[usize],
    ) -> (Vec<f32>, StepOutcome, SimClock) {
        let n = rows_data.len();
        let d = buckets.total();
        let ctx = ParallelCtx::serial();
        let mut agg = aggregation::by_name(name, n).unwrap();
        let mut exec = PipelinedExecutor::new(n, buckets.clone(), false);
        let mut grads = GradSet::zeros(n, d);
        let mut out = vec![0.0f32; d];
        let mut clock = SimClock::new(n);
        let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
        let (exchange, handles) = elastic_fixture(rows_data, buckets, compute, die);
        let outcome = exec
            .run_step_elastic(
                &exchange,
                policy,
                agg.as_mut(),
                name,
                &mut grads,
                &mut out,
                &ctx,
                &mut clock,
                &cost,
            )
            .unwrap();
        for h in handles {
            let _ = h.join();
        }
        (out, outcome, clock)
    }

    #[test]
    fn elastic_full_strength_matches_normal_path_bitwise() {
        // Cutoff armed but nothing fails and nobody straggles: the step
        // must be bitwise what the non-elastic exchange path computes,
        // with identical simulated time.
        let d = 2 * CHUNK + 9;
        let n = 3;
        let data = rows(n, d, 17);
        let buckets = Buckets::fixed(d, CHUNK);
        let compute = vec![0.01, 0.012, 0.011];
        for name in ["mean", "adacons"] {
            // Normal exchange path (overlap off).
            let ctx = ParallelCtx::serial();
            let mut agg = aggregation::by_name(name, n).unwrap();
            let mut exec = PipelinedExecutor::new(n, buckets.clone(), false);
            let mut grads = GradSet::zeros(n, d);
            let mut normal = vec![0.0f32; d];
            let mut clock_a = SimClock::new(n);
            let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
            let (exchange, ports) = StepExchange::new(n);
            let mut handles = Vec::new();
            for port in ports {
                let row = data[port.rank()].clone();
                let bk = buckets.clone();
                let cs = compute[port.rank()];
                handles.push(std::thread::spawn(move || {
                    port.submit(&bk, &row);
                    port.done(1.0, cs);
                    port.complete();
                }));
            }
            exec.run_step_exchange(
                &exchange,
                agg.as_mut(),
                &mut grads,
                &mut normal,
                &ctx,
                &mut clock_a,
                &cost,
            )
            .unwrap();
            for h in handles {
                h.join().unwrap();
            }
            let policy = ElasticPolicy {
                k: 2,
                grace_s: 10.0,
                krum_f: 0,
            };
            let (elastic, outcome, clock_b) =
                elastic_run(&policy, name, &data, &buckets, &compute, &[]);
            assert_eq!(elastic, normal, "{name}");
            assert_eq!(outcome.survivors, n);
            assert!(outcome.dead_ranks.is_empty());
            assert_eq!(clock_a.now().to_bits(), clock_b.now().to_bits(), "{name}");
        }
    }

    #[test]
    fn elastic_cutoff_drops_the_straggler_and_renormalizes() {
        let d = CHUNK;
        let n = 4;
        let data = rows(n, d, 23);
        let buckets = Buckets::single(d);
        // Rank 2 straggles far beyond the grace window.
        let compute = vec![0.01, 0.011, 5.0, 0.012];
        let policy = ElasticPolicy {
            k: 3,
            grace_s: 0.5,
            krum_f: 0,
        };
        let (out, outcome, clock) =
            elastic_run(&policy, "mean", &data, &buckets, &compute, &[]);
        assert_eq!(outcome.survivors, 3);
        assert!(outcome.dead_ranks.is_empty());
        // Unbiasedness mechanics: the degraded direction is the plain
        // mean over the survivor rows — weights renormalized to sum to
        // one across survivors, nothing leaking from the dropped rank.
        let survivor_rows: Vec<Vec<f32>> = [0usize, 1, 3]
            .iter()
            .map(|&r| data[r].clone())
            .collect();
        let mut expect = vec![0.0f32; d];
        GradSet::from_rows(&survivor_rows).mean_into(&mut expect);
        assert_eq!(out, expect);
        // The cancelled straggler does not pace the simulated step.
        assert!(clock.now() < 1.0, "{}", clock.now());
    }

    #[test]
    fn elastic_step_survives_a_dead_rank() {
        let d = CHUNK;
        let n = 3;
        let data = rows(n, d, 29);
        let buckets = Buckets::fixed(d, CHUNK / 2);
        let policy = ElasticPolicy {
            k: 2,
            grace_s: 1.0,
            krum_f: 0,
        };
        let (out, outcome, _) =
            elastic_run(&policy, "mean", &data, &buckets, &[0.01; 3], &[1]);
        assert_eq!(outcome.dead_ranks, vec![1]);
        assert_eq!(outcome.survivors, 2);
        let survivor_rows = vec![data[0].clone(), data[2].clone()];
        let mut expect = vec![0.0f32; d];
        GradSet::from_rows(&survivor_rows).mean_into(&mut expect);
        assert_eq!(out, expect);
    }

    #[test]
    fn elastic_krum_excludes_nan_and_outlier_ranks() {
        let d = 64;
        let n = 5;
        let mut data = rows(n, d, 37);
        // Rank 1 ships NaNs (corrupted buffers), rank 4 a huge outlier.
        data[1] = vec![f32::NAN; d];
        data[4] = vec![1.0e6; d];
        let buckets = Buckets::single(d);
        let policy = ElasticPolicy {
            k: 2,
            grace_s: 10.0,
            krum_f: 1,
        };
        let (out, outcome, _) =
            elastic_run(&policy, "mean", &data, &buckets, &[0.01; 5], &[]);
        // NaN rank always excluded; among the 4 finite rows (m=4 >= f+3)
        // the krum score drops the distant outlier.
        assert_eq!(outcome.survivors, 3);
        let survivor_rows: Vec<Vec<f32>> =
            [0usize, 2, 3].iter().map(|&r| data[r].clone()).collect();
        let mut expect = vec![0.0f32; d];
        GradSet::from_rows(&survivor_rows).mean_into(&mut expect);
        assert_eq!(out, expect);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn elastic_quorum_violation_fails_the_step() {
        let d = 16;
        let n = 3;
        let data = rows(n, d, 41);
        let buckets = Buckets::single(d);
        let ctx = ParallelCtx::serial();
        let mut agg = aggregation::by_name("mean", n).unwrap();
        let mut exec = PipelinedExecutor::new(n, buckets.clone(), false);
        let mut grads = GradSet::zeros(n, d);
        let mut out = vec![0.0f32; d];
        let mut clock = SimClock::new(n);
        let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
        let policy = ElasticPolicy {
            k: 3,
            grace_s: 1.0,
            krum_f: 0,
        };
        let (exchange, handles) =
            elastic_fixture(&data, &buckets, &[0.01; 3], &[0, 2]);
        let err = exec
            .run_step_elastic(
                &exchange,
                &policy,
                agg.as_mut(),
                "mean",
                &mut grads,
                &mut out,
                &ctx,
                &mut clock,
                &cost,
            )
            .unwrap_err();
        assert!(err.to_string().contains("quorum"), "{err}");
        for h in handles {
            let _ = h.join();
        }
    }

    #[test]
    fn producer_error_propagates_cleanly() {
        let d = 2 * CHUNK;
        let n = 3;
        let data = rows(n, d, 7);
        let buckets = Buckets::fixed(d, CHUNK);
        let ctx = ParallelCtx::new(ParallelPolicy {
            threads: 2,
            min_shard_elems: CHUNK,
        });
        let mut agg = aggregation::by_name("mean", n).unwrap();
        let mut exec = PipelinedExecutor::new(n, buckets.clone(), true);
        let mut grads = GradSet::zeros(n, d);
        let mut out = vec![0.0f32; d];
        let mut clock = SimClock::new(n);
        let cost = CostModel::from_topology(&Topology::ring_gbps(n, 100.0));
        let mut produce = |rank: usize,
                           deliver: &mut dyn FnMut(usize, &[f32])|
         -> Result<(f64, f64)> {
            if rank == 2 {
                return Err(crate::err!("rank 2 fell over"));
            }
            for (b, (lo, hi)) in buckets.iter().enumerate() {
                deliver(b, &data[rank][lo..hi]);
            }
            Ok((0.0, 0.01))
        };
        let r = exec.run_step(
            &mut produce,
            agg.as_mut(),
            &mut grads,
            &mut out,
            &ctx,
            &mut clock,
            &cost,
        );
        assert!(r.is_err());
        // The executor must stay reusable after a failed step (bucket
        // stores that were moved into tasks are rebuilt on the error
        // path): a clean retry produces the correct aggregate.
        let mut retry = |rank: usize,
                         deliver: &mut dyn FnMut(usize, &[f32])|
         -> Result<(f64, f64)> {
            for (b, (lo, hi)) in buckets.iter().enumerate() {
                deliver(b, &data[rank][lo..hi]);
            }
            Ok((0.0, 0.01))
        };
        exec.run_step(
            &mut retry,
            agg.as_mut(),
            &mut grads,
            &mut out,
            &ctx,
            &mut clock,
            &cost,
        )
        .unwrap();
        let mut expect = vec![0.0f32; d];
        GradSet::from_rows(&data).mean_into(&mut expect);
        assert_eq!(out, expect);
    }
}
