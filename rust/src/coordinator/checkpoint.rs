//! Binary checkpoints.
//!
//! Version 2 (`ADACONS2`) captures the **complete** training state, not
//! just the iterate: parameters + step counter, optimizer slot state
//! (momentum / Adam moments + bias-correction clock), the aggregator's
//! internal momentum (AdaCons' per-rank EMA statistics), and every
//! compression error-feedback residual (per-rank codecs and the
//! hierarchical set codec). Restoring therefore continues a fault-free
//! run **bitwise-identically** — the invariant
//! `tests/fault_tolerance.rs` pins across aggregators, topologies, and
//! compression settings. Version 1 files (`ADACONS1`: step + params
//! only) still load, with empty extras.
//!
//! Layout (all integers LE): magic, step u64, params (u64 len + f32s),
//! opt_t u64, opt slots (u64 count, each u64 len + f32s), aggregator
//! state rows (u64 count, each u64 len + f64s), per-rank residuals (u64
//! rank count, each u64 bucket count, each u64 len + f32s), set-codec
//! flag u8 (1 => step u64 + banks as u64 count, each u64 len + f32s),
//! then an *optional trailing* adaptive local-step section: flag u8
//! (1 => H u64). The trailing section is absent in files written before
//! the local-step regime existed — the reader maps EOF to `None`, so
//! those files still load.

use std::io::{Read, Write};
use std::path::Path;

use crate::util::error::{bail, Context, Result};

const MAGIC_V1: &[u8; 8] = b"ADACONS1";
const MAGIC_V2: &[u8; 8] = b"ADACONS2";

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<f32>,
    /// Optimizer step clock (Adam's bias-correction `t`; 0 for
    /// stateless/SGD-momentum optimizers).
    pub opt_t: u64,
    /// Optimizer slot state (velocity / first + second moments).
    pub opt_slots: Vec<Vec<f32>>,
    /// Aggregator momentum state (AdaCons' sorted per-rank EMA rows).
    pub agg_state: Vec<Vec<f64>>,
    /// Per-rank compression error-feedback residuals
    /// (rank -> bucket -> columns); empty when compression is off.
    pub rank_residuals: Vec<Vec<Vec<f32>>>,
    /// Hierarchical set-codec state: (stochastic-rounding step, per-bucket
    /// error-feedback banks).
    pub set_codec: Option<(u64, Vec<Vec<f32>>)>,
    /// Adaptive local-step controller carry: the H the next sync round
    /// would use under `--local-steps auto:<min>-<max>`. None for
    /// fixed-H runs and files written before the local-step regime.
    pub local_h: Option<u64>,
}

fn write_f32s(f: &mut impl Write, v: &[f32]) -> Result<()> {
    f.write_all(&(v.len() as u64).to_le_bytes())?;
    for x in v {
        f.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn write_f64s(f: &mut impl Write, v: &[f64]) -> Result<()> {
    f.write_all(&(v.len() as u64).to_le_bytes())?;
    for x in v {
        f.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s(f: &mut impl Read) -> Result<Vec<f32>> {
    let len = read_u64(f)? as usize;
    let mut bytes = vec![0u8; len * 4];
    f.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_f64s(f: &mut impl Read) -> Result<Vec<f64>> {
    let len = read_u64(f)? as usize;
    let mut bytes = vec![0u8; len * 8];
    f.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| {
            f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
        })
        .collect())
}

impl Checkpoint {
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC_V2)?;
        f.write_all(&self.step.to_le_bytes())?;
        write_f32s(&mut f, &self.params)?;
        f.write_all(&self.opt_t.to_le_bytes())?;
        f.write_all(&(self.opt_slots.len() as u64).to_le_bytes())?;
        for slot in &self.opt_slots {
            write_f32s(&mut f, slot)?;
        }
        f.write_all(&(self.agg_state.len() as u64).to_le_bytes())?;
        for row in &self.agg_state {
            write_f64s(&mut f, row)?;
        }
        f.write_all(&(self.rank_residuals.len() as u64).to_le_bytes())?;
        for rank in &self.rank_residuals {
            f.write_all(&(rank.len() as u64).to_le_bytes())?;
            for bucket in rank {
                write_f32s(&mut f, bucket)?;
            }
        }
        match &self.set_codec {
            None => f.write_all(&[0u8])?,
            Some((step, banks)) => {
                f.write_all(&[1u8])?;
                f.write_all(&step.to_le_bytes())?;
                f.write_all(&(banks.len() as u64).to_le_bytes())?;
                for bank in banks {
                    write_f32s(&mut f, bank)?;
                }
            }
        }
        match self.local_h {
            None => f.write_all(&[0u8])?,
            Some(h) => {
                f.write_all(&[1u8])?;
                f.write_all(&h.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path).with_context(|| format!("{:?}", path.as_ref()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        let v2 = match &magic {
            m if m == MAGIC_V2 => true,
            m if m == MAGIC_V1 => false,
            _ => bail!("not an adacons checkpoint"),
        };
        let step = read_u64(&mut f)?;
        let params = read_f32s(&mut f)?;
        if !v2 {
            // Legacy step+params file: no optimizer/aggregator/residual
            // state was captured.
            return Ok(Checkpoint {
                step,
                params,
                ..Checkpoint::default()
            });
        }
        let opt_t = read_u64(&mut f)?;
        let n_slots = read_u64(&mut f)? as usize;
        let mut opt_slots = Vec::with_capacity(n_slots);
        for _ in 0..n_slots {
            opt_slots.push(read_f32s(&mut f)?);
        }
        let n_rows = read_u64(&mut f)? as usize;
        let mut agg_state = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            agg_state.push(read_f64s(&mut f)?);
        }
        let n_ranks = read_u64(&mut f)? as usize;
        let mut rank_residuals = Vec::with_capacity(n_ranks);
        for _ in 0..n_ranks {
            let n_buckets = read_u64(&mut f)? as usize;
            let mut buckets = Vec::with_capacity(n_buckets);
            for _ in 0..n_buckets {
                buckets.push(read_f32s(&mut f)?);
            }
            rank_residuals.push(buckets);
        }
        let mut flag = [0u8; 1];
        f.read_exact(&mut flag)?;
        let set_codec = if flag[0] == 1 {
            let step = read_u64(&mut f)?;
            let n_banks = read_u64(&mut f)? as usize;
            let mut banks = Vec::with_capacity(n_banks);
            for _ in 0..n_banks {
                banks.push(read_f32s(&mut f)?);
            }
            Some((step, banks))
        } else {
            None
        };
        // Trailing adaptive-H section: absent (EOF right here) in files
        // written before the local-step regime — treat that as None.
        let mut hflag = [0u8; 1];
        let local_h = match f.read_exact(&mut hflag) {
            Ok(()) if hflag[0] == 1 => Some(read_u64(&mut f)?),
            Ok(()) => None,
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => None,
            Err(e) => return Err(e.into()),
        };
        Ok(Checkpoint {
            step,
            params,
            opt_t,
            opt_slots,
            agg_state,
            rank_residuals,
            set_codec,
            local_h,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bit_exact() {
        let ck = Checkpoint {
            step: 123,
            params: vec![1.5, -2.25, f32::MIN_POSITIVE, 0.0, 3.0e30],
            opt_t: 7,
            opt_slots: vec![vec![0.5, -0.5], vec![]],
            agg_state: vec![vec![1.0e-300, 2.5], vec![-3.25]],
            rank_residuals: vec![vec![vec![0.125], vec![]], vec![vec![9.0, -9.0]]],
            set_codec: Some((42, vec![vec![1.0, 2.0], vec![]])),
            local_h: Some(12),
        };
        let dir = std::env::temp_dir().join("adacons_ckpt_test");
        let path = dir.join("a.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_without_extras() {
        let ck = Checkpoint {
            step: 5,
            params: vec![1.0, 2.0],
            ..Checkpoint::default()
        };
        let dir = std::env::temp_dir().join("adacons_ckpt_plain");
        let path = dir.join("p.ckpt");
        ck.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ck);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_v1_files_still_load() {
        // Hand-write a v1 (step + params) file; extras must come back
        // empty rather than erroring.
        let dir = std::env::temp_dir().join("adacons_ckpt_v1");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old.ckpt");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"ADACONS1");
        bytes.extend_from_slice(&9u64.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        bytes.extend_from_slice(&1.5f32.to_le_bytes());
        bytes.extend_from_slice(&(-2.5f32).to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.step, 9);
        assert_eq!(ck.params, vec![1.5, -2.5]);
        assert_eq!(ck.opt_t, 0);
        assert!(ck.opt_slots.is_empty() && ck.agg_state.is_empty());
        assert!(ck.rank_residuals.is_empty() && ck.set_codec.is_none());
        assert!(ck.local_h.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v2_without_trailing_local_h_section_still_loads() {
        // Files written before the local-step regime end right after the
        // set-codec section; truncating the trailing byte(s) simulates
        // one. The reader must map EOF there to `local_h: None`.
        let ck = Checkpoint {
            step: 17,
            params: vec![0.25, -4.0],
            opt_t: 3,
            opt_slots: vec![vec![1.0]],
            agg_state: vec![vec![2.0]],
            rank_residuals: vec![],
            set_codec: None,
            local_h: None,
        };
        let dir = std::env::temp_dir().join("adacons_ckpt_pre_local_h");
        let path = dir.join("pre.ckpt");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.pop(); // drop the trailing local-H flag byte
        std::fs::write(&path, bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("adacons_ckpt_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
