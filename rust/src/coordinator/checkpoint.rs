//! Binary checkpoints: magic + version + step + param vector (LE f32).

use std::io::{Read, Write};
use std::path::Path;

use crate::util::error::{bail, Context, Result};

const MAGIC: &[u8; 8] = b"ADACONS1";

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<f32>,
}

impl Checkpoint {
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&(self.params.len() as u64).to_le_bytes())?;
        for p in &self.params {
            f.write_all(&p.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path).with_context(|| format!("{:?}", path.as_ref()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not an adacons checkpoint");
        }
        let mut u64buf = [0u8; 8];
        f.read_exact(&mut u64buf)?;
        let step = u64::from_le_bytes(u64buf);
        f.read_exact(&mut u64buf)?;
        let len = u64::from_le_bytes(u64buf) as usize;
        let mut bytes = vec![0u8; len * 4];
        f.read_exact(&mut bytes)?;
        let params = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Checkpoint { step, params })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bit_exact() {
        let ck = Checkpoint {
            step: 123,
            params: vec![1.5, -2.25, f32::MIN_POSITIVE, 0.0, 3.0e30],
        };
        let dir = std::env::temp_dir().join("adacons_ckpt_test");
        let path = dir.join("a.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("adacons_ckpt_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
