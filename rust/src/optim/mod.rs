//! Optimizers applied to the aggregated direction (paper §3.2: "other
//! optimizers (e.g., Adam) can be applied to the obtained aggregated
//! directions"), learning-rate schedules, and gradient clipping.

pub mod clip;
pub mod linreg_exact;
pub mod optimizer;
pub mod schedule;

pub use clip::clip_global_norm;
pub use linreg_exact::LinregExact;
pub use optimizer::{Adam, AdamW, Lamb, Optimizer, Sgd, SgdMomentum};
pub use schedule::Schedule;

/// Build an optimizer by name: `sgd`, `sgd-momentum`, `adam`, `adamw`, `lamb`.
pub fn by_name(name: &str, d: usize) -> Option<Box<dyn Optimizer>> {
    match name {
        "sgd" => Some(Box::new(Sgd::new())),
        "linreg-exact" => Some(Box::new(LinregExact::new())),
        "sgd-momentum" => Some(Box::new(SgdMomentum::new(d, 0.9))),
        "adam" => Some(Box::new(Adam::new(d, 0.9, 0.999, 1e-8))),
        "adamw" => Some(Box::new(AdamW::new(d, 0.9, 0.999, 1e-8, 0.01))),
        "lamb" => Some(Box::new(Lamb::new(d, 0.9, 0.999, 1e-6, 0.01))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn registry() {
        for n in ["sgd", "sgd-momentum", "adam", "adamw", "lamb", "linreg-exact"] {
            assert!(super::by_name(n, 4).is_some(), "{n}");
        }
        assert!(super::by_name("lion", 4).is_none());
    }
}
