//! Exact line search for the stochastic linear-regression task (Eq. 14).
//!
//! The paper's Fig. 2 protocol: "For a fair, hyperparameter-free
//! comparison, we provide each method with the optimal (analytical) step
//! size".  For `f(w) = ½ E_{x~U[0,1]^d} (wᵀx)² = ½ wᵀHw` the Hessian is
//! known in closed form — `H = I/12 + 𝟙𝟙ᵀ/4` (Var[x_i] = 1/12,
//! E[x_i x_j] = 1/4) — so the exact minimizer along any direction ψ is
//! `η* = (ψᵀHw)/(ψᵀHψ)`, computable in O(d) from two dot products and two
//! sums.  This gives *every* aggregator its optimal step, which is what
//! makes the Fig. 2 comparison scale-free (AdaCons' normalized direction
//! has a different magnitude than the mean; line search absorbs it).

use super::optimizer::Optimizer;
use crate::tensor::ops;

#[derive(Debug, Default)]
pub struct LinregExact;

impl LinregExact {
    pub fn new() -> Self {
        LinregExact
    }

    /// `Hv` contraction helpers: vᵀHu = (v·u)/12 + (Σv)(Σu)/4.
    fn h_bilinear(v: &[f32], u: &[f32]) -> f64 {
        ops::dot(v, u) / 12.0 + ops::sum(v) * ops::sum(u) / 4.0
    }
}

impl Optimizer for LinregExact {
    fn name(&self) -> &'static str {
        "linreg-exact"
    }

    fn step(&mut self, params: &mut [f32], direction: &[f32], _lr: f32) {
        let num = Self::h_bilinear(direction, params);
        let den = Self::h_bilinear(direction, direction);
        if den <= 0.0 || !num.is_finite() || !den.is_finite() {
            return;
        }
        let eta = (num / den) as f32;
        ops::axpy(-eta, direction, params);
    }

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn loss(w: &[f32]) -> f64 {
        // ½ wᵀHw with H = I/12 + J/4.
        0.5 * (ops::sqnorm(w) / 12.0 + ops::sum(w).powi(2) / 4.0)
    }

    fn grad(w: &[f32]) -> Vec<f32> {
        // Hw
        let s = (ops::sum(w) / 4.0) as f32;
        w.iter().map(|&x| x / 12.0 + s).collect()
    }

    #[test]
    fn line_search_monotonically_decreases_population_loss() {
        let mut rng = Rng::new(0);
        let mut w: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.2)).collect();
        let mut opt = LinregExact::new();
        let init = loss(&w);
        let mut prev = init;
        // Steepest descent with exact line search on a kappa~200 quadratic
        // converges at ((k-1)/(k+1))^2 per step — slow but monotone; the
        // fast convergence in training comes from stochastic directions.
        for _ in 0..300 {
            let g = grad(&w);
            opt.step(&mut w, &g, 0.0);
            let cur = loss(&w);
            assert!(cur <= prev + 1e-9, "{cur} > {prev}");
            prev = cur;
        }
        assert!(prev < 0.05 * init, "final loss {prev} vs init {init}");
    }

    #[test]
    fn exact_step_on_eigvector_reaches_zero_in_one_step() {
        // Along the all-ones direction, one exact step removes that mode.
        let d = 16;
        let w = vec![1.0f32; d];
        let mut w2 = w.clone();
        let g = grad(&w);
        LinregExact::new().step(&mut w2, &g, 0.0);
        assert!(loss(&w2) < 1e-10 * loss(&w));
    }

    #[test]
    fn degenerate_direction_is_ignored() {
        let mut w = vec![1.0f32, 2.0];
        let before = w.clone();
        LinregExact::new().step(&mut w, &[0.0, 0.0], 0.0);
        assert_eq!(w, before);
    }
}
