//! Global-norm gradient clipping (paper §5.4 / Fig. 8: clipping is
//! critical for large transformers but limits AdaCons' effectiveness —
//! the Fig. 8 harness toggles this).

use crate::tensor::ops;

/// Clip `grad` in place to global L2 norm `max_norm`. Returns the scale
/// that was applied (1.0 when no clipping happened).
pub fn clip_global_norm(grad: &mut [f32], max_norm: f64) -> f64 {
    let norm = ops::nrm2(grad);
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        ops::scale(scale as f32, grad);
        scale
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clips_when_above() {
        let mut g = vec![3.0f32, 4.0]; // norm 5
        let s = clip_global_norm(&mut g, 1.0);
        assert!((s - 0.2).abs() < 1e-12);
        assert!((ops::nrm2(&g) - 1.0).abs() < 1e-6);
        // direction preserved
        assert!((g[0] / g[1] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn noop_when_below() {
        let mut g = vec![0.3f32, 0.4];
        let s = clip_global_norm(&mut g, 1.0);
        assert_eq!(s, 1.0);
        assert_eq!(g, vec![0.3, 0.4]);
    }

    #[test]
    fn zero_gradient_safe() {
        let mut g = vec![0.0f32; 4];
        assert_eq!(clip_global_norm(&mut g, 1.0), 1.0);
    }
}
