//! Learning-rate schedules (the MLPerf baselines use warmup + decay).

/// LR as a function of the global step.
#[derive(Debug, Clone, PartialEq)]
pub enum Schedule {
    Const {
        lr: f64,
    },
    /// Linear warmup to `lr` over `warmup` steps, then cosine decay to
    /// `final_frac * lr` at `total` steps.
    WarmupCosine {
        lr: f64,
        warmup: usize,
        total: usize,
        final_frac: f64,
    },
    /// Step decay: lr * gamma^(step / every).
    StepDecay {
        lr: f64,
        every: usize,
        gamma: f64,
    },
    /// Linear warmup then inverse-sqrt decay (transformer pretraining).
    WarmupInvSqrt {
        lr: f64,
        warmup: usize,
    },
}

impl Schedule {
    pub fn lr(&self, step: usize) -> f64 {
        match *self {
            Schedule::Const { lr } => lr,
            Schedule::WarmupCosine {
                lr,
                warmup,
                total,
                final_frac,
            } => {
                if warmup > 0 && step < warmup {
                    lr * (step + 1) as f64 / warmup as f64
                } else {
                    let t = ((step - warmup) as f64 / (total.saturating_sub(warmup)).max(1) as f64)
                        .min(1.0);
                    let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
                    lr * (final_frac + (1.0 - final_frac) * cos)
                }
            }
            Schedule::StepDecay { lr, every, gamma } => lr * gamma.powi((step / every) as i32),
            Schedule::WarmupInvSqrt { lr, warmup } => {
                if warmup > 0 && step < warmup {
                    lr * (step + 1) as f64 / warmup as f64
                } else {
                    lr * (warmup.max(1) as f64 / (step + 1) as f64).sqrt()
                }
            }
        }
    }

    /// Parse `const:0.1`, `cosine:0.1:100:1000[:0.01]`, `step:0.1:30:0.1`,
    /// `invsqrt:0.001:100`.
    pub fn parse(s: &str) -> Option<Schedule> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["const", lr] => Some(Schedule::Const { lr: lr.parse().ok()? }),
            ["cosine", lr, warmup, total] => Some(Schedule::WarmupCosine {
                lr: lr.parse().ok()?,
                warmup: warmup.parse().ok()?,
                total: total.parse().ok()?,
                final_frac: 0.0,
            }),
            ["cosine", lr, warmup, total, ff] => Some(Schedule::WarmupCosine {
                lr: lr.parse().ok()?,
                warmup: warmup.parse().ok()?,
                total: total.parse().ok()?,
                final_frac: ff.parse().ok()?,
            }),
            ["step", lr, every, gamma] => Some(Schedule::StepDecay {
                lr: lr.parse().ok()?,
                every: every.parse().ok()?,
                gamma: gamma.parse().ok()?,
            }),
            ["invsqrt", lr, warmup] => Some(Schedule::WarmupInvSqrt {
                lr: lr.parse().ok()?,
                warmup: warmup.parse().ok()?,
            }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_schedule() {
        let s = Schedule::Const { lr: 0.1 };
        assert_eq!(s.lr(0), 0.1);
        assert_eq!(s.lr(10_000), 0.1);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = Schedule::WarmupCosine {
            lr: 1.0,
            warmup: 10,
            total: 110,
            final_frac: 0.0,
        };
        assert!(s.lr(0) < s.lr(5));
        assert!((s.lr(9) - 1.0).abs() < 1e-9); // end of warmup
        assert!(s.lr(60) < 1.0);
        assert!(s.lr(109) < 0.01);
        assert!(s.lr(500) >= 0.0); // clamped past total
    }

    #[test]
    fn step_decay() {
        let s = Schedule::StepDecay {
            lr: 1.0,
            every: 10,
            gamma: 0.5,
        };
        assert_eq!(s.lr(0), 1.0);
        assert_eq!(s.lr(10), 0.5);
        assert_eq!(s.lr(25), 0.25);
    }

    #[test]
    fn invsqrt_decays() {
        let s = Schedule::WarmupInvSqrt { lr: 1.0, warmup: 4 };
        assert!(s.lr(0) < s.lr(3));
        assert!((s.lr(3) - 1.0).abs() < 1e-9);
        assert!(s.lr(99) < 0.3);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(
            Schedule::parse("const:0.5").unwrap(),
            Schedule::Const { lr: 0.5 }
        );
        assert!(matches!(
            Schedule::parse("cosine:0.1:10:100").unwrap(),
            Schedule::WarmupCosine { .. }
        ));
        assert!(matches!(
            Schedule::parse("step:0.1:30:0.5").unwrap(),
            Schedule::StepDecay { .. }
        ));
        assert!(matches!(
            Schedule::parse("invsqrt:0.001:100").unwrap(),
            Schedule::WarmupInvSqrt { .. }
        ));
        assert!(Schedule::parse("bogus").is_none());
        assert!(Schedule::parse("const:x").is_none());
    }
}
