//! First-order optimizers over flat parameter vectors.

/// A stateful optimizer stepping flat `f32` parameters with a flat update
/// direction (the aggregated gradient).
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;
    /// `params -= lr * f(direction)` where `f` is the optimizer's transform.
    fn step(&mut self, params: &mut [f32], direction: &[f32], lr: f32);
    fn reset(&mut self);

    /// Serializable state for checkpointing: a step counter plus flat f32
    /// slot vectors (momentum/variance buffers). Stateless optimizers
    /// export `(0, [])`.
    fn export_state(&self) -> (u64, Vec<Vec<f32>>) {
        (0, Vec::new())
    }

    /// Restore state exported by [`Optimizer::export_state`]. Slots whose
    /// shapes do not match this optimizer (e.g. a v1 checkpoint with no
    /// optimizer section) are ignored — the optimizer keeps fresh state,
    /// which matches the pre-versioned restore behaviour.
    fn import_state(&mut self, t: u64, slots: &[Vec<f32>]) {
        let _ = (t, slots);
    }
}

/// Plain SGD.
#[derive(Debug, Default)]
pub struct Sgd;

impl Sgd {
    pub fn new() -> Self {
        Sgd
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn step(&mut self, params: &mut [f32], direction: &[f32], lr: f32) {
        for (p, g) in params.iter_mut().zip(direction) {
            *p -= lr * g;
        }
    }

    fn reset(&mut self) {}
}

/// SGD with heavy-ball momentum.
#[derive(Debug)]
pub struct SgdMomentum {
    mu: f32,
    velocity: Vec<f32>,
}

impl SgdMomentum {
    pub fn new(d: usize, mu: f32) -> Self {
        SgdMomentum {
            mu,
            velocity: vec![0.0; d],
        }
    }
}

impl Optimizer for SgdMomentum {
    fn name(&self) -> &'static str {
        "sgd-momentum"
    }

    fn step(&mut self, params: &mut [f32], direction: &[f32], lr: f32) {
        for ((p, g), v) in params.iter_mut().zip(direction).zip(&mut self.velocity) {
            *v = self.mu * *v + g;
            *p -= lr * *v;
        }
    }

    fn reset(&mut self) {
        self.velocity.iter_mut().for_each(|v| *v = 0.0);
    }

    fn export_state(&self) -> (u64, Vec<Vec<f32>>) {
        (0, vec![self.velocity.clone()])
    }

    fn import_state(&mut self, _t: u64, slots: &[Vec<f32>]) {
        if slots.len() == 1 && slots[0].len() == self.velocity.len() {
            self.velocity.copy_from_slice(&slots[0]);
        }
    }
}

/// Adam (Kingma & Ba) with bias correction.
#[derive(Debug)]
pub struct Adam {
    b1: f32,
    b2: f32,
    eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
}

impl Adam {
    pub fn new(d: usize, b1: f32, b2: f32, eps: f32) -> Self {
        Adam {
            b1,
            b2,
            eps,
            m: vec![0.0; d],
            v: vec![0.0; d],
            t: 0,
        }
    }

    fn adam_update(&mut self, params: &mut [f32], direction: &[f32], lr: f32, wd: f32) {
        self.t += 1;
        let c1 = 1.0 - self.b1.powi(self.t);
        let c2 = 1.0 - self.b2.powi(self.t);
        for i in 0..params.len() {
            let g = direction[i];
            self.m[i] = self.b1 * self.m[i] + (1.0 - self.b1) * g;
            self.v[i] = self.b2 * self.v[i] + (1.0 - self.b2) * g * g;
            let mhat = self.m[i] / c1;
            let vhat = self.v[i] / c2;
            params[i] -= lr * (mhat / (vhat.sqrt() + self.eps) + wd * params[i]);
        }
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn step(&mut self, params: &mut [f32], direction: &[f32], lr: f32) {
        self.adam_update(params, direction, lr, 0.0);
    }

    fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }

    fn export_state(&self) -> (u64, Vec<Vec<f32>>) {
        (self.t as u64, vec![self.m.clone(), self.v.clone()])
    }

    fn import_state(&mut self, t: u64, slots: &[Vec<f32>]) {
        if slots.len() == 2 && slots[0].len() == self.m.len() && slots[1].len() == self.v.len() {
            self.m.copy_from_slice(&slots[0]);
            self.v.copy_from_slice(&slots[1]);
            self.t = t as i32;
        }
    }
}

/// AdamW — Adam with decoupled weight decay.
#[derive(Debug)]
pub struct AdamW {
    inner: Adam,
    weight_decay: f32,
}

impl AdamW {
    pub fn new(d: usize, b1: f32, b2: f32, eps: f32, weight_decay: f32) -> Self {
        AdamW {
            inner: Adam::new(d, b1, b2, eps),
            weight_decay,
        }
    }
}

impl Optimizer for AdamW {
    fn name(&self) -> &'static str {
        "adamw"
    }

    fn step(&mut self, params: &mut [f32], direction: &[f32], lr: f32) {
        let wd = self.weight_decay;
        self.inner.adam_update(params, direction, lr, wd);
    }

    fn reset(&mut self) {
        self.inner.reset();
    }

    fn export_state(&self) -> (u64, Vec<Vec<f32>>) {
        self.inner.export_state()
    }

    fn import_state(&mut self, t: u64, slots: &[Vec<f32>]) {
        self.inner.import_state(t, slots);
    }
}

/// LAMB (You et al.) — layer-adaptive large-batch optimizer; here applied
/// model-wise over the flat vector (trust ratio over the whole vector),
/// which is the flat-parameter analogue the BERT bench uses.
#[derive(Debug)]
pub struct Lamb {
    inner: Adam,
    weight_decay: f32,
}

impl Lamb {
    pub fn new(d: usize, b1: f32, b2: f32, eps: f32, weight_decay: f32) -> Self {
        Lamb {
            inner: Adam::new(d, b1, b2, eps),
            weight_decay,
        }
    }
}

impl Optimizer for Lamb {
    fn name(&self) -> &'static str {
        "lamb"
    }

    fn step(&mut self, params: &mut [f32], direction: &[f32], lr: f32) {
        let a = &mut self.inner;
        a.t += 1;
        let c1 = 1.0 - a.b1.powi(a.t);
        let c2 = 1.0 - a.b2.powi(a.t);
        // Build the Adam update, then rescale by the trust ratio.
        let mut update = vec![0.0f32; params.len()];
        for i in 0..params.len() {
            let g = direction[i];
            a.m[i] = a.b1 * a.m[i] + (1.0 - a.b1) * g;
            a.v[i] = a.b2 * a.v[i] + (1.0 - a.b2) * g * g;
            let mhat = a.m[i] / c1;
            let vhat = a.v[i] / c2;
            update[i] = mhat / (vhat.sqrt() + a.eps) + self.weight_decay * params[i];
        }
        let wnorm = crate::tensor::ops::nrm2(params) as f32;
        let unorm = crate::tensor::ops::nrm2(&update) as f32;
        let trust = if wnorm > 0.0 && unorm > 0.0 {
            wnorm / unorm
        } else {
            1.0
        };
        for (p, u) in params.iter_mut().zip(&update) {
            *p -= lr * trust * u;
        }
    }

    fn reset(&mut self) {
        self.inner.reset();
    }

    fn export_state(&self) -> (u64, Vec<Vec<f32>>) {
        self.inner.export_state()
    }

    fn import_state(&mut self, t: u64, slots: &[Vec<f32>]) {
        self.inner.import_state(t, slots);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quadratic_converges(opt: &mut dyn Optimizer, lr: f32) -> f32 {
        // min 0.5*||x||^2, grad = x.
        let mut x = vec![1.0f32, -2.0, 3.0];
        for _ in 0..200 {
            let g = x.clone();
            opt.step(&mut x, &g, lr);
        }
        crate::tensor::ops::nrm2(&x) as f32
    }

    #[test]
    fn all_optimizers_converge_on_quadratic() {
        assert!(quadratic_converges(&mut Sgd::new(), 0.1) < 1e-3);
        assert!(quadratic_converges(&mut SgdMomentum::new(3, 0.9), 0.02) < 1e-3);
        assert!(quadratic_converges(&mut Adam::new(3, 0.9, 0.999, 1e-8), 0.05) < 1e-2);
        assert!(quadratic_converges(&mut AdamW::new(3, 0.9, 0.999, 1e-8, 0.0), 0.05) < 1e-2);
        assert!(quadratic_converges(&mut Lamb::new(3, 0.9, 0.999, 1e-6, 0.0), 0.05) < 1e-1);
    }

    #[test]
    fn sgd_step_is_exact() {
        let mut x = vec![1.0f32, 2.0];
        Sgd::new().step(&mut x, &[0.5, -0.5], 0.1);
        assert!((x[0] - 0.95).abs() < 1e-7);
        assert!((x[1] - 2.05).abs() < 1e-7);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = SgdMomentum::new(1, 0.9);
        let mut x = vec![0.0f32];
        opt.step(&mut x, &[1.0], 1.0); // v=1, x=-1
        opt.step(&mut x, &[1.0], 1.0); // v=1.9, x=-2.9
        assert!((x[0] + 2.9).abs() < 1e-6);
        opt.reset();
        opt.step(&mut x, &[0.0], 1.0);
        assert!((x[0] + 2.9).abs() < 1e-6); // velocity cleared
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // Bias correction makes |Δ| ≈ lr regardless of gradient scale.
        let mut opt = Adam::new(1, 0.9, 0.999, 1e-8);
        let mut x = vec![0.0f32];
        opt.step(&mut x, &[1e-3], 0.1);
        assert!((x[0].abs() - 0.1).abs() < 1e-3, "{}", x[0]);
    }

    #[test]
    fn adamw_decays_weights_without_gradient() {
        let mut opt = AdamW::new(1, 0.9, 0.999, 1e-8, 0.1);
        let mut x = vec![1.0f32];
        opt.step(&mut x, &[0.0], 0.1);
        assert!(x[0] < 1.0); // decay applied
        assert!(x[0] > 0.95);
    }

    #[test]
    fn state_round_trip_is_bitwise_for_stateful_optimizers() {
        // Export mid-run, import into a fresh optimizer, and the next
        // step must be bitwise-equal to the uninterrupted one — the
        // checkpoint/resume contract.
        let mk: Vec<Box<dyn Fn() -> Box<dyn Optimizer>>> = vec![
            Box::new(|| Box::new(Sgd::new())),
            Box::new(|| Box::new(SgdMomentum::new(3, 0.9))),
            Box::new(|| Box::new(Adam::new(3, 0.9, 0.999, 1e-8))),
            Box::new(|| Box::new(AdamW::new(3, 0.9, 0.999, 1e-8, 0.01))),
            Box::new(|| Box::new(Lamb::new(3, 0.9, 0.999, 1e-6, 0.01))),
        ];
        for f in mk {
            let mut a = f();
            let mut xa = vec![1.0f32, -2.0, 3.0];
            for _ in 0..3 {
                let g = xa.clone();
                a.step(&mut xa, &g, 0.05);
            }
            let (t, slots) = a.export_state();
            let mut b = f();
            let mut xb = xa.clone();
            b.import_state(t, &slots);
            let g = xa.clone();
            a.step(&mut xa, &g.clone(), 0.05);
            b.step(&mut xb, &g, 0.05);
            assert_eq!(xa, xb, "{}", a.name());
        }
    }

    #[test]
    fn import_ignores_mismatched_slots() {
        // A v1 checkpoint has no optimizer section: empty slots must leave
        // fresh state untouched rather than panic or corrupt.
        let mut opt = Adam::new(2, 0.9, 0.999, 1e-8);
        opt.import_state(7, &[]);
        let (t, slots) = opt.export_state();
        assert_eq!(t, 0);
        assert_eq!(slots, vec![vec![0.0f32; 2], vec![0.0f32; 2]]);
    }
}
