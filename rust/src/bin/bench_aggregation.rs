//! `bench_aggregation` — reproduce `BENCH_aggregation.json` (the
//! aggregation-engine thread-scaling sweep) from anywhere:
//!
//!   cargo run --release --bin bench_aggregation                  # full grid
//!   cargo run --release --bin bench_aggregation -- --smoke --budget 0.05
//!   cargo run --release --bin bench_aggregation -- --check BENCH_aggregation.json
//!   cargo run --release --bin bench_aggregation -- --table BENCH_aggregation.json

use adacons::bench::aggregation_sweep::{
    markdown_table, run_and_write, validate_file, SweepConfig,
};
use adacons::util::argparse::Args;
use adacons::util::error::Result;
use adacons::util::json::Json;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["smoke"]);
    if let Some(path) = args.str_opt("check") {
        return validate_file(path);
    }
    if let Some(path) = args.str_opt("table") {
        let text = std::fs::read_to_string(path)?;
        let doc = Json::parse(&text).map_err(|e| adacons::err!("{path}: {e}"))?;
        print!("{}", markdown_table(&doc));
        return Ok(());
    }
    let smoke = args.flag("smoke");
    let budget = args.f64_or("budget", if smoke { 0.05 } else { 0.4 })?;
    let cfg = if smoke {
        SweepConfig::smoke(budget)
    } else {
        SweepConfig::full(budget)
    };
    let out = args.str_or("out", "BENCH_aggregation.json");
    run_and_write(&cfg, &out)
}
