//! `bench_aggregation` — reproduce `BENCH_aggregation.json` (the
//! aggregation-engine thread-scaling sweep) from anywhere:
//!
//!   cargo run --release --bin bench_aggregation                  # full grid
//!   cargo run --release --bin bench_aggregation -- --smoke --budget 0.05
//!   cargo run --release --bin bench_aggregation -- --overlap on   # on|off|both
//!   cargo run --release --bin bench_aggregation -- --interp-step off  # skip backend step cases
//!   cargo run --release --bin bench_aggregation -- --hier-step off    # skip hier topology cases
//!   cargo run --release --bin bench_aggregation -- --compress-step off # skip compressed-step cases
//!   cargo run --release --bin bench_aggregation -- --degraded-step off # skip elastic degraded-step cases
//!   cargo run --release --bin bench_aggregation -- --local-step off    # skip local-step regime cases
//!   cargo run --release --bin bench_aggregation -- --obs-step off      # skip tracing-overhead cases
//!   cargo run --release --bin bench_aggregation -- --compress-sweep    # ratio-vs-loss table
//!   cargo run --release --bin bench_aggregation -- --check BENCH_aggregation.json
//!   cargo run --release --bin bench_aggregation -- --table BENCH_aggregation.json
//!   cargo run --release --bin bench_aggregation -- --compare bench_history/baseline.json \
//!       BENCH_aggregation.json --max-regress 1.3 --max-regress-step 1.5 \
//!       --history bench_history

use adacons::bench::aggregation_sweep::{
    compare_files, compress_loss_sweep, markdown_table, run_and_write, validate_file, SweepConfig,
};
use adacons::util::argparse::Args;
use adacons::util::error::Result;
use adacons::util::json::Json;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["smoke", "compress-sweep"]);
    if let Some(path) = args.str_opt("check") {
        return validate_file(path);
    }
    if args.flag("compress-sweep") {
        let steps = args.f64_or("steps", 60.0)? as usize;
        return compress_loss_sweep(steps);
    }
    if let Some(path) = args.str_opt("table") {
        let text = std::fs::read_to_string(path)?;
        let doc = Json::parse(&text).map_err(|e| adacons::err!("{path}: {e}"))?;
        print!("{}", markdown_table(&doc));
        return Ok(());
    }
    if let Some(baseline) = args.str_opt("compare") {
        let current = args
            .positional
            .first()
            .map(String::as_str)
            .unwrap_or("BENCH_aggregation.json");
        let max_ratio = args.f64_or("max-regress", 1.3)?;
        // The pipelined-step cases gate looser (scheduling variance);
        // rationale in EXPERIMENTS.md §Perf.
        let max_step_ratio = args.f64_or("max-regress-step", 1.5)?;
        // `--history` names the accumulated bench_history/ archive; with
        // enough runs there the step gate tightens below the default to
        // the spread actually observed on this host.
        let history = args.str_opt("history");
        return compare_files(baseline, current, max_ratio, max_step_ratio, history);
    }
    let smoke = args.flag("smoke");
    let budget = args.f64_or("budget", if smoke { 0.05 } else { 0.4 })?;
    let mut cfg = if smoke {
        SweepConfig::smoke(budget)
    } else {
        SweepConfig::full(budget)
    };
    if let Some(mode) = args.str_opt("overlap") {
        cfg.overlap_modes = match mode {
            "on" => vec![true],
            "off" => vec![false],
            "both" => vec![false, true],
            "none" => vec![],
            other => return Err(adacons::err!("--overlap {other:?}: want on|off|both|none")),
        };
    }
    if let Some(v) = args.str_opt("interp-step") {
        cfg.interp_step = match v {
            "on" => true,
            "off" => false,
            other => return Err(adacons::err!("--interp-step {other:?}: want on|off")),
        };
    }
    if let Some(v) = args.str_opt("hier-step") {
        cfg.hier_step = match v {
            "on" => true,
            "off" => false,
            other => return Err(adacons::err!("--hier-step {other:?}: want on|off")),
        };
    }
    if let Some(v) = args.str_opt("compress-step") {
        cfg.compress_step = match v {
            "on" => true,
            "off" => false,
            other => return Err(adacons::err!("--compress-step {other:?}: want on|off")),
        };
    }
    if let Some(v) = args.str_opt("degraded-step") {
        cfg.degraded_step = match v {
            "on" => true,
            "off" => false,
            other => return Err(adacons::err!("--degraded-step {other:?}: want on|off")),
        };
    }
    if let Some(v) = args.str_opt("local-step") {
        cfg.local_step = match v {
            "on" => true,
            "off" => false,
            other => return Err(adacons::err!("--local-step {other:?}: want on|off")),
        };
    }
    if let Some(v) = args.str_opt("obs-step") {
        cfg.obs_step = match v {
            "on" => true,
            "off" => false,
            other => return Err(adacons::err!("--obs-step {other:?}: want on|off")),
        };
    }
    let out = args.str_or("out", "BENCH_aggregation.json");
    run_and_write(&cfg, &out)
}
