//! Worker rank state.
//!
//! A worker owns its data shard (a seeded stream), its failure injector,
//! and its gradient slot. Everything here is `Send`, so a worker can run
//! round-robin on the leader thread (`--rank-threads off`, each rank
//! charged only its own compute on the [`SimClock`]) or be moved into a
//! real rank thread (`--rank-threads on`, `coordinator::team::RankTeam`)
//! that owns its executable and streams buckets to the leader over
//! `comm::StepExchange`; on a multi-accelerator deployment each rank
//! would be a process and the collectives real. Both placements draw the
//! same deterministic data/injection streams, so their gradients are
//! bitwise-identical.
//!
//! [`SimClock`]: crate::collective::SimClock

use crate::data::{Batch, DataGen, GradInjector, StepFault};
use crate::runtime::Executable;
use crate::tensor::Buckets;
use crate::util::error::{err, Result};
use crate::util::prng::Rng;

pub struct Worker {
    pub rank: usize,
    gen: Box<dyn DataGen>,
    injector: GradInjector,
    inject_rng: Rng,
    /// Last computed local loss.
    pub last_loss: f32,
    /// Wall-clock seconds spent in grad computation this step (per-rank
    /// compute time charged to the sim clock).
    pub last_compute_s: f64,
    /// Persistent gradient assembly buffer for the streaming path.
    grad_buf: Vec<f32>,
    /// Per-bucket filled-element counts for the streaming path.
    bucket_fill: Vec<usize>,
    /// Observed per-bucket completion offsets (seconds of on-thread
    /// compute at which each bucket's gradient was final) from the last
    /// `compute_grad_buckets` call — the measured readiness the
    /// topology-aware timeline consumes in threaded mode.
    bucket_s: Vec<f64>,
    /// Local step counter: drives step-keyed fault injection
    /// (`panic-at:S`) and checkpoint/rejoin fast-forward.
    step: u64,
}

impl Worker {
    pub fn new(rank: usize, gen: Box<dyn DataGen>, injector: GradInjector, seed: u64) -> Self {
        Worker {
            rank,
            gen,
            injector,
            inject_rng: Rng::new(seed ^ 0xFA11).fork(rank as u64),
            last_loss: 0.0,
            last_compute_s: 0.0,
            grad_buf: Vec::new(),
            bucket_fill: Vec::new(),
            bucket_s: Vec::new(),
            step: 0,
        }
    }

    /// Steps this worker has drawn so far (completed or panicked).
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Advance the worker's deterministic streams past `steps` completed
    /// steps without computing anything — replays exactly the per-step
    /// draw sequence of a live step (fault decision, data batch, injector
    /// application on a zero scratch gradient of length `d`), so a fresh
    /// worker fast-forwarded to step `S` continues bitwise-identically to
    /// one that trained through `S`. Used by checkpoint `--resume` and by
    /// rank rejoin after a fault.
    pub fn fast_forward(&mut self, steps: u64, local_batch: usize, d: usize) {
        let mut scratch = if matches!(self.injector, GradInjector::None) {
            Vec::new()
        } else {
            vec![0.0f32; d]
        };
        for _ in 0..steps {
            let _ = self.injector.step_fault(self.step, &mut self.inject_rng);
            let _ = self.gen.next_batch(local_batch);
            if !matches!(self.injector, GradInjector::None) {
                scratch.fill(0.0);
                self.injector.apply(&mut scratch, &mut self.inject_rng);
            }
            self.step += 1;
        }
    }

    /// Observed per-bucket compute offsets of the last
    /// [`Worker::compute_grad_buckets`] call (`last_bucket_s()[b]` =
    /// on-thread seconds into the backward at which bucket `b` was
    /// final). Injector ranks replay: every bucket reads as ready at
    /// backward end.
    pub fn last_bucket_s(&self) -> &[f64] {
        &self.bucket_s
    }

    /// Draw the next local batch.
    pub fn next_batch(&mut self, local_batch: usize) -> Batch {
        self.gen.next_batch(local_batch)
    }

    /// Compute the local gradient into `grad_out` via the PJRT executable,
    /// then apply this rank's failure injection.
    ///
    /// Process-level chaos faults fire here: an injected panic returns an
    /// error before any compute (in threaded mode the rank thread dies and
    /// its `Down` guard fires), an injected delay inflates the reported
    /// compute seconds (a straggler the cutoff path can drop).
    pub fn compute_grad(
        &mut self,
        exe: &Executable,
        params: &[f32],
        local_batch: usize,
        grad_out: &mut [f32],
    ) -> Result<()> {
        let fault = self.injector.step_fault(self.step, &mut self.inject_rng);
        self.step += 1;
        if fault == StepFault::Panic {
            return Err(err!(
                "injected panic at rank {} step {}",
                self.rank,
                self.step - 1
            ));
        }
        let batch = self.gen.next_batch(local_batch);
        let t = crate::util::timer::Timer::start();
        let (loss, grads) = exe.run_train(params, &batch)?;
        self.last_compute_s = t.elapsed_s();
        if let StepFault::Delay(f) = fault {
            self.last_compute_s *= f;
        }
        self.last_loss = loss;
        grad_out.copy_from_slice(&grads);
        self.injector.apply(grad_out, &mut self.inject_rng);
        Ok(())
    }

    /// Compute the local gradient and deliver it **bucket by bucket**
    /// through `on_bucket(b, columns)` — the DDP-style arrival surface the
    /// pipelined executor consumes.
    ///
    /// Healthy workers take the **live** path: the executable streams
    /// parameter-gradient segments as its backward pass finalizes them
    /// (reverse layer order on the interpreter backend), and each bucket
    /// is delivered the moment the segments cover it — genuine per-rank
    /// compute overlapping the pool's aggregation tasks, not a replay.
    /// Workers with a failure injector fall back to compute-then-replay,
    /// because injectors draw from their RNG in flat element order and
    /// must see the whole gradient at once (bitwise-identical to the
    /// pre-streaming behaviour).
    ///
    /// `par` is the intra-step parallel context: the interpreter shards
    /// its matmul kernels over its worker pool with results bitwise
    /// invariant to the pool width, so any `ParallelCtx` (including
    /// [`ParallelCtx::serial`]) yields identical gradients.
    ///
    /// [`ParallelCtx::serial`]: crate::parallel::ParallelCtx::serial
    pub fn compute_grad_buckets(
        &mut self,
        exe: &Executable,
        params: &[f32],
        local_batch: usize,
        buckets: &Buckets,
        par: &crate::parallel::ParallelCtx,
        on_bucket: &mut dyn FnMut(usize, &[f32]),
    ) -> Result<()> {
        let d = buckets.total();
        let mut grad_buf = std::mem::take(&mut self.grad_buf);
        grad_buf.resize(d, 0.0);
        if matches!(self.injector, GradInjector::None) {
            self.step += 1;
            let batch = self.gen.next_batch(local_batch);
            self.bucket_fill.clear();
            self.bucket_fill.resize(buckets.len(), 0);
            self.bucket_s.clear();
            self.bucket_s.resize(buckets.len(), 0.0);
            let fill = &mut self.bucket_fill;
            let bucket_s = &mut self.bucket_s;
            // Delivery work (bucket copies, overlap-mode task submission)
            // is timed separately and excluded from the compute seconds
            // charged to the sim clock — the clock models rank backward
            // time, not the leader's aggregation hooks.
            let mut deliver_s = 0.0f64;
            let t = crate::util::timer::Timer::start();
            let r = exe.run_train_stream_ctx(params, &batch, &mut grad_buf, par, &mut |g, off, len| {
                // Credit the segment to every bucket it overlaps; a
                // bucket is ready exactly when its range is fully
                // written (segments never overlap, so counts are exact).
                let dt = crate::util::timer::Timer::start();
                // Compute-only elapsed at this segment boundary: what the
                // backward has actually spent, delivery hooks excluded.
                let elapsed = (t.elapsed_s() - deliver_s).max(0.0);
                let end = off + len;
                for (b, (lo, hi)) in buckets.iter().enumerate() {
                    let ov = end.min(hi).saturating_sub(off.max(lo));
                    if ov == 0 {
                        continue;
                    }
                    fill[b] += ov;
                    if fill[b] == hi - lo {
                        bucket_s[b] = elapsed;
                        on_bucket(b, &g[lo..hi]);
                    }
                }
                deliver_s += dt.elapsed_s();
            });
            self.last_compute_s = (t.elapsed_s() - deliver_s).max(0.0);
            self.grad_buf = grad_buf;
            let loss = r?;
            debug_assert!(
                self.bucket_fill
                    .iter()
                    .enumerate()
                    .all(|(b, &f)| f == buckets.range(b).1 - buckets.range(b).0),
                "streamed segments did not cover every bucket"
            );
            self.last_loss = loss;
            return Ok(());
        }
        // Injector ranks reuse the whole-vector path (compute_grad owns
        // the draw/timer/injection sequence) and replay bucket arrival —
        // every bucket observed ready at backward end.
        let r = self.compute_grad(exe, params, local_batch, &mut grad_buf);
        self.grad_buf = grad_buf;
        r?;
        self.bucket_s.clear();
        self.bucket_s
            .resize(buckets.len(), self.last_compute_s);
        for (b, (lo, hi)) in buckets.iter().enumerate() {
            on_bucket(b, &self.grad_buf[lo..hi]);
        }
        Ok(())
    }

    /// One **local-step sync round**: starting from the consensus params,
    /// take `lrs.len()` plain-SGD steps on this rank's own stream (pass
    /// `p` at learning rate `lrs[p]`), accumulating the round's delta
    /// Δ = Σ_p g^(p) — the sum of the local gradients, i.e. the model
    /// movement measured in *gradient units* — then deliver Δ bucket by
    /// bucket through `on_bucket`, exactly like a one-step gradient.
    ///
    /// Keeping Δ in gradient units (rather than (θ_sync − θ_local)/lr)
    /// lets the five aggregators, the compression codecs, and the outer
    /// optimizer consume it unchanged: for a constant-lr schedule,
    /// `θ_sync − lr·agg(Δ)` is exactly the consensus-weighted average of
    /// the ranks' local models (the weights sum to 1), so delta
    /// aggregation inherits the synchronous path's unbiasedness.
    ///
    /// This helper is the **shared** H>1 execution path: both the
    /// round-robin producer and the rank threads call it, so every float
    /// lands in the same operation order and the two modes stay
    /// bitwise-equal. (H==1 never routes here — the trainer takes the
    /// historical synchronous path verbatim, preserving its bitwise
    /// invariant and live per-bucket streaming.)
    ///
    /// After the call, `last_loss` is the mean of the round's local
    /// losses, `last_compute_s` the summed backward seconds, and every
    /// bucket reads as ready at the round's compute end (delta buckets
    /// only exist once the last local pass finishes, so there is no
    /// intra-round arrival to overlap).
    pub fn compute_delta_round(
        &mut self,
        exe: &Executable,
        sync_params: &[f32],
        local_batch: usize,
        buckets: &Buckets,
        par: &crate::parallel::ParallelCtx,
        lrs: &[f32],
        on_bucket: &mut dyn FnMut(usize, &[f32]),
    ) -> Result<()> {
        let h = lrs.len();
        debug_assert!(h >= 1, "a sync round needs at least one local pass");
        let d = buckets.total();
        let mut local = sync_params.to_vec();
        let mut delta = vec![0.0f32; d];
        let mut loss_sum = 0.0f64;
        let mut compute_s = 0.0f64;
        for &lr in lrs {
            // Each pass draws its own batch/fault/injection step — the
            // worker's deterministic streams advance one *local* step at
            // a time, so fast-forward/rejoin replay stays draw-exact.
            self.compute_grad_buckets(exe, &local, local_batch, buckets, par, &mut |_, _| {})?;
            loss_sum += self.last_loss as f64;
            compute_s += self.last_compute_s;
            // Fixed flat-element order: accumulate the delta, then apply
            // the local SGD update for the next pass.
            for j in 0..d {
                delta[j] += self.grad_buf[j];
            }
            for j in 0..d {
                local[j] -= lr * self.grad_buf[j];
            }
        }
        self.last_loss = (loss_sum / h as f64) as f32;
        self.last_compute_s = compute_s;
        self.bucket_s.clear();
        self.bucket_s.resize(buckets.len(), compute_s);
        for (b, (lo, hi)) in buckets.iter().enumerate() {
            on_bucket(b, &delta[lo..hi]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Array;

    struct ConstGen(f32, usize);

    impl DataGen for ConstGen {
        fn next_batch(&mut self, b: usize) -> Batch {
            vec![Array::F32(vec![self.0; b * self.1], vec![b, self.1])]
        }
    }

    #[test]
    fn worker_is_send_for_rank_threads() {
        // The threaded rank runtime moves workers into rank threads;
        // keep the whole state tree (data gen, injector, RNG) Send.
        fn assert_send<T: Send>() {}
        assert_send::<Worker>();
    }

    #[test]
    fn fast_forward_matches_live_draw_sequence() {
        // A fresh worker fast-forwarded past N steps must sit at exactly
        // the stream position of a worker that lived through them.
        let meta = crate::util::json::Json::parse(r#"{"dim":16}"#).unwrap();
        let mk = || {
            Worker::new(
                2,
                crate::data::for_model("linreg", 7, 2, 0.0, &meta).unwrap(),
                GradInjector::GaussNoise(0.1),
                5,
            )
        };
        let (lb, d) = (4, 8);
        let mut live = mk();
        for _ in 0..3 {
            // Mimic compute_grad's draw sequence without an executable:
            // fault decision, batch, injector application.
            let _ = live.injector.step_fault(live.step, &mut live.inject_rng);
            live.step += 1;
            let _ = live.gen.next_batch(lb);
            let mut g = vec![0.5f32; d];
            live.injector.apply(&mut g, &mut live.inject_rng);
        }
        let mut ffwd = mk();
        ffwd.fast_forward(3, lb, d);
        assert_eq!(ffwd.step(), 3);
        // Same next batch...
        let (ba, bb) = (live.next_batch(lb), ffwd.next_batch(lb));
        assert_eq!(ba[0].as_f32().unwrap(), bb[0].as_f32().unwrap());
        // ...and the same next injector draws.
        let mut ga = vec![1.0f32; d];
        let mut gb = vec![1.0f32; d];
        live.injector.apply(&mut ga, &mut live.inject_rng);
        ffwd.injector.apply(&mut gb, &mut ffwd.inject_rng);
        assert_eq!(ga, gb);
    }

    #[test]
    fn fast_forward_consumes_step_keyed_faults() {
        // Rejoining past a `panic-at:S` step must not re-fire the panic:
        // the counter lands beyond S.
        let mut w = Worker::new(
            0,
            Box::new(ConstGen(1.0, 4)),
            GradInjector::PanicAt(1),
            3,
        );
        w.fast_forward(2, 2, 4);
        assert_eq!(w.step(), 2);
        assert_eq!(
            w.injector.step_fault(w.step, &mut w.inject_rng),
            StepFault::None
        );
    }

    #[test]
    fn injector_applies_to_stream() {
        let mut w = Worker::new(0, Box::new(ConstGen(1.0, 4)), GradInjector::SignFlip, 3);
        let b = w.next_batch(2);
        assert_eq!(b[0].as_f32().unwrap(), &[1.0; 8]);
        // injector applied at the gradient level is covered in compute_grad;
        // here check the injector state machine directly
        let mut g = vec![1.0f32, -1.0];
        w.injector.apply(&mut g, &mut w.inject_rng);
        assert_eq!(g, vec![-1.0, 1.0]);
    }
}
