//! Worker rank state.
//!
//! A worker owns its data shard (a seeded stream), its failure injector,
//! and its gradient slot.  The testbed is a single CPU, so ranks execute
//! round-robin against the shared PJRT client while the [`SimClock`]
//! models them running in parallel (each rank is charged only its own
//! compute time); on a multi-accelerator deployment each rank would be a
//! process and the collectives real.
//!
//! [`SimClock`]: crate::collective::SimClock

use crate::data::{Batch, DataGen, GradInjector};
use crate::runtime::Executable;
use crate::tensor::Buckets;
use crate::util::error::Result;
use crate::util::prng::Rng;

pub struct Worker {
    pub rank: usize,
    gen: Box<dyn DataGen>,
    injector: GradInjector,
    inject_rng: Rng,
    /// Last computed local loss.
    pub last_loss: f32,
    /// Wall-clock seconds spent in grad computation this step (per-rank
    /// compute time charged to the sim clock).
    pub last_compute_s: f64,
}

impl Worker {
    pub fn new(rank: usize, gen: Box<dyn DataGen>, injector: GradInjector, seed: u64) -> Self {
        Worker {
            rank,
            gen,
            injector,
            inject_rng: Rng::new(seed ^ 0xFA11).fork(rank as u64),
            last_loss: 0.0,
            last_compute_s: 0.0,
        }
    }

    /// Draw the next local batch.
    pub fn next_batch(&mut self, local_batch: usize) -> Batch {
        self.gen.next_batch(local_batch)
    }

    /// Compute the local gradient into `grad_out` via the PJRT executable,
    /// then apply this rank's failure injection.
    pub fn compute_grad(
        &mut self,
        exe: &Executable,
        params: &[f32],
        local_batch: usize,
        grad_out: &mut [f32],
    ) -> Result<()> {
        let batch = self.next_batch(local_batch);
        let t = crate::util::timer::Timer::start();
        let (loss, grads) = exe.run_train(params, &batch)?;
        self.last_compute_s = t.elapsed_s();
        self.last_loss = loss;
        grad_out.copy_from_slice(&grads);
        self.injector.apply(grad_out, &mut self.inject_rng);
        Ok(())
    }

    /// Compute the local gradient via the existing executable, then
    /// deliver it **bucket by bucket** through `on_bucket(b, columns)` in
    /// bucket order — the DDP-style arrival surface the pipelined
    /// executor consumes (on real hardware each bucket would fire as the
    /// backward pass reaches it; here the full gradient exists first and
    /// the buckets replay its arrival). Injection is applied before
    /// delivery, so downstream consumers see exactly what `compute_grad`
    /// would have produced.
    pub fn compute_grad_buckets(
        &mut self,
        exe: &Executable,
        params: &[f32],
        local_batch: usize,
        buckets: &Buckets,
        on_bucket: &mut dyn FnMut(usize, &[f32]),
    ) -> Result<()> {
        let batch = self.next_batch(local_batch);
        let t = crate::util::timer::Timer::start();
        let (loss, mut grads) = exe.run_train(params, &batch)?;
        self.last_compute_s = t.elapsed_s();
        self.last_loss = loss;
        self.injector.apply(&mut grads, &mut self.inject_rng);
        for (b, (lo, hi)) in buckets.iter().enumerate() {
            on_bucket(b, &grads[lo..hi]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Array;

    struct ConstGen(f32, usize);

    impl DataGen for ConstGen {
        fn next_batch(&mut self, b: usize) -> Batch {
            vec![Array::F32(vec![self.0; b * self.1], vec![b, self.1])]
        }
    }

    #[test]
    fn injector_applies_to_stream() {
        let mut w = Worker::new(0, Box::new(ConstGen(1.0, 4)), GradInjector::SignFlip, 3);
        let b = w.next_batch(2);
        assert_eq!(b[0].as_f32().unwrap(), &[1.0; 8]);
        // injector applied at the gradient level is covered in compute_grad;
        // here check the injector state machine directly
        let mut g = vec![1.0f32, -1.0];
        w.injector.apply(&mut g, &mut w.inject_rng);
        assert_eq!(g, vec![-1.0, 1.0]);
    }
}
