//! In-process rank-to-rank transport: typed mailboxes and a reusable step
//! barrier.
//!
//! On the single-accelerator testbed the coordinator drives ranks
//! round-robin (see `worker/`), but the aggregation algebra itself is
//! host-side and thread-safe; this module provides the transport for the
//! threaded deployment shape — N rank threads exchanging gradients with a
//! leader — and is exercised by `threaded_allreduce`, a multi-threaded
//! driver of the simulated collectives used in tests and benches.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};

/// A typed point-to-point mailbox (multi-producer, single-consumer).
pub struct Mailbox<T> {
    tx: Sender<T>,
    rx: Mutex<Receiver<T>>,
}

impl<T> Default for Mailbox<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Mailbox<T> {
    pub fn new() -> Self {
        let (tx, rx) = channel();
        Mailbox {
            tx,
            rx: Mutex::new(rx),
        }
    }

    pub fn sender(&self) -> Sender<T> {
        self.tx.clone()
    }

    /// Blocking receive.
    pub fn recv(&self) -> T {
        self.rx.lock().unwrap().recv().expect("mailbox closed")
    }

    /// Receive exactly `n` messages.
    pub fn recv_n(&self, n: usize) -> Vec<T> {
        let rx = self.rx.lock().unwrap();
        (0..n).map(|_| rx.recv().expect("mailbox closed")).collect()
    }
}

/// The leader's view of a step exchange: collect one gradient per rank,
/// return the aggregated direction to all ranks.
pub struct StepExchange {
    pub n: usize,
    grads_in: Mailbox<(usize, Vec<f32>)>,
    results_out: Vec<Sender<Arc<Vec<f32>>>>,
    results_in: Vec<Mutex<Receiver<Arc<Vec<f32>>>>>,
    pub barrier: Arc<Barrier>,
}

impl StepExchange {
    pub fn new(n: usize) -> Self {
        let mut results_out = Vec::with_capacity(n);
        let mut results_in = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            results_out.push(tx);
            results_in.push(Mutex::new(rx));
        }
        StepExchange {
            n,
            grads_in: Mailbox::new(),
            results_out,
            results_in,
            barrier: Arc::new(Barrier::new(n + 1)), // ranks + leader
        }
    }

    /// Rank side: submit this step's gradient.
    pub fn submit(&self, rank: usize, grad: Vec<f32>) {
        self.grads_in.sender().send((rank, grad)).unwrap();
    }

    /// Rank side: wait for the aggregated direction.
    pub fn wait_result(&self, rank: usize) -> Arc<Vec<f32>> {
        self.results_in[rank]
            .lock()
            .unwrap()
            .recv()
            .expect("exchange closed")
    }

    /// Leader side: gather all rank gradients (any order), aggregate with
    /// `f`, broadcast the result.
    pub fn leader_step(&self, f: impl FnOnce(Vec<Vec<f32>>) -> Vec<f32>) {
        let mut slots: Vec<Option<Vec<f32>>> = (0..self.n).map(|_| None).collect();
        for (rank, grad) in self.grads_in.recv_n(self.n) {
            slots[rank] = Some(grad);
        }
        let grads: Vec<Vec<f32>> = slots.into_iter().map(|s| s.expect("missing rank")).collect();
        let result = Arc::new(f(grads));
        for tx in &self.results_out {
            tx.send(result.clone()).unwrap();
        }
    }
}

/// Multi-threaded driver: N rank threads aggregate `rounds` of locally
/// generated gradients through a shared [`StepExchange`] with the given
/// aggregator name. Returns the final aggregated vector. Used by tests to
/// prove the aggregation path is thread-clean end-to-end.
pub fn threaded_allreduce(
    n: usize,
    d: usize,
    rounds: usize,
    aggregator: &str,
    make_grad: impl Fn(usize, usize) -> Vec<f32> + Send + Sync + 'static,
) -> Vec<f32> {
    use crate::tensor::{Buckets, GradSet};
    let exchange = Arc::new(StepExchange::new(n));
    let make_grad = Arc::new(make_grad);
    let mut handles = Vec::new();
    for rank in 0..n {
        let ex = exchange.clone();
        let mg = make_grad.clone();
        handles.push(std::thread::spawn(move || {
            for round in 0..rounds {
                ex.submit(rank, mg(rank, round));
                let _ = ex.wait_result(rank);
                ex.barrier.wait();
            }
        }));
    }
    let mut agg = crate::aggregation::by_name(aggregator, n).expect("aggregator");
    let buckets = Buckets::single(d);
    let mut last = vec![0.0f32; d];
    for _ in 0..rounds {
        exchange.leader_step(|grads| {
            let gs = GradSet::from_rows(&grads);
            let mut out = vec![0.0f32; d];
            agg.aggregate(&gs, &buckets, &mut out);
            last = out.clone();
            out
        });
        exchange.barrier.wait();
    }
    for h in handles {
        h.join().unwrap();
    }
    last
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mailbox_roundtrip() {
        let mb = Mailbox::new();
        let tx = mb.sender();
        std::thread::spawn(move || tx.send(42u32).unwrap());
        assert_eq!(mb.recv(), 42);
    }

    #[test]
    fn exchange_collects_out_of_order_ranks() {
        let ex = Arc::new(StepExchange::new(3));
        for rank in [2usize, 0, 1] {
            let ex = ex.clone();
            std::thread::spawn(move || {
                ex.submit(rank, vec![rank as f32; 2]);
            });
        }
        ex.leader_step(|grads| {
            assert_eq!(grads[0], vec![0.0; 2]);
            assert_eq!(grads[1], vec![1.0; 2]);
            assert_eq!(grads[2], vec![2.0; 2]);
            vec![9.0; 2]
        });
        for rank in 0..3 {
            assert_eq!(*ex.wait_result(rank), vec![9.0; 2]);
        }
    }

    #[test]
    fn threaded_mean_matches_expectation() {
        // rank r contributes the constant vector r+1 -> mean = (1+2+3+4)/4.
        let out = threaded_allreduce(4, 16, 3, "mean", |rank, _| vec![(rank + 1) as f32; 16]);
        for x in out {
            assert!((x - 2.5).abs() < 1e-6);
        }
    }

    #[test]
    fn threaded_adacons_runs_multiround() {
        let out = threaded_allreduce(4, 32, 5, "adacons", |rank, round| {
            let mut rng = crate::util::prng::Rng::new((rank * 1000 + round) as u64);
            (0..32).map(|_| rng.normal_f32(1.0) + 0.5).collect()
        });
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
