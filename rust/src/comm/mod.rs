//! In-process rank-to-rank transport: typed mailboxes and the step
//! exchange the threaded rank runtime speaks.
//!
//! The deployment shape is N rank threads streaming gradients to one
//! leader ([`StepExchange::new`] hands back the leader half plus one
//! [`RankPort`] per rank). The wire unit is a **bucket**, not a whole
//! gradient: ranks send `(rank, bucket, columns)` messages as each bucket
//! of their backward completes ([`RankPort::submit_bucket`]), then a
//! [`RankMsg::Done`] carrying the step's loss and on-thread compute
//! seconds. The leader drains messages **in arrival order**
//! ([`StepExchange::leader_ingest`]) — the pipelined executor feeds ready
//! buckets to the pool straight from this loop.
//!
//! Failure is a first-class message, not a hang: every `RankPort` is an
//! armed guard, and dropping one without [`RankPort::complete`] (the
//! unwind path of a panicking rank thread) emits [`RankMsg::Down`], so
//! the leader's ingest loop fails the step with the dead rank's id
//! instead of blocking forever on a `recv` that can never complete. The
//! exchange holds no sender of its own, so even a guard-less mass death
//! of every rank surfaces as a closed-channel error rather than a hang.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::collective::NodeMap;
use crate::compress::Payload;
use crate::tensor::{Buckets, GradSet};
use crate::util::error::Result;
use crate::{bail, ensure, err};

/// A typed point-to-point mailbox (multi-producer, single-consumer). The
/// mailbox owns only the receiving half — producers own every sender —
/// so `recv` errors once all producers are gone instead of hanging.
pub struct Mailbox<T> {
    rx: Mutex<Receiver<T>>,
}

impl<T> Mailbox<T> {
    /// Create a mailbox plus its first sender (clone it for more
    /// producers).
    pub fn channel() -> (Sender<T>, Mailbox<T>) {
        let (tx, rx) = channel();
        (
            tx,
            Mailbox {
                rx: Mutex::new(rx),
            },
        )
    }

    /// Blocking receive; errors once every sender has disconnected.
    pub fn recv(&self) -> Result<T> {
        self.rx
            .lock()
            .unwrap()
            .recv()
            .map_err(|_| err!("mailbox closed: every sender disconnected"))
    }

}

/// One rank-to-leader message on the step exchange.
#[derive(Debug)]
pub enum RankMsg {
    /// One bucket's gradient columns, sent as the backward finalizes it.
    /// The payload is the **encoded wire form** ([`Payload::Raw`] when
    /// compression is off — bitwise passthrough), carrying its true wire
    /// size; the leader decodes before aggregation.
    Bucket {
        rank: usize,
        bucket: usize,
        payload: Payload,
    },
    /// The rank finished its backward for this step. `bucket_s[b]` is the
    /// on-thread compute seconds at which bucket `b`'s gradient was final
    /// (empty when the rank does not measure per-bucket readiness) — the
    /// observed arrival times the hierarchical timeline consumes.
    Done {
        rank: usize,
        loss: f64,
        compute_s: f64,
        bucket_s: Vec<f64>,
    },
    /// The rank died (panic, compute error) — emitted by its port's
    /// guard so the leader errors instead of hanging.
    Down { rank: usize, reason: String },
}

/// Per-rank completion report delivered with [`RankMsg::Done`]: the local
/// loss and the wall compute seconds measured **on the rank thread**
/// (fed to the `SimClock` by the coordinator), plus the observed
/// per-bucket completion offsets (empty when not measured — the
/// round-robin producer path and legacy [`RankPort::done`] senders).
#[derive(Debug, Clone, Default)]
pub struct RankReport {
    pub loss: f64,
    pub compute_s: f64,
    pub bucket_s: Vec<f64>,
}

/// Outcome of one elastic ingest ([`StepExchange::leader_ingest_elastic`]):
/// per-rank completion reports (`None` for ranks that went down) plus the
/// ranks that died this step with their reported reasons.
#[derive(Debug)]
pub struct ElasticReport {
    pub reports: Vec<Option<RankReport>>,
    pub dead: Vec<(usize, String)>,
}

impl ElasticReport {
    /// Ranks that completed the step.
    pub fn live(&self) -> usize {
        self.reports.iter().filter(|r| r.is_some()).count()
    }
}

/// A rank thread's handle on the exchange: the only sender for its
/// messages plus the receiver for broadcast results. The port doubles as
/// a death guard — dropping it without [`RankPort::complete`] (or
/// [`RankPort::report_down`]) reports the rank down, which is exactly
/// what happens when a rank thread unwinds from a panic.
pub struct RankPort {
    rank: usize,
    /// The node group this rank belongs to (0 on ungrouped exchanges).
    node: usize,
    tx: Sender<RankMsg>,
    result_rx: Receiver<Arc<Vec<f32>>>,
    armed: bool,
}

impl RankPort {
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The node group this rank belongs to (per the exchange's
    /// [`NodeMap`]; 0 on ungrouped exchanges).
    pub fn node(&self) -> usize {
        self.node
    }

    /// Send one bucket's columns as soon as it is ready. A send to a
    /// departed leader is dropped silently — the rank notices at its next
    /// blocking point. Columns ship uncompressed ([`Payload::Raw`]); a
    /// compressing rank encodes first and uses [`RankPort::submit_payload`].
    pub fn submit_bucket(&self, bucket: usize, cols: Vec<f32>) {
        self.submit_payload(bucket, Payload::Raw(cols));
    }

    /// Send one bucket's **encoded** columns (the compressed-collective
    /// wire path; see `compress::RankCodec`).
    pub fn submit_payload(&self, bucket: usize, payload: Payload) {
        let _ = self.tx.send(RankMsg::Bucket {
            rank: self.rank,
            bucket,
            payload,
        });
    }

    /// Send a whole gradient as its bucket sequence (the degenerate
    /// single-bucket path when `buckets` is [`Buckets::single`]).
    pub fn submit(&self, buckets: &Buckets, grad: &[f32]) {
        assert_eq!(grad.len(), buckets.total());
        for (b, (lo, hi)) in buckets.iter().enumerate() {
            self.submit_bucket(b, grad[lo..hi].to_vec());
        }
    }

    /// Mark this step's backward complete, reporting the local loss and
    /// the compute seconds measured on this thread.
    pub fn done(&self, loss: f64, compute_s: f64) {
        self.done_timed(loss, compute_s, Vec::new());
    }

    /// Like [`RankPort::done`], additionally carrying the observed
    /// on-thread completion offset of every bucket (`bucket_s[b]` seconds
    /// into this rank's backward) — the measured readiness the
    /// topology-aware timeline uses instead of the uniform-emission
    /// model.
    pub fn done_timed(&self, loss: f64, compute_s: f64, bucket_s: Vec<f64>) {
        let _ = self.tx.send(RankMsg::Done {
            rank: self.rank,
            loss,
            compute_s,
            bucket_s,
        });
    }

    /// Wait for the leader's broadcast result; errors once the leader
    /// (and its exchange) is gone — the rank's clean-shutdown signal.
    pub fn wait_result(&self) -> Result<Arc<Vec<f32>>> {
        self.result_rx
            .recv()
            .map_err(|_| err!("step exchange closed (leader gone)"))
    }

    /// Report this rank down with an explicit reason (e.g. a compute
    /// error) and disarm the guard.
    pub fn report_down(mut self, reason: &str) {
        let _ = self.tx.send(RankMsg::Down {
            rank: self.rank,
            reason: reason.to_string(),
        });
        self.armed = false;
    }

    /// Clean shutdown: disarm the guard so dropping the port does not
    /// report the rank down.
    pub fn complete(mut self) {
        self.armed = false;
    }
}

impl Drop for RankPort {
    fn drop(&mut self) {
        if self.armed {
            let reason = if std::thread::panicking() {
                "rank thread panicked"
            } else {
                "rank port dropped before complete()"
            };
            let _ = self.tx.send(RankMsg::Down {
                rank: self.rank,
                reason: reason.to_string(),
            });
        }
    }
}

/// The leader's half of a step exchange: drain every rank's bucket
/// messages in arrival order, broadcast the aggregated result. A grouped
/// exchange ([`StepExchange::new_grouped`]) additionally knows the node
/// hierarchy: ports are node-tagged, and
/// [`StepExchange::leader_ingest_nodes`] surfaces **node-level bucket
/// completion** (the moment a bucket completes within one node's rank
/// group) for callers that drive the exchange directly. The pipelined
/// executor tracks the same per-group completion in its arrival sink —
/// one implementation shared with the producer-fed path, which has no
/// exchange to lean on.
pub struct StepExchange {
    n: usize,
    map: Option<NodeMap>,
    msgs_in: Mailbox<RankMsg>,
    results_out: Vec<Sender<Arc<Vec<f32>>>>,
    /// Elastic exchanges keep one message sender purely to mint
    /// replacement ports for respawned ranks ([`StepExchange::respawn_port`]).
    /// `None` on the plain constructors, which stay sender-free so even a
    /// guard-less mass rank death closes the channel instead of hanging.
    respawn_tx: Option<Sender<RankMsg>>,
}

impl StepExchange {
    /// Build the exchange plus one [`RankPort`] per rank (move each port
    /// into its rank thread). The exchange keeps no sender of its own,
    /// so rank death is always observable on the leader side.
    pub fn new(n: usize) -> (StepExchange, Vec<RankPort>) {
        Self::build(n, None, false)
    }

    /// Grouped construction: rank threads are grouped per node (`map`),
    /// each port tagged with its node id. Port count == `map.n_ranks()`
    /// by construction — the consistency the hierarchy tests pin down.
    pub fn new_grouped(map: &NodeMap) -> (StepExchange, Vec<RankPort>) {
        Self::build(map.n_ranks(), Some(map.clone()), false)
    }

    /// Elastic construction: like [`StepExchange::new`]/`new_grouped`, but
    /// the exchange retains one message sender so a dead rank's port can
    /// be re-minted after a respawn ([`StepExchange::respawn_port`]). Rank
    /// death still surfaces: the armed port guards fire `Down` on every
    /// unwind path, and the elastic ingest counts them.
    pub fn new_elastic(n: usize, map: Option<&NodeMap>) -> (StepExchange, Vec<RankPort>) {
        Self::build(n, map.cloned(), true)
    }

    fn build(n: usize, map: Option<NodeMap>, elastic: bool) -> (StepExchange, Vec<RankPort>) {
        if let Some(m) = &map {
            assert_eq!(m.n_ranks(), n, "node map does not cover every rank");
        }
        let (msg_tx, msgs_in) = Mailbox::channel();
        let mut results_out = Vec::with_capacity(n);
        let mut ports = Vec::with_capacity(n);
        for rank in 0..n {
            let (tx, rx) = channel();
            results_out.push(tx);
            ports.push(RankPort {
                rank,
                node: map.as_ref().map(|m| m.locate(rank).0).unwrap_or(0),
                tx: msg_tx.clone(),
                result_rx: rx,
                armed: true,
            });
        }
        (
            StepExchange {
                n,
                map,
                msgs_in,
                results_out,
                respawn_tx: elastic.then(|| msg_tx.clone()),
            },
            ports,
        )
    }

    /// Mint a fresh [`RankPort`] for a respawned rank on an elastic
    /// exchange, replacing its result channel. Errors on non-elastic
    /// exchanges (no sender retained) or out-of-range ranks.
    pub fn respawn_port(&mut self, rank: usize) -> Result<RankPort> {
        ensure!(rank < self.n, "respawn_port: unknown rank {rank}");
        let tx = self
            .respawn_tx
            .as_ref()
            .ok_or_else(|| err!("respawn_port needs an elastic exchange"))?
            .clone();
        let (result_tx, result_rx) = channel();
        self.results_out[rank] = result_tx;
        Ok(RankPort {
            rank,
            node: self.map.as_ref().map(|m| m.locate(rank).0).unwrap_or(0),
            tx,
            result_rx,
            armed: true,
        })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The node grouping of a grouped exchange.
    pub fn map(&self) -> Option<&NodeMap> {
        self.map.as_ref()
    }

    /// Drain one step's messages **in arrival order**, invoking
    /// `on_bucket(rank, bucket, cols)` per bucket message until every
    /// rank has delivered every bucket — plus, with `expect_done`, one
    /// [`RankMsg::Done`] per rank (returned as rank-indexed
    /// [`RankReport`]s; empty otherwise).
    ///
    /// Fails the step — instead of hanging — when a rank reports
    /// [`RankMsg::Down`] (the error names the rank) or when every rank
    /// sender disconnects without a guard firing.
    pub fn leader_ingest(
        &self,
        buckets: &Buckets,
        expect_done: bool,
        on_bucket: &mut dyn FnMut(usize, usize, Vec<f32>),
    ) -> Result<Vec<RankReport>> {
        let nb = buckets.len();
        let mut seen = vec![false; self.n * nb];
        let mut remaining_buckets = self.n * nb;
        let mut reports = vec![None; self.n];
        let mut remaining_done = if expect_done { self.n } else { 0 };
        while remaining_buckets > 0 || remaining_done > 0 {
            match self.msgs_in.recv()? {
                RankMsg::Bucket {
                    rank,
                    bucket,
                    payload,
                } => {
                    ensure!(
                        rank < self.n && bucket < nb,
                        "bucket message out of range: rank {rank}, bucket {bucket}"
                    );
                    let (lo, hi) = buckets.range(bucket);
                    ensure!(
                        payload.n_cols() == hi - lo,
                        "bucket {bucket} payload width {} != {}",
                        payload.n_cols(),
                        hi - lo
                    );
                    ensure!(
                        !std::mem::replace(&mut seen[rank * nb + bucket], true),
                        "duplicate bucket {bucket} from rank {rank}"
                    );
                    remaining_buckets -= 1;
                    // Decode at the receiving edge: aggregation always sees
                    // f32 columns (`Raw` decodes by moving, zero-copy).
                    on_bucket(rank, bucket, payload.into_cols());
                }
                RankMsg::Done {
                    rank,
                    loss,
                    compute_s,
                    bucket_s,
                } => {
                    ensure!(expect_done, "unexpected done message from rank {rank}");
                    ensure!(rank < self.n, "done message from unknown rank {rank}");
                    ensure!(
                        reports[rank].is_none(),
                        "duplicate done message from rank {rank}"
                    );
                    reports[rank] = Some(RankReport {
                        loss,
                        compute_s,
                        bucket_s,
                    });
                    remaining_done -= 1;
                }
                RankMsg::Down { rank, reason } => {
                    bail!("rank {rank} went down mid-step: {reason}")
                }
            }
        }
        Ok(if expect_done {
            reports
                .into_iter()
                .map(|r| r.expect("counted n done messages"))
                .collect()
        } else {
            Vec::new()
        })
    }

    /// Fault-tolerant ingest: drain one step's messages until every rank
    /// has either delivered all its buckets plus a `Done` report **or**
    /// reported [`RankMsg::Down`]. Dead ranks yield `None` reports; their
    /// partial bucket deliveries (already handed to `on_bucket`) are the
    /// caller's to discard — the elastic step assembles the full gradient
    /// matrix first and aggregates over survivors only.
    ///
    /// Fails — listing the dead ranks — only when survivors drop below
    /// `min_ranks`, the quorum under which a degraded step would no
    /// longer be meaningful.
    pub fn leader_ingest_elastic(
        &self,
        buckets: &Buckets,
        min_ranks: usize,
        on_bucket: &mut dyn FnMut(usize, usize, Vec<f32>),
    ) -> Result<ElasticReport> {
        let nb = buckets.len();
        let mut seen = vec![false; self.n * nb];
        let mut delivered = vec![0usize; self.n];
        let mut reports: Vec<Option<RankReport>> = vec![None; self.n];
        let mut down = vec![false; self.n];
        let mut dead: Vec<(usize, String)> = Vec::new();
        // Ranks still owed a terminal message (Done or Down).
        let mut pending = self.n;
        while pending > 0 {
            match self.msgs_in.recv()? {
                RankMsg::Bucket {
                    rank,
                    bucket,
                    payload,
                } => {
                    ensure!(
                        rank < self.n && bucket < nb,
                        "bucket message out of range: rank {rank}, bucket {bucket}"
                    );
                    ensure!(!down[rank], "bucket from dead rank {rank}");
                    let (lo, hi) = buckets.range(bucket);
                    ensure!(
                        payload.n_cols() == hi - lo,
                        "bucket {bucket} payload width {} != {}",
                        payload.n_cols(),
                        hi - lo
                    );
                    ensure!(
                        !std::mem::replace(&mut seen[rank * nb + bucket], true),
                        "duplicate bucket {bucket} from rank {rank}"
                    );
                    delivered[rank] += 1;
                    on_bucket(rank, bucket, payload.into_cols());
                }
                RankMsg::Done {
                    rank,
                    loss,
                    compute_s,
                    bucket_s,
                } => {
                    ensure!(rank < self.n, "done message from unknown rank {rank}");
                    ensure!(
                        !down[rank] && reports[rank].is_none(),
                        "duplicate done message from rank {rank}"
                    );
                    ensure!(
                        delivered[rank] == nb,
                        "rank {rank} done after only {}/{nb} buckets",
                        delivered[rank]
                    );
                    reports[rank] = Some(RankReport {
                        loss,
                        compute_s,
                        bucket_s,
                    });
                    pending -= 1;
                }
                RankMsg::Down { rank, reason } => {
                    ensure!(rank < self.n, "down message from unknown rank {rank}");
                    if down[rank] || reports[rank].is_some() {
                        // A disarmed double-report (e.g. explicit
                        // report_down raced with a guard) — ignore.
                        continue;
                    }
                    down[rank] = true;
                    crate::log_warn!("rank {rank} down: {reason}");
                    dead.push((rank, reason));
                    pending -= 1;
                    let live = self.n - dead.len();
                    ensure!(
                        live >= min_ranks,
                        "only {live} ranks live (< quorum {min_ranks}); dead: {dead:?}"
                    );
                }
            }
        }
        Ok(ElasticReport { reports, dead })
    }

    /// Node-level ingest on a grouped exchange: like
    /// [`StepExchange::leader_ingest`], but additionally fires
    /// `on_node_bucket(node, bucket)` at the arrival that completes the
    /// bucket **within that node's rank group** — the node-completion
    /// edge the hierarchical ingest is built around, exposed here for
    /// direct exchange drivers and the grouped-team tests (the pipelined
    /// executor computes the same edge in its source-agnostic sink).
    pub fn leader_ingest_nodes(
        &self,
        buckets: &Buckets,
        expect_done: bool,
        on_bucket: &mut dyn FnMut(usize, usize, Vec<f32>),
        on_node_bucket: &mut dyn FnMut(usize, usize),
    ) -> Result<Vec<RankReport>> {
        let map = self
            .map
            .as_ref()
            .ok_or_else(|| err!("node-level ingest needs a grouped exchange"))?;
        let nb = buckets.len();
        let g = map.groups();
        let mut counts = vec![0usize; g * nb];
        self.leader_ingest(buckets, expect_done, &mut |rank, b, cols| {
            let (k, _) = map.locate(rank);
            counts[k * nb + b] += 1;
            let node_complete = counts[k * nb + b] == map.size(k);
            on_bucket(rank, b, cols);
            if node_complete {
                on_node_bucket(k, b);
            }
        })
    }

    /// Broadcast the aggregated result to every rank (sends to departed
    /// ranks are dropped — their death already surfaced, or will, as a
    /// `Down` message).
    pub fn broadcast(&self, result: Arc<Vec<f32>>) {
        for tx in &self.results_out {
            let _ = tx.send(result.clone());
        }
    }

    /// Leader side, whole-step convenience: gather `n * buckets.len()`
    /// bucket messages (any arrival order) into the assembled gradient
    /// matrix, aggregate with `f`, broadcast the result. Errors — with
    /// the failing rank's id — when a rank goes down mid-step.
    pub fn leader_step(
        &self,
        buckets: &Buckets,
        f: impl FnOnce(GradSet) -> Vec<f32>,
    ) -> Result<()> {
        let mut gs = GradSet::zeros(self.n, buckets.total());
        self.leader_ingest(buckets, false, &mut |rank, b, cols| {
            let (lo, hi) = buckets.range(b);
            gs.row_mut(rank)[lo..hi].copy_from_slice(&cols);
        })?;
        self.broadcast(Arc::new(f(gs)));
        Ok(())
    }
}

/// Multi-threaded driver: N rank threads aggregate `rounds` of locally
/// generated gradients through a shared [`StepExchange`] with the given
/// aggregator name, sending per-bucket messages (`bucket_cap` columns per
/// bucket; `None` = one bucket). Returns the final aggregated vector, or
/// an error naming the failing rank if one dies mid-run. Used by tests
/// to prove the bucketed aggregation path is thread-clean end-to-end.
pub fn threaded_allreduce(
    n: usize,
    d: usize,
    rounds: usize,
    aggregator: &str,
    bucket_cap: Option<usize>,
    make_grad: impl Fn(usize, usize) -> Vec<f32> + Send + Sync + 'static,
) -> Result<Vec<f32>> {
    let buckets = match bucket_cap {
        Some(cap) => Buckets::fixed(d, cap),
        None => Buckets::single(d),
    };
    let (exchange, ports) = StepExchange::new(n);
    let make_grad = Arc::new(make_grad);
    let mut handles = Vec::new();
    for (rank, port) in ports.into_iter().enumerate() {
        let mg = make_grad.clone();
        let bk = buckets.clone();
        handles.push(std::thread::spawn(move || {
            for round in 0..rounds {
                port.submit(&bk, &mg(rank, round));
                if port.wait_result().is_err() {
                    // Leader gone (a step failed): exit without arming a
                    // spurious Down.
                    return;
                }
            }
            port.complete();
        }));
    }
    let mut agg = crate::aggregation::by_name(aggregator, n).expect("aggregator");
    let mut last = vec![0.0f32; d];
    let mut step_err = None;
    for _ in 0..rounds {
        let r = exchange.leader_step(&buckets, |gs| {
            let mut out = vec![0.0f32; d];
            agg.aggregate(&gs, &buckets, &mut out);
            last = out.clone();
            out
        });
        if let Err(e) = r {
            step_err = Some(e);
            break;
        }
    }
    // Unblock any rank waiting on a result the failed step never produced.
    drop(exchange);
    let mut panicked = Vec::new();
    for (rank, h) in handles.into_iter().enumerate() {
        if h.join().is_err() {
            panicked.push(rank);
        }
    }
    if let Some(e) = step_err {
        return Err(e);
    }
    ensure!(
        panicked.is_empty(),
        "rank threads {panicked:?} panicked after the final round"
    );
    Ok(last)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mailbox_roundtrip_and_closed_error() {
        let (tx, mb) = Mailbox::channel();
        std::thread::spawn(move || tx.send(42u32).unwrap());
        assert_eq!(mb.recv().unwrap(), 42);
        // All senders gone: recv errors instead of hanging.
        assert!(mb.recv().is_err());
    }

    #[test]
    fn exchange_collects_out_of_order_bucket_messages() {
        let (ex, ports) = StepExchange::new(3);
        let buckets = Buckets::fixed(4, 2); // 2 buckets of 2 columns
        let mut handles = Vec::new();
        for port in ports {
            handles.push(std::thread::spawn(move || {
                let rank = port.rank();
                // Deliberately send bucket 1 before bucket 0.
                port.submit_bucket(1, vec![rank as f32 + 10.0; 2]);
                port.submit_bucket(0, vec![rank as f32; 2]);
                let got = port.wait_result().unwrap();
                port.complete();
                got
            }));
        }
        ex.leader_step(&buckets, |gs| {
            for rank in 0..3 {
                assert_eq!(gs.row(rank)[..2], [rank as f32; 2]);
                assert_eq!(gs.row(rank)[2..], [rank as f32 + 10.0; 2]);
            }
            vec![9.0; 4]
        })
        .unwrap();
        for h in handles {
            assert_eq!(*h.join().unwrap(), vec![9.0; 4]);
        }
    }

    #[test]
    fn leader_ingest_collects_done_reports_by_rank() {
        let (ex, ports) = StepExchange::new(2);
        let buckets = Buckets::single(3);
        let mut handles = Vec::new();
        for port in ports {
            handles.push(std::thread::spawn(move || {
                let rank = port.rank();
                port.submit_bucket(0, vec![rank as f32; 3]);
                port.done(rank as f64 + 0.5, 0.1 * (rank + 1) as f64);
                port.complete();
            }));
        }
        let mut got = Vec::new();
        let reports = ex
            .leader_ingest(&buckets, true, &mut |rank, b, cols| {
                got.push((rank, b, cols));
            })
            .unwrap();
        assert_eq!(reports.len(), 2);
        for (rank, r) in reports.iter().enumerate() {
            assert_eq!(r.loss, rank as f64 + 0.5);
            assert!((r.compute_s - 0.1 * (rank + 1) as f64).abs() < 1e-12);
        }
        assert_eq!(got.len(), 2);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn grouped_exchange_ports_match_the_node_map() {
        // n_ranks consistency between NodeMap and StepExchange port count
        // (uneven groups included), and every port knows its node.
        let map = NodeMap::from_sizes(&[3, 2, 1]);
        let (ex, ports) = StepExchange::new_grouped(&map);
        assert_eq!(ex.n(), map.n_ranks());
        assert_eq!(ports.len(), map.n_ranks());
        assert_eq!(ex.map(), Some(&map));
        for port in &ports {
            assert_eq!(port.node(), map.locate(port.rank()).0);
        }
        // Ungrouped exchanges have no map and node 0 everywhere.
        let (ex, ports) = StepExchange::new(3);
        assert!(ex.map().is_none());
        assert!(ports.iter().all(|p| p.node() == 0));
    }

    #[test]
    fn node_level_ingest_fires_on_group_completion() {
        let map = NodeMap::from_sizes(&[2, 1]);
        let (ex, ports) = StepExchange::new_grouped(&map);
        let buckets = Buckets::fixed(4, 2); // 2 buckets
        let mut handles = Vec::new();
        for port in ports {
            handles.push(std::thread::spawn(move || {
                let rank = port.rank();
                port.submit_bucket(0, vec![rank as f32; 2]);
                port.submit_bucket(1, vec![rank as f32; 2]);
                port.done_timed(0.0, 0.01, vec![0.004, 0.008]);
                port.complete();
            }));
        }
        let mut node_events = Vec::new();
        let mut arrivals = 0usize;
        let reports = ex
            .leader_ingest_nodes(
                &buckets,
                true,
                &mut |_, _, _| arrivals += 1,
                &mut |node, b| node_events.push((node, b)),
            )
            .unwrap();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(arrivals, 6);
        // Every (node, bucket) pair completes exactly once.
        node_events.sort_unstable();
        assert_eq!(node_events, vec![(0, 0), (0, 1), (1, 0), (1, 1)]);
        // Observed per-bucket readiness rides the Done reports.
        assert_eq!(reports.len(), 3);
        for r in &reports {
            assert_eq!(r.bucket_s, vec![0.004, 0.008]);
        }
        // Node-level ingest on an ungrouped exchange is a clean error.
        let (ex, ports) = StepExchange::new(1);
        drop(ports);
        assert!(ex
            .leader_ingest_nodes(&buckets, false, &mut |_, _, _| {}, &mut |_, _| {})
            .is_err());
    }

    #[test]
    fn rank_panic_surfaces_as_step_error_not_hang() {
        // The regression this guards: a rank thread dying mid-step used
        // to leave the leader blocked forever in recv (the exchange held
        // its own sender, so the channel never closed). The port guard
        // now reports the rank down and the step fails with its id.
        let (ex, ports) = StepExchange::new(2);
        let buckets = Buckets::fixed(4, 2);
        let mut ports = ports.into_iter();
        let p0 = ports.next().unwrap();
        let p1 = ports.next().unwrap();
        let h0 = std::thread::spawn(move || {
            p0.submit(&Buckets::fixed(4, 2), &[1.0, 2.0, 3.0, 4.0]);
            let _ = p0.wait_result();
            p0.complete();
        });
        let h1 = std::thread::spawn(move || {
            p1.submit_bucket(0, vec![5.0, 6.0]);
            panic!("injected rank failure");
        });
        let err = ex.leader_step(&buckets, |_| vec![0.0; 4]).unwrap_err();
        assert!(err.to_string().contains("rank 1"), "{err}");
        drop(ex); // unblock the healthy rank
        h0.join().unwrap();
        assert!(h1.join().is_err());
    }

    #[test]
    fn compute_error_report_down_names_the_rank() {
        let (ex, ports) = StepExchange::new(1);
        let buckets = Buckets::single(2);
        let port = ports.into_iter().next().unwrap();
        std::thread::spawn(move || port.report_down("compute failed: injected"));
        let err = ex
            .leader_ingest(&buckets, true, &mut |_, _, _| {})
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("rank 0") && msg.contains("injected"), "{msg}");
    }

    #[test]
    fn guardless_mass_death_errors_instead_of_hanging() {
        let (ex, ports) = StepExchange::new(2);
        let buckets = Buckets::single(2);
        for port in ports {
            port.complete(); // disarm, then drop: no Down, no senders left
        }
        assert!(ex.leader_ingest(&buckets, false, &mut |_, _, _| {}).is_err());
    }

    #[test]
    fn elastic_ingest_survives_a_rank_death() {
        let (ex, ports) = StepExchange::new_elastic(3, None);
        let buckets = Buckets::fixed(4, 2);
        let mut handles = Vec::new();
        for port in ports {
            let bk = buckets.clone();
            handles.push(std::thread::spawn(move || {
                let rank = port.rank();
                if rank == 1 {
                    // Dies after a partial delivery: one bucket, no Done.
                    port.submit_bucket(0, vec![9.0, 9.0]);
                    panic!("injected rank failure");
                }
                port.submit(&bk, &[rank as f32; 4]);
                port.done(rank as f64, 0.1);
                let _ = port.wait_result();
                port.complete();
            }));
        }
        let mut arrivals = Vec::new();
        let rep = ex
            .leader_ingest_elastic(&buckets, 2, &mut |rank, b, _| arrivals.push((rank, b)))
            .unwrap();
        assert_eq!(rep.live(), 2);
        assert!(rep.reports[0].is_some() && rep.reports[2].is_some());
        assert!(rep.reports[1].is_none());
        assert_eq!(rep.dead.len(), 1);
        assert_eq!(rep.dead[0].0, 1);
        // The dead rank's partial bucket was surfaced (caller discards it).
        assert!(arrivals.contains(&(1, 0)));
        ex.broadcast(Arc::new(vec![0.0; 4]));
        for h in handles {
            let _ = h.join();
        }
    }

    #[test]
    fn elastic_ingest_bails_below_quorum() {
        let (ex, ports) = StepExchange::new_elastic(2, None);
        let buckets = Buckets::single(2);
        for port in ports {
            std::thread::spawn(move || port.report_down("injected"));
        }
        let err = ex
            .leader_ingest_elastic(&buckets, 2, &mut |_, _, _| {})
            .unwrap_err();
        assert!(err.to_string().contains("quorum"), "{err}");
    }

    #[test]
    fn respawned_port_rejoins_the_exchange() {
        let (mut ex, ports) = StepExchange::new_elastic(2, None);
        let buckets = Buckets::single(2);
        let mut it = ports.into_iter();
        let p0 = it.next().unwrap();
        let p1 = it.next().unwrap();
        // Step 1: rank 1 dies immediately.
        let h0 = std::thread::spawn(move || {
            p0.submit_bucket(0, vec![1.0, 1.0]);
            p0.done(0.0, 0.1);
            assert_eq!(*p0.wait_result().unwrap(), vec![7.0, 7.0]);
            // Step 2 from the same surviving thread.
            p0.submit_bucket(0, vec![2.0, 2.0]);
            p0.done(0.0, 0.1);
            let _ = p0.wait_result();
            p0.complete();
        });
        p1.report_down("injected");
        let rep = ex
            .leader_ingest_elastic(&buckets, 1, &mut |_, _, _| {})
            .unwrap();
        assert_eq!(rep.live(), 1);
        ex.broadcast(Arc::new(vec![7.0, 7.0]));
        // Respawn rank 1 and run a full-strength step.
        let p1b = ex.respawn_port(1).unwrap();
        assert_eq!(p1b.rank(), 1);
        let h1 = std::thread::spawn(move || {
            p1b.submit_bucket(0, vec![3.0, 3.0]);
            p1b.done(0.0, 0.1);
            let _ = p1b.wait_result();
            p1b.complete();
        });
        let rep = ex
            .leader_ingest_elastic(&buckets, 2, &mut |_, _, _| {})
            .unwrap();
        assert_eq!(rep.live(), 2);
        assert!(rep.dead.is_empty());
        ex.broadcast(Arc::new(vec![0.0, 0.0]));
        h0.join().unwrap();
        h1.join().unwrap();
    }

    #[test]
    fn respawn_needs_an_elastic_exchange() {
        let (mut ex, ports) = StepExchange::new(2);
        drop(ports);
        assert!(ex.respawn_port(1).is_err());
        let (mut ex, ports) = StepExchange::new_elastic(2, None);
        assert!(ex.respawn_port(5).is_err());
        drop(ports);
    }

    #[test]
    fn threaded_mean_matches_expectation() {
        // rank r contributes the constant vector r+1 -> mean = (1+2+3+4)/4.
        let out =
            threaded_allreduce(4, 16, 3, "mean", None, |rank, _| vec![(rank + 1) as f32; 16])
                .unwrap();
        for x in out {
            assert!((x - 2.5).abs() < 1e-6);
        }
    }

    #[test]
    fn threaded_adacons_runs_multiround() {
        let out = threaded_allreduce(4, 32, 5, "adacons", None, |rank, round| {
            let mut rng = crate::util::prng::Rng::new((rank * 1000 + round) as u64);
            (0..32).map(|_| rng.normal_f32(1.0) + 0.5).collect()
        })
        .unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn threaded_allreduce_errors_when_a_rank_dies() {
        let err = threaded_allreduce(3, 8, 2, "mean", Some(4), |rank, round| {
            if rank == 2 && round == 1 {
                panic!("injected failure");
            }
            vec![1.0; 8]
        })
        .unwrap_err();
        assert!(err.to_string().contains("rank 2"), "{err}");
    }

    #[test]
    fn bucketed_sends_reassemble_the_exact_gradient_matrix() {
        // The per-bucket wire format is a pure transport change: whatever
        // the bucketization, the leader must reassemble bit-identical
        // rows in rank order (this checks the assembly directly, so rank
        // or column misplacement cannot hide behind a symmetric
        // aggregator downstream).
        let (n, d) = (3usize, 50usize);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|rank| {
                let mut rng = crate::util::prng::Rng::new(rank as u64 + 7);
                (0..d).map(|_| rng.normal_f32(1.0)).collect()
            })
            .collect();
        let assemble = |cap: Option<usize>| -> Vec<Vec<f32>> {
            let buckets = match cap {
                Some(c) => Buckets::fixed(d, c),
                None => Buckets::single(d),
            };
            let (ex, ports) = StepExchange::new(n);
            let mut handles = Vec::new();
            for port in ports {
                let g = grads[port.rank()].clone();
                let bk = buckets.clone();
                handles.push(std::thread::spawn(move || {
                    port.submit(&bk, &g);
                    let _ = port.wait_result();
                    port.complete();
                }));
            }
            let mut rows = Vec::new();
            ex.leader_step(&buckets, |gs| {
                rows = (0..n).map(|i| gs.row(i).to_vec()).collect();
                vec![0.0; d]
            })
            .unwrap();
            for h in handles {
                h.join().unwrap();
            }
            rows
        };
        let whole = assemble(None);
        assert_eq!(whole, grads);
        for cap in [1usize, 7, 16, 50] {
            assert_eq!(whole, assemble(Some(cap)), "cap={cap}");
        }
    }
}
