//! In-process rank-to-rank transport: typed mailboxes and a reusable step
//! barrier.
//!
//! On the single-accelerator testbed the coordinator drives ranks
//! round-robin (see `worker/`), but the aggregation algebra itself is
//! host-side and thread-safe; this module provides the transport for the
//! threaded deployment shape — N rank threads exchanging gradients with a
//! leader — and is exercised by `threaded_allreduce`, a multi-threaded
//! driver of the simulated collectives used in tests and benches.
//!
//! The wire unit is a **bucket**, not a whole gradient: ranks send
//! `(rank, bucket, columns)` messages as each bucket of their backward
//! completes ([`StepExchange::submit_bucket`]), matching the pipelined
//! executor's arrival surface; the leader assembles buckets in any
//! arrival order and aggregates once the matrix is complete.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};

use crate::tensor::{Buckets, GradSet};

/// A typed point-to-point mailbox (multi-producer, single-consumer).
pub struct Mailbox<T> {
    tx: Sender<T>,
    rx: Mutex<Receiver<T>>,
}

impl<T> Default for Mailbox<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Mailbox<T> {
    pub fn new() -> Self {
        let (tx, rx) = channel();
        Mailbox {
            tx,
            rx: Mutex::new(rx),
        }
    }

    pub fn sender(&self) -> Sender<T> {
        self.tx.clone()
    }

    /// Blocking receive.
    pub fn recv(&self) -> T {
        self.rx.lock().unwrap().recv().expect("mailbox closed")
    }

    /// Receive exactly `n` messages.
    pub fn recv_n(&self, n: usize) -> Vec<T> {
        let rx = self.rx.lock().unwrap();
        (0..n).map(|_| rx.recv().expect("mailbox closed")).collect()
    }
}

/// The leader's view of a step exchange: collect every rank's gradient
/// buckets, return the aggregated direction to all ranks.
pub struct StepExchange {
    pub n: usize,
    /// `(rank, bucket, columns)` — one message per bucket per rank.
    buckets_in: Mailbox<(usize, usize, Vec<f32>)>,
    results_out: Vec<Sender<Arc<Vec<f32>>>>,
    results_in: Vec<Mutex<Receiver<Arc<Vec<f32>>>>>,
    pub barrier: Arc<Barrier>,
}

impl StepExchange {
    pub fn new(n: usize) -> Self {
        let mut results_out = Vec::with_capacity(n);
        let mut results_in = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            results_out.push(tx);
            results_in.push(Mutex::new(rx));
        }
        StepExchange {
            n,
            buckets_in: Mailbox::new(),
            results_out,
            results_in,
            barrier: Arc::new(Barrier::new(n + 1)), // ranks + leader
        }
    }

    /// Rank side: send one bucket's columns as soon as it is ready.
    pub fn submit_bucket(&self, rank: usize, bucket: usize, cols: Vec<f32>) {
        self.buckets_in.sender().send((rank, bucket, cols)).unwrap();
    }

    /// Rank side: send a whole gradient as its bucket sequence (the
    /// degenerate single-bucket path when `buckets` is
    /// [`Buckets::single`]).
    pub fn submit(&self, rank: usize, buckets: &Buckets, grad: &[f32]) {
        assert_eq!(grad.len(), buckets.total());
        for (b, (lo, hi)) in buckets.iter().enumerate() {
            self.submit_bucket(rank, b, grad[lo..hi].to_vec());
        }
    }

    /// Rank side: wait for the aggregated direction.
    pub fn wait_result(&self, rank: usize) -> Arc<Vec<f32>> {
        self.results_in[rank]
            .lock()
            .unwrap()
            .recv()
            .expect("exchange closed")
    }

    /// Leader side: gather `n * buckets.len()` bucket messages (any
    /// arrival order) into the assembled gradient matrix, aggregate with
    /// `f`, broadcast the result.
    pub fn leader_step(&self, buckets: &Buckets, f: impl FnOnce(GradSet) -> Vec<f32>) {
        let nb = buckets.len();
        let mut gs = GradSet::zeros(self.n, buckets.total());
        let mut seen = vec![false; self.n * nb];
        for (rank, b, cols) in self.buckets_in.recv_n(self.n * nb) {
            let (lo, hi) = buckets.range(b);
            assert_eq!(cols.len(), hi - lo, "bucket {b} payload width");
            assert!(
                !std::mem::replace(&mut seen[rank * nb + b], true),
                "duplicate bucket {b} from rank {rank}"
            );
            gs.row_mut(rank)[lo..hi].copy_from_slice(&cols);
        }
        let result = Arc::new(f(gs));
        for tx in &self.results_out {
            tx.send(result.clone()).unwrap();
        }
    }
}

/// Multi-threaded driver: N rank threads aggregate `rounds` of locally
/// generated gradients through a shared [`StepExchange`] with the given
/// aggregator name, sending per-bucket messages (`bucket_cap` columns per
/// bucket; `None` = one bucket). Returns the final aggregated vector.
/// Used by tests to prove the bucketed aggregation path is thread-clean
/// end-to-end.
pub fn threaded_allreduce(
    n: usize,
    d: usize,
    rounds: usize,
    aggregator: &str,
    bucket_cap: Option<usize>,
    make_grad: impl Fn(usize, usize) -> Vec<f32> + Send + Sync + 'static,
) -> Vec<f32> {
    let buckets = Arc::new(match bucket_cap {
        Some(cap) => Buckets::fixed(d, cap),
        None => Buckets::single(d),
    });
    let exchange = Arc::new(StepExchange::new(n));
    let make_grad = Arc::new(make_grad);
    let mut handles = Vec::new();
    for rank in 0..n {
        let ex = exchange.clone();
        let mg = make_grad.clone();
        let bk = buckets.clone();
        handles.push(std::thread::spawn(move || {
            for round in 0..rounds {
                ex.submit(rank, &bk, &mg(rank, round));
                let _ = ex.wait_result(rank);
                ex.barrier.wait();
            }
        }));
    }
    let mut agg = crate::aggregation::by_name(aggregator, n).expect("aggregator");
    let mut last = vec![0.0f32; d];
    for _ in 0..rounds {
        exchange.leader_step(&buckets, |gs| {
            let mut out = vec![0.0f32; d];
            agg.aggregate(&gs, &buckets, &mut out);
            last = out.clone();
            out
        });
        exchange.barrier.wait();
    }
    for h in handles {
        h.join().unwrap();
    }
    last
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mailbox_roundtrip() {
        let mb = Mailbox::new();
        let tx = mb.sender();
        std::thread::spawn(move || tx.send(42u32).unwrap());
        assert_eq!(mb.recv(), 42);
    }

    #[test]
    fn exchange_collects_out_of_order_bucket_messages() {
        let ex = Arc::new(StepExchange::new(3));
        let buckets = Buckets::fixed(4, 2); // 2 buckets of 2 columns
        for rank in [2usize, 0, 1] {
            let ex = ex.clone();
            std::thread::spawn(move || {
                // Deliberately send bucket 1 before bucket 0.
                ex.submit_bucket(rank, 1, vec![rank as f32 + 10.0; 2]);
                ex.submit_bucket(rank, 0, vec![rank as f32; 2]);
            });
        }
        ex.leader_step(&buckets, |gs| {
            for rank in 0..3 {
                assert_eq!(gs.row(rank)[..2], [rank as f32; 2]);
                assert_eq!(gs.row(rank)[2..], [rank as f32 + 10.0; 2]);
            }
            vec![9.0; 4]
        });
        for rank in 0..3 {
            assert_eq!(*ex.wait_result(rank), vec![9.0; 4]);
        }
    }

    #[test]
    fn threaded_mean_matches_expectation() {
        // rank r contributes the constant vector r+1 -> mean = (1+2+3+4)/4.
        let out =
            threaded_allreduce(4, 16, 3, "mean", None, |rank, _| vec![(rank + 1) as f32; 16]);
        for x in out {
            assert!((x - 2.5).abs() < 1e-6);
        }
    }

    #[test]
    fn threaded_adacons_runs_multiround() {
        let out = threaded_allreduce(4, 32, 5, "adacons", None, |rank, round| {
            let mut rng = crate::util::prng::Rng::new((rank * 1000 + round) as u64);
            (0..32).map(|_| rng.normal_f32(1.0) + 0.5).collect()
        });
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn bucketed_sends_reassemble_the_exact_gradient_matrix() {
        // The per-bucket wire format is a pure transport change: whatever
        // the bucketization, the leader must reassemble bit-identical
        // rows in rank order (this checks the assembly directly, so rank
        // or column misplacement cannot hide behind a symmetric
        // aggregator downstream).
        let (n, d) = (3usize, 50usize);
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|rank| {
                let mut rng = crate::util::prng::Rng::new(rank as u64 + 7);
                (0..d).map(|_| rng.normal_f32(1.0)).collect()
            })
            .collect();
        let assemble = |cap: Option<usize>| -> Vec<Vec<f32>> {
            let buckets = match cap {
                Some(c) => Buckets::fixed(d, c),
                None => Buckets::single(d),
            };
            let ex = Arc::new(StepExchange::new(n));
            let mut handles = Vec::new();
            for rank in 0..n {
                let ex = ex.clone();
                let g = grads[rank].clone();
                let bk = buckets.clone();
                handles.push(std::thread::spawn(move || {
                    ex.submit(rank, &bk, &g);
                    let _ = ex.wait_result(rank);
                }));
            }
            let mut rows = Vec::new();
            ex.leader_step(&buckets, |gs| {
                rows = (0..n).map(|i| gs.row(i).to_vec()).collect();
                vec![0.0; d]
            });
            for h in handles {
                h.join().unwrap();
            }
            rows
        };
        let whole = assemble(None);
        assert_eq!(whole, grads);
        for cap in [1usize, 7, 16, 50] {
            assert_eq!(whole, assemble(Some(cap)), "cap={cap}");
        }
    }
}
