//! The process-wide runtime: one PJRT CPU client + a compile cache.
//!
//! The PJRT path needs the `xla` crate, which only exists in toolchain
//! images that vendor its dependency closure; the default build is
//! offline/dependency-free, so everything touching `xla` is gated behind
//! the `pjrt` cargo feature. Without it the manifest still loads (so
//! `inspect` and the shape-level tooling work) and `load()` reports a
//! clear error instead of executing.

use std::path::Path;
use std::sync::Arc;

use super::artifact::Manifest;
use super::executable::Executable;
use crate::util::error::Result;

/// Owns the PJRT client, the artifact manifest, and compiled executables.
/// Executables are compiled lazily on first use and shared via `Arc` (the
/// PJRT CPU client is thread-safe; worker threads share one client, which
/// matches one-accelerator-per-process semantics without N copies of XLA).
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    pub manifest: Manifest,
    #[cfg(feature = "pjrt")]
    cache: std::sync::Mutex<std::collections::BTreeMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Whether this build can actually execute artifacts.
    pub const HAS_PJRT: bool = cfg!(feature = "pjrt");

    pub fn create<P: AsRef<Path>>(artifact_dir: P) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Runtime {
            #[cfg(feature = "pjrt")]
            client: xla::PjRtClient::cpu()?,
            manifest,
            #[cfg(feature = "pjrt")]
            cache: std::sync::Mutex::new(std::collections::BTreeMap::new()),
        })
    }

    /// Open the default artifact directory (`$ADACONS_ARTIFACTS` or
    /// `artifacts/`).
    pub fn open_default() -> Result<Runtime> {
        Self::create(Manifest::default_dir())
    }

    #[cfg(feature = "pjrt")]
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn platform(&self) -> String {
        "none (built without the `pjrt` feature)".to_string()
    }

    /// Get (compiling if needed) the executable for an artifact.
    #[cfg(feature = "pjrt")]
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?;
        let t = crate::util::timer::Timer::start();
        let exe = Arc::new(Executable::compile(&self.client, spec)?);
        crate::log_info!("compiled {} in {:.2}s", name, t.elapsed_s());
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Without PJRT the manifest lookup still validates the name, then we
    /// refuse to execute.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        let _ = self.manifest.get(name)?;
        crate::bail!(
            "artifact {name:?}: this binary was built without the `pjrt` feature, \
             so it cannot execute compiled artifacts. On a toolchain image that \
             vendors the xla crate, add `xla = \"0.1.6\"` to rust/Cargo.toml \
             [dependencies] and rebuild with `--features pjrt`"
        )
    }
}
