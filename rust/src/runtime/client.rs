//! The process-wide runtime: backend selection, the artifact manifest,
//! and a load cache.
//!
//! Two backends live behind one dispatch surface:
//! * `Backend::Interp` — the native interpreter; always available, runs
//!   every artifact that carries a `ProgramSpec` (builtin fallback specs
//!   cover linreg/MLP when no `artifacts/` directory exists).
//! * `Backend::Pjrt` — XLA execution via the `xla` crate; needs the
//!   `pjrt` cargo feature and a toolchain image that vendors the crate's
//!   dependency closure.
//!
//! `Backend::Auto` resolves to PJRT when compiled in, else the
//! interpreter — so the default offline build trains end to end while a
//! toolchain image keeps its old behaviour unchanged.

use std::path::Path;
use std::sync::Arc;

use super::artifact::Manifest;
use super::executable::Executable;
use crate::util::error::Result;

/// Which execution engine runs the artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pick the best available: PJRT if compiled in, else the interpreter.
    Auto,
    /// Native Rust interpreter (std-only, no toolchain image).
    Interp,
    /// XLA via PJRT (`--features pjrt`).
    Pjrt,
}

impl Backend {
    /// Parse a config/CLI value (`auto` | `interp` | `pjrt`).
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "auto" => Some(Backend::Auto),
            "interp" | "interpreter" => Some(Backend::Interp),
            "pjrt" | "xla" => Some(Backend::Pjrt),
            _ => None,
        }
    }

    /// Resolve `Auto` to a concrete backend for this build.
    pub fn effective(self) -> Backend {
        match self {
            Backend::Auto => {
                if cfg!(feature = "pjrt") {
                    Backend::Pjrt
                } else {
                    Backend::Interp
                }
            }
            b => b,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::Interp => "interp",
            Backend::Pjrt => "pjrt",
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Owns the backend state, the artifact manifest, and loaded executables.
/// Executables are built lazily on first use and shared via `Arc` (the
/// PJRT CPU client is thread-safe and the interpreter is stateless;
/// worker threads share one runtime, matching one-accelerator-per-process
/// semantics without N copies of the engine).
pub struct Runtime {
    backend: Backend,
    #[cfg(feature = "pjrt")]
    client: Option<xla::PjRtClient>,
    pub manifest: Manifest,
    cache: std::sync::Mutex<std::collections::BTreeMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Whether this build can actually execute PJRT artifacts.
    pub const HAS_PJRT: bool = cfg!(feature = "pjrt");

    /// Open `artifact_dir` on the build's default backend (`Auto`).
    pub fn create<P: AsRef<Path>>(artifact_dir: P) -> Result<Runtime> {
        Self::create_with(artifact_dir, Backend::Auto)
    }

    /// Open `artifact_dir` on an explicit backend. The manifest falls
    /// back to the builtin interpreter specs when no `manifest.json`
    /// exists on disk.
    pub fn create_with<P: AsRef<Path>>(artifact_dir: P, backend: Backend) -> Result<Runtime> {
        let manifest = Manifest::load_or_builtin(artifact_dir)?;
        let backend = backend.effective();
        #[cfg(not(feature = "pjrt"))]
        if backend == Backend::Pjrt {
            crate::bail!(
                "backend pjrt: this binary was built without the `pjrt` feature. \
                 Use --backend interp, or rebuild with `--features pjrt` on a \
                 toolchain image that vendors the real xla crate"
            );
        }
        if backend == Backend::Pjrt && manifest.builtin {
            // Fail fast with the old guidance: the builtin specs carry no
            // HLO files, so letting PJRT proceed would surface only as a
            // confusing parse error at first load.
            crate::bail!(
                "backend pjrt: no artifacts/manifest.json found (the builtin fallback \
                 specs are interpreter-only). Run `make artifacts` first, or use \
                 --backend interp"
            );
        }
        Ok(Runtime {
            backend,
            #[cfg(feature = "pjrt")]
            client: match backend {
                Backend::Pjrt => Some(xla::PjRtClient::cpu()?),
                _ => None,
            },
            manifest,
            cache: std::sync::Mutex::new(std::collections::BTreeMap::new()),
        })
    }

    /// Open the default artifact directory (`$ADACONS_ARTIFACTS` or
    /// `artifacts/`) on the build's default backend.
    pub fn open_default() -> Result<Runtime> {
        Self::create(Manifest::default_dir())
    }

    /// Open the default artifact directory on an explicit backend.
    pub fn open_default_with(backend: Backend) -> Result<Runtime> {
        Self::create_with(Manifest::default_dir(), backend)
    }

    /// The concrete backend this runtime executes on.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    pub fn platform(&self) -> String {
        match self.backend {
            Backend::Interp => format!(
                "interp (native interpreter{})",
                if self.manifest.builtin {
                    ", builtin fallback specs"
                } else {
                    ""
                }
            ),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt => match &self.client {
                Some(c) => c.platform_name(),
                None => "pjrt (no client)".to_string(),
            },
            #[cfg(not(feature = "pjrt"))]
            Backend::Pjrt => "pjrt (unavailable in this build)".to_string(),
            Backend::Auto => unreachable!("create_with resolves Auto"),
        }
    }

    /// Get (building if needed) the executable for an artifact.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?;
        let exe = match self.backend {
            Backend::Interp => Arc::new(Executable::interpret(spec)?),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt => {
                let client = self
                    .client
                    .as_ref()
                    .expect("pjrt backend always holds a client");
                let t = crate::util::timer::Timer::start();
                let exe = Arc::new(Executable::compile(client, spec)?);
                crate::log_info!("compiled {} in {:.2}s", name, t.elapsed_s());
                exe
            }
            #[cfg(not(feature = "pjrt"))]
            Backend::Pjrt => crate::bail!(
                "artifact {name:?}: this binary was built without the `pjrt` feature, \
                 so it cannot execute compiled artifacts. On a toolchain image that \
                 vendors the xla crate, rebuild with `--features pjrt`"
            ),
            Backend::Auto => unreachable!("create_with resolves Auto"),
        };
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Build a fresh, caller-owned executable for `name` — one per rank
    /// thread (`coordinator::team::RankTeam`). Interpreter executables
    /// are plain-data programs, so per-rank ownership is cheap and the
    /// instance is `Send` with no shared mutable state. PJRT executables
    /// are process-shared device handles; refuse with guidance instead
    /// of pretending per-rank ownership is possible.
    pub fn load_owned(&self, name: &str) -> Result<Executable> {
        let spec = self.manifest.get(name)?;
        match self.backend {
            Backend::Interp => Executable::interpret(spec),
            Backend::Pjrt => crate::bail!(
                "artifact {name:?}: per-rank owned executables need the interp \
                 backend (PJRT executables are process-shared device handles); \
                 run with --backend interp or --rank-threads off"
            ),
            Backend::Auto => unreachable!("create_with resolves Auto"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_and_resolution() {
        assert_eq!(Backend::parse("interp"), Some(Backend::Interp));
        assert_eq!(Backend::parse("pjrt"), Some(Backend::Pjrt));
        assert_eq!(Backend::parse("auto"), Some(Backend::Auto));
        assert_eq!(Backend::parse("tpu"), None);
        let eff = Backend::Auto.effective();
        assert_ne!(eff, Backend::Auto);
        if !Runtime::HAS_PJRT {
            assert_eq!(eff, Backend::Interp);
        }
        assert_eq!(Backend::Interp.to_string(), "interp");
    }

    #[test]
    fn interp_runtime_loads_builtin_artifacts() {
        let dir = std::env::temp_dir().join("adacons_interp_rt_test");
        let rt = Runtime::create_with(&dir, Backend::Interp).unwrap();
        assert_eq!(rt.backend(), Backend::Interp);
        assert!(rt.platform().contains("interp"));
        let exe = rt.load("linreg_b16").unwrap();
        assert!(exe.is_interp());
        // Cache returns the same executable.
        let again = rt.load("linreg_b16").unwrap();
        assert!(Arc::ptr_eq(&exe, &again));
        // Unknown names still error through the manifest.
        assert!(rt.load("nope").is_err());
    }

    #[test]
    fn load_owned_builds_independent_send_executables() {
        fn assert_send<T: Send>(_: &T) {}
        let dir = std::env::temp_dir().join("adacons_interp_rt_test");
        let rt = Runtime::create_with(&dir, Backend::Interp).unwrap();
        // Fresh instance per call — the per-rank-thread ownership shape —
        // and movable into a rank thread.
        let a = rt.load_owned("linreg_b16").unwrap();
        let b = rt.load_owned("linreg_b16").unwrap();
        assert!(a.is_interp() && b.is_interp());
        assert_send(&a);
        std::thread::spawn(move || drop(b)).join().unwrap();
        assert!(rt.load_owned("nope").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_refused_without_feature() {
        let dir = std::env::temp_dir().join("adacons_interp_rt_test");
        let err = Runtime::create_with(&dir, Backend::Pjrt).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
