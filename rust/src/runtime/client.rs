//! The process-wide runtime: one PJRT CPU client + a compile cache.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use super::artifact::Manifest;
use super::executable::Executable;

/// Owns the PJRT client, the artifact manifest, and compiled executables.
/// Executables are compiled lazily on first use and shared via `Arc` (the
/// PJRT CPU client is thread-safe; worker threads share one client, which
/// matches one-accelerator-per-process semantics without N copies of XLA).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: std::sync::Mutex<BTreeMap<String, Arc<Executable>>>,
}

impl Runtime {
    pub fn create<P: AsRef<Path>>(artifact_dir: P) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Runtime {
            client,
            manifest,
            cache: std::sync::Mutex::new(BTreeMap::new()),
        })
    }

    /// Open the default artifact directory (`$ADACONS_ARTIFACTS` or
    /// `artifacts/`).
    pub fn open_default() -> Result<Runtime> {
        Self::create(Manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling if needed) the executable for an artifact.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?;
        let t = crate::util::timer::Timer::start();
        let exe = Arc::new(Executable::compile(&self.client, spec)?);
        log::info!("compiled {} in {:.2}s", name, t.elapsed_s());
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}
