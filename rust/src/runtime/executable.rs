//! A loaded artifact: typed host I/O over one of the two execution
//! backends (native interpreter / PJRT).
//!
//! The PJRT variant needs the `xla` crate and lives behind the `pjrt`
//! feature; the interpreter variant is always available, carries a
//! [`InterpExec`] program, and — being plain data with no shared mutable
//! state — is `Send`: each rank thread of the threaded runtime owns its
//! own instance (`Runtime::load_owned`). Input validation (arity,
//! shapes, dtypes, parameter length) is shared, so both backends reject
//! bad batches with identical errors.
//!
//! [`InterpExec`]: crate::runtime::interp::InterpExec

use super::artifact::ArtifactSpec;
use crate::data::{Array, Batch};
use crate::runtime::interp::InterpExec;
use crate::util::error::{bail, Context, Result};

/// A compiled or interpreted, ready-to-run computation.
pub struct Executable {
    pub spec: ArtifactSpec,
    imp: Imp,
}

enum Imp {
    Interp(InterpExec),
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtLoadedExecutable),
}

/// Stage one host array on the device.
///
/// NOTE: this deliberately uses `buffer_from_host_buffer` + `execute_b`
/// rather than `execute::<Literal>`: the literal path in the bundled
/// xla_extension leaks the converted input buffers (~input-size bytes per
/// call, measured in examples/_leaktest.rs history — see EXPERIMENTS.md
/// §Perf), while the host-buffer path is leak-free and skips one copy.
#[cfg(feature = "pjrt")]
fn buffer_from_array(client: &xla::PjRtClient, a: &Array) -> Result<xla::PjRtBuffer> {
    let b = match a {
        Array::F32(data, shape) => client.buffer_from_host_buffer(data, shape, None)?,
        Array::I32(data, shape) => client.buffer_from_host_buffer(data, shape, None)?,
    };
    Ok(b)
}

#[cfg(feature = "pjrt")]
fn array_from_literal(lit: &xla::Literal, spec: &crate::runtime::IoSpec) -> Result<Array> {
    let shape = spec.shape.clone();
    match spec.dtype.as_str() {
        "f32" => Ok(Array::F32(lit.to_vec::<f32>()?, shape)),
        "i32" => Ok(Array::I32(lit.to_vec::<i32>()?, shape)),
        other => bail!("unsupported output dtype {other}"),
    }
}

impl Executable {
    /// Build the interpreter executable for `spec` (requires a program
    /// record; errors with guidance otherwise).
    pub fn interpret(spec: &ArtifactSpec) -> Result<Executable> {
        Ok(Executable {
            spec: spec.clone(),
            imp: Imp::Interp(InterpExec::new(spec)?),
        })
    }

    /// Compile `spec`'s HLO text on the given PJRT client.
    #[cfg(feature = "pjrt")]
    pub fn compile(client: &xla::PjRtClient, spec: &ArtifactSpec) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(&spec.hlo_path)
            .with_context(|| format!("parsing HLO text {:?}", spec.hlo_path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        Ok(Executable {
            spec: spec.clone(),
            imp: Imp::Pjrt(exe),
        })
    }

    /// Access the underlying PJRT executable (benches / probes).
    #[cfg(feature = "pjrt")]
    pub fn raw(&self) -> Option<&xla::PjRtLoadedExecutable> {
        match &self.imp {
            Imp::Pjrt(e) => Some(e),
            _ => None,
        }
    }

    /// True when this executable runs on the native interpreter.
    pub fn is_interp(&self) -> bool {
        matches!(self.imp, Imp::Interp(_))
    }

    /// Shared host-side validation: parameter length, batch arity, input
    /// shapes/dtypes — identical errors on both backends.
    fn validate_io(&self, params: Option<&[f32]>, batch: &Batch) -> Result<()> {
        if self.spec.param_dim > 0 {
            let p = params.context("artifact expects a parameter vector")?;
            if p.len() != self.spec.param_dim {
                bail!(
                    "{}: params len {} != param_dim {}",
                    self.spec.name,
                    p.len(),
                    self.spec.param_dim
                );
            }
        }
        if batch.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} batch arrays, expected {}",
                self.spec.name,
                batch.len(),
                self.spec.inputs.len()
            );
        }
        for (a, spec) in batch.iter().zip(&self.spec.inputs) {
            if a.numel() != spec.numel() || a.dtype_str() != spec.dtype {
                bail!(
                    "{}: input {} mismatch (got {:?}/{}, want {:?}/{})",
                    self.spec.name,
                    spec.name,
                    a.shape(),
                    a.dtype_str(),
                    spec.shape,
                    spec.dtype
                );
            }
        }
        Ok(())
    }

    /// Execute with an optional leading flat-parameter vector plus the
    /// batch arrays (manifest order). Returns the output arrays.
    pub fn run(&self, params: Option<&[f32]>, batch: &Batch) -> Result<Vec<Array>> {
        self.validate_io(params, batch)?;
        match &self.imp {
            Imp::Interp(exec) => exec.run(&self.spec, params.unwrap_or(&[]), batch),
            #[cfg(feature = "pjrt")]
            Imp::Pjrt(_) => self.run_pjrt(params, batch),
        }
    }

    #[cfg(feature = "pjrt")]
    fn run_pjrt(&self, params: Option<&[f32]>, batch: &Batch) -> Result<Vec<Array>> {
        let Imp::Pjrt(exe) = &self.imp else {
            bail!("{}: not a PJRT executable", self.spec.name)
        };
        let client = exe.client();
        let mut buffers: Vec<xla::PjRtBuffer> = Vec::with_capacity(batch.len() + 1);
        if self.spec.param_dim > 0 {
            let p = params.context("artifact expects a parameter vector")?;
            buffers.push(client.buffer_from_host_buffer(p, &[p.len()], None)?);
        }
        for a in batch.iter() {
            buffers.push(buffer_from_array(client, a)?);
        }
        let result = exe.execute_b(&buffers)?;
        let tuple = result[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True: always a tuple at the root.
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, expected {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| array_from_literal(lit, spec))
            .collect()
    }

    /// Convenience for train artifacts: returns (loss, grads).
    pub fn run_train(&self, params: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
        let outs = self.run(Some(params), batch)?;
        let loss = outs[0]
            .as_f32()
            .and_then(|v| v.first().copied())
            .context("train output 0 must be the f32 loss")?;
        let grads = match outs.into_iter().nth(1) {
            Some(Array::F32(g, _)) => g,
            _ => bail!("train output 1 must be the f32 gradient vector"),
        };
        Ok((loss, grads))
    }

    /// Train step with streaming gradient segments: `on_segment(grads,
    /// offset, len)` fires as each contiguous parameter-gradient block is
    /// finalized (reverse layer order on the interpreter — the real DDP
    /// arrival order — or one whole-vector segment on PJRT, which has no
    /// intra-step hook). The full gradient is assembled into `grad_out`;
    /// returns the loss.
    pub fn run_train_stream(
        &self,
        params: &[f32],
        batch: &Batch,
        grad_out: &mut [f32],
        on_segment: &mut dyn FnMut(&[f32], usize, usize),
    ) -> Result<f32> {
        self.run_train_stream_ctx(
            params,
            batch,
            grad_out,
            &crate::parallel::ParallelCtx::serial(),
            on_segment,
        )
    }

    /// [`run_train_stream`] with an intra-step parallel context: the
    /// interpreter shards its matmul kernels over `ctx`'s worker pool
    /// (bitwise-identical results at every pool width — the kernels never
    /// combine partial sums). PJRT manages its own threading and ignores
    /// `ctx`.
    ///
    /// [`run_train_stream`]: Executable::run_train_stream
    pub fn run_train_stream_ctx(
        &self,
        params: &[f32],
        batch: &Batch,
        grad_out: &mut [f32],
        ctx: &crate::parallel::ParallelCtx,
        on_segment: &mut dyn FnMut(&[f32], usize, usize),
    ) -> Result<f32> {
        self.validate_io(Some(params), batch)?;
        if grad_out.len() != self.spec.param_dim {
            bail!(
                "{}: grad buffer len {} != param_dim {}",
                self.spec.name,
                grad_out.len(),
                self.spec.param_dim
            );
        }
        match &self.imp {
            Imp::Interp(exec) => exec.run_train_stream_ctx(params, batch, grad_out, ctx, on_segment),
            #[cfg(feature = "pjrt")]
            Imp::Pjrt(_) => {
                let (loss, grads) = self.run_train(params, batch)?;
                grad_out.copy_from_slice(&grads);
                on_segment(grad_out, 0, grad_out.len());
                Ok(loss)
            }
        }
    }
}
